"""Process-wide metrics registry: counters, gauges, histograms.

Pure stdlib (importable without JAX, like ``repro.analysis.verify``), so
every layer of the stack can emit structured observations without
dragging in the accelerator runtime:

  * ``repro.dist.recovery`` counts every journal transition
    (``edst_recovery_transitions_total{cause,action}``) at the same
    choke point that appends the journal entry, so the journal and the
    counters reconcile by construction;
  * ``repro.dist.health`` counts failed link probes, checksum
    deviations and straggler flags per detection tick;
  * ``repro.dist.chaos`` counts injected events by kind;
  * ``repro.dist.fault`` counts schedule flips and dynamic rebuilds;
  * the executors (``repro.dist.tree_allreduce`` / ``.striped``) note
    every program *trace* -- waves, static wire bytes, codec selection,
    and repeat traces of an identical program signature (the retrace
    detector) -- at JAX trace time, where the static program facts are
    known and the hook costs nothing per step;
  * ``repro.launch.train`` counts committed train steps.

Export as JSON (:func:`snapshot`) or Prometheus text exposition format
(:func:`prometheus_text`).  The registry is process-global state by
design (one process == one fabric participant); tests isolate through
:func:`reset`.
"""
from __future__ import annotations

import json
import threading

_INF = float("inf")

# default histogram buckets: seconds-scale latencies from 10us to ~2min
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 30.0, 120.0)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Metric:
    """One named metric; values are kept per label set."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: dict = {}

    def labeled(self) -> dict:
        """label-tuple -> value (the raw store; JSON-able for counters
        and gauges, per-bucket dicts for histograms)."""
        return dict(self._values)

    def value(self, **labels):
        """The value for one label set (0/None when never touched)."""
        return self._values.get(_label_key(labels))


class Counter(Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> float:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount
        return self._values[key]

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> float:
        self._values[_label_key(labels)] = float(value)
        return self._values[_label_key(labels)]

    def inc(self, amount: float = 1.0, **labels) -> float:
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount
        return self._values[key]


class Histogram(Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=None):
        super().__init__(name, help)
        bounds = tuple(sorted(buckets or DEFAULT_BUCKETS))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = bounds

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        h = self._values.get(key)
        if h is None:
            h = {"count": 0, "sum": 0.0,
                 "buckets": [0] * (len(self.buckets) + 1)}
            self._values[key] = h
        h["count"] += 1
        h["sum"] += float(value)
        for i, bound in enumerate(self.buckets):
            if value <= bound:
                h["buckets"][i] += 1
                break
        else:
            h["buckets"][-1] += 1


class MetricsRegistry:
    """Name -> metric.  Registration is idempotent per (name, kind);
    re-registering a name as a different kind is a programming error and
    raises."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()
        # program signatures the executors have already traced -- the
        # retrace detector's memory (see :func:`note_program`)
        self._seen_programs: set = set()

    def _get(self, cls, name: str, help: str, **kw) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, not {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Metric | None:
        return self._metrics.get(name)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._seen_programs.clear()

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able dump: name -> {type, help, values: [{labels, value}]}.
        Histogram values carry {count, sum, buckets: {le -> count}}."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            values = []
            for key in sorted(m._values):
                val = m._values[key]
                if isinstance(m, Histogram):
                    les = [*(repr(b) for b in m.buckets), "+Inf"]
                    val = {"count": val["count"], "sum": val["sum"],
                           "buckets": dict(zip(les, val["buckets"]))}
                values.append({"labels": dict(key), "value": val})
            out[name] = {"type": m.kind, "help": m.help, "values": values}
        return out

    def dump_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
            f.write("\n")

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key in sorted(m._values):
                val = m._values[key]
                if isinstance(m, Histogram):
                    cum = 0
                    for bound, cnt in zip([*m.buckets, _INF],
                                          val["buckets"]):
                        cum += cnt
                        le = "+Inf" if bound == _INF else repr(bound)
                        lines.append(f"{name}_bucket"
                                     f"{_fmt_labels(key, le=le)} {cum}")
                    lines.append(f"{name}_sum{_fmt_labels(key)}"
                                 f" {_fmt_value(val['sum'])}")
                    lines.append(f"{name}_count{_fmt_labels(key)}"
                                 f" {val['count']}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)}"
                                 f" {_fmt_value(val)}")
        return "\n".join(lines) + "\n"


def _fmt_value(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def _fmt_labels(key: tuple, **extra) -> str:
    items = [*key, *((k, str(v)) for k, v in extra.items())]
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in items)
    return "{" + body + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


# ---------------------------------------------------------------------------
# the process-wide default registry + module-level conveniences
# ---------------------------------------------------------------------------

REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "", buckets=None) -> Histogram:
    return REGISTRY.histogram(name, help, buckets=buckets)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def prometheus_text() -> str:
    return REGISTRY.prometheus_text()


def reset() -> None:
    REGISTRY.reset()


def counter_values(name: str) -> dict:
    """label-tuple -> value for one counter (empty when never touched).
    The chaos soak diffs this against itself to reconcile the metrics
    stream with the recovery journal."""
    m = REGISTRY.get(name)
    return dict(m._values) if m is not None else {}


def note_program(engine: str, key, waves: int, wire_bytes: int,
                 codec: str | None = None) -> None:
    """Trace-time executor hook: called once per JAX trace of a compiled
    wave program (NOT per step -- inside ``jit`` the Python body runs
    only when tracing).  Counts program traces per engine, sets the
    static program gauges (wave count, total wire bytes on the fabric's
    busiest schedule), notes the codec selection, and flags *retraces*:
    a second trace of an identical (engine, spec key, payload, codec)
    signature means an executable that should have been cached was
    compiled again."""
    sig = (engine, key, int(wire_bytes), codec)
    if sig in REGISTRY._seen_programs:
        counter("edst_retrace_detections_total",
                "repeat JAX traces of an identical compiled program "
                "signature").inc(engine=engine)
    else:
        REGISTRY._seen_programs.add(sig)
    counter("edst_program_traces_total",
            "JAX traces of compiled wave programs").inc(engine=engine)
    gauge("edst_program_waves",
          "waves in the most recently traced program").set(waves,
                                                           engine=engine)
    gauge("edst_wire_bytes",
          "total predicted wire bytes of the most recently traced "
          "program").set(wire_bytes, engine=engine)
    if codec is not None:
        counter("edst_codec_selections_total",
                "wire codec selections at executor trace time"
                ).inc(codec=codec)
