"""Chrome-trace-event export of compiled wave programs, for Perfetto.

Renders ANY compiled spec -- per-tree, fused, pipelined, striped, and
whole fault-runtime entry tables -- as Chrome Trace Event Format JSON
(load in https://ui.perfetto.dev or ``chrome://tracing``):

  * one *lane* per device (``lane="device"``, the default: tid = vertex
    id, spans sit on the sender's lane) or per tree (``lane="tree"``);
  * one *span* (``ph: "X"``) per message, all of a wave's spans sharing
    the wave's start/duration; ``args`` carry the wave index, tree, op
    kind, wire bytes and segment index;
  * *flow events* (``ph: "s"`` / ``"f"``, matched ids) along the
    recovered happens-before DAG: message ``(s -> d, tree j)`` depends
    on the latest earlier wave's arrivals at ``s`` in tree ``j`` --
    exactly the data dependence the static verifier
    (:mod:`repro.analysis.verify`) re-derives from the routing tables
    (children's reduces before the parent's, the root's last reduce
    before its first broadcast, RS before AG on the striped engine).

Timings are *predicted* by default -- each wave lasts ``alpha +
wire_bytes / link_bw`` under the (deterministic) default
:class:`repro.core.collectives.CostModel`, so traces are byte-stable and
golden-diffable -- or *measured* when per-wave durations from
:mod:`repro.telemetry.timing` are passed via ``wave_times``.

Pure NumPy + stdlib (the verifier's scanners do the message recovery):
importable and runnable without JAX, like the verify CLI.

    PYTHONPATH=src python -m repro.telemetry.trace \
        --topology slimfly --engine striped --out trace.json
    PYTHONPATH=src python -m repro.telemetry.trace \
        --topologies paper5 --all-engines --out-dir traces/ --validate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from ..core.collectives import (BCAST, REDUCE, CostModel, chunk_sizes,
                                StripedCollectiveSpec, striped_tables,
                                wave_wire_bytes)

DEFAULT_NBYTES = 4 << 20      # 4 MiB f32 payload: the bench's regime

_KIND_NAMES = {REDUCE: "reduce", BCAST: "bcast"}


# ---------------------------------------------------------------------------
# message recovery (one normalized form for every engine)
# ---------------------------------------------------------------------------

def spec_messages(spec, nbytes: int = DEFAULT_NBYTES, itemsize: int = 4,
                  fractions=None):
    """Normalize a compiled spec to per-wave messages.

    Returns ``(wires, msgs)``: ``wires[w]`` is wave w's wire bytes (what
    every hop of the wave ships), ``msgs`` a list of
    ``(wave, tree, op, src, dst, msg_bytes)`` in wave order, where
    ``op`` is ``reduce``/``bcast`` (chunk engines) or ``rs``/``ag``
    (striped).  Chunk engines reuse the verifier's message scanners; the
    striped engine reads its *bound* waves (empty stripe windows are
    dropped exactly as the executor drops them)."""
    from ..analysis import verify as _v

    wires = wave_wire_bytes(spec, nbytes, itemsize, fractions)
    if isinstance(spec, StripedCollectiveSpec):
        elems = max(1, -(-int(nbytes) // itemsize))
        fr = None if fractions is None else tuple(fractions)
        bound = striped_tables(spec, elems, fr)
        msgs = []
        for w, bw in enumerate(bound.waves):
            op = "rs" if bw.op == REDUCE else "ag"
            for s, d in bw.perm:
                msgs.append((w, int(bw.recv_tree[d]), op, s, d,
                             int(bw.recv_len[d]) * itemsize))
        return wires, msgs

    sink: list = []   # scanner violations; specs were verified at compile
    eng = _v.engine_of(spec)
    if eng == "pipelined":
        raw = _v._scan_pipelined(spec, spec.waves, "waves", sink)
    elif eng == "fused":
        raw = _v._scan_fused(spec, sink)
    else:
        raw = _v._scan_per_tree(spec, sink)
    msgs = [(w, j, _KIND_NAMES[kind], s, d, wires[w])
            for (w, j, kind, s, d) in sorted(raw)]
    return wires, msgs


def happens_before(msgs):
    """The recovered happens-before DAG at message granularity: edges
    ``(producer_index, consumer_index)`` into ``msgs``.  A message
    ``(s -> d, tree j)`` at wave w forwards state ``s`` accumulated on
    tree ``j``, so it depends on the arrivals at ``s`` in tree ``j``
    from the *latest* earlier wave -- the verifier's
    children-before-parent / root-reduce-before-broadcast / RS-before-AG
    rules collapse to exactly this data dependence."""
    arrivals: dict = {}            # (tree, vertex) -> [(wave, msg index)]
    for i, (w, j, _op, _s, d, _b) in enumerate(msgs):
        arrivals.setdefault((j, d), []).append((w, i))
    edges = []
    for i, (w, j, _op, s, _d, _b) in enumerate(msgs):
        earlier = [(w2, i2) for (w2, i2) in arrivals.get((j, s), ())
                   if w2 < w]
        if not earlier:
            continue
        last = max(w2 for w2, _ in earlier)
        edges.extend((i2, i) for (w2, i2) in earlier if w2 == last)
    return edges


# ---------------------------------------------------------------------------
# event building
# ---------------------------------------------------------------------------

def _round(us: float) -> float:
    return round(us, 3)


def trace_events(spec, nbytes: int = DEFAULT_NBYTES, cost_model=None,
                 wave_times=None, fractions=None, lane: str = "device",
                 label: str | None = None, pid: int = 0,
                 flow_base: int = 0, t0_us: float = 0.0,
                 itemsize: int = 4, segment: int = 0):
    """Chrome trace events for one compiled spec (list of dicts).

    ``wave_times`` overrides the predicted per-wave durations with
    measured seconds (same length as the program's wave count);
    ``pid``/``flow_base``/``t0_us`` offset lanes, flow ids and time so
    several specs (a fault runtime's entries) compose into one trace."""
    if lane not in ("device", "tree"):
        raise ValueError(f"lane {lane!r} not in ('device', 'tree')")
    cm = cost_model or CostModel()
    wires, msgs = spec_messages(spec, nbytes, itemsize, fractions)
    times = tuple(wave_times) if wave_times is not None \
        else cm.wave_times(spec, nbytes, itemsize, fractions)
    if len(times) != len(wires):
        raise ValueError(f"{len(times)} wave times for a "
                         f"{len(wires)}-wave program")

    starts, t = [], t0_us
    for sec in times:
        starts.append(t)
        t += sec * 1e6

    label = label or f"edst/{getattr(spec, 'k', 0)}-tree"
    events = [{"name": "process_name", "ph": "M", "ts": 0.0, "pid": pid,
               "tid": 0, "args": {"name": label}}]
    lanes = sorted({(s if lane == "device" else j)
                    for (_w, j, _op, s, _d, _b) in msgs})
    for t_id in lanes:
        events.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                       "pid": pid, "tid": int(t_id),
                       "args": {"name": (f"dev{t_id}" if lane == "device"
                                         else f"tree{t_id}")}})

    spans = []
    for (w, j, op, s, d, mbytes) in msgs:
        tid = s if lane == "device" else j
        spans.append({
            "name": f"t{j}/{op}", "cat": "wave", "ph": "X",
            "ts": _round(starts[w]), "dur": _round(times[w] * 1e6),
            "pid": pid, "tid": int(tid),
            "args": {"wave": w, "tree": j, "kind": op, "src": s, "dst": d,
                     "bytes": mbytes, "wire_bytes": wires[w],
                     "segment": segment},
        })
    events.extend(spans)

    for fid, (i2, i) in enumerate(happens_before(msgs)):
        prod, cons = spans[i2], spans[i]
        fid += flow_base
        events.append({"name": "dep", "cat": "hb", "ph": "s", "id": fid,
                       "ts": _round(prod["ts"] + prod["dur"]),
                       "pid": pid, "tid": prod["tid"]})
        events.append({"name": "dep", "cat": "hb", "ph": "f", "bp": "e",
                       "id": fid, "ts": _round(max(cons["ts"],
                                                   prod["ts"] + prod["dur"])),
                       "pid": pid, "tid": cons["tid"]})
    return events


def chrome_trace(events, **other) -> dict:
    """Wrap events in the Chrome Trace Event Format envelope, metadata
    first, the rest sorted by timestamp (the writer's monotonic-``ts``
    guarantee the validator checks)."""
    meta = [e for e in events if e["ph"] == "M"]
    rest = sorted((e for e in events if e["ph"] != "M"),
                  key=lambda e: (e["ts"], e["pid"], e["tid"]))
    return {"traceEvents": meta + rest, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.telemetry.trace", **other}}


def trace_spec(spec, **kw) -> dict:
    """One compiled spec -> a complete Chrome trace dict."""
    return chrome_trace(trace_events(spec, **kw))


def trace_runtime(runtime, nbytes: int = DEFAULT_NBYTES, cost_model=None,
                  lane: str = "device", itemsize: int = 4) -> dict:
    """A fault runtime's whole entry table in one trace: one process
    lane group per precompiled failure class (``sid0/full``,
    ``sid1/degraded-tree0``, ...), each rendered with its own weighted
    stripe fractions.  k=0 entries (nothing to run) are skipped."""
    events, flow_base = [], 0
    for i, e in enumerate(runtime.entries):
        if e.k == 0:
            continue
        evs = trace_events(e.spec, nbytes=nbytes, cost_model=cost_model,
                           fractions=e.fractions or None, lane=lane,
                           label=f"sid{i}/{e.name}", pid=i,
                           flow_base=flow_base, itemsize=itemsize)
        flow_base += sum(1 for ev in evs if ev["ph"] == "s")
        events.extend(evs)
    return chrome_trace(events, entries=len(runtime.entries))


def write_trace(path, trace: dict) -> None:
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
        f.write("\n")


# ---------------------------------------------------------------------------
# schema validation (the CI gate and the test suite's oracle)
# ---------------------------------------------------------------------------

_REQUIRED = ("name", "ph", "ts", "pid", "tid")
_PHASES = ("X", "s", "f", "M")


def validate_trace(trace) -> list:
    """Chrome-trace schema violations (empty list == valid):

      * envelope: a dict with a non-empty ``traceEvents`` list;
      * every event carries name/ph/ts/pid/tid; ``X`` spans also a
        non-negative ``dur`` and an ``args`` dict; ``ts`` never negative;
      * monotonic ``ts``: non-metadata events sorted by timestamp, and
        per (pid, tid) lane timestamps never decrease;
      * matched flows: every flow id appears exactly once as ``"s"`` and
        once as ``"f"``, with the finish no earlier than the start.
    """
    out = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["envelope: not a dict with a 'traceEvents' key"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["envelope: 'traceEvents' is not a non-empty list"]

    last_ts = None
    lane_ts: dict = {}
    flows: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            out.append(f"event[{i}]: not a dict")
            continue
        missing = [k for k in _REQUIRED if k not in ev]
        if missing:
            out.append(f"event[{i}]: missing keys {missing}")
            continue
        ph = ev["ph"]
        if ph not in _PHASES:
            out.append(f"event[{i}]: unknown phase {ph!r}")
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            out.append(f"event[{i}]: bad ts {ts!r}")
            continue
        if ph == "M":
            continue
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                out.append(f"event[{i}]: X span with bad dur "
                           f"{ev.get('dur')!r}")
            if not isinstance(ev.get("args"), dict):
                out.append(f"event[{i}]: X span without args")
        if last_ts is not None and ts < last_ts:
            out.append(f"event[{i}]: ts {ts} decreases (prev {last_ts})")
        last_ts = ts
        lane = (ev["pid"], ev["tid"])
        if lane in lane_ts and ts < lane_ts[lane]:
            out.append(f"event[{i}]: lane {lane} ts {ts} decreases")
        lane_ts[lane] = ts
        if ph in ("s", "f"):
            if "id" not in ev:
                out.append(f"event[{i}]: flow event without id")
                continue
            flows.setdefault(ev["id"], {}).setdefault(ph, []).append(ts)

    for fid in sorted(flows):
        f = flows[fid]
        if len(f.get("s", ())) != 1 or len(f.get("f", ())) != 1:
            out.append(f"flow {fid}: needs exactly one 's' and one 'f' "
                       f"(got {len(f.get('s', ()))}/{len(f.get('f', ()))})")
        elif f["f"][0] < f["s"][0]:
            out.append(f"flow {fid}: finish ts {f['f'][0]} before start "
                       f"ts {f['s'][0]}")
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _resolve_topologies(args) -> list:
    from ..analysis.verify import PAPER_TOPOLOGIES
    if args.topologies:
        if args.topologies == "paper5":
            return list(PAPER_TOPOLOGIES)
        return args.topologies.split(",")
    if not args.topology:
        return ["torus4x4"]
    hits = [t for t in PAPER_TOPOLOGIES
            if t == args.topology or t.startswith(args.topology)]
    if len(hits) != 1:
        raise SystemExit(f"--topology {args.topology!r} matches {hits} "
                         f"(known: {', '.join(PAPER_TOPOLOGIES)})")
    return hits


def _out_path(args, label: str, engine: str) -> str:
    if args.out_dir:
        os.makedirs(args.out_dir, exist_ok=True)
        return os.path.join(args.out_dir, f"trace_{label}_{engine}.json")
    return args.out or f"trace_{label}_{engine}.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.trace",
        description=__doc__.splitlines()[0])
    ap.add_argument("--topology", default=None,
                    help="paper topology (unambiguous prefixes accepted, "
                         "e.g. 'slimfly'); default torus4x4")
    ap.add_argument("--topologies", default=None,
                    help="'paper5' or a comma list (overrides --topology)")
    ap.add_argument("--engine", default="pipelined",
                    help="per_tree | fused | pipelined | striped")
    ap.add_argument("--all-engines", action="store_true")
    ap.add_argument("--out", default=None,
                    help="output path (single topology x engine)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for trace_<topology>_<engine>.json "
                         "(multi-case runs)")
    ap.add_argument("--nbytes", type=int, default=DEFAULT_NBYTES)
    ap.add_argument("--lane", choices=("device", "tree"), default="device")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate every written trace (exit 1 on "
                         "any violation)")
    ap.add_argument("--measured", action="store_true",
                    help="time each wave on fake host devices (imports "
                         "JAX; pipelined/striped only) instead of using "
                         "CostModel predictions")
    args = ap.parse_args(argv)

    from ..analysis.verify import _compile_specs, _schedule_for
    engines = (("per_tree", "fused", "pipelined", "striped")
               if args.all_engines else (args.engine,))
    topologies = _resolve_topologies(args)

    failed = 0
    for label in topologies:
        sched = _schedule_for(label)
        specs = _compile_specs(sched, engines)
        for engine in engines:
            spec = specs[engine]
            if isinstance(spec, str):
                print(f"[trace] {label}/{engine}: SKIP ({spec})")
                continue
            wave_times = None
            if args.measured:
                if engine not in ("pipelined", "striped"):
                    print(f"[trace] {label}/{engine}: SKIP measured mode "
                          "(pipelined/striped only)")
                    continue
                from .timing import measured_wave_times
                wave_times = measured_wave_times(spec, nbytes=args.nbytes)
            trace = trace_spec(spec, nbytes=args.nbytes, lane=args.lane,
                               label=f"{label}/{engine}",
                               wave_times=wave_times)
            path = _out_path(args, label, engine)
            write_trace(path, trace)
            nspans = sum(1 for e in trace["traceEvents"] if e["ph"] == "X")
            note = ""
            if args.validate:
                violations = validate_trace(trace)
                if violations:
                    failed += 1
                    note = f"  INVALID ({len(violations)} violations)"
                    for v in violations[:5]:
                        print(f"  [trace]   {v}")
                else:
                    note = "  schema OK"
            print(f"[trace] {label}/{engine}: {nspans} spans, "
                  f"{sum(1 for e in trace['traceEvents'] if e['ph'] == 's')}"
                  f" flows -> {path}{note}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
