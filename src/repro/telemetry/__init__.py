"""Wave-level observability for the EDST stack.

Three pillars (see ``src/repro/dist/README.md`` -> "Observability"):

  * :mod:`repro.telemetry.metrics` -- process-wide counters / gauges /
    histograms with JSON and Prometheus-text export, fed by the
    executors, the health monitor, the recovery controller, the chaos
    injector and the train loop;
  * :mod:`repro.telemetry.trace`   -- Chrome-trace-event (Perfetto)
    export of any compiled wave program: spans per message, lanes per
    device or tree, flow events along the verifier's happens-before DAG,
    predicted (CostModel) or measured timings;
  * :mod:`repro.telemetry.timing`  -- the wave-by-wave instrumented
    executor: per-wave measured durations, residuals against the
    CostModel's predictions, and calibration fitting.

``metrics`` is pure stdlib and imported eagerly; ``trace`` needs NumPy
only; ``timing`` imports JAX and is loaded lazily.
"""
from __future__ import annotations

from . import metrics  # noqa: F401  (stdlib-only, always safe)

__all__ = ("metrics", "trace", "timing")


def __getattr__(name):
    if name in ("trace", "timing"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
