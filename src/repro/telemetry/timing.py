"""Measured wave timing: the wave-by-wave instrumented executor.

The production executors run a compiled program's waves inside one
``jit``; XLA is free to fuse and overlap, so end-to-end wall clock says
nothing about *which* waves dominate.  This module re-runs the SAME wave
bodies (the pipelined engine's ``_select_payload``/``_apply_wave`` pair,
the striped engine's ``_run_wave``) one jitted step per wave with
``block_until_ready`` between steps, yielding per-wave durations to set
against the :class:`repro.core.collectives.CostModel`'s per-wave
predictions (``CostModel.wave_times``).  Residuals land in
``BENCH_telemetry.json`` via :mod:`benchmarks.telemetry_bench`, and
:func:`register_measured` feeds the fitted ``alpha``/``link_bw`` back
into the measured-calibration registry
(``CostModel.register_calibration``).

Serializing waves adds dispatch overhead the fused program doesn't pay,
so measured *totals* here upper-bound the production path; the per-wave
*shape* (which waves are wide, where alpha dominates) is the datapoint.
For attribution inside the production path itself, the executors label
every wave with ``jax.named_scope("edst/t{tree}/w{wave}/{op}")`` (see
``tree_allreduce.set_wave_scopes``), so an XLA device profile taken with
``jax.profiler.trace`` groups per-op time by wave with zero runtime
cost.

JAX imports are function-local: importing this module is safe without an
accelerator runtime, and calling :func:`ensure_devices` FIRST (before
anything imports jax) forces enough fake host devices for the spec.
"""
from __future__ import annotations

import os
import sys
import time

from ..core.collectives import (CostModel, PipelinedAllreduceSpec,
                                StripedCollectiveSpec, chunk_sizes,
                                striped_tables, wave_wire_bytes)

DEFAULT_NBYTES = 4 << 20
DEFAULT_ITERS = 5


def ensure_devices(n: int) -> None:
    """Force >= ``n`` fake host devices; must run BEFORE jax initializes
    its backend (no-op once jax is imported -- the later device-count
    check raises with instructions instead)."""
    if "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def _mesh_for(spec):
    import jax
    if jax.device_count() < spec.n:
        raise RuntimeError(
            f"spec needs {spec.n} devices, backend has "
            f"{jax.device_count()}; call telemetry.timing.ensure_devices"
            f"({spec.n}) (or set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={spec.n}) before anything imports jax")
    return jax.make_mesh((spec.n,), (spec.axes[0],))


def _jit_wave(step, mesh, nstate: int):
    """jit(shard_map(...)) around one wave body over ``nstate`` state
    arrays, each carried with a leading sharded device axis."""
    import jax
    from jax.sharding import PartitionSpec as P
    spec_in = (P(mesh.axis_names[0]),) * nstate

    def outer(*arrs):
        out = step(tuple(a.reshape(a.shape[1:]) for a in arrs))
        return tuple(a[None] for a in out)

    sm = jax.shard_map(outer, mesh=mesh, in_specs=spec_in,
                       out_specs=spec_in)
    return jax.jit(lambda state: sm(*state))


def _pipelined_steps(spec, mesh, nbytes: int, fractions):
    """(initial state, per-wave jitted step fns) for the pipelined
    engine's S=1 wave program: state is the tuple of k chunk rows."""
    import jax
    import jax.numpy as jnp
    from ..dist import tree_allreduce as ta
    axis = spec.axes[0]
    elems = max(1, -(-int(nbytes) // 4))
    k = spec.k
    if fractions is None:
        mrow = -(-elems // k)
        sizes = (mrow,) * k
    else:
        sizes = chunk_sizes(elems, tuple(fractions))
        mrow = max(sizes)

    def prep(arrs):
        return tuple(ta._rows_of(arrs[0].reshape(-1), k, sizes, mrow))

    def wave_step(wv):
        def step(rows, wv=wv):
            idx = jax.lax.axis_index(axis)
            recv = jax.lax.ppermute(
                ta._select_payload(list(rows), wv, idx), axis,
                list(wv.perm))
            return tuple(ta._apply_wave(list(rows), wv, recv, idx))
        return step

    x = (jnp.arange(spec.n * elems, dtype=jnp.float32)
         .reshape(spec.n, elems) * 1e-4)
    prep_in = (jax.shard_map(
        lambda a: tuple(r[None] for r in prep((a.reshape(a.shape[1:]),))),
        mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(mesh.axis_names[0]),
        out_specs=(jax.sharding.PartitionSpec(mesh.axis_names[0]),) * k))
    state = jax.jit(prep_in)(x)
    fns = [_jit_wave(wave_step(wv), mesh, k) for wv in spec.waves]
    return state, fns


def _striped_steps(spec, mesh, nbytes: int, fractions):
    """(initial state, per-wave jitted step fns) for the striped
    engine's composed RS/AG program: state is the (k, mrow) row stack."""
    import jax
    import jax.numpy as jnp
    from ..dist import striped as sd
    axis = spec.axes[0]
    elems = max(1, -(-int(nbytes) // 4))
    fr = None if fractions is None else tuple(fractions)
    bound = striped_tables(spec, elems, fr)

    def wave_step(bw):
        def step(arrs, bw=bw):
            idx = jax.lax.axis_index(axis)
            return (sd._run_wave(arrs[0], bw, idx, axis, None, None),)
        return step

    x = (jnp.arange(spec.n * elems, dtype=jnp.float32)
         .reshape(spec.n, elems) * 1e-4)
    P = jax.sharding.PartitionSpec
    prep_in = jax.shard_map(
        lambda a: sd._rows_in(a.reshape(a.shape[1:]).reshape(-1),
                              bound.sizes, bound.mrow)[None],
        mesh=mesh, in_specs=P(mesh.axis_names[0]),
        out_specs=P(mesh.axis_names[0]))
    state = (jax.jit(prep_in)(x),)
    fns = [_jit_wave(wave_step(bw), mesh, 1) for bw in bound.waves]
    return state, fns


def measured_wave_times(spec, nbytes: int = DEFAULT_NBYTES,
                        iters: int = DEFAULT_ITERS, fractions=None,
                        mesh=None) -> tuple:
    """Best-of-``iters`` measured seconds per wave of the compiled
    program, executed wave-by-wave on real (or fake-host) devices with a
    ``block_until_ready`` barrier per wave.  Every wave is timed against
    its true input state (states are propagated through the program
    first, which also compiles every step)."""
    ensure_devices(spec.n)
    import jax
    if isinstance(spec, StripedCollectiveSpec):
        builder = _striped_steps
    elif isinstance(spec, PipelinedAllreduceSpec):
        builder = _pipelined_steps
    else:
        raise NotImplementedError(
            "wave-by-wave timing instruments the production engines "
            "(pipelined, striped); use the named-scope profiler path for "
            "the fused/per-tree baselines")
    mesh = mesh or _mesh_for(spec)
    state, fns = builder(spec, mesh, nbytes, fractions)

    states = [state]
    for fn in fns:                      # compile + propagate true inputs
        state = fn(state)
        states.append(state)
    jax.block_until_ready(states[-1])

    best = [float("inf")] * len(fns)
    for _ in range(max(1, iters)):
        for w, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(states[w]))
            best[w] = min(best[w], time.perf_counter() - t0)
    return tuple(best)


def wave_report(spec, nbytes: int = DEFAULT_NBYTES,
                iters: int = DEFAULT_ITERS, fractions=None,
                cost_model=None, mesh=None) -> dict:
    """Per-wave measured-vs-predicted residuals for one compiled spec:
    the row schema ``BENCH_telemetry.json`` persists."""
    from ..analysis.verify import engine_of
    measured = measured_wave_times(spec, nbytes, iters, fractions, mesh)
    import jax
    cm = cost_model or CostModel.for_backend(jax.default_backend())
    predicted = cm.wave_times(spec, nbytes, 4, fractions)
    wires = wave_wire_bytes(spec, nbytes, 4, fractions)
    meas_us = [t * 1e6 for t in measured]
    pred_us = [t * 1e6 for t in predicted]
    resid_us = [m - p for m, p in zip(meas_us, pred_us)]
    return {
        "engine": engine_of(spec),
        "waves": len(wires),
        "nbytes": int(nbytes),
        "wire_bytes": [int(w) for w in wires],
        "predicted_us": [round(v, 3) for v in pred_us],
        "measured_us": [round(v, 3) for v in meas_us],
        "residual_us": [round(v, 3) for v in resid_us],
        "summary": {
            "predicted_total_us": round(sum(pred_us), 3),
            "measured_total_us": round(sum(meas_us), 3),
            "mean_abs_residual_us": round(
                sum(abs(r) for r in resid_us) / max(1, len(resid_us)), 3),
            "max_abs_residual_us": round(
                max((abs(r) for r in resid_us), default=0.0), 3),
        },
    }


def fit_calibration(wire_bytes, measured_s) -> dict:
    """Least-squares ``t = alpha + bytes / link_bw`` over measured waves
    (the CostModel's two constants).  Degenerate samples (fewer than two
    distinct wire widths, or a non-positive slope on noisy hosts) pin
    ``link_bw`` high so alpha alone carries the fit."""
    import numpy as np
    b = np.asarray(wire_bytes, dtype=float)
    t = np.asarray(measured_s, dtype=float)
    if b.size < 2 or np.ptp(b) == 0.0:
        return {"alpha": float(t.mean()) if t.size else 0.0,
                "link_bw": 1e15}
    slope, intercept = np.polyfit(b, t, 1)
    return {"alpha": max(float(intercept), 0.0),
            "link_bw": float(1.0 / slope) if slope > 0 else 1e15}


def register_measured(wire_bytes, measured_s, backend=None) -> dict:
    """Fit a calibration from measured waves and feed it back into the
    registry ``CostModel.for_backend`` consults.  Returns the registered
    row (``{"backend", "alpha", "link_bw"}``)."""
    cal = fit_calibration(wire_bytes, measured_s)
    if backend is None:
        import jax
        backend = jax.default_backend()
    CostModel.register_calibration(backend, **cal)
    return {"backend": backend, **cal}
