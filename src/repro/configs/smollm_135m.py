"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="lm",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536, vocab=49152,
    # small d_model: 16k-token serve blocks fit VMEM and amortize
    # per-block stream-through (EXPERIMENTS.md, hillclimb 1 iterations 2-4)
    serve_q_block=16_384, serve_kv_block=16_384,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; sub-quadratic required for 500k",
)
