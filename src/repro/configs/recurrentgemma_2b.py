"""recurrentgemma-2b [hybrid]: 26L d_model=2560, RG-LRU + local attention
1:2 (pattern rec,rec,attn), 10H (MQA kv=1, head_dim 256), d_ff=7680 (GeGLU),
vocab=256000, window 2048 [arXiv:2402.19427].  Sub-quadratic: runs long_500k."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="rglru",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, head_dim=256,
    d_ff=7680, vocab=256000, mlp_kind="geglu", window=2048,
    lru_width=2560, pattern=("rec", "rec", "attn"), conv_width=4,
    # sliding-window attention: serve blocks beyond the 2048 window only
    # add masked work (measured -5% on prefill_32k at 4096)
    serve_q_block=2_048, serve_kv_block=2_048,
)
