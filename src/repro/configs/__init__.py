"""Architecture registry: the 10 assigned configs (+ reduced variants)."""
from . import (internvl2_2b, mistral_nemo_12b, olmoe_1b_7b, qwen2_7b,
               qwen2_moe_a2_7b, qwen3_8b, recurrentgemma_2b, rwkv6_7b,
               seamless_m4t_large_v2, smollm_135m)
from .base import LM_SHAPES, ArchConfig, ShapeSpec

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    seamless_m4t_large_v2, mistral_nemo_12b, smollm_135m, qwen2_7b, qwen3_8b,
    olmoe_1b_7b, qwen2_moe_a2_7b, internvl2_2b, recurrentgemma_2b, rwkv6_7b)}


def get(name: str) -> ArchConfig:
    return ARCHS[name]
