"""internvl2-2b [vlm]: InternViT frontend (STUB: patch embeddings) +
InternLM2 backbone 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92553,
    n_img_tokens=1024,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; sub-quadratic required for 500k",
)
