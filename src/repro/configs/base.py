"""Architecture + shape configuration (the assigned 10-arch pool)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# the LM-family shape set (assigned): every arch pairs with these four
LM_SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # lm | moe | encdec | vlm | rglru | rwkv6
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int | None = None        # sliding-window attention
    mlp_kind: str = "swiglu"
    norm_kind: str = "rmsnorm"
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared: int = 0
    moe_renorm: bool = True
    moe_group_size: int = 512
    moe_capacity_factor: float = 1.0
    moe_seq_shard_out: bool = False   # §Perf hillclimb 2 (reduce-scatter EP)
    # encdec
    n_dec_layers: int = 0
    # vlm
    n_img_tokens: int = 1_024
    # rglru (recurrentgemma)
    lru_width: int = 0               # 0 -> d_model
    pattern: tuple = ()              # e.g. ("rec", "rec", "attn")
    conv_width: int = 4
    # rwkv6
    head_size: int = 64
    # runtime
    act_dtype_name: str = "bfloat16"
    remat: bool = True
    q_block: int = 1_024
    kv_block: int = 1_024
    # serve-time (prefill/decode) attention blocks: §Perf hillclimb 1 showed
    # 32k prefill amortizes per-block stream-through only at >=4k blocks
    serve_q_block: int = 4_096
    serve_kv_block: int = 4_096
    aux_loss_weight: float = 0.01
    tp_divisor: int = 16             # model-axis size params get padded for
    skip_shapes: tuple = ()
    skip_reason: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = 16 * self.tp_divisor
        return -(-self.vocab // m) * m

    @property
    def n_experts_padded(self) -> int:
        if not self.n_experts:
            return 0
        return -(-self.n_experts // self.tp_divisor) * self.tp_divisor

    @property
    def act_dtype(self):
        return jnp.dtype(self.act_dtype_name)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def shapes(self) -> tuple:
        return tuple(s for s in LM_SHAPES if s.name not in self.skip_shapes)

    def shape(self, name: str) -> ShapeSpec:
        for s in LM_SHAPES:
            if s.name == name:
                return s
        raise KeyError(name)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6 N D)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * hd * (self.n_heads * 2 + self.n_kv * 2)
        if self.family == "rwkv6":
            attn = 5 * d * d + d * 32 * 6  # r,k,v,g,o + lora decays (approx)
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_expert + \
                self.n_shared * 3 * d * self.d_expert + d * self.n_experts
        else:
            mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            ffn = mult * d * self.d_ff
        layers = self.n_layers + self.n_dec_layers
        emb = self.vocab * d
        return layers * (attn + ffn) + emb

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        attn = d * self.head_dim_ * (self.n_heads * 2 + self.n_kv * 2)
        ffn = (self.top_k + self.n_shared) * 3 * d * self.d_expert \
            + d * self.n_experts
        return self.n_layers * (attn + ffn) + self.vocab * d

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv, heads))
        while heads % kv:
            kv -= 1
        kw = dict(
            n_layers=len(self.pattern) or 2,
            d_model=128, n_heads=heads, n_kv=kv, head_dim=32,
            d_ff=192, vocab=256, tp_divisor=1,
            q_block=64, kv_block=64, remat=False,
            act_dtype_name="float32",
        )
        if self.is_moe:
            kw.update(n_experts=8, top_k=min(self.top_k, 2),
                      d_expert=64, n_shared=min(self.n_shared, 1),
                      moe_group_size=32)
        if self.family == "encdec":
            kw.update(n_layers=2, n_dec_layers=2)
        if self.family == "vlm":
            kw.update(n_img_tokens=8)
        if self.family == "rglru":
            kw.update(lru_width=128, window=32, head_dim=32)
        if self.family == "rwkv6":
            kw.update(head_size=32)
        if self.window is not None and self.family != "rglru":
            kw.update(window=32)
        return dataclasses.replace(self, **kw)
