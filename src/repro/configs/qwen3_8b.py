"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk_norm [hf:Qwen/Qwen3-8B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="lm",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=12288, vocab=151936, qk_norm=True, rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; sub-quadratic required for 500k",
)
