"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone.

24L encoder + 24L decoder, d_model=1024, 16H (GQA kv=16), d_ff=8192,
vocab=256206 [arXiv:2308.11596; hf].  The speech/text frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S, d).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_dec_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=8192, vocab=256206, mlp_kind="gelu", norm_kind="layernorm",
    skip_shapes=("long_500k",),
    skip_reason="full-attention enc-dec: 500k dense decode cache is architecturally meaningless",
)
