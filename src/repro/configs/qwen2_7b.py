"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
QKV bias [arXiv:2407.10671]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="lm",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, head_dim=128,
    d_ff=18944, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; sub-quadratic required for 500k",
)
