"""rwkv6-7b [ssm]: Finch, attention-free, 32L d_model=4096 d_ff=14336
vocab=65536, head_size 64 (data-dependent decay) [arXiv:2404.05892].
State recurrence: runs long_500k."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="rwkv6",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336, vocab=65536,
    head_size=64,
)
