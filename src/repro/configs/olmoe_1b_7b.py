"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, d_expert=1024, moe_renorm=False, qk_norm=True,
    # GShard dispatch cost ~ G*E*C*d with C ~ G*k/E: smaller groups cut the
    # dispatch einsums 2x (frac +7%, compute term -35%; EXPERIMENTS follow-ups)
    moe_group_size=256,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; sub-quadratic required for 500k",
)
