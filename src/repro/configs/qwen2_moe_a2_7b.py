"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff(expert)=1408
vocab=151936, 60 routed top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B].
Experts are padded 60 -> 64 for 16-way EP; padded experts router-masked."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=151936,
    n_experts=60, top_k=4, d_expert=1408, n_shared=4, qkv_bias=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; sub-quadratic required for 500k",
)
