"""Three-term roofline from the dry-run's compiled artifact (§Roofline).

    compute term    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory term     = HLO_bytes      / (chips * HBM_bw)
    collective term = collective_B   / (chips * link_bw)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  All HLO quantities here are PER DEVICE (the SPMD module
is one device's program; our loop-aware analyzer multiplies scan bodies by
trip count), so chips=1 in the denominators and the terms are per-device
step times; MODEL_FLOPS is divided by the device count for the utilization
ratio.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float           # 6*N*D (train) or 2*N*D (serve), per device
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs -- catches remat/redundancy waste."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """(model_flops / peak) / bound -- fraction of the chip's peak the
        step achieves if it runs exactly at the roofline bound."""
        ideal = self.model_flops / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def _attention_flops_per_token(cfg, seq_len: int) -> float:
    """Useful attention matmul FLOPs per token: 4 * L_attn * ctx * H * hd
    (qk^T + pv), with causal avg ctx = S/2, clipped by sliding window.
    Attention-free (rwkv6) and recurrent layers contribute ~0 here (their
    state math is counted in active params)."""
    if cfg.family == "rwkv6":
        return 0.0
    n_attn_layers = cfg.n_layers + cfg.n_dec_layers
    if cfg.family == "rglru":
        pat = cfg.pattern or ("rec", "rec", "attn")
        n_attn_layers = sum(
            1 for i in range(cfg.n_layers) if pat[i % len(pat)] == "attn")
    ctx = seq_len / 2.0
    if cfg.window:
        ctx = min(ctx, float(cfg.window))
    return 4.0 * n_attn_layers * ctx * cfg.n_heads * cfg.head_dim_


def model_flops_for(cfg, shape, n_devices: int) -> float:
    """Per-device MODEL_FLOPS: (6 |train, 2 |serve) * N_active * D plus the
    attention-matmul term (3x for train fwd+bwd), which dominates small
    models at 32k context."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        attn = 3.0 * _attention_flops_per_token(cfg, shape.seq_len)
        return (6.0 * n_active + attn) * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        attn = _attention_flops_per_token(cfg, shape.seq_len)
        return (2.0 * n_active + attn) * tokens / n_devices
    tokens = shape.global_batch  # one token per sequence
    attn = 2.0 * _attention_flops_per_token(cfg, shape.seq_len)  # full ctx
    return (2.0 * n_active + attn) * tokens / n_devices


def roofline(cfg, shape, mesh_name: str, n_devices: int,
             hlo_flops: float, hlo_bytes: float,
             collective_bytes: float, links_per_chip: float = 4.0) -> RooflineTerms:
    """All HLO inputs are per-device.  A v5e chip has 4 ICI links; the
    collective term divides the per-device collective bytes over them."""
    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_name,
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=hlo_bytes / HBM_BW,
        collective_s=collective_bytes / (links_per_chip * LINK_BW),
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops_for(cfg, shape, n_devices),
        n_devices=n_devices,
    )
