"""Loop-aware static analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE -- with
scan-over-layers models that under-reports FLOPs/bytes/collectives by the
layer count.  This module parses the HLO text into computations, extracts
while-loop trip counts from their condition computations, and aggregates

  * dot FLOPs (2 * prod(output dims) * prod(contraction dims)),
  * per-op bytes touched (operand + output shape bytes),
  * collective bytes by op kind,

from the entry computation downward, multiplying by trip counts.  This is
the "profile" the §Perf loop iterates on (no real-TPU timings exist here).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_CALL_RE = re.compile(
    r"(?:to_apply|calls|body|condition|branch_computations)=\{?%?([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _out_shape_bytes(line: str) -> int:
    """Bytes of the op's output (the shape(s) before the op name)."""
    m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", line)
    if not m:
        return 0
    rhs = m.group(1)
    opm = re.search(r"\b([\w\-]+)\(", rhs)
    head = rhs[: opm.start()] if opm else rhs
    return _shape_bytes(head)


@dataclass
class CompStats:
    dot_flops: float = 0.0
    bytes_touched: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)         # (name, is_fusion)
    fusion_sites: list = field(default_factory=list)  # (name, out_bytes)
    whiles: list = field(default_factory=list)        # (body, cond)
    root_is_dus: bool = False   # root (or tuple root) is an in-place update


def _parse_computations(text: str) -> dict:
    comps: dict[str, list] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.rstrip()
        # computation headers start at column 0 and end with '{'
        if stripped and not line.startswith((" ", "\t")) and \
                stripped.endswith("{"):
            hdr = _COMP_HDR.match(stripped)
            if hdr:
                cur = hdr.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if stripped.strip() == "}":
                cur = None
                continue
            comps[cur].append(stripped)
    return comps


def _dot_flops(line: str, symbols: dict) -> float:
    """2 * prod(out dims) * prod(contracting dims) for dot ops.

    Optimized HLO may reference operands either by bare name
    (``dot(%p.1, %p.2)``) or with an inline shape
    (``dot(f32[128,128]{1,0} %p.1, ...)``).  The lhs dims come from the
    inline shape when present (naive comma-splitting would cut the shape's
    own commas), falling back to the ``symbols`` table (name -> dims)."""
    m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", line)
    if not m:
        return 0.0
    rhs = m.group(1)
    if not re.search(r"\bdot\(", rhs):
        return 0.0
    head = rhs.split("dot(", 1)[0]
    out_dims = 1
    sm = _SHAPE_RE.search(head)
    if sm:
        for d in sm.group(2).split(","):
            if d:
                out_dims *= int(d)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    contract = 1
    opm = re.search(r"dot\(([^)]*)\)", rhs)
    if cm and opm:
        inner = opm.group(1)
        lhs_dims = None
        shapes = _SHAPE_RE.findall(inner)
        if shapes:   # inline operand shapes: the first is the lhs
            lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
        else:        # bare names: resolve the first operand via symbols
            first = inner.split(",")[0].strip().lstrip("%")
            lhs_dims = symbols.get(first)
        if lhs_dims is not None:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
    return 2.0 * out_dims * contract


def _analyze_comp(lines: list) -> CompStats:
    st = CompStats(collective_bytes={c: 0 for c in COLLECTIVES},
                   collective_counts={c: 0 for c in COLLECTIVES})
    dus_names = set()
    for line in lines:
        dm = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=.*"
                      r"dynamic-update-slice\(", line)
        if dm:
            dus_names.add(dm.group(1))
        if "ROOT" in line:
            if "dynamic-update-slice(" in line:
                st.root_is_dus = True
            tm = re.search(r"ROOT\s+%?[\w.\-]+\s*=\s*\([^=]*\)?\s*tuple\(([^)]*)\)",
                           line)
            if tm:
                ops = [o.strip().lstrip("%") for o in tm.group(1).split(",")]
                if ops and all(o in dus_names for o in ops if o):
                    st.root_is_dus = True
    # symbol table: op name -> output dims (for dot contraction lookup)
    symbols: dict = {}
    for line in lines:
        dm = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]",
                      line)
        if dm:
            symbols[dm.group(1)] = [int(d) for d in dm.group(3).split(",") if d]
    FREE_OPS = ("get-tuple-element(", "tuple(", "parameter(", "bitcast(",
                "constant(", "iota(", "after-all(", "reshape(",
                "partition-id(", "replica-id(")
    for line in lines:
        rhs_m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", line)
        rhs = rhs_m.group(1) if rhs_m else ""
        clean = line.split("metadata=")[0].split("backend_config=")[0]
        if any(f" {op}" in rhs or rhs.split("{", 1)[-1].startswith(op) or
               re.search(rf"\b{re.escape(op[:-1])}\(", rhs)
               for op in FREE_OPS):
            pass  # layout/tuple plumbing: no HBM traffic
        elif "dynamic-update-slice(" in rhs:
            # in-place update: traffic = the update operand, not the buffer
            dm = re.search(r"dynamic-update-slice\(([^)]*)\)", rhs)
            upd = dm.group(1).split(",")[1].strip().lstrip("%") if dm else ""
            dims = symbols.get(upd)
            if dims is not None:
                n = 1
                for d in dims:
                    n *= d
                st.bytes_touched += 2 * n * 4  # read+write, assume f32 worst
        else:
            st.bytes_touched += _shape_bytes(clean)
        st.dot_flops += _dot_flops(line, symbols)
        if " while(" in rhs or rhs.startswith("while("):
            body = re.search(r"body=\{?%?([\w.\-]+)", rhs)
            cond = re.search(r"condition=\{?%?([\w.\-]+)", rhs)
            if body and cond:
                st.whiles.append((body.group(1), cond.group(1)))
            continue
        called = False
        for kind in ("fusion", "call", "custom-call", "conditional",
                     "reduce", "sort", "scatter", "map", "reduce-window"):
            if f" {kind}(" in rhs or rhs.startswith(f"{kind}("):
                for cm in _CALL_RE.finditer(rhs):
                    if kind == "fusion":
                        # bytes decided at aggregation: in-place (DUS-root)
                        # fusions count the update, others their output
                        st.fusion_sites.append(
                            (cm.group(1), _out_shape_bytes(line)))
                        st.bytes_touched -= _shape_bytes(
                            line.split("metadata=")[0]
                            .split("backend_config=")[0])
                    st.calls.append((cm.group(1), kind == "fusion"))
                called = True
                break
        if called:
            continue
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(?:-start)?\(", rhs):
                nb = _out_shape_bytes(line)
                st.collective_bytes[c] += nb
                st.collective_counts[c] += 1
                break
    return st


def _trip_count(cond_lines: list) -> int:
    """Trip count from the condition's ROOT compare: resolve its constant
    operand (falling back to the largest constant if the compare is wrapped
    in a fusion whose operands we cannot see)."""
    consts: dict[str, int] = {}
    for line in cond_lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*s\d+\[\]\s*"
                     r"constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        cm = re.search(r"(?:compare|fusion)\(([^)]*)\)", line)
        if cm and ("ROOT" in line or "compare" in line):
            # operands may carry inline shapes ("s32[] %constant.23"): take
            # the trailing name token of each operand
            for name in re.findall(r"%?([\w.\-]+)(?:\s*[,)]|$)", cm.group(1)):
                if name in consts:
                    return consts[name]
    return max(consts.values(), default=1)


# ---------------------------------------------------------------------------
# HLO contract linter (flat site counting)
# ---------------------------------------------------------------------------
#
# The loop-aware analyzer above multiplies collective counts by while-loop
# trip counts -- the right thing for cost accounting.  The contract linter
# deliberately counts FLAT sites instead: the executors' scan compile
# promises the HLO holds each wave's collective exactly ONCE regardless of
# the segment count, so a flat site count equal to the wave count IS the
# "program size flat in S" contract the JAX tests used to hand-roll.

_SITE_RE = re.compile(
    r"=\s+(\S+)\s+(" + "|".join(re.escape(c) for c in COLLECTIVES)
    + r")(?:-start)?\(")


@dataclass(frozen=True)
class CollectiveSite:
    """One collective op site in the HLO text (counted flat, not
    trip-count-multiplied).  ``dtype``/``elems`` come from the site's
    first output shape (the wire payload; ``-start`` tuple outputs report
    their first element)."""
    kind: str
    dtype: str
    elems: int


@dataclass(frozen=True)
class HloContract:
    """What a correct executor compile must look like, enforced by
    :func:`lint_hlo`.  ``None`` fields are unconstrained.

    ``ppermutes``           exact flat ``collective-permute`` site count
                            (== the spec's wave count: one collective per
                            wave, flat in the segment count);
    ``max_f32_sites``       most f32-wire ppermute sites allowed (the
                            quantized broadcast waves: reduce wires must
                            be int8);
    ``max_f32_wire_elems``  largest f32 wire element count allowed (the
                            bit-packed lane width: a full f32 row means
                            the codec was silently dropped).
    """
    ppermutes: int | None = None
    max_f32_sites: int | None = None
    max_f32_wire_elems: int | None = None


def collective_sites(text: str) -> list:
    """Every collective op site in the HLO text, flat (each site once,
    independent of any enclosing while-loop's trip count)."""
    sites = []
    for line in text.splitlines():
        m = _SITE_RE.search(line)
        if not m:
            continue
        # the output shape token sits between '=' and the op name; -start
        # sites wrap it in a tuple "(s8[18]{0}, ...)" -- the first shape
        # is the wire payload either way
        sm = _SHAPE_RE.search(m.group(1))
        dtype, elems = "", 0
        if sm:
            dtype = sm.group(1)
            elems = 1
            for d in sm.group(2).split(","):
                if d:
                    elems *= int(d)
        sites.append(CollectiveSite(m.group(2), dtype, elems))
    return sites


def lint_hlo(text: str, contract: HloContract) -> list:
    """Check compiled HLO text against an :class:`HloContract`; returns a
    list of human-readable violation strings (empty = clean).  Use
    :func:`repro.analysis.verify.hlo_contract_for` to derive the contract
    from a compiled spec."""
    sites = collective_sites(text)
    perms = [s for s in sites if s.kind == "collective-permute"]
    out = []
    if contract.ppermutes is not None and len(perms) != contract.ppermutes:
        out.append(
            f"collective-permute site count {len(perms)} != contracted "
            f"{contract.ppermutes} (one collective per wave, flat in the "
            "segment count)")
    f32 = [s for s in perms if s.dtype == "f32"]
    if contract.max_f32_sites is not None \
            and len(f32) > contract.max_f32_sites:
        out.append(
            f"{len(f32)} f32-wire collective-permute sites, contract "
            f"allows {contract.max_f32_sites} (reduce wires must be "
            "quantized)")
    if contract.max_f32_wire_elems is not None:
        for s in f32:
            if s.elems > contract.max_f32_wire_elems:
                out.append(
                    f"f32 wire of {s.elems} elements exceeds the packed-"
                    f"lane cap {contract.max_f32_wire_elems} (an "
                    "unquantized full row leaked onto the wire)")
    return out


@dataclass
class HloStats:
    dot_flops: float
    bytes_touched: float
    collective_bytes: dict
    collective_counts: dict

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(text: str, entry: str | None = None) -> HloStats:
    comps = _parse_computations(text)
    stats = {name: _analyze_comp(lines) for name, lines in comps.items()}

    # entry = computation not called by any other (fallback: 'main')
    called = set()
    for st in stats.values():
        called.update(n for n, _ in st.calls)
        for b, c in st.whiles:
            called.add(b)
            called.add(c)
    if entry is None:
        entries = [n for n in comps if n not in called and "main" in n]
        entry = entries[0] if entries else next(
            (n for n in comps if n not in called), "main")

    memo: dict[str, HloStats] = {}

    def agg(name: str, depth=0) -> HloStats:
        if name in memo:
            return memo[name]
        if name not in stats or depth > 50:
            return HloStats(0, 0, {c: 0 for c in COLLECTIVES},
                            {c: 0 for c in COLLECTIVES})
        st = stats[name]
        flops = st.dot_flops
        byts = st.bytes_touched
        cb = dict(st.collective_bytes)
        cc = dict(st.collective_counts)

        def add(sub: HloStats, mult: float):
            nonlocal flops, byts
            flops += sub.dot_flops * mult
            byts += sub.bytes_touched * mult
            for c in COLLECTIVES:
                cb[c] += sub.collective_bytes[c] * mult
                cc[c] += sub.collective_counts[c] * mult

        fusion_out = dict(st.fusion_sites)
        for callee, is_fusion in st.calls:
            sub = agg(callee, depth + 1)
            if is_fusion:
                # in-place (DUS-rooted) fusions: traffic = the update ops
                # inside the body; other fusions: their output write
                site_bytes = stats[callee].bytes_touched \
                    if callee in stats and stats[callee].root_is_dus \
                    else fusion_out.get(callee, 0.0)
                sub = HloStats(sub.dot_flops, site_bytes,
                               sub.collective_bytes, sub.collective_counts)
            add(sub, 1.0)
        for body, cond in st.whiles:
            trip = _trip_count(comps.get(cond, []))
            add(agg(body, depth + 1), trip)
            add(agg(cond, depth + 1), trip)
        out = HloStats(flops, byts, cb, cc)
        memo[name] = out
        return out

    return agg(entry)
