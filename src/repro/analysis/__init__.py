from .hlo import HloStats, analyze_hlo
from .roofline import RooflineTerms, roofline
