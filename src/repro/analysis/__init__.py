"""Static analysis: loop-aware HLO accounting (:mod:`.hlo`), roofline
terms (:mod:`.roofline`), the static wave-program verifier
(:mod:`.verify`) and the AST repo lint (:mod:`.lint`).

Submodule attributes resolve lazily (PEP 562): ``python -m
repro.analysis.verify`` then runs the CLI without the package import
having pre-loaded the module, and importing :mod:`repro.analysis` stays
cheap for consumers that only need one analyzer.
"""
_EXPORTS = {
    "HloStats": "hlo", "analyze_hlo": "hlo", "lint_hlo": "hlo",
    "HloContract": "hlo", "CollectiveSite": "hlo",
    "collective_sites": "hlo",
    "RooflineTerms": "roofline", "roofline": "roofline",
    "SpecVerificationError": "verify", "VerifyReport": "verify",
    "Violation": "verify", "assert_valid": "verify",
    "engine_of": "verify", "hlo_contract_for": "verify",
    "verify_spec": "verify",
    "lint_paths": "lint", "lint_source": "lint",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
