"""Static wave-program verifier for every compiled EDST allreduce engine.

The paper's guarantees (edge-disjointness, full-cardinality spanning,
bounded depth) make k-tree collectives safe to overlap -- but until now
the repo only checked them *dynamically*, by packet-simulating each
compiled spec.  This module proves a compiled wave program legal in
O(messages), without executing JAX or the simulator:

  * **ppermute legality** -- every wave's (src, dst) pairs form a partial
    bijection (unique sources AND unique destinations);
  * **routing-table agreement** -- the send tables and the receive
    flags/rows describe the same messages (no dropped or stray receives,
    no arrival landing in a different chunk row than was shipped);
  * **link-race freedom** -- in a segment-streamed program (per-tree,
    fused, pipelined) each *directed* link is claimed by at most one
    wave across the whole program, so at pipeline step t wave w (moving
    segment t-w) can never collide with wave w' (moving segment t-w'):
    overlap is safe for every segment count S.  This is the static
    equivalent of the simulator's max_link_load == 1 check;
  * **happens-before closure** -- every message's wave is strictly later
    than all of its reduce/gather predecessors' waves (the list
    scheduler's delivery contract, re-derived from the tables);
  * **tree recovery** -- the k trees are rebuilt from the routing tables
    themselves (NOT trusted from the schedule) and checked: one parent
    per non-root vertex, a single root, no cycles, n-1 edges
    (spanning), broadcast edges exactly the reversed reduce edges, and
    pairwise edge-disjointness across trees (the EDST property);
  * **stripe-window conservation** (striped engine) -- per tree edge the
    four message kinds appear exactly once each, the up/down slot
    windows are exact circular complements (so every owner slot crosses
    every tree edge exactly once per phase), the below-window length
    equals the recovered subtree size, and child windows nest inside
    their parent's;
  * **phase/op homogeneity** -- striped waves are op-homogeneous
    (accumulate vs overwrite), the quantized pipelined program is
    phase-separated at ``q8_boundary``, and per-wave ``rows`` /
    ``sole_add`` metadata matches the tables executors specialize on.

Violation codes (each maps to one invariant; mutation tests in
``tests/test_verify.py`` assert the mapping):

  ==================== ====================================================
  code                 invariant
  ==================== ====================================================
  ``spec-meta``        spec-level metadata broken (axes, row range)
  ``wave-illegal``     a wave reuses a source or destination
  ``link-race``        a directed link claimed by two waves (segment race)
  ``recv-dropped``     an arrival has no landing flag at its destination
  ``row-misroute``     arrival lands in a different row/window than shipped
  ``table-stray``      receive flag / metadata without a matching arrival
  ``op-mixed``         wave or phase mixes accumulate/overwrite semantics
  ``tree-malformed``   recovered routing is not a spanning tree
  ``phase-mismatch``   broadcast edges are not the reversed reduce edges
  ``edge-disjointness``two trees route over the same physical link
  ``message-conservation`` wrong per-edge or per-program message multiset
  ``happens-before``   a message scheduled no later than a predecessor
  ``stripe-conservation`` slot windows do not partition the owner circle
  ``stale-ownership``  spec.trees ownership slots disagree with the routed
                       windows (stripe table not re-striped after failover)
  ``depth-mismatch``   spec.depth disagrees with the recovered trees
  ``sid-out-of-range`` a schedule id outside a runtime's precompiled entry
                       table (``lax.switch`` would silently clamp it to a
                       wrong failure-class branch)
  ==================== ====================================================

Levels: ``"cheap"`` runs the single-pass wave scans plus the link-race
check (the production assert mode); ``"full"`` adds tree recovery,
happens-before, edge-disjointness, stripe conservation and depth (the
test / CI mode).  The spec compilers in ``repro.core.collectives`` call
:func:`assert_valid` under their ``verify=`` flag, resolved from the
``REPRO_VERIFY_SPECS`` environment variable (tests set ``full``).

CLI (the CI gate; ``benchmarks/wave_check.py`` is a deprecation shim)::

    python -m repro.analysis.verify --all-engines --topologies paper5

verifies every engine's compiled spec on the five paper topology
families statically; ``--simulate`` additionally replays the NumPy
packet simulators (the historical dynamic gate).
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass, field

import numpy as np

from ..core.collectives import (AG_DOWN, AG_UP, BCAST, REDUCE, RS_DOWN,
                                RS_UP, FusedAllreduceSpec,
                                PipelinedAllreduceSpec,
                                StripedCollectiveSpec, _RS_KINDS,
                                _striped_op, striped_tables)
from ..core.graph import canon
from .hlo import HloContract

ENGINES = ("per_tree", "fused", "pipelined", "striped")
LEVELS = ("cheap", "full")

_AG_KINDS = frozenset({AG_UP, AG_DOWN})
_ALL_STRIPED_KINDS = frozenset({RS_UP, RS_DOWN, AG_UP, AG_DOWN})
_UP_OF = {_RS_KINDS: RS_UP, _AG_KINDS: AG_UP, _ALL_STRIPED_KINDS: RS_UP}
# which kinds carry the child's *below* window (subtree slots); the other
# two carry the complementary *above* window
_BELOW_KINDS = frozenset({RS_DOWN, AG_UP})
_KIND_NAME = {REDUCE: "reduce", BCAST: "bcast", RS_UP: "RS_UP",
              RS_DOWN: "RS_DOWN", AG_UP: "AG_UP", AG_DOWN: "AG_DOWN"}


@dataclass(frozen=True)
class Violation:
    code: str
    detail: str

    def __str__(self):
        return f"[{self.code}] {self.detail}"


@dataclass
class VerifyReport:
    """Outcome of one static verification pass."""
    engine: str
    n: int
    k: int
    level: str
    messages: int
    waves: int
    violations: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self, limit: int = 8) -> str:
        head = (f"{self.engine}: n={self.n} k={self.k} "
                f"{self.messages} messages / {self.waves} waves "
                f"[{self.level}] -> "
                + ("ok" if self.ok else f"{len(self.violations)} violation(s)"))
        lines = [str(v) for v in self.violations[:limit]]
        if len(self.violations) > limit:
            lines.append(f"... and {len(self.violations) - limit} more")
        return "\n".join([head] + [f"  - {ln}" for ln in lines])


class SpecVerificationError(ValueError):
    """A compiled spec failed static verification."""

    def __init__(self, report: VerifyReport, context: str = ""):
        self.report = report
        msg = report.summary()
        if context:
            msg = f"{context}: {msg}"
        super().__init__(msg)


def check_schedule_id(num_entries: int, schedule_id: int) -> Violation | None:
    """The ``sid-out-of-range`` check: ``jax.lax.switch`` clamps its index
    into ``[0, num_branches)``, so an out-of-range schedule id would
    silently run the WRONG failure-class program instead of erroring.
    Host-side callers (:class:`repro.dist.recovery.RecoveryController`)
    gate every flip through this; the traced twin lives in
    ``FaultAwareAllreduce.make_allreduce(debug=True)``."""
    if 0 <= schedule_id < num_entries:
        return None
    return Violation(
        "sid-out-of-range",
        f"schedule id {schedule_id} outside the precompiled entry table "
        f"[0, {num_entries}); lax.switch would clamp it to branch "
        f"{min(max(schedule_id, 0), num_entries - 1)}")


# ---------------------------------------------------------------------------
# engine dispatch
# ---------------------------------------------------------------------------

def engine_of(spec) -> str:
    """Engine name of a compiled spec.  The per-tree form lives in
    ``repro.dist.tree_allreduce`` (a JAX-importing module), so it is
    duck-typed on its attributes instead of imported here."""
    if isinstance(spec, PipelinedAllreduceSpec):
        return "pipelined"
    if isinstance(spec, FusedAllreduceSpec):
        return "fused"
    if isinstance(spec, StripedCollectiveSpec):
        return "striped"
    if (hasattr(spec, "trees") and hasattr(spec, "axes")
            and hasattr(spec, "n")
            and all(hasattr(t, "reduce_rounds") for t in spec.trees)):
        return "per_tree"
    raise TypeError(f"not a compiled allreduce spec: {type(spec).__name__}")


# ---------------------------------------------------------------------------
# shared wave / program checks
# ---------------------------------------------------------------------------

def _scan_perm(w: int, perm, label: str, out: list) -> None:
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if len(set(srcs)) != len(srcs):
        dup = sorted(s for s in set(srcs) if srcs.count(s) > 1)[0]
        out.append(Violation("wave-illegal",
                             f"{label}[{w}]: source {dup} sends twice in one "
                             "wave (ppermute needs unique sources)"))
    if len(set(dsts)) != len(dsts):
        dup = sorted(d for d in set(dsts) if dsts.count(d) > 1)[0]
        out.append(Violation("wave-illegal",
                             f"{label}[{w}]: destination {dup} receives twice "
                             "in one wave (ppermute needs unique "
                             "destinations)"))


def _check_link_race(msgs, label: str, out: list) -> None:
    """Each directed link at most once across the WHOLE program: with
    segment streaming, wave w moves segment t-w at step t, so two waves
    sharing a directed link would put two in-flight segments on it."""
    first: dict = {}
    for w, _, _, s, d in msgs:
        if (s, d) in first and first[(s, d)] != w:
            out.append(Violation(
                "link-race",
                f"{label}: directed link {s}->{d} claimed by waves "
                f"{first[(s, d)]} and {w}; segment streaming would put two "
                "in-flight segments on it in one step"))
        else:
            first.setdefault((s, d), w)


def _recover_parent(n: int, up_edges, j: int, label: str, out: list):
    """Rebuild one tree from its child->parent messages and check it is a
    spanning tree: single parent, single root, acyclic, n-1 edges.
    Returns (parent, root, depth_of, clean)."""
    parent: dict = {}
    clean = True
    for c, p in up_edges:
        if c in parent:
            out.append(Violation(
                "tree-malformed",
                f"{label}: tree {j}: vertex {c} has two parents "
                f"({parent[c]} and {p})"))
            clean = False
        else:
            parent[c] = p
    if n > 1 and len(parent) != n - 1:
        out.append(Violation(
            "tree-malformed",
            f"{label}: tree {j}: {len(parent)} up edges; a spanning tree "
            f"of {n} vertices needs {n - 1}"))
        clean = False
    roots = [v for v in range(n) if v not in parent]
    if len(roots) != 1:
        out.append(Violation(
            "tree-malformed",
            f"{label}: tree {j}: {len(roots)} root candidates "
            f"{roots[:4]} (need exactly one vertex that never sends up)"))
        clean = False
    root = roots[0] if len(roots) == 1 else None
    depth_of = {root: 0} if root is not None else {}
    for v0 in range(n):
        if v0 in depth_of:
            continue
        chain, seen, u = [], set(), v0
        cyclic = False
        while u not in depth_of:
            if u in seen:
                out.append(Violation(
                    "tree-malformed",
                    f"{label}: tree {j}: parent cycle through vertex {u}"))
                clean, cyclic = False, True
                break
            if u not in parent:     # stray extra root: anchor at depth 0
                depth_of[u] = 0
                break
            seen.add(u)
            chain.append(u)
            u = parent[u]
        if cyclic:
            return parent, root, depth_of, False
        base = depth_of.get(u, 0)
        for i, x in enumerate(reversed(chain)):
            depth_of[x] = base + i + 1
    return parent, root, depth_of, clean


def _check_trees(n: int, k: int, msgs, spec_depth, label: str, out: list,
                 hb_only: bool = False, depth_is_min: bool = False) -> None:
    """Full tree-recovery suite for the chunk engines (REDUCE/BCAST
    messages).  With ``hb_only`` (the pipelined q8 program -- same trees,
    different wave assignment) only happens-before is re-checked.  With
    ``depth_is_min`` (per-tree engine: ``_split_unique`` may split one
    BFS level into several ppermute-legal sub-rounds) ``spec_depth`` is a
    lower bound on rounds, not an exact BFS depth."""
    per_tree: dict = {j: [] for j in range(k)}
    for m in msgs:
        if 0 <= m[1] < k:
            per_tree[m[1]].append(m)
    scratch: list = []
    struct_out = scratch if hb_only else out
    edge_owner: dict = {}
    max_depth = 0
    structural = len(out)
    for j in range(k):
        red = [(s, d) for _, _, kind, s, d in per_tree[j] if kind == REDUCE]
        parent, root, depth_of, clean = _recover_parent(
            n, red, j, label, struct_out)
        rwave, bwave, bsrc = {}, {}, {}
        for w, _, kind, s, d in per_tree[j]:
            if kind == REDUCE:
                rwave.setdefault(s, w)
            else:
                if d in bwave:
                    struct_out.append(Violation(
                        "message-conservation",
                        f"{label}: tree {j}: vertex {d} receives two "
                        "broadcast messages"))
                else:
                    bwave[d], bsrc[d] = w, s
        if not hb_only:
            if n > 1 and not per_tree[j]:
                out.append(Violation(
                    "tree-malformed",
                    f"{label}: tree {j} moves no messages at all"))
                continue
            # broadcast edges must be exactly the reversed reduce edges
            down = {(p, c) for c, p in parent.items()}
            bc = {(bsrc[c], c) for c in bwave}
            if down != bc:
                diff = sorted(down ^ bc)[:3]
                out.append(Violation(
                    "phase-mismatch",
                    f"{label}: tree {j}: broadcast edges are not the "
                    f"reversed reduce edges (mismatched: {diff})"))
            # edge-disjointness across trees (the EDST property itself)
            for c, p in parent.items():
                e = canon(c, p)
                if e in edge_owner and edge_owner[e] != j:
                    out.append(Violation(
                        "edge-disjointness",
                        f"{label}: trees {edge_owner[e]} and {j} both route "
                        f"over physical link {e}"))
                edge_owner.setdefault(e, j)
        if clean:
            max_depth = max(max_depth, max(depth_of.values(), default=0))
        # happens-before over the recovered structure
        children: dict = {}
        for c, p in parent.items():
            children.setdefault(p, []).append(c)
        for c, p in parent.items():
            if c not in rwave:
                continue
            for g in children.get(c, ()):
                if g in rwave and rwave[g] >= rwave[c]:
                    out.append(Violation(
                        "happens-before",
                        f"{label}: tree {j}: reduce {c}->{p} rides wave "
                        f"{rwave[c]} but child {g}'s reduce only lands in "
                        f"wave {rwave[g]}"))
        for c in bwave:
            p = bsrc[c]
            if root is not None and p == root:
                for g in children.get(root, ()):
                    if g in rwave and rwave[g] >= bwave[c]:
                        out.append(Violation(
                            "happens-before",
                            f"{label}: tree {j}: broadcast {p}->{c} rides "
                            f"wave {bwave[c]} but the root's total needs "
                            f"{g}'s reduce (wave {rwave[g]})"))
            elif p in bwave and bwave[p] >= bwave[c]:
                out.append(Violation(
                    "happens-before",
                    f"{label}: tree {j}: broadcast {p}->{c} rides wave "
                    f"{bwave[c]} but {p} only receives the total in wave "
                    f"{bwave[p]}"))
    if (not hb_only and spec_depth is not None and k > 0
            and len(out) == structural):
        bad = (spec_depth < max_depth) if depth_is_min \
            else (max_depth != spec_depth)
        if bad:
            rel = "is below" if depth_is_min else "disagrees with"
            out.append(Violation(
                "depth-mismatch",
                f"{label}: spec.depth={spec_depth} {rel} the deepest "
                f"recovered tree depth {max_depth}"))


# ---------------------------------------------------------------------------
# chunk-engine table scans (message recovery from the routing tables)
# ---------------------------------------------------------------------------

def _scan_pipelined(spec, waves, label: str, out: list):
    msgs = []
    k = spec.k
    for w, wv in enumerate(waves):
        _scan_perm(w, wv.perm, label, out)
        for s, d in wv.perm:
            j = int(wv.send_row[s])
            if not 0 <= j < k:
                out.append(Violation(
                    "spec-meta",
                    f"{label}[{w}]: sender {s} ships row {j}, outside "
                    f"0..{k - 1}"))
                continue
            rows_r = np.nonzero(wv.reduce_flag[:, d])[0]
            rows_b = np.nonzero(wv.bcast_flag[:, d])[0]
            nflag = len(rows_r) + len(rows_b)
            if nflag == 0:
                out.append(Violation(
                    "recv-dropped",
                    f"{label}[{w}]: arrival {s}->{d} (row {j}) has no "
                    f"landing flag at vertex {d}"))
                continue
            if nflag > 1:
                out.append(Violation(
                    "table-stray",
                    f"{label}[{w}]: vertex {d} is flagged {nflag} times for "
                    "a single arrival"))
            jj = int(rows_r[0]) if len(rows_r) else int(rows_b[0])
            kind = REDUCE if len(rows_r) else BCAST
            if jj != j:
                out.append(Violation(
                    "row-misroute",
                    f"{label}[{w}]: arrival {s}->{d} carries row {j} but "
                    f"lands in row {jj}"))
                continue
            msgs.append((w, j, kind, s, d))
        flagged = set(np.nonzero(wv.reduce_flag.any(axis=0)
                                 | wv.bcast_flag.any(axis=0))[0].tolist())
        stray = flagged - {d for _, d in wv.perm}
        for d in sorted(stray):
            out.append(Violation(
                "table-stray",
                f"{label}[{w}]: vertex {d} is flagged to receive but no "
                "message arrives"))
        # executor-specialization metadata
        expect_rows = tuple(sorted({int(wv.send_row[s])
                                    for s, _ in wv.perm}))
        if tuple(wv.rows) != expect_rows:
            out.append(Violation(
                "table-stray",
                f"{label}[{w}]: rows metadata {wv.rows} but senders ship "
                f"rows {expect_rows}"))
        expect_sole = (expect_rows[0]
                       if len(expect_rows) == 1 and not wv.bcast_flag.any()
                       else -1)
        if wv.sole_add != expect_sole:
            out.append(Violation(
                "table-stray",
                f"{label}[{w}]: sole_add={wv.sole_add} but the tables imply "
                f"{expect_sole} (executors skip masking on sole_add waves)"))
    return msgs


def _scan_fused(spec, out: list):
    msgs = []
    rounds = ([(REDUCE, r) for r in spec.reduce_rounds]
              + [(BCAST, r) for r in spec.bcast_rounds])
    for w, (kind, rnd) in enumerate(rounds):
        _scan_perm(w, rnd.perm, "rounds", out)
        for s, d in rnd.perm:
            j = int(rnd.send_row[s])
            if not 0 <= j < spec.k:
                out.append(Violation(
                    "spec-meta",
                    f"rounds[{w}]: sender {s} ships row {j}, outside "
                    f"0..{spec.k - 1}"))
                continue
            if not rnd.recv_flag[d]:
                out.append(Violation(
                    "recv-dropped",
                    f"rounds[{w}]: arrival {s}->{d} (row {j}) but vertex "
                    f"{d}'s recv_flag is off"))
                continue
            jj = int(rnd.recv_row[d])
            if jj != j:
                out.append(Violation(
                    "row-misroute",
                    f"rounds[{w}]: arrival {s}->{d} carries row {j} but "
                    f"lands in row {jj}"))
                continue
            msgs.append((w, j, kind, s, d))
        stray = (set(np.nonzero(rnd.recv_flag)[0].tolist())
                 - {d for _, d in rnd.perm})
        for d in sorted(stray):
            out.append(Violation(
                "table-stray",
                f"rounds[{w}]: vertex {d} is flagged to receive but no "
                "message arrives"))
    return msgs


def _scan_per_tree(spec, out: list):
    msgs = []
    w = 0
    for j, tp in enumerate(spec.trees):
        for perm in tp.reduce_rounds:
            _scan_perm(w, perm, f"tree{j}.reduce", out)
            msgs.extend((w, j, REDUCE, s, d) for s, d in perm)
            w += 1
        dst_tables = tp.bcast_dst or (None,) * len(tp.bcast_rounds)
        if len(dst_tables) != len(tp.bcast_rounds):
            out.append(Violation(
                "table-stray",
                f"tree{j}: {len(dst_tables)} bcast_dst tables for "
                f"{len(tp.bcast_rounds)} broadcast rounds"))
            dst_tables = (None,) * len(tp.bcast_rounds)
        for perm, table in zip(tp.bcast_rounds, dst_tables):
            _scan_perm(w, perm, f"tree{j}.bcast", out)
            if table is not None:
                dsts = {d for _, d in perm}
                flagged = {v for v, f in enumerate(table) if f}
                for d in sorted(dsts - flagged):
                    out.append(Violation(
                        "recv-dropped",
                        f"tree{j}.bcast[{w}]: arrival at {d} but its "
                        "bcast_dst flag is off"))
                for d in sorted(flagged - dsts):
                    out.append(Violation(
                        "table-stray",
                        f"tree{j}.bcast[{w}]: vertex {d} flagged in "
                        "bcast_dst but no message arrives"))
            msgs.extend((w, j, BCAST, s, d) for s, d in perm)
            w += 1
    return msgs


# ---------------------------------------------------------------------------
# striped engine
# ---------------------------------------------------------------------------

def _scan_striped_program(spec, waves, expected_kinds, label: str,
                          out: list):
    """Per-wave scan of one striped program; returns messages with their
    slot windows: (wave, tree, kind, src, dst, slot, nslot)."""
    msgs = []
    n, k = spec.n, spec.k
    for w, wv in enumerate(waves):
        _scan_perm(w, wv.perm, label, out)
        if wv.op not in (REDUCE, BCAST):
            out.append(Violation(
                "op-mixed", f"{label}[{w}]: op {wv.op} is neither "
                "accumulate (REDUCE) nor overwrite (BCAST)"))
        if sorted(wv.perm) != sorted((s, d) for _, _, s, d in wv.msgs):
            out.append(Violation(
                "table-stray",
                f"{label}[{w}]: perm and msgs disagree on which links the "
                "wave uses"))
        for j, kind, s, d in wv.msgs:
            if not 0 <= j < k:
                out.append(Violation(
                    "spec-meta",
                    f"{label}[{w}]: message names tree {j}, outside "
                    f"0..{k - 1}"))
                continue
            if kind not in expected_kinds:
                out.append(Violation(
                    "op-mixed",
                    f"{label}[{w}]: kind {_KIND_NAME.get(kind, kind)} does "
                    "not belong to this program"))
                continue
            if _striped_op((j, kind, s, d)) != wv.op:
                out.append(Violation(
                    "op-mixed",
                    f"{label}[{w}]: {_KIND_NAME[kind]} message {s}->{d} in "
                    "a wave whose op disagrees (executor applies ONE op per "
                    "wave)"))
            if int(wv.send_tree[s]) != j or int(wv.recv_tree[d]) != j:
                out.append(Violation(
                    "row-misroute",
                    f"{label}[{w}]: message {s}->{d} belongs to tree {j} "
                    f"but the tables say send_tree={int(wv.send_tree[s])} "
                    f"recv_tree={int(wv.recv_tree[d])}"))
                continue
            swin = (int(wv.send_slot[s]), int(wv.send_nslot[s]))
            rwin = (int(wv.recv_slot[d]), int(wv.recv_nslot[d]))
            if swin != rwin:
                out.append(Violation(
                    "row-misroute",
                    f"{label}[{w}]: message {s}->{d} ships window {swin} "
                    f"but the receiver expects {rwin}"))
                continue
            if not 0 < swin[1] <= n or not 0 <= swin[0] < n:
                out.append(Violation(
                    "stripe-conservation",
                    f"{label}[{w}]: window {swin} of {s}->{d} is not a "
                    f"non-empty circular window mod {n}"))
                continue
            msgs.append((w, j, kind, s, d, swin[0], swin[1]))
    return msgs


def _check_striped_structure(spec, msgs, expected_kinds, label: str,
                             out: list) -> None:
    n, k = spec.n, spec.k
    up_kind = _UP_OF[expected_kinds]
    structural = len(out)
    max_depth = 0
    all_clean = True
    edge_owner: dict = {}
    for j in range(k):
        mine = [m for m in msgs if m[1] == j]
        up = [(s, d) for _, _, kind, s, d, _, _ in mine if kind == up_kind]
        parent, root, depth_of, clean = _recover_parent(
            n, up, j, label, out)
        all_clean = all_clean and clean
        # edge-disjointness across trees (the EDST property itself)
        for c, p in parent.items():
            e = canon(c, p)
            if e in edge_owner and edge_owner[e] != j:
                out.append(Violation(
                    "edge-disjointness",
                    f"{label}: trees {edge_owner[e]} and {j} both route "
                    f"over physical link {e}"))
            edge_owner.setdefault(e, j)
        if clean:
            max_depth = max(max_depth, max(depth_of.values(), default=0))
        # spec.trees metadata must agree with the recovered routing
        if clean and j < len(spec.trees):
            st = spec.trees[j]
            meta = {c: int(st.parent[c]) for c in range(n)
                    if st.parent[c] >= 0}
            if meta != parent or st.root != root:
                out.append(Violation(
                    "tree-malformed",
                    f"{label}: tree {j}: spec.trees metadata disagrees "
                    "with the tree recovered from the routing tables"))
        children: dict = {}
        for c, p in parent.items():
            children.setdefault(p, []).append(c)
        # recovered subtree sizes (leaves first)
        size = {v: 1 for v in range(n)}
        if clean:
            for v in sorted(depth_of, key=lambda v: -depth_of[v]):
                if v in parent:
                    size[parent[v]] += size[v]
        # per-edge kind multiplicity, direction, and windows
        per_edge: dict = {}
        wave_of: dict = {}
        for w, _, kind, s, d, lo, ns in mine:
            c = s if kind in (RS_UP, AG_UP) else d
            p_end = d if kind in (RS_UP, AG_UP) else s
            slot = per_edge.setdefault(c, {})
            if kind in slot:
                out.append(Violation(
                    "message-conservation",
                    f"{label}: tree {j}: edge of child {c} carries "
                    f"{_KIND_NAME[kind]} twice"))
                continue
            slot[kind] = (lo, ns, p_end)
            wave_of[(c, kind)] = w
        for c, slot in per_edge.items():
            missing = expected_kinds - set(slot)
            if missing:
                out.append(Violation(
                    "message-conservation",
                    f"{label}: tree {j}: edge of child {c} is missing "
                    f"{sorted(_KIND_NAME[m] for m in missing)}"))
                continue
            for kind, (lo, ns, p_end) in slot.items():
                if c in parent and p_end != parent[c]:
                    out.append(Violation(
                        "phase-mismatch",
                        f"{label}: tree {j}: {_KIND_NAME[kind]} of child "
                        f"{c} runs to/from {p_end}, not its parent "
                        f"{parent[c]}"))
            below = [slot[kd][:2] for kd in slot if kd in _BELOW_KINDS]
            above = [slot[kd][:2] for kd in slot if kd not in _BELOW_KINDS]
            if len(set(below)) > 1 or len(set(above)) > 1:
                out.append(Violation(
                    "stripe-conservation",
                    f"{label}: tree {j}: child {c}'s reduce-scatter and "
                    f"allgather windows disagree (below {below}, above "
                    f"{above})"))
                continue
            if below and above:
                (blo, bns), (alo, ans) = below[0], above[0]
                if (bns + ans != n or (blo + bns) % n != alo
                        or (alo + ans) % n != blo):
                    out.append(Violation(
                        "stripe-conservation",
                        f"{label}: tree {j}: windows below={below[0]} "
                        f"above={above[0]} of child {c} are not circular "
                        f"complements mod {n} -- some owner slot crosses "
                        "the edge twice or never"))
            if below and clean and below[0][1] != size.get(c, -1):
                out.append(Violation(
                    "stripe-conservation",
                    f"{label}: tree {j}: child {c}'s below-window holds "
                    f"{below[0][1]} slots but its recovered subtree has "
                    f"{size.get(c)}"))
            # the ownership table (DFS preorder slots) executors cut own
            # stripes with must agree with the routed windows: a preorder
            # subtree owns exactly [pre[c], pre[c]+size[c]) -- a stale
            # table kept across a re-striping failover silently
            # mis-slices every owner cut
            if below and clean and j < len(spec.trees):
                st = spec.trees[j]
                if (int(st.pre[c]) != below[0][0]
                        or int(st.size[c]) != below[0][1]):
                    out.append(Violation(
                        "stale-ownership",
                        f"{label}: tree {j}: ownership table says child "
                        f"{c} owns slots [{int(st.pre[c])}, "
                        f"+{int(st.size[c])}) but the routed below-window "
                        f"is {below[0]} -- stripe table is stale w.r.t. "
                        "the routing (re-stripe after failover)"))
        # child windows nest inside the parent's below window
        if all(len(slot) == len(expected_kinds) for slot in
               per_edge.values()):
            for c, p in parent.items():
                if p == root or p not in per_edge or c not in per_edge:
                    continue
                cb = [per_edge[c][kd][:2] for kd in per_edge[c]
                      if kd in _BELOW_KINDS]
                pb = [per_edge[p][kd][:2] for kd in per_edge[p]
                      if kd in _BELOW_KINDS]
                if not cb or not pb:
                    continue
                (clo, cns), (plo, pns) = cb[0], pb[0]
                if (clo - plo) % n + cns > pns:
                    out.append(Violation(
                        "stripe-conservation",
                        f"{label}: tree {j}: child {c}'s below window "
                        f"{cb[0]} escapes its parent {p}'s subtree window "
                        f"{pb[0]}"))
        # happens-before: the striped dependency rules, re-derived
        ru = {c: wave_of.get((c, RS_UP)) for c in parent}
        rd = {c: wave_of.get((c, RS_DOWN)) for c in parent}
        au = {c: wave_of.get((c, AG_UP)) for c in parent}
        ad = {c: wave_of.get((c, AG_DOWN)) for c in parent}

        def _need(later, earlier, what):
            if later is not None and earlier is not None \
                    and earlier >= later:
                out.append(Violation(
                    "happens-before",
                    f"{label}: tree {j}: {what} (waves {later} vs "
                    f"{earlier})"))

        for c, p in parent.items():
            kids_c = children.get(c, ())
            kids_p = children.get(p, ())
            for g in kids_c:
                _need(ru.get(c), ru.get(g),
                      f"RS_UP({c}->{p}) before child {g}'s RS_UP")
                _need(au.get(c), au.get(g),
                      f"AG_UP({c}->{p}) before child {g}'s AG_UP")
                _need(au.get(c), ru.get(g),
                      f"AG_UP({c}->{p}) before child {g}'s RS_UP")
            _need(au.get(c), rd.get(c),
                  f"AG_UP({c}->{p}) before its own RS_DOWN")
            for g in kids_p:
                if g != c:
                    _need(rd.get(c), ru.get(g),
                          f"RS_DOWN({p}->{c}) before sibling {g}'s RS_UP")
                    _need(ad.get(c), au.get(g),
                          f"AG_DOWN({p}->{c}) before sibling {g}'s AG_UP")
                _need(ad.get(c), ru.get(g),
                      f"AG_DOWN({p}->{c}) before {p}'s child {g}'s RS_UP")
            if p in parent:             # p is not the root
                _need(rd.get(c), rd.get(p),
                      f"RS_DOWN({p}->{c}) before {p}'s own RS_DOWN")
                _need(ad.get(c), rd.get(p),
                      f"AG_DOWN({p}->{c}) before {p}'s own RS_DOWN")
                _need(ad.get(c), ad.get(p),
                      f"AG_DOWN({p}->{c}) before {p}'s own AG_DOWN")
    if (len(out) == structural and all_clean and k > 0
            and expected_kinds is _ALL_STRIPED_KINDS
            and max_depth != spec.depth):
        out.append(Violation(
            "depth-mismatch",
            f"{label}: spec.depth={spec.depth} but the deepest recovered "
            f"tree has depth {max_depth}"))


# ---------------------------------------------------------------------------
# verify_spec / assert_valid
# ---------------------------------------------------------------------------

def verify_spec(spec, level: str = "full") -> VerifyReport:
    """Statically verify one compiled spec (any engine).  ``"cheap"``
    runs the single-pass wave scans + the link-race check; ``"full"``
    adds tree recovery, happens-before, edge-disjointness, stripe
    conservation and depth.  Never executes JAX or the simulator."""
    if level not in LEVELS:
        raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
    engine = engine_of(spec)
    out: list = []
    if spec.k == 0:                    # the empty (pass-through) program
        return VerifyReport(engine, spec.n, 0, level, 0, 0, out)
    if not spec.axes:
        out.append(Violation("spec-meta", "spec.axes is empty"))

    if engine == "pipelined":
        msgs = _scan_pipelined(spec, spec.waves, "waves", out)
        qmsgs = _scan_pipelined(spec, spec.q8_waves, "q8_waves", out)
        _check_link_race(msgs, "waves", out)
        _check_link_race(qmsgs, "q8_waves", out)
        b = spec.q8_boundary
        for w, _, kind, s, d in qmsgs:
            if (kind == BCAST) != (w >= b):
                out.append(Violation(
                    "op-mixed",
                    f"q8_waves[{w}]: {_KIND_NAME[kind]} message {s}->{d} on "
                    f"the wrong side of q8_boundary={b} (the pack-once "
                    "point)"))
        if sorted(m[1:] for m in msgs) != sorted(m[1:] for m in qmsgs):
            out.append(Violation(
                "message-conservation",
                "q8_waves move a different message multiset than waves"))
        if level == "full":
            _check_trees(spec.n, spec.k, msgs, spec.depth, "waves", out)
            _check_trees(spec.n, spec.k, qmsgs, None, "q8_waves", out,
                         hb_only=True)
        nmsgs, nwaves = len(msgs), len(spec.waves)

    elif engine == "fused":
        msgs = _scan_fused(spec, out)
        _check_link_race(msgs, "rounds", out)
        if level == "full":
            _check_trees(spec.n, spec.k, msgs, spec.depth, "rounds", out)
        nmsgs = len(msgs)
        nwaves = len(spec.reduce_rounds) + len(spec.bcast_rounds)

    elif engine == "per_tree":
        msgs = _scan_per_tree(spec, out)
        _check_link_race(msgs, "rounds", out)
        if level == "full":
            _check_trees(spec.n, spec.k, msgs, spec.depth, "rounds", out,
                         depth_is_min=True)
        nmsgs = len(msgs)
        nwaves = sum(len(t.reduce_rounds) + len(t.bcast_rounds)
                     for t in spec.trees)

    else:                              # striped
        programs = (("waves", spec.waves, _ALL_STRIPED_KINDS),
                    ("rs_waves", spec.rs_waves, _RS_KINDS),
                    ("ag_waves", spec.ag_waves, _AG_KINDS))
        scanned = {}
        for label, waves, kinds in programs:
            scanned[label] = _scan_striped_program(spec, waves, kinds,
                                                   label, out)
            if level == "full":
                _check_striped_structure(spec, scanned[label], kinds,
                                         label, out)
        comp = sorted(m[1:5] for m in scanned["waves"])
        split = sorted([m[1:5] for m in scanned["rs_waves"]]
                       + [m[1:5] for m in scanned["ag_waves"]])
        if comp != split:
            out.append(Violation(
                "message-conservation",
                "the composed program moves a different message multiset "
                "than rs_waves + ag_waves"))
        nmsgs, nwaves = len(scanned["waves"]), len(spec.waves)

    return VerifyReport(engine, spec.n, spec.k, level, nmsgs, nwaves, out)


def assert_valid(spec, level: str = "full", context: str = "") -> VerifyReport:
    """:func:`verify_spec`, raising :class:`SpecVerificationError` on any
    violation.  The spec compilers call this under their ``verify=``
    flag, so an illegal schedule is rejected at build time."""
    report = verify_spec(spec, level=level)
    if not report.ok:
        raise SpecVerificationError(report, context)
    return report


# ---------------------------------------------------------------------------
# HLO contract builder (the lint_hlo side of the verifier)
# ---------------------------------------------------------------------------

def hlo_contract_for(spec, quantize: bool = False,
                     m: int | None = None,
                     phase: str = "composed") -> HloContract:
    """The HLO contract a correct executor compile of ``spec`` satisfies,
    enforced by :func:`repro.analysis.hlo.lint_hlo`:

      * exactly one ``collective-permute`` site per wave, *flat in the
        segment count* (the scan path holds each wave's collective once);
      * quantized programs put at most ``bcast-wave-count`` f32 wire
        sites in the HLO (reduce wires are int8; broadcast wires are the
        bit-packed f32 lanes), and every f32 wire is the *packed* width,
        never a full ``mrow``-element row.

    ``phase`` (striped engine only) selects which program the executor
    compiled: ``"composed"`` (``striped_allreduce``), ``"rs"`` / ``"ag"``
    (the standalone reduce-scatter / allgather), or ``"zero1"`` (one
    zero1 train step: gradient reduce-scatter + param allgather, no
    composed program) -- the contract under which the zero1 step proves
    it issues strictly fewer collective waves than the composed
    allreduce.
    """
    engine = engine_of(spec)
    if phase != "composed" and engine != "striped":
        raise ValueError(f"phase={phase!r} needs the striped engine; "
                         f"{engine} compiles only the composed program")
    ppermutes: int | None
    max_f32_sites = None
    max_f32_wire = None
    if engine == "pipelined":
        ppermutes = len(spec.q8_waves) if quantize else len(spec.waves)
        if quantize:
            max_f32_sites = len(spec.q8_waves) - spec.q8_boundary
    elif engine == "fused":
        ppermutes = spec.num_collectives
        if quantize:
            max_f32_sites = len(spec.bcast_rounds)
    elif engine == "per_tree":
        ppermutes = sum(len(t.reduce_rounds) + len(t.bcast_rounds)
                        for t in spec.trees)
        if quantize:
            max_f32_sites = sum(len(t.bcast_rounds) for t in spec.trees)
    else:                              # striped: f32 payload sites, and
        # a ``phase`` choosing the compiled program (see docstring);
        # binding to a payload size m drops empty-stripe waves exactly
        # like the executor does
        bound = striped_tables(spec, m) if m else None

        def _nwaves(name):
            return len(getattr(bound if m else spec, name))

        if phase == "composed":
            ppermutes = _nwaves("waves")
        elif phase == "rs":
            ppermutes = _nwaves("rs_waves")
        elif phase == "ag":
            ppermutes = _nwaves("ag_waves")
        elif phase == "zero1":
            ppermutes = _nwaves("rs_waves") + _nwaves("ag_waves")
        else:
            raise ValueError(f"phase {phase!r} not in "
                             "('composed', 'rs', 'ag', 'zero1')")
        quantize = False
    if quantize and m is not None and spec.k:
        mrow = -(-m // spec.k)
        # the packed broadcast wire is ceil(mrow/4) f32 lanes + 1 scale
        # lane (+1 headroom for segment padding); a full f32 row (mrow
        # elements, the codec-off wire) must exceed this cap
        max_f32_wire = -(-mrow // 4) + 2
    return HloContract(ppermutes=ppermutes, max_f32_sites=max_f32_sites,
                       max_f32_wire_elems=max_f32_wire)


# ---------------------------------------------------------------------------
# CLI: engines x paper topologies (the CI gate)
# ---------------------------------------------------------------------------

PAPER_TOPOLOGIES = ("torus4x4", "hyperx4x4", "slimfly_q5",
                    "polarstar_er3_qr5", "bundlefly_q4_a5")


def _topology_case(label: str):
    """(star product, explicit-E set or None) for one paper topology."""
    from ..core import topologies as topo
    if label == "torus4x4":
        return topo.device_topology((4, 4)), None
    if label == "hyperx4x4":
        return topo.hyperx([4, 4]), None
    if label == "slimfly_q5":
        return topo.slimfly(5), None
    if label == "polarstar_er3_qr5":
        return topo.polarstar(3, "qr", 5), None
    if label == "bundlefly_q4_a5":
        return topo.bundlefly(4, 5), topo.edst_set_for(topo.slimfly(4))
    raise KeyError(f"unknown topology {label!r}; known: "
                   f"{', '.join(PAPER_TOPOLOGIES)}")


def _schedule_for(label: str):
    from ..core.collectives import allreduce_schedule
    from ..core.edst_star import star_edsts
    sp, es = _topology_case(label)
    res = star_edsts(sp, Es=es) if es is not None else star_edsts(sp)
    return allreduce_schedule(sp.product().n, res.trees)


def _compile_specs(sched, engines):
    """engine -> compiled spec (or a skip-reason string).  Compiled with
    ``verify=False``: the CLI runs :func:`verify_spec` itself."""
    from ..core.collectives import (fused_spec_from_schedule,
                                    pipelined_spec_from_schedule,
                                    striped_spec_from_schedule)
    axes = ("data",)
    specs: dict = {}
    for eng in engines:
        if eng == "fused":
            specs[eng] = fused_spec_from_schedule(sched, axes, verify=False)
        elif eng == "pipelined":
            specs[eng] = pipelined_spec_from_schedule(sched, axes,
                                                      verify=False)
        elif eng == "striped":
            specs[eng] = striped_spec_from_schedule(sched, axes,
                                                    verify=False)
        elif eng == "per_tree":
            try:
                from ..dist.tree_allreduce import spec_from_schedule
            except ImportError as e:   # jax unavailable: skip, don't fail
                specs[eng] = f"skipped (cannot import repro.dist: {e})"
                continue
            specs[eng] = spec_from_schedule(sched, axes, verify=False)
    return specs


def _simulate_case(label: str, sched, specs) -> list:
    """The historical dynamic gate (``benchmarks.wave_check``): replay
    every engine's program through the NumPy packet simulators."""
    from ..core.collectives import (simulate_allreduce,
                                    simulate_striped_program,
                                    simulate_wave_program, striped_tables)
    failures = []
    n, k = sched.n, sched.k
    rng = np.random.RandomState(sum(map(ord, label)))
    d = 8 * k + 3                          # uneven on purpose
    vals = rng.randn(n, d)

    sim = simulate_allreduce(sched, rng.randn(n, 8 * k))
    if not sim.ok:
        failures.append("per_tree: wrong sums")
    if sim.max_link_load != 1:
        failures.append(f"per_tree: link load {sim.max_link_load} != 1")

    pspec = specs.get("pipelined")
    if pspec is not None and not isinstance(pspec, str):
        for segments in (1, 4):
            for q in (False, True):
                sim = simulate_wave_program(pspec, vals, segments,
                                            quantized=q)
                if not sim.ok:
                    failures.append(
                        f"pipelined: wrong sums (S={segments} q={q})")
                if sim.max_link_load != 1:
                    failures.append(
                        f"pipelined: directed-link load "
                        f"{sim.max_link_load} != 1 (S={segments} q={q})")

    sspec = specs.get("striped")
    if sspec is not None and not isinstance(sspec, str):
        ssim = simulate_striped_program(sspec, vals)
        bound = striped_tables(sspec, d)
        if not ssim.ok:
            failures.append("striped: wrong sums")
        if not ssim.stripes_ok:
            failures.append("striped: per-stripe conservation violated")
        for bw, wire in zip(bound.waves, ssim.wire_elems):
            if wire != int(bw.recv_len.max()):
                failures.append("striped: wave wire != max window length")
            if wire > bound.smax * (n - 1):
                failures.append(
                    f"striped: wire {wire} exceeds ceil(m/n)*(n-1) slots")
        if bound.mrow >= n and ssim.max_wire >= bound.mrow:
            failures.append(
                f"striped: max wire {ssim.max_wire} not < m {bound.mrow}")
    return failures


_STATS_NBYTES = 64 * 1024 * 1024


def _stats_row(label: str, eng: str, spec, rep: VerifyReport) -> dict:
    """One ``--stats`` table row: schedule-quality numbers for a verified
    spec -- wave count, tree depth, and the :class:`CostModel` makespan of
    a 64 MiB allreduce (the same score the anytime schedule search
    minimizes, so greedy/search/composed runs are directly comparable in
    CI logs)."""
    from ..core.collectives import CostModel
    cm = CostModel()
    makespan = None
    try:
        if eng == "striped":
            makespan = cm.striped_allreduce(_STATS_NBYTES, spec)
        elif eng == "pipelined":
            makespan = cm.pipelined_allreduce(
                _STATS_NBYTES, spec, cm.best_segments(_STATS_NBYTES, spec))
    except Exception:                  # cost model is advisory here
        makespan = None
    return {"topology": label, "engine": eng, "n": rep.n, "k": rep.k,
            "depth": getattr(spec, "depth", None), "waves": rep.waves,
            "messages": rep.messages, "makespan_us": makespan}


def _print_stats(rows) -> None:
    """Aligned waves/depth/makespan table (the ``--stats`` output)."""
    heads = ("topology", "engine", "n", "k", "depth", "waves", "messages",
             "makespan_us")
    table = [heads]
    for r in rows:
        ms = r["makespan_us"]
        table.append((r["topology"], r["engine"], str(r["n"]), str(r["k"]),
                      "-" if r["depth"] is None else str(r["depth"]),
                      str(r["waves"]), str(r["messages"]),
                      "-" if ms is None else f"{ms * 1e6:.1f}"))
    width = [max(len(row[c]) for row in table) for c in range(len(heads))]
    print("\nschedule stats (CostModel, 64 MiB allreduce):")
    for i, row in enumerate(table):
        print("  " + "  ".join(cell.ljust(w)
                               for cell, w in zip(row, width)).rstrip())
        if i == 0:
            print("  " + "  ".join("-" * w for w in width))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description="Static wave-program verification of every compiled "
                    "EDST allreduce engine on the paper topologies "
                    "(no JAX execution).")
    p.add_argument("--engines", default=None,
                   help="comma-separated subset of " + ",".join(ENGINES))
    p.add_argument("--all-engines", action="store_true",
                   help="verify every engine (the default when --engines "
                        "is omitted)")
    p.add_argument("--topologies", default="paper5",
                   help="'paper5' or a comma-separated subset of "
                        + ",".join(PAPER_TOPOLOGIES))
    p.add_argument("--level", default="full", choices=LEVELS)
    p.add_argument("--simulate", action="store_true",
                   help="additionally replay the NumPy packet simulators "
                        "(the old benchmarks.wave_check dynamic gate)")
    p.add_argument("--stats", action="store_true",
                   help="print a waves/depth/makespan table per engine x "
                        "topology after verification (CostModel at 64 MiB; "
                        "the CI-log compile summary)")
    p.add_argument("--trace", metavar="DIR", default=None,
                   help="also write a predicted Perfetto (Chrome trace "
                        "event JSON) file per verified engine x topology "
                        "into DIR -- the --stats table rendered as a "
                        "timeline (same 64 MiB CostModel timings)")
    args = p.parse_args(argv)

    engines = (ENGINES if args.engines is None or args.all_engines
               else tuple(e.strip() for e in args.engines.split(",") if e))
    for e in engines:
        if e not in ENGINES:
            p.error(f"unknown engine {e!r}; known: {', '.join(ENGINES)}")
    labels = (PAPER_TOPOLOGIES if args.topologies == "paper5"
              else tuple(t.strip() for t in args.topologies.split(",") if t))

    t0 = time.perf_counter()
    bad = 0
    stats_rows = []
    for label in labels:
        sched = _schedule_for(label)
        specs = _compile_specs(sched, engines)
        for eng in engines:
            spec = specs.get(eng)
            if isinstance(spec, str):
                print(f"verify/{label}/{eng}: {spec}")
                continue
            rep = verify_spec(spec, level=args.level)
            status = "ok" if rep.ok else "FAIL"
            print(f"verify/{label}/{eng}: {status} "
                  f"({rep.messages} messages, {rep.waves} waves)"
                  + "".join(f"\n  - {v}" for v in rep.violations[:20]))
            bad += len(rep.violations)
            if args.stats:
                stats_rows.append(_stats_row(label, eng, spec, rep))
            if args.trace:
                import os

                from ..telemetry import trace as ttrace
                os.makedirs(args.trace, exist_ok=True)
                path = os.path.join(args.trace, f"trace_{label}_{eng}.json")
                ttrace.write_trace(path, ttrace.trace_spec(
                    spec, nbytes=_STATS_NBYTES, label=f"{label}/{eng}"))
                print(f"  trace -> {path}")
        if args.simulate:
            failures = _simulate_case(label, sched, specs)
            status = "ok" if not failures else "FAIL"
            print(f"simulate/{label}: {status}"
                  + "".join(f"\n  - {f}" for f in failures))
            bad += len(failures)
    if args.stats and stats_rows:
        _print_stats(stats_rows)
    dt = time.perf_counter() - t0
    if bad:
        print(f"\n{bad} invariant violation(s) in {dt:.2f}s")
        return 1
    print(f"\nall engines statically legal on all requested topologies "
          f"({dt:.2f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
