"""AST-based repo lint for the invariants the verifier cannot see.

The static wave-program verifier (:mod:`repro.analysis.verify`) proves a
*compiled spec* legal; this module lints the *source* for the hygiene
rules that keep specs cheap and jit caches stable:

``spec-construct``
    The compiled spec classes (``FusedAllreduceSpec``,
    ``PipelinedAllreduceSpec``, ``StripedCollectiveSpec``,
    ``TreeAllreduceSpec``) may only be constructed inside their defining
    compiler modules.  Everyone else must go through the cached
    ``*_spec_from_schedule`` constructors -- a hand-rolled spec bypasses
    both the compile-time verifier and the identity cache that keeps
    jitted executors from retracing.

``axis-literal``
    Inside ``repro/dist``, ``jax.lax`` collectives (``ppermute`` /
    ``psum`` / ``pmean`` / ``axis_index`` / ...) must not receive a
    string-literal axis name: the axis names live on the spec
    (``spec.axes``), so executors stay correct under any mesh naming.

``traced-table-build``
    Inside ``repro/dist``, a function nested in another function (the
    shape every traced closure takes here) must not build a table from a
    Python list/comprehension literal via ``jnp.asarray`` / ``np.array``
    & co. -- per-call table construction inside traced bodies is exactly
    the trace-time cost the spec compilers exist to hoist.

``nested-numpy``
    Inside ``repro/dist``, nested (traced-closure) functions must not
    call ``np.*`` at all: NumPy inside a traced body bakes silently into
    constants at trace time.  Module-level helpers preparing static
    tables from the spec are fine (and idiomatic).

Run as ``python -m repro.analysis.lint src`` (the CI verify job does);
exits non-zero on any finding.
"""
from __future__ import annotations

import argparse
import ast
import sys
from dataclasses import dataclass
from pathlib import Path

SPEC_CLASSES = ("FusedAllreduceSpec", "PipelinedAllreduceSpec",
                "StripedCollectiveSpec", "TreeAllreduceSpec")
# module suffix -> spec classes it is allowed to construct (its compilers)
SPEC_HOME = {
    "core/collectives.py": {"FusedAllreduceSpec", "PipelinedAllreduceSpec",
                            "StripedCollectiveSpec"},
    "core/product_schedule.py": {"PipelinedAllreduceSpec",
                                 "StripedCollectiveSpec"},
    "core/schedule_search.py": {"FusedAllreduceSpec",
                                "PipelinedAllreduceSpec",
                                "StripedCollectiveSpec"},
    "dist/tree_allreduce.py": {"TreeAllreduceSpec"},
}
AXIS_FNS = {"ppermute": 1, "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1,
            "axis_index": 0, "all_gather": 1, "psum_scatter": 1}
TABLE_FNS = ("asarray", "array", "stack", "concatenate")
LITERALS = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp)
RULES = ("spec-construct", "axis-literal", "traced-table-build",
         "nested-numpy")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _call_root(node: ast.Call) -> str:
    f = node.func
    while isinstance(f, ast.Attribute):
        f = f.value
    return f.id if isinstance(f, ast.Name) else ""


def _is_str_literal(node) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return bool(node.elts) and all(_is_str_literal(e)
                                       for e in node.elts)
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, in_dist: bool):
        self.path = path
        self.in_dist = in_dist
        self.depth = 0                   # enclosing function nesting
        self.findings: list = []
        suffix = next((s for s in SPEC_HOME if path.endswith(s)), None)
        self.allowed_specs = SPEC_HOME.get(suffix, set())

    def _emit(self, rule, node, msg):
        self.findings.append(Finding(rule, self.path, node.lineno, msg))

    def visit_FunctionDef(self, node):
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self.depth += 1
        self.generic_visit(node)
        self.depth -= 1

    def visit_Call(self, node):
        name = _call_name(node)
        root = _call_root(node)
        if name in SPEC_CLASSES and name not in self.allowed_specs:
            self._emit("spec-construct", node,
                       f"{name} constructed directly; obtain specs via the "
                       "cached *_spec_from_schedule compilers (they verify "
                       "and keep jit caches stable)")
        if self.in_dist and name in AXIS_FNS:
            pos = AXIS_FNS[name]
            cands = []
            if len(node.args) > pos:
                cands.append(node.args[pos])
            cands.extend(kw.value for kw in node.keywords
                         if kw.arg in ("axis_name", "axis"))
            if any(_is_str_literal(c) for c in cands):
                self._emit("axis-literal", node,
                           f"jax.lax.{name} called with a string-literal "
                           "axis name; use the spec's axes "
                           "(spec.axes / _axis_arg)")
        if self.in_dist and self.depth >= 2:   # inside a nested function
            if name in TABLE_FNS and root in ("jnp", "np", "numpy", "jax") \
                    and node.args \
                    and isinstance(node.args[0], LITERALS):
                self._emit("traced-table-build", node,
                           f"{root}.{name} of a Python literal inside a "
                           "nested (traced) function; hoist the table to "
                           "spec-compile time")
            if root in ("np", "numpy"):
                self._emit("nested-numpy", node,
                           f"numpy call {root}.{name} inside a nested "
                           "(traced) function body; NumPy bakes into "
                           "trace-time constants -- compute it at "
                           "spec-compile time instead")
        self.generic_visit(node)


def lint_source(text: str, path: str = "<string>") -> list:
    """Lint one module's source; returns a list of :class:`Finding`."""
    norm = path.replace("\\", "/")
    in_dist = "/dist/" in norm or norm.startswith("dist/")
    tree = ast.parse(text, filename=path)
    linter = _Linter(norm, in_dist)
    linter.visit(tree)
    return linter.findings


def lint_paths(paths) -> list:
    """Lint every ``.py`` file under the given files/directories."""
    findings: list = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST repo lint: spec-construction, axis-name and "
                    "traced-body hygiene (see module docstring).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for r in RULES:
            print(r)
        return 0
    findings = lint_paths(args.paths)
    for f in findings:
        print(f)
    if findings:
        print(f"\n{len(findings)} lint finding(s)")
        return 1
    print("repo lint clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
