"""End-to-end training driver.

Composes: config -> model -> sharded train step (gspmd | edst | psum_dp
gradient sync) -> deterministic data stream -> checkpoint/restart -> fault
events.  Runs on whatever devices exist (CPU smoke: --mesh 1,1); the
production launch passes --mesh 16,16 (or 2,16,16 with pod axis) on real
slices.

    python -m repro.launch.train --arch smollm-135m --steps 300 \
        --batch 8 --seq 256 --mesh 1,1 --sync edst --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import (latest_step, restore, restore_sharded,
                        save_checkpoint, save_sharded_checkpoint)
from repro.core.collectives import owner_element_map
from repro.data import SyntheticLMStream
from repro.dist import sharding as shd
from repro.dist.steps import dp_size, edst_spec_for_mesh, make_train_step
from repro.models.api import build
from repro.optim import AdamW, ShardedAdamW, cosine_schedule
from repro.optim.adamw import OptState


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split(","))
    names = ("pod", "data", "model")[-len(dims):]
    return dims, names


def _save(args, step, params, opt_state, zmap):
    if args.zero1:
        psize = sum(int(np.prod(p.shape, dtype=np.int64))
                    for p in jax.tree.leaves(params))
        save_sharded_checkpoint(args.ckpt_dir, step, params, opt_state,
                                zmap, psize)
    else:
        save_checkpoint(args.ckpt_dir, step, {"p": params, "o": opt_state})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--sync", default="gspmd",
                    choices=["gspmd", "edst", "psum_dp"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quantize-grads", action="store_true")
    ap.add_argument("--edst-engine", default="pipelined",
                    choices=["pipelined", "striped", "fused"],
                    help="compiled allreduce form for --sync edst")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: reduce-scatter grads, owner-stripe "
                         "AdamW, allgather params (forces --sync edst "
                         "--edst-engine striped)")
    ap.add_argument("--recover", action="store_true",
                    help="close the fault loop (--sync edst): heartbeat-"
                         "probe the fabric each step, feed step-time and "
                         "gradient-checksum telemetry to the recovery "
                         "controller, and recover in place -- retry on "
                         "flaps, schedule-id flip on link kills, "
                         "background rebuild + hot-swap on bursts; node "
                         "loss checkpoints and exits (rescale by "
                         "relaunching on the surviving mesh)")
    ap.add_argument("--trace-out", default=None,
                    help="write a predicted Perfetto trace (Chrome trace "
                         "event JSON) of the compiled sync program at this "
                         "run's gradient payload size before training "
                         "starts (--sync edst; with --recover the whole "
                         "fault-runtime entry table is rendered)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a JAX profiler trace of the training "
                         "loop into DIR; the executors' edst/t*/w*/op "
                         "named scopes label every sync wave in the "
                         "timeline")
    ap.add_argument("--metrics-out", default=None,
                    help="dump the telemetry metrics registry (JSON) at "
                         "the end of the run")
    ap.add_argument("--journal-out", default=None,
                    help="append the recovery journal to this JSONL file "
                         "as transitions happen (--recover)")
    args = ap.parse_args(argv)
    if args.zero1:
        args.sync, args.edst_engine = "edst", "striped"
    if args.recover and (args.sync != "edst" or args.zero1):
        ap.error("--recover requires --sync edst without --zero1 (the "
                 "zero1 recovery loop lives in benchmarks/chaos_soak.py)")

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    dims, names = parse_mesh(args.mesh)
    mesh = jax.make_mesh(dims, names)
    opt = AdamW(cosine_schedule(args.lr, args.warmup, args.steps))

    key = jax.random.PRNGKey(args.seed)
    with jax.set_mesh(mesh):
        params, axes = api.init(key)
        pshard = shd.tree_shardings(axes, params, mesh)
        params = jax.tree.map(jax.device_put, params, pshard)
        zspec = zmap = None
        if args.zero1:
            zspec = edst_spec_for_mesh(dims, names, engine="striped")
            psize = sum(int(np.prod(p.shape, dtype=np.int64))
                        for p in jax.tree.leaves(params))
            zmap = owner_element_map(zspec, psize)
            opt_state = ShardedAdamW(opt).init_for(
                params, zspec, dp_size(mesh))
            opt_state = jax.tree.map(
                jax.device_put, opt_state,
                shd.zero1_state_shardings(opt_state, mesh))
        else:
            opt_state = opt.init(params)

        runtime = monitor = ctrl = None
        if args.recover and dp_size(mesh) > 1:
            from repro.dist.health import HealthMonitor
            from repro.dist.recovery import RecoveryController
            from repro.dist.steps import fault_runtime_for_mesh
            runtime = fault_runtime_for_mesh(dims, names,
                                             engine=args.edst_engine)
            monitor = HealthMonitor(mesh, runtime)
            ctrl = RecoveryController(runtime, journal_path=args.journal_out)

        step_fn = make_train_step(api, opt, mesh, mode=args.sync,
                                  quantize=args.quantize_grads,
                                  engine=args.edst_engine,
                                  zero1=args.zero1,
                                  fault_runtime=runtime,
                                  telemetry=runtime is not None)
        # rollback on a suspect step needs the pre-step buffers alive
        donate = () if ctrl is not None else (0, 1)
        jstep = jax.jit(step_fn, donate_argnums=donate)

        if args.trace_out:
            if args.sync != "edst" or dp_size(mesh) < 2:
                print("[train] --trace-out skipped: no compiled EDST sync "
                      "program on this mesh/sync mode")
            else:
                from repro.telemetry import trace as ttrace
                psize = sum(int(np.prod(p.shape, dtype=np.int64))
                            for p in jax.tree.leaves(params))
                if runtime is not None:
                    tr = ttrace.trace_runtime(runtime, nbytes=4 * psize)
                else:
                    spec = (zspec if zspec is not None else
                            edst_spec_for_mesh(dims, names,
                                               engine=args.edst_engine))
                    tr = ttrace.trace_spec(spec, nbytes=4 * psize,
                                           label=f"edst/{args.edst_engine}")
                ttrace.write_trace(args.trace_out, tr)
                print(f"[train] predicted sync trace -> {args.trace_out}")

        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            if args.zero1:
                params, opt_state, start, extra = restore_sharded(
                    args.ckpt_dir, params, zmap,
                    state_shardings=shd.zero1_state_shardings(
                        opt_state, mesh))
            else:
                state, start, extra = restore(args.ckpt_dir,
                                              {"p": params, "o": opt_state})
                params, opt_state = state["p"], state["o"]
            print(f"[train] resumed from step {start}")

        stream = SyntheticLMStream(cfg.vocab, args.seq, args.batch,
                                   seed=args.seed)
        from repro.telemetry import metrics as tmetrics
        steps_total = tmetrics.counter(
            "edst_train_steps_total", "optimizer steps committed, by sync mode")
        if args.profile_dir:
            jax.profiler.start_trace(args.profile_dir)
        t0 = time.time()
        losses = []
        step = start
        while step < args.steps:
            batch = {"tokens": jnp.asarray(stream.batch(step))}
            if ctrl is not None:
                snapshot = (params, opt_state)
                t1 = time.time()
                params, opt_state, metrics = jstep(
                    params, opt_state, batch, jnp.int32(ctrl.schedule_id))
                loss = float(metrics["loss"])   # blocks: dt is the real step
                report = monitor.check(
                    step, step_time=time.time() - t1,
                    checksum_dev=float(metrics.get("sync_dev", 0.0)))
                dec = ctrl.observe(report)
                if dec.action == "rescale" and ctrl.state == "stalled":
                    # a lost node needs a NEW process mesh: checkpoint and
                    # hand off to repro.launch.elastic on the survivors
                    params, opt_state = snapshot
                    if args.ckpt_dir:
                        _save(args, step, params, opt_state, zmap)
                    print(f"[train] node loss at step {step} "
                          f"({dec.detail.get('nodes')}); checkpoint saved "
                          "-- relaunch on the surviving mesh "
                          "(repro.launch.elastic)")
                    break
                if dec.action != "none":
                    # the step ran over suspect fabric: discard and redo
                    # after recovery (flip / hot-swap / backoff)
                    params, opt_state = snapshot
                    print(f"[train] step {step}: {dec.action} "
                          f"(schedule {dec.schedule_id}) {dec.detail}")
                    if dec.runtime_changed:
                        from repro.dist.health import HealthMonitor
                        step_fn = make_train_step(
                            api, opt, mesh, mode=args.sync,
                            quantize=args.quantize_grads,
                            engine=args.edst_engine,
                            fault_runtime=ctrl.runtime, telemetry=True)
                        jstep = jax.jit(step_fn)
                        monitor = HealthMonitor(mesh, ctrl.runtime,
                                                straggler=monitor.straggler)
                    if dec.backoff_s:
                        time.sleep(dec.backoff_s)
                    continue
            else:
                params, opt_state, metrics = jstep(params, opt_state, batch)
                loss = float(metrics["loss"])
            losses.append(loss)
            steps_total.inc(mode=args.sync)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                _save(args, step + 1, params, opt_state, zmap)
            step += 1
        if args.profile_dir:
            jax.profiler.stop_trace()
            print(f"[train] profiler trace -> {args.profile_dir}")
        if args.metrics_out:
            tmetrics.REGISTRY.dump_json(args.metrics_out)
            print(f"[train] metrics -> {args.metrics_out}")
        if ctrl is not None and ctrl.journal:
            print(f"[train] recovery journal ({len(ctrl.journal)} entries):")
            for row in ctrl.journal_rows():
                print(f"[train]   {json.dumps(row)}")
        if args.ckpt_dir:
            _save(args, args.steps, params, opt_state, zmap)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
