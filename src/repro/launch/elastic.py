"""Elastic rescaling: resume a checkpoint onto a DIFFERENT mesh and rebuild
the EDST collective schedule for the new fabric.

The two halves of elasticity here:
  * parameters/optimizer state: checkpoints store fully-gathered host
    arrays; ``restore`` re-places them with the *new* mesh's shardings
    (logical shapes are mesh-independent, so any mesh whose divisibility
    rules accept the shapes works);
  * collectives: the EDST packing is a function of the device fabric, so a
    changed fabric (fewer pods, a resized data axis, a failed chip excluded)
    gets a fresh maximal packing via the paper's constructions (or
    Roskind-Tarjan on an irregular residual fabric).

    python -m repro.launch.elastic --ckpt-dir /tmp/ck \
        --from-mesh 4,4 --to-mesh 2,8 --arch smollm-135m --reduced
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.ckpt import latest_step, restore
from repro.dist import sharding as shd
from repro.dist.steps import dp_axes_of, edst_spec_for_mesh
from repro.models.api import build
from repro.optim import AdamW, cosine_schedule


def reshard_checkpoint(api, opt, ckpt_dir: str, mesh):
    """Load the latest checkpoint and place it on ``mesh``.  Returns
    (params, opt_state, step)."""
    key = jax.random.PRNGKey(0)
    with jax.set_mesh(mesh):
        params, axes = api.init(key)
        opt_state = opt.init(params)
        pshard = shd.tree_shardings(axes, params, mesh)
        oshard = type(opt_state)(
            jax.tree.map(lambda _: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), opt_state.step),
            pshard, pshard)
        state, step, _ = restore(ckpt_dir, {"p": params, "o": opt_state},
                                 shardings={"p": pshard, "o": oshard})
    if state is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    return state["p"], state["o"], step


def rebuild_schedule(mesh, dp_torus_shape=None):
    """Fresh EDST allreduce spec for the (possibly new) DP fabric, or None
    when the mesh has no DP extent (single data shard: nothing to sync)."""
    from repro.dist.steps import dp_size
    if dp_size(mesh) <= 1:
        return None
    return edst_spec_for_mesh(tuple(mesh.devices.shape),
                              tuple(mesh.axis_names), dp_torus_shape)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--to-mesh", required=True)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    dims = tuple(int(x) for x in args.to_mesh.split(","))
    names = ("pod", "data", "model")[-len(dims):]
    mesh = jax.make_mesh(dims, names)
    opt = AdamW(cosine_schedule(3e-4, 10, 100))
    params, opt_state, step = reshard_checkpoint(api, opt, args.ckpt_dir, mesh)
    spec = rebuild_schedule(mesh)
    k = spec.k if spec is not None else 0
    print(f"[elastic] resumed step {step} onto mesh {dims}; "
          f"EDST schedule rebuilt with k={k} trees")
    return params, opt_state, step


if __name__ == "__main__":
    main()
