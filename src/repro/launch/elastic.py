"""Elastic rescaling + failure drills: resume a checkpoint onto a DIFFERENT
mesh, rebuild the EDST collective schedule for the new fabric, and exercise
the precompiled failure-class schedules end to end.

The two halves of elasticity here:
  * parameters/optimizer state: checkpoints store fully-gathered host
    arrays; ``restore`` re-places them with the *new* mesh's shardings
    (logical shapes are mesh-independent, so any mesh whose divisibility
    rules accept the shapes works);
  * collectives: the EDST packing is a function of the device fabric, so a
    changed fabric (fewer pods, a resized data axis, a failed chip excluded)
    gets a fresh maximal packing via the paper's constructions (or
    Roskind-Tarjan on an irregular residual fabric).

``failure_drill`` is the third half :-) -- the driver-side loop for
:mod:`repro.dist.fault`: inject link failures into the DP fabric, pick the
recovery schedule (a scalar id flip, no retrace), verify every chosen
program with the packet-level simulator, and report effective allreduce
bandwidth before/after each event and after the Roskind-Tarjan rebuild.

    python -m repro.launch.elastic --ckpt-dir /tmp/ck \
        --from-mesh 4,4 --to-mesh 2,8 --arch smollm-135m --reduced
    python -m repro.launch.elastic --failure-drill --to-mesh 4,4 --events 3
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.ckpt import latest_step, restore
from repro.core.collectives import CostModel
from repro.core.edst_rt import max_edsts
from repro.core.fault import FailureEvent
from repro.core.graph import Graph
from repro.dist import sharding as shd
from repro.dist.chaos import out_of_class_burst
from repro.dist.fault import FaultAwareAllreduce, NoScheduleError
from repro.dist.steps import (dp_axes_of, edst_spec_for_mesh,
                              fault_runtime_for_mesh)
from repro.models.api import build
from repro.optim import AdamW, cosine_schedule


def reshard_checkpoint(api, opt, ckpt_dir: str, mesh):
    """Load the latest checkpoint and place it on ``mesh``.  Returns
    (params, opt_state, step)."""
    key = jax.random.PRNGKey(0)
    with jax.set_mesh(mesh):
        params, axes = api.init(key)
        opt_state = opt.init(params)
        pshard = shd.tree_shardings(axes, params, mesh)
        oshard = type(opt_state)(
            jax.tree.map(lambda _: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), opt_state.step),
            pshard, pshard)
        state, step, _ = restore(ckpt_dir, {"p": params, "o": opt_state},
                                 shardings={"p": pshard, "o": oshard})
    if state is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    return state["p"], state["o"], step


def rebuild_schedule(mesh, dp_torus_shape=None, engine: str = "pipelined",
                     schedule: str = "greedy"):
    """EDST allreduce spec for the (possibly new) DP fabric, or None when
    the mesh has no DP extent (single data shard: nothing to sync).
    Rescales that land on an already-compiled fabric hit the spec caches
    (``edst_spec_for_mesh`` memoizes per (topology, axes, engine,
    schedule), the spec compilers per schedule key) and return the
    IDENTICAL spec object -- a jitted executor taking the spec statically
    never retraces.  ``schedule="composed"`` routes through the
    compositional product-schedule compiler, whose caches key on
    ``StarProduct.cache_key()``."""
    from repro.dist.steps import dp_size
    if dp_size(mesh) <= 1:
        return None
    return edst_spec_for_mesh(tuple(mesh.devices.shape),
                              tuple(mesh.axis_names), dp_torus_shape,
                              engine=engine, schedule=schedule)


# Surviving-fabric runtimes, keyed by (n, surviving edge set, axes,
# engine): a drill (or a flapping node) that lands on an already-seen
# residual fabric reuses the runtime's entries -- every entry spec is the
# identical object, so nothing downstream retraces -- instead of
# re-running Roskind-Tarjan and 2k+1 spec compiles per event.
_RESCALE_CACHE: dict = {}


def rescale_after_node_loss(runtime, event: FailureEvent,
                            ) -> tuple:
    """Elastic node-loss recovery: drop the dead nodes entirely, relabel
    the surviving chips 0..n'-1, repack a maximal EDST set on the
    residual fabric (Roskind-Tarjan), and build a fresh
    :class:`repro.dist.fault.FaultAwareAllreduce` for it.  Returns
    ``(new_runtime, relabel)`` where ``relabel[old_vertex] == new_vertex``
    for every survivor -- the map drivers use to re-place per-rank state
    (the same relabeling ``repro.core.fault`` applies internally).
    Raises :class:`NoScheduleError` when the survivors are disconnected.

    Repeat rescales onto the same surviving fabric are served from
    ``_RESCALE_CACHE``: the returned runtime shares the cached entries
    (and jitted reshard gathers) object-for-object, with only the
    history fresh.
    """
    dead = event.dead_links(runtime.graph)
    residual = runtime.graph.without_edges(dead)
    alive = [v for v in range(runtime.graph.n) if v not in event.nodes]
    relabel = {v: i for i, v in enumerate(alive)}
    sub = Graph(len(alive),
                {(relabel[u], relabel[v]) for u, v in residual.edges
                 if u in relabel and v in relabel}, name="rescaled")
    if not sub.is_connected():
        raise NoScheduleError(
            f"surviving fabric ({len(alive)} nodes) disconnected; "
            "cannot rescale")
    key = (sub.n, frozenset(sub.edges), runtime.axes, runtime.engine)
    base = _RESCALE_CACHE.get(key)
    if base is None:
        trees, _ = max_edsts(sub)
        if not trees:
            raise NoScheduleError("surviving fabric packs no spanning tree")
        base = FaultAwareAllreduce.build(sub, trees, runtime.axes,
                                         engine=runtime.engine)
        _RESCALE_CACHE[key] = base
    new_rt = FaultAwareAllreduce(base.graph, base.axes, base.entries,
                                 engine=base.engine,
                                 _reshard_cache=base._reshard_cache)
    new_rt.history = runtime.history + [("rescaled", len(alive))]
    return new_rt, relabel


def failure_drill(runtime, n_events: int = 3, nbytes: float = 64 << 20,
                  seed: int = 0, cost_model: CostModel | None = None,
                  kinds=("link",)) -> dict:
    """Inject ``n_events`` seeded failures into the fabric (cycling
    through ``kinds``), observe the runtime's recovery choice after each,
    and report effective bandwidth: healthy -> recovered per event.

      * ``"link"``  -- a single-link kill: recovery is a precompiled
        schedule-id flip (``on_failure``), falling back to a dynamic
        repack only if no class survives;
      * ``"burst"`` -- an out-of-class multi-link burst (grown with
        :func:`repro.dist.chaos.out_of_class_burst` until no precompiled
        class survives), forcing the ``with_rebuild`` Roskind-Tarjan
        path;
      * ``"node"``  -- a node loss: checkpointless here, exercising
        :func:`rescale_after_node_loss` (relabel survivors + repack).
        The rescaled fabric has fewer chips, so its ``bw_retained`` is
        relative to a *different* healthy baseline and may exceed 1.

    Events are independent -- each is injected into the healthy runtime.
    Each chosen schedule is validated with the packet-level simulator
    (``repro.core.collectives.simulate_allreduce``), so the drill runs on
    any host -- no devices needed; the shard_map execution path of the same
    programs is covered by tests/test_fault_runtime_jax.py and the chaos
    soak (benchmarks/chaos_soak.py).
    """
    cm = cost_model or CostModel()
    rng = np.random.RandomState(seed)
    healthy_bw = runtime.effective_bandwidth(nbytes, 0, cm)
    report = {"n": runtime.graph.n, "k": runtime.k, "nbytes": nbytes,
              "healthy_gbps": round(healthy_bw / 1e9, 3), "events": []}
    tree_links = sorted(set().union(
        *(ts.tree for ts in runtime.entries[0].sched.trees)))
    for i in range(n_events):
        kind = kinds[i % len(kinds)]
        if kind == "link":
            link = tree_links[rng.randint(len(tree_links))]
            event = FailureEvent(links=frozenset({link}))
            rec = {"event": i, "kind": "link", "dead_link": list(link)}
            try:
                rt = runtime.on_failure(event)      # precompiled: id flip only
                deg = runtime.on_failure(event, prefer="degraded")
                rec.update({
                    "schedule": rt.entry.name, "schedule_id": rt.active,
                    "k": rt.entry.k,
                    "depth": rt.entry.depth,
                    "sim_ok": rt.verify_entry(rt.active),
                    "gbps": round(rt.effective_bandwidth(nbytes, rt.active,
                                                         cm) / 1e9, 3),
                    "degraded_gbps": round(
                        deg.effective_bandwidth(nbytes, deg.active, cm)
                        / 1e9, 3),
                })
            except NoScheduleError:                 # dynamic repack
                rt = runtime.with_rebuild(event)
                rec.update({
                    "schedule": "with_rebuild", "schedule_id": 0, "k": rt.k,
                    "depth": rt.entry.depth,
                    "sim_ok": rt.verify_entry(0),
                    "gbps": round(rt.effective_bandwidth(nbytes, 0, cm)
                                  / 1e9, 3),
                })
        elif kind == "burst":
            burst = out_of_class_burst(runtime,
                                       np.random.default_rng(seed + i))
            event = FailureEvent(links=frozenset(burst))
            assert not runtime.valid_ids(event)
            rt = runtime.with_rebuild(event)
            rec = {"event": i, "kind": "burst",
                   "dead_links": sorted(list(e) for e in burst),
                   "schedule": "with_rebuild", "schedule_id": 0, "k": rt.k,
                   "depth": rt.entry.depth,
                   "sim_ok": rt.verify_entry(0),
                   "gbps": round(rt.effective_bandwidth(nbytes, 0, cm)
                                 / 1e9, 3)}
        elif kind == "node":
            v = int(rng.randint(runtime.graph.n))
            event = FailureEvent(nodes=frozenset({v}))
            rt, relabel = rescale_after_node_loss(runtime, event)
            rec = {"event": i, "kind": "node", "dead_node": v,
                   "schedule": "rescale", "schedule_id": 0,
                   "n_after": rt.graph.n, "k": rt.k,
                   "depth": rt.entry.depth,
                   "sim_ok": rt.verify_entry(0),
                   "gbps": round(rt.effective_bandwidth(nbytes, 0, cm)
                                 / 1e9, 3)}
        else:
            raise ValueError(f"unknown drill kind {kind!r} "
                             "(not in ('link', 'burst', 'node'))")
        rec["bw_retained"] = round(rec["gbps"] * 1e9 / healthy_bw, 3)
        report["events"].append(rec)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--to-mesh", required=True)
    ap.add_argument("--failure-drill", action="store_true",
                    help="no checkpoint: build the elastic EDST runtime for "
                         "the DP fabric of --to-mesh, inject failures, "
                         "report recovery + bandwidth as JSON")
    ap.add_argument("--events", type=int, default=3)
    ap.add_argument("--nbytes", type=int, default=64 << 20)
    ap.add_argument("--drill-kinds", default="link,burst,node",
                    help="comma list of failure kinds the drill cycles "
                         "through: link (schedule flip), burst "
                         "(out-of-class with_rebuild), node (elastic "
                         "rescale)")
    args = ap.parse_args(argv)

    if args.failure_drill:
        dims = tuple(int(x) for x in args.to_mesh.split(","))
        runtime = fault_runtime_for_mesh((int(np.prod(dims)), 1),
                                         ("data", "model"),
                                         dp_torus_shape=dims)
        report = failure_drill(runtime, n_events=args.events,
                               nbytes=args.nbytes,
                               kinds=tuple(args.drill_kinds.split(",")))
        print(json.dumps(report, indent=2))
        return report

    if args.ckpt_dir is None:
        ap.error("--ckpt-dir is required unless --failure-drill")
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    dims = tuple(int(x) for x in args.to_mesh.split(","))
    names = ("pod", "data", "model")[-len(dims):]
    mesh = jax.make_mesh(dims, names)
    opt = AdamW(cosine_schedule(3e-4, 10, 100))
    params, opt_state, step = reshard_checkpoint(api, opt, args.ckpt_dir, mesh)
    spec = rebuild_schedule(mesh)
    k = spec.k if spec is not None else 0
    print(f"[elastic] resumed step {step} onto mesh {dims}; "
          f"EDST schedule rebuilt with k={k} trees")
    return params, opt_state, step


if __name__ == "__main__":
    main()
