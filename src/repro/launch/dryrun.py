import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with ShapeDtypeStruct stand-ins (no allocation).

For each cell this prints/records:
  * compiled.memory_analysis()  -- proves the cell fits per-device HBM;
  * compiled.cost_analysis()    -- HLO FLOPs / bytes for the roofline;
  * collective-op bytes parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute) -- the
    roofline's collective term.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import sharding as shd
from repro.dist.steps import make_train_step
from repro.launch.mesh import make_production_mesh
from repro.models.api import build
from repro.optim import AdamW, cosine_schedule

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(?:-start|-done)?\(", rhs):
                op = c
                break
        if op is None or f"{op}-done(" in rhs:
            continue  # count the -start, skip the -done (same buffer)
        head = rhs.split(f" {op}", 1)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(head):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _shapes_and_axes(fn, *args):
    """eval_shape that also captures the (static) logical-axes side output."""
    box = {}

    def wrapper(*a):
        out, axes = fn(*a)
        box["axes"] = axes
        return out

    shapes = jax.eval_shape(wrapper, *args)
    return shapes, box["axes"]


def build_cell(arch: str, shape_name: str, mesh, sync_mode: str = "gspmd",
               fsdp: bool = True, cfg_overrides: dict | None = None):
    """Returns (step_fn, in_shapes tuple, in_shardings tuple)."""
    import dataclasses
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = cfg.shape(shape_name)
    if shape.kind != "train" and cfg.serve_q_block and not cfg_overrides:
        # serve-time attention blocks (§Perf hillclimb 1)
        cfg = dataclasses.replace(cfg, q_block=cfg.serve_q_block,
                                  kv_block=cfg.serve_kv_block)
    if shape.kind == "decode" and shape.global_batch >= 16:
        # weights stay TP-resident at serve time (§Perf hillclimb 2).
        # batch-1 ultra-long decode is the exception: it streams the whole
        # weight shard per token, so ZeRO-3 sharding (smaller local reads +
        # gather) wins -- measured on rwkv6 long_500k (12x memory-term hit
        # with resident weights).
        fsdp = False
    api = build(cfg)
    key = jax.random.PRNGKey(0)

    pshapes, paxes = _shapes_and_axes(lambda k: api.init(k), key)
    pshard = shd.tree_shardings(paxes, pshapes, mesh, fsdp=fsdp)

    batch_shapes = api.input_specs(shape)
    batch_axes = api.batch_axes(shape)
    bshard = {k: jax.sharding.NamedSharding(
                  mesh, shd.spec_for(batch_axes[k], v.shape, mesh, fsdp=False))
              for k, v in batch_shapes.items()}

    if shape.kind == "train":
        from repro.optim.adamw import OptState
        opt = AdamW(cosine_schedule(3e-4, 100, 10_000))
        oshapes = jax.eval_shape(opt.init, pshapes)
        oshard = OptState(jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), pshard, pshard)
        step_fn = make_train_step(api, opt, mesh, mode=sync_mode, fsdp=fsdp)
        return step_fn, (pshapes, oshapes, batch_shapes), \
            (pshard, oshard, bshard)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return api.prefill_fn(params, batch)
        return prefill_step, (pshapes, batch_shapes), (pshard, bshard)

    # decode
    cshapes, caxes = _shapes_and_axes(
        lambda: api.init_cache(shape.global_batch, shape.seq_len))
    cshard = shd.tree_shardings(caxes, cshapes, mesh, fsdp=False)

    def decode_step(params, caches, batch):
        return api.decode_fn(params, caches, batch)

    return decode_step, (pshapes, cshapes, batch_shapes), \
        (pshard, cshard, bshard)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sync_mode: str = "gspmd", fsdp: bool = True,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step_fn, in_shapes, in_shardings = build_cell(arch, shape_name, mesh,
                                                  sync_mode, fsdp)
    with jax.set_mesh(mesh):
        jitted = jax.jit(step_fn, in_shardings=in_shardings)
        lowered = jitted.lower(*in_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    from repro.analysis.hlo import analyze_hlo
    loop_aware = analyze_hlo(hlo_text)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "sync": sync_mode, "fsdp": fsdp,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "loop_aware": {
            "dot_flops": loop_aware.dot_flops,
            "bytes_touched": loop_aware.bytes_touched,
            "collective_bytes": loop_aware.collective_bytes,
            "collective_counts": loop_aware.collective_counts,
            "total_collective_bytes": loop_aware.total_collective_bytes,
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {result['mesh']} "
              f"({sync_mode}): OK  lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e}")
        print(f"  collective bytes: {coll['total_bytes']:.3e} "
              f"{coll['counts']}")
    return result


def iter_cells():
    for name, cfg in configs.ARCHS.items():
        for shape in configs.LM_SHAPES:
            if shape.name in cfg.skip_shapes:
                yield name, shape.name, True
            else:
                yield name, shape.name, False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sync", default="gspmd",
                    choices=["gspmd", "edst", "psum_dp"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = []
    if args.all:
        cells = list(iter_cells())
    else:
        cells = [(args.arch, args.shape, False)]
    for arch, shape_name, skipped in cells:
        if skipped:
            cfg = configs.get(arch)
            results.append({"arch": arch, "shape": shape_name,
                            "mesh": "2x16x16" if args.multi_pod else "16x16",
                            "skipped": True, "reason": cfg.skip_reason})
            print(f"[dryrun] {arch} x {shape_name}: SKIP ({cfg.skip_reason})")
            continue
        try:
            results.append(run_cell(arch, shape_name, args.multi_pod,
                                    args.sync, not args.no_fsdp))
        except Exception as e:  # noqa: BLE001 -- report and continue the sweep
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape_name,
                            "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    failed = [r for r in results if "error" in r]
    print(f"[dryrun] done: {len(results) - len(failed)}/{len(results)} OK")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
