"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.  Callers (dryrun.py) set XLA_FLAGS for placeholder devices
*before* importing jax.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) over 256 chips (a v5e pod's 16x16
    torus).  Multi-pod: (pod=2, data=16, model=16) over 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))
