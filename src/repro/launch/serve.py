"""Batched serving driver: prefill a batch of prompts, then decode with the
KV cache (the serve_step exercised by the decode_* dry-run cells).

CPU-sized demo:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.api import build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family not in ("lm", "moe", "rglru", "rwkv6"):
        raise SystemExit(f"serve demo supports decoder-only archs, not {cfg.family}")
    api = build(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = api.init(key)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    if cfg.family in ("lm", "moe"):
        from repro.models import transformer as T
        logits, caches = T.prefill(cfg, params, prompts, max_len)
        decode = jax.jit(lambda p, c, tok, n: T.decode_step(cfg, p, c, tok, n))
    elif cfg.family == "rglru":
        from repro.models import rglru as G
        logits, caches = G.prefill(cfg, params, prompts)
        decode = jax.jit(lambda p, c, tok, n: G.decode_step(cfg, p, c, tok, n))
    else:
        from repro.models import rwkv6 as R
        logits, caches = R.prefill(cfg, params, prompts)
        decode = jax.jit(lambda p, c, tok, n: R.decode_step(cfg, p, c, tok, n))
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{time.time() - t0:.2f}s")

    tokens = jnp.argmax(logits[..., : cfg.vocab], axis=-1)[:, None]
    out = [tokens]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, tokens,
                                jnp.int32(args.prompt_len + i))
        tokens = jnp.argmax(logits[..., : cfg.vocab], axis=-1)[:, None]
        out.append(tokens)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen - 1} steps x {args.batch} seqs in "
          f"{dt:.2f}s ({(args.gen - 1) * args.batch / dt:.1f} tok/s)")
    print("[serve] greedy continuations (token ids):")
    for row in gen.tolist():
        print("  ", row)
    return gen


if __name__ == "__main__":
    main()
