"""Distributed execution on EDST fabrics.

Four modules wire the paper's edge-disjoint-spanning-tree constructions
(:mod:`repro.core`) into runnable JAX:

  * :mod:`repro.dist.sharding`       -- logical axis names -> PartitionSpecs
    (tensor-parallel priority rules + FSDP on the largest divisible dim);
  * :mod:`repro.dist.tree_allreduce` -- the k-tree allreduce executed with
    ``ppermute`` under ``shard_map``, gradient chunks striped across the
    edge-disjoint trees;
  * :mod:`repro.dist.striped`        -- first-class tree_reduce_scatter /
    tree_allgather / striped_allreduce collectives: owner stripes per
    vertex, stripe-sized wires instead of full-chunk hops;
  * :mod:`repro.dist.steps`          -- sharded train steps with selectable
    gradient sync (gspmd | psum_dp | edst), the mesh -> star-product
    decomposition chooser, and the ZeRO-1 path (``zero1=True``:
    reduce-scatter grads -> owner-stripe AdamW -> allgather params);
  * :mod:`repro.dist.pipeline`       -- GPipe microbatch schedule over a
    'stage' mesh axis;
  * :mod:`repro.dist.fault`          -- elastic EDST runtime: precompiled
    degraded/rebuilt schedules per failure class, switched by a traced
    schedule id without retracing.

See README.md in this directory for the data flow.
"""
from . import compat as _compat

_compat.install()

from . import (fault, pipeline, sharding, steps,  # noqa: E402
               striped, tree_allreduce)

__all__ = ["sharding", "steps", "striped", "tree_allreduce", "pipeline",
           "fault"]
