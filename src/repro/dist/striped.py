"""Striped EDST collectives: reduce-scatter, allgather, and the composed
bandwidth-optimal allreduce, executed with ``ppermute`` under ``shard_map``.

The engines in :mod:`repro.dist.tree_allreduce` ship the full m-sized
chunk along every tree edge.  This module executes the
:class:`repro.core.collectives.StripedCollectiveSpec` program instead:
each vertex owns one stripe of every tree's chunk (DFS-preorder slots,
largest-remainder ``chunk_sizes`` widths), reduce-scatter waves move
partial sums so every edge carries only the stripes owned on the far
side of it, and allgather waves fan the finished stripes back out as a
pure gather.  Per-wave wire bytes drop from ``m`` to
``ceil(m/n) * slots-in-window`` at roughly twice the wave count -- the
win on bandwidth-dominated fabrics, the loss on alpha-dominated hosts
(see the engine-selection matrix in ``src/repro/dist/README.md``).

Execution model: state is the ``(k, mrow)`` stack of padded chunk rows.
Every window is one *circular* interval of a row (the preorder trick:
a subtree and its complement are both contiguous mod n), so a wave needs
only ``(n,)``-shaped offset/length tables -- a sender rolls its row and
slices the wave's wire width, a receiver rolls the zero-padded arrival
back into place and either accumulates (reduce-scatter) or overwrites
(allgather) under a circular mask.  Weighted fractions reuse the SAME
slot->offset table over the padded width ``mrow``: padding elements are
zero everywhere, so reducing and gathering them is harmless, and
degraded (k-1)-striping shares the healthy program's wave structure.

With ``quantize=True`` reduce-scatter hops obey the ``codec`` policy
(int8 wire via the Pallas codec in ``repro.kernels.tree_combine``, one
collective per hop) and allgather hops always take the int8 wire when
the codec is enabled -- each hop re-codes, since unlike the broadcast
phase of the chunk engines the gathered windows differ hop to hop.

Everything this executor relies on -- op-homogeneous ppermute-legal
waves, window/tree agreement between sender and receiver, circular
complement of below/above windows, child-window nesting, RS-then-AG
happens-before -- is provable from the spec's tables alone and IS
proved, statically, by :mod:`repro.analysis.verify` (see the "Static
invariants" section of ``src/repro/dist/README.md``); spec compilation
already ran the cheap tier via ``verify_compiled_spec``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.collectives import (StripedCollectiveSpec, REDUCE,
                                striped_tables)
from .tree_allreduce import (_FLOATS, _REDUCE_WIRE, _axis_arg, _gather,
                             _note_trace, _rows_of, _rows_out, _scope,
                             _send, resolve_codec)


def _normalize(fractions):
    return None if fractions is None else tuple(fractions)


def _wires(quantize: bool, codec, dtype) -> tuple:
    """(reduce-scatter wire, allgather wire) for the codec policy."""
    codec = resolve_codec(codec) if quantize else "off"
    if dtype not in _FLOATS:
        codec = "off"       # integer payloads always travel verbatim
    return _REDUCE_WIRE[codec], ("q8" if codec != "off" else None)


def _rows_in(flat, sizes, mrow):
    """Stack the per-tree chunk slices into the padded (k, mrow) state
    (the shared ``_rows_of`` splitter from ``tree_allreduce``)."""
    return jnp.stack(_rows_of(flat, len(sizes), sizes, mrow))


def _run_wave(state, bw, idx, axis, rs_wire, ag_wire):
    """Execute ONE bound striped wave on the (k, mrow) state.

    Non-senders compute a (discarded) payload and non-receivers carry a
    zero-length mask, so the whole wave is branch-free; ``ppermute``
    hands devices nobody sent to a zero payload, which the circular mask
    drops anyway.  Split out of :func:`_run_waves` so the instrumented
    wave-by-wave executor (:mod:`repro.telemetry.timing`) can jit and
    time exactly the production wave body."""
    k, mrow = state.shape
    pos = jnp.arange(mrow)
    rows_iota = jnp.arange(k)
    src_tree = _gather(bw.send_tree, idx)
    src_off = _gather(bw.send_off, idx)
    row = jax.lax.dynamic_index_in_dim(state, src_tree, 0,
                                       keepdims=False)
    payload = jnp.roll(row, -src_off)[:bw.wire]
    recv = _send(payload, axis, bw.perm,
                 rs_wire if bw.op == REDUCE else ag_wire)
    roff = _gather(bw.recv_off, idx)
    rlen = _gather(bw.recv_len, idx)
    rtree = _gather(bw.recv_tree, idx)
    full = recv if bw.wire == mrow \
        else jnp.pad(recv, (0, mrow - bw.wire))
    rolled = jnp.roll(full, roff)
    mask = jnp.roll(pos < rlen, roff)      # circular window, len 0 = none
    onehot = rows_iota == rtree
    if bw.op == REDUCE:
        contrib = jnp.where(mask, rolled, jnp.zeros((), rolled.dtype))
        return state + onehot.astype(state.dtype)[:, None] \
            * contrib[None, :]
    sel = onehot[:, None] & mask[None, :]
    return jnp.where(sel, rolled[None, :], state)


def _run_waves(state, waves, idx, axis, rs_wire, ag_wire):
    """Execute bound striped waves on the (k, mrow) state."""
    for w, bw in enumerate(waves):
        op = "rs" if bw.op == REDUCE else "ag"
        with _scope(f"edst/t*/w{w}/{op}"):
            state = _run_wave(state, bw, idx, axis, rs_wire, ag_wire)
    return state


def _prep(x, spec, fractions):
    axis = _axis_arg(spec)
    idx = jax.lax.axis_index(axis)
    flat = x.reshape(-1)
    bound = striped_tables(spec, flat.size, _normalize(fractions))
    return axis, idx, flat, bound


def _cut_own(state, spec, bound, idx):
    """Cut this vertex's own stripe out of every (k, mrow) row (circular
    windows never wrap for a single slot, so one roll + static slice
    suffices); rows are zero-padded to the widest stripe ``smax``."""
    own = []
    for j in range(spec.k):
        off = _gather(bound.own_off[j], idx)
        length = _gather(bound.own_len[j], idx)
        stripe = jnp.roll(state[j], -off)[:bound.smax]
        own.append(jnp.where(jnp.arange(bound.smax) < length, stripe,
                             jnp.zeros((), stripe.dtype)))
    return jnp.stack(own)


def tree_reduce_scatter(x, spec: StripedCollectiveSpec, fractions=None,
                        quantize: bool = False, codec=None):
    """Reduce-scatter of ``x`` over ``spec.axes``: returns the
    ``(k, smax)`` stack of THIS vertex's owner stripes, each row the
    globally-summed stripe of one tree's chunk, zero-padded to the
    widest stripe.  Stripe geometry (offset/width per tree) comes from
    :func:`stripe_layout`.  Must run inside a ``shard_map`` whose manual
    axes include ``spec.axes``."""
    if spec.k == 0 or x.size == 0:
        return x
    axis, idx, flat, bound = _prep(x, spec, fractions)
    rs_wire, _ = _wires(quantize, codec, x.dtype)
    state = _rows_in(flat, bound.sizes, bound.mrow)
    state = _run_waves(state, bound.rs_waves, idx, axis, rs_wire, None)
    return _cut_own(state, spec, bound, idx)


def stripe_slices(x, spec: StripedCollectiveSpec, fractions=None):
    """This vertex's ``(k, smax)`` owner stripes of a REPLICATED array
    ``x`` -- the same cut :func:`tree_reduce_scatter` applies after its
    reduce waves, with zero communication.  The ZeRO-1 train step uses
    it to slice the (replicated) params and weight-decay mask into the
    scattered domain the sharded optimizer updates in.  Must run inside
    a ``shard_map`` whose manual axes include ``spec.axes``."""
    if spec.k == 0 or x.size == 0:
        return x
    _, idx, flat, bound = _prep(x, spec, fractions)
    state = _rows_in(flat, bound.sizes, bound.mrow)
    return _cut_own(state, spec, bound, idx)


def tree_allgather(owned, spec: StripedCollectiveSpec, shape,
                   fractions=None, quantize: bool = False, codec=None):
    """Allgather of owner stripes: the inverse of
    :func:`tree_reduce_scatter`.  ``owned`` is the ``(k, smax)`` stack
    of this vertex's stripes; returns the full ``shape``-d array (every
    stripe of every tree, replicated across the fabric).  Must run
    inside a ``shard_map`` whose manual axes include ``spec.axes``."""
    if spec.k == 0:
        return owned
    size = 1
    for d in shape:
        size *= int(d)
    axis = _axis_arg(spec)
    idx = jax.lax.axis_index(axis)
    bound = striped_tables(spec, size, _normalize(fractions))
    _, ag_wire = _wires(quantize, codec, owned.dtype)
    rows = []
    for j in range(spec.k):
        off = _gather(bound.own_off[j], idx)
        length = _gather(bound.own_len[j], idx)
        stripe = jnp.where(jnp.arange(bound.smax) < length, owned[j],
                           jnp.zeros((), owned.dtype))
        full = stripe if bound.smax == bound.mrow \
            else jnp.pad(stripe, (0, bound.mrow - bound.smax))
        rows.append(jnp.roll(full, off))
    state = jnp.stack(rows)
    state = _run_waves(state, bound.ag_waves, idx, axis, None, ag_wire)
    return _rows_out(state, bound.sizes, size).reshape(shape)


def striped_allreduce(x, spec: StripedCollectiveSpec, quantize: bool = False,
                      fractions=None, codec=None):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``
    as reduce-scatter ∘ allgather on the COMPOSED wave program (one DAG:
    a shallow tree's gather overlaps a deep tree's scatter tail).
    Returns the summed array in the original shape, replicated across
    the fabric.  Must run inside a ``shard_map`` whose manual axes
    include ``spec.axes``."""
    if spec.k == 0 or x.size == 0:
        return x
    if fractions is not None and len(fractions) != spec.k:
        raise ValueError(f"{len(fractions)} fractions for k={spec.k} trees; "
                         "spec and striping must come from the same schedule")
    _note_trace("striped", spec, x,
                codec=(resolve_codec(codec) if quantize else None),
                fractions=fractions)
    shape, dtype = x.shape, x.dtype
    axis, idx, flat, bound = _prep(x, spec, fractions)
    rs_wire, ag_wire = _wires(quantize, codec, dtype)
    state = _rows_in(flat, bound.sizes, bound.mrow)
    state = _run_waves(state, bound.waves, idx, axis, rs_wire, ag_wire)
    return _rows_out(state, bound.sizes, flat.size) \
        .reshape(shape).astype(dtype)


def stripe_layout(spec: StripedCollectiveSpec, size: int, fractions=None):
    """The bound stripe geometry for a payload of ``size`` elements:
    the :class:`repro.core.collectives.StripedTables` whose ``sizes`` /
    ``offsets`` / ``own_off`` / ``own_len`` describe exactly how
    :func:`tree_reduce_scatter` apportions ownership."""
    return striped_tables(spec, size, _normalize(fractions))


def rs_conservation_gap(flat_reduced, owned, axis):
    """In-graph integrity check for the scattered domain (the striped /
    ZeRO-1 engines never replicate, so :func:`repro.dist.health
    .replication_divergence` does not apply): after a reduce-scatter the
    owner stripes across the fabric must partition the reduced vector,
    so the global sum of owned elements must equal the global sum of the
    (per-device mean-contribution) payload.  Returns the RELATIVE gap
    ``|sum(owned) - sum(reduced)| / (|sum(reduced)| + 1)`` -- ~1e-7 of
    float reassociation noise when healthy, O(magnitude) when a wire
    corrupted, duplicated, or dropped a stripe.  Two scalar ``psum``\\ s;
    call it inside the same ``shard_map`` as the reduce-scatter, passing
    ``flat_reduced`` as this device's contribution ALREADY divided by
    the fabric size (so its psum is the reduced vector's sum)."""
    a = jax.lax.psum(jnp.sum(flat_reduced.astype(jnp.float32)), axis)
    b = jax.lax.psum(jnp.sum(owned.astype(jnp.float32)), axis)
    return jnp.abs(b - a) / (jnp.abs(a) + 1.0)
