"""In-graph fault *detection* for the EDST collective engines.

:mod:`repro.dist.fault` can recover from failures it is told about -- a
``FailureEvent`` flips a traced schedule id -- but nothing in the runtime
*produced* those events: the drills injected them by hand.  This module
closes the sensing half of the loop (detect -> classify -> escalate ->
recover -> verify; the escalation ladder lives in
:mod:`repro.dist.recovery`):

  * **link heartbeat probes** -- every directed link any compiled wave
    program uses (extracted from the spec's own routing tables, so the
    probe covers exactly the fabric the collective depends on) is echoed
    with a tiny one-element ``ppermute``.  The sender ships ``rank + 1``;
    the receiver compares against the statically-known expected sender
    (``ppermute`` zero-fills devices nobody sent to, so a dead wire reads
    0 and can never alias a healthy token).  Results scatter into a
    global ``(L,)`` link-OK bitmap shared via ``psum`` -- a handful of
    scalar collectives, cheap enough to run between steps.
  * **payload checksums** -- after a gradient allreduce every replica
    must hold bit-identical sums; :func:`replication_divergence` measures
    the cross-replica spread of a (sum, sum-of-squares) checksum in-graph,
    catching corrupt-wire faults that no schedule switch can see.  The
    striped/ZeRO-1 engines scatter instead of replicate, so their
    integrity check is conservation, not replication -- see
    :func:`repro.dist.striped.rs_conservation_gap`.
  * **straggler detection** -- wall-clock per-step times against a rolling
    median (:class:`StragglerDetector`): a step slower than
    ``ratio x median`` flags a straggling fabric without any schedule
    knowledge.

:class:`HealthMonitor` bundles the three detectors behind one
``check(step, ...)`` call returning a :class:`HealthReport`; the report's
``failed_edges()`` / ``node_suspects()`` are what
:class:`repro.dist.recovery.RecoveryController` classifies into
``FailureEvent``s.  The probe takes a traced ``(L,)`` ``fault_mask`` so
the chaos harness (:mod:`repro.dist.chaos`) can inject wire faults at
the telemetry boundary without retracing -- on a real fabric the mask
stays all-ones and dead wires zero the bitmap by themselves.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..analysis.verify import engine_of
from ..core.graph import canon
from ..telemetry import metrics as _metrics
from .compat import shard_map


# ---------------------------------------------------------------------------
# link extraction: the probe plan is compiled from the routing tables
# ---------------------------------------------------------------------------

def program_links(spec) -> tuple:
    """Sorted directed ``(src, dst)`` links the compiled wave program
    moves payload over, for any engine's spec form.  Read from the same
    routing tables the executors run, so the probe set is exactly the
    fabric surface the collective depends on."""
    eng = engine_of(spec)
    links = set()
    if eng in ("pipelined", "striped"):
        for wv in spec.waves:
            links.update((int(s), int(d)) for s, d in wv.perm)
    elif eng == "fused":
        for rnd in tuple(spec.reduce_rounds) + tuple(spec.bcast_rounds):
            links.update((int(s), int(d)) for s, d in rnd.perm)
    else:  # per_tree
        for tp in spec.trees:
            for perm in tuple(tp.reduce_rounds) + tuple(tp.bcast_rounds):
                links.update((int(s), int(d)) for s, d in perm)
    return tuple(sorted(links))


def runtime_links(runtime) -> tuple:
    """Union of :func:`program_links` over every precompiled failure
    class of a :class:`repro.dist.fault.FaultAwareAllreduce` -- one probe
    plan covers every schedule the runtime can flip to, so probing never
    retraces on failover."""
    links = set()
    for e in runtime.entries:
        if e.k > 0:
            links.update(program_links(e.spec))
    return tuple(sorted(links))


def _pack_probe_waves(links) -> tuple:
    """Greedy split of the directed links into ppermute-legal waves
    (unique sources AND unique destinations per wave)."""
    remaining = list(links)
    waves = []
    while remaining:
        srcs, dsts, take, rest = set(), set(), [], []
        for s, d in remaining:
            if s not in srcs and d not in dsts:
                take.append((s, d))
                srcs.add(s)
                dsts.add(d)
            else:
                rest.append((s, d))
        waves.append(tuple(take))
        remaining = rest
    return tuple(waves)


@dataclass(frozen=True, eq=False)
class LinkProbeSpec:
    """Compiled heartbeat plan: ``links[i]`` is the directed link that
    owns bitmap slot ``i``; each wave carries per-vertex expected-sender
    and slot tables (-1 = this vertex receives nothing that wave)."""
    n: int
    axes: tuple
    links: tuple               # ((src, dst), ...) sorted
    waves: tuple               # tuple[tuple[(src, dst)]], ppermute-legal
    recv_src: tuple            # tuple[np.ndarray (n,)], expected sender
    recv_slot: tuple           # tuple[np.ndarray (n,)], bitmap slot

    @property
    def num_links(self) -> int:
        return len(self.links)


def compile_link_probe(spec_or_runtime) -> LinkProbeSpec:
    """Build the heartbeat plan for a compiled spec or a fault runtime
    (the union of its failure classes -- see :func:`runtime_links`)."""
    if hasattr(spec_or_runtime, "entries"):   # FaultAwareAllreduce
        links = runtime_links(spec_or_runtime)
        n = spec_or_runtime.graph.n
        axes = tuple(spec_or_runtime.axes)
    else:
        links = program_links(spec_or_runtime)
        n = spec_or_runtime.n
        axes = tuple(spec_or_runtime.axes)
    slot = {l: i for i, l in enumerate(links)}
    waves = _pack_probe_waves(links)
    recv_src, recv_slot = [], []
    for wave in waves:
        src = np.full(n, -1, np.int32)
        slt = np.full(n, -1, np.int32)
        for s, d in wave:
            src[d] = s
            slt[d] = slot[(s, d)]
        recv_src.append(src)
        recv_slot.append(slt)
    return LinkProbeSpec(n=n, axes=axes, links=links, waves=waves,
                         recv_src=tuple(recv_src),
                         recv_slot=tuple(recv_slot))


def make_link_probe(spec_or_runtime):
    """``(probe, plan)``: ``probe(fault_mask)`` runs under ``shard_map``
    over the plan's axes and returns the global ``(L,)`` link-OK bitmap
    (1.0 = echo arrived intact).  ``fault_mask`` is a traced ``(L,)``
    vector ANDed onto the receive path -- the chaos injection point; pass
    ones on a real fabric."""
    plan = compile_link_probe(spec_or_runtime)
    axis = plan.axes[0] if len(plan.axes) == 1 else tuple(plan.axes)
    L = plan.num_links

    def probe(fault_mask):
        idx = jax.lax.axis_index(axis)
        token = (idx + 1).astype(jnp.float32)[None]
        # slot L is the spill row for non-receivers (-1 -> L), cut at the end
        bitmap = jnp.zeros(L + 1, jnp.float32)
        for w, wave in enumerate(plan.waves):
            recv = jax.lax.ppermute(token, axis, wave)[0]
            expect = jnp.asarray(plan.recv_src[w])[idx].astype(jnp.float32)
            slot = jnp.asarray(plan.recv_slot[w])[idx]
            ok = jnp.where(slot >= 0, (recv == expect + 1.0), 0.0)
            ok = ok * jnp.where(slot >= 0, fault_mask[jnp.clip(slot, 0)], 0.0)
            bitmap = bitmap.at[jnp.where(slot >= 0, slot, L)].add(
                ok.astype(jnp.float32))
        return jax.lax.psum(bitmap[:L], axis)

    return probe, plan


def mesh_link_probe(mesh, spec_or_runtime):
    """Jitted driver-side heartbeat: returns ``(run, plan)`` where
    ``run(fault_mask=None) -> np.ndarray (L,) of {0., 1.}`` executes the
    probe on ``mesh`` (mask defaults to all-ones)."""
    probe, plan = make_link_probe(spec_or_runtime)
    fn = jax.jit(shard_map(probe, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_rep=False))
    ones = np.ones(plan.num_links, np.float32)

    def run(fault_mask=None):
        mask = ones if fault_mask is None else fault_mask
        return jax.device_get(fn(jnp.asarray(mask, jnp.float32)))

    return run, plan


# ---------------------------------------------------------------------------
# payload checksums (corrupt-wire detection)
# ---------------------------------------------------------------------------

def payload_checksum(x) -> jnp.ndarray:
    """(2,) traced checksum of a payload: (sum, sum of squares) in f32.
    Cheap, order-independent, and any single-element corruption moves at
    least one component."""
    flat = x.astype(jnp.float32).reshape(-1)
    return jnp.stack([jnp.sum(flat), jnp.sum(flat * flat)])


def replication_divergence(chk, axis) -> jnp.ndarray:
    """Cross-replica spread of a per-device checksum under ``shard_map``:
    0.0 when every replica holds identical payload (the allreduce
    postcondition), > 0 when a corrupt wire broke replication."""
    return jnp.max(jax.lax.pmax(chk, axis) - jax.lax.pmin(chk, axis))


# ---------------------------------------------------------------------------
# straggler detection (wall-clock quantiles)
# ---------------------------------------------------------------------------

class StragglerDetector:
    """Rolling-median step-time monitor: ``observe(dt)`` returns True when
    ``dt`` exceeds ``ratio`` times the median of the last ``window``
    healthy samples (flagged samples stay out of the baseline so a
    sustained straggler cannot normalize itself)."""

    def __init__(self, window: int = 32, ratio: float = 2.5,
                 min_samples: int = 5):
        self.window = int(window)
        self.ratio = float(ratio)
        self.min_samples = int(min_samples)
        self._times = collections.deque(maxlen=self.window)

    def baseline(self) -> float:
        if not self._times:
            return 0.0
        return float(np.median(self._times))

    def observe(self, dt: float) -> bool:
        if len(self._times) >= self.min_samples \
                and dt > self.ratio * self.baseline():
            return True
        self._times.append(float(dt))
        return False


# ---------------------------------------------------------------------------
# the bundled monitor
# ---------------------------------------------------------------------------

@dataclass
class HealthReport:
    """One detection tick: raw bitmap plus the derived classifications
    the recovery controller consumes."""
    step: int
    links: tuple                      # directed (src, dst) per bitmap slot
    link_ok: np.ndarray               # (L,) bool
    checksum_dev: float = 0.0
    checksum_tol: float = 1e-3
    step_time: float | None = None
    straggler: bool = False
    extras: dict = field(default_factory=dict)

    @property
    def all_links_ok(self) -> bool:
        return bool(self.link_ok.all())

    @property
    def checksum_ok(self) -> bool:
        return self.checksum_dev <= self.checksum_tol

    def failed_directed(self) -> tuple:
        return tuple(l for l, ok in zip(self.links, self.link_ok) if not ok)

    def failed_edges(self) -> frozenset:
        """Canonical undirected edges with at least one dead direction."""
        return frozenset(canon(s, d) for s, d in self.failed_directed())

    def node_suspects(self) -> frozenset:
        """Vertices whose EVERY probed link (both directions) is dead --
        the link-level signature of a lost node."""
        incident: dict = {}
        for (s, d), ok in zip(self.links, self.link_ok):
            for v in (s, d):
                alive, total = incident.get(v, (0, 0))
                incident[v] = (alive + bool(ok), total + 1)
        return frozenset(v for v, (alive, total) in incident.items()
                         if total > 0 and alive == 0)


class HealthMonitor:
    """Driver-side bundle of the three detectors for one mesh + runtime.

    ``check(step, fault_mask=, step_time=, checksum_dev=)`` runs the
    heartbeat probe and folds in the caller-measured step time and
    checksum divergence (the in-graph divergence is computed by the train
    step's telemetry -- see ``make_train_step(telemetry=True)``)."""

    def __init__(self, mesh, spec_or_runtime,
                 straggler: StragglerDetector | None = None,
                 checksum_tol: float = 1e-3):
        self.probe, self.plan = mesh_link_probe(mesh, spec_or_runtime)
        self.straggler = straggler or StragglerDetector()
        self.checksum_tol = float(checksum_tol)

    @property
    def links(self) -> tuple:
        return self.plan.links

    def check(self, step: int, fault_mask=None, step_time: float | None = None,
              checksum_dev: float = 0.0) -> HealthReport:
        bitmap = self.probe(fault_mask)
        slow = (step_time is not None
                and self.straggler.observe(float(step_time)))
        report = HealthReport(step=step, links=self.plan.links,
                              link_ok=np.asarray(bitmap) > 0.5,
                              checksum_dev=float(checksum_dev),
                              checksum_tol=self.checksum_tol,
                              step_time=step_time, straggler=slow)
        n_failed = int((~report.link_ok).sum())
        _metrics.counter("edst_health_checks_total",
                         "heartbeat/checksum/straggler detection ticks"
                         ).inc()
        if n_failed:
            _metrics.counter("edst_probe_failures_total",
                             "directed links that failed a heartbeat probe"
                             ).inc(n_failed)
        _metrics.gauge("edst_failed_links",
                       "directed links failing the latest probe"
                       ).set(n_failed)
        if not report.checksum_ok:
            _metrics.counter("edst_checksum_failures_total",
                             "payload checksum divergences past tolerance"
                             ).inc()
        if slow:
            _metrics.counter("edst_straggler_flags_total",
                             "steps flagged as stragglers").inc()
        return report
