"""Logical-axis sharding rules: axis-name tuples -> ``PartitionSpec``.

Every ``init_*`` in :mod:`repro.models` returns a params tree plus a parallel
tree of logical axis names (("embed", "mlp"), ("vocab", "embed"), ...).
``spec_for`` turns one such tuple into a ``PartitionSpec`` for a mesh:

  * "batch" dims map to the data-parallel mesh axes ("pod", "data");
  * exactly one tensor dim maps to the "model" axis, chosen by Megatron-style
    priority (experts > vocab > mlp > heads > kv_heads > head_dim), skipping
    dims the mesh extent does not divide;
  * with ``fsdp=True`` (ZeRO-3) the largest remaining divisible named dim is
    additionally split over the data axes;
  * "layers" (the scan-stacked leading dim) and unnamed dims stay replicated;
    any axis name whose mesh axis is absent falls back to replicated.

Divisibility is always checked against the mesh axis sizes, so shapes that
do not tile (heads=28 on a 16-way model axis, batch=1 on a 16-way data axis)
degrade gracefully instead of erroring.
"""
from __future__ import annotations

import jax

PartitionSpec = jax.sharding.PartitionSpec

# data-parallel mesh axes, outermost first (flattened row-major = DP rank)
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"
# tensor-parallel candidates, highest priority first
TENSOR_AXES = ("experts", "vocab", "mlp", "heads", "kv_heads", "head_dim")
# never sharded: scan-stacked layer dim must stay whole for lax.scan
UNSHARDED_AXES = ("layers",)


def _axis_sizes(mesh) -> dict:
    """axis name -> extent; works on real meshes and duck-typed stand-ins
    (anything with ``.axis_names`` and ``.devices.shape``)."""
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def _dp_axes(sizes: dict):
    names = tuple(a for a in DATA_AXES if a in sizes)
    total = 1
    for a in names:
        total *= sizes[a]
    return names, total


def _dp_entry(names):
    return names[0] if len(names) == 1 else names


def spec_for(axes, shape, mesh, fsdp: bool = True) -> PartitionSpec:
    """PartitionSpec for one array with logical ``axes`` and ``shape``."""
    axes = tuple(axes)
    shape = tuple(shape)
    sizes = _axis_sizes(mesh)
    dp_names, dp_total = _dp_axes(sizes)
    model_n = sizes.get(MODEL_AXIS, 0)
    entries = [None] * len(shape)

    # 1. batch dims -> data axes
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if ax == "batch" and dp_names and dim and dim % dp_total == 0:
            entries[i] = _dp_entry(dp_names)

    # 2. one tensor dim -> model axis, by priority then divisibility
    if model_n:
        best = None
        for i, (ax, dim) in enumerate(zip(axes, shape)):
            if ax in TENSOR_AXES and entries[i] is None and dim \
                    and dim % model_n == 0:
                rank = TENSOR_AXES.index(ax)
                if best is None or rank < best[0]:
                    best = (rank, i)
        if best is not None:
            entries[best[1]] = MODEL_AXIS

    # 3. FSDP: largest remaining divisible named dim -> data axes (skipped
    # when a batch dim already holds them -- an axis may appear only once)
    if fsdp and dp_names and all(e is None or e == MODEL_AXIS
                                 for e in entries):
        best = None
        for i, (ax, dim) in enumerate(zip(axes, shape)):
            if ax is None or ax == "batch" or ax in UNSHARDED_AXES:
                continue
            if entries[i] is None and dim and dim % dp_total == 0:
                if best is None or dim > best[0]:
                    best = (dim, i)
        if best is not None:
            entries[best[1]] = _dp_entry(dp_names)

    return PartitionSpec(*entries)


def owner_stripe_spec(mesh) -> PartitionSpec:
    """PartitionSpec for ZeRO-1 owner-stripe state: the leading axis of a
    ``(ndp, kmax, smax)`` array is the owner device, split over the
    data-parallel mesh axes so device ``d`` holds only its own stripe
    rows; the stripe dims stay unsplit.  Meshes without a DP extent get
    the replicated spec (zero1 has nothing to shard there)."""
    names, total = _dp_axes(_axis_sizes(mesh))
    if not names or total <= 1:
        return PartitionSpec()
    return PartitionSpec(_dp_entry(names))


def zero1_state_shardings(opt_state, mesh):
    """NamedSharding tree for a :class:`repro.optim.sharded.ShardedOptState`:
    ``mu`` / ``nu`` take :func:`owner_stripe_spec`, the scalar step
    replicates.  Use as jit in_shardings / device_put placement."""
    stripe = jax.sharding.NamedSharding(mesh, owner_stripe_spec(mesh))
    rep = jax.sharding.NamedSharding(mesh, PartitionSpec())
    return type(opt_state)(rep, stripe, stripe)


def _is_axes_leaf(x) -> bool:
    """A leaf of an axes tree is a (possibly empty) tuple of names/Nones;
    tuples of sub-trees (e.g. a (k, v) cache pair) are interior nodes."""
    return isinstance(x, tuple) and \
        all(a is None or isinstance(a, str) for a in x)


def tree_shardings(axes_tree, params_tree, mesh, fsdp: bool = True):
    """NamedSharding tree matching ``params_tree`` (arrays or
    ShapeDtypeStructs), driven by the parallel ``axes_tree`` of logical axis
    tuples produced by the model inits."""
    def one(ax, p):
        spec = spec_for(ax, p.shape, mesh, fsdp=fsdp)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, params_tree, is_leaf=_is_axes_leaf)
