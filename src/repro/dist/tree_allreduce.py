"""k-tree allreduce under ``shard_map`` (the paper's Sec. 1.1 payoff, run).

Two executors share this module:

  * the **fused global-round** executor (:func:`fused_tree_allreduce`, the
    default engine) consumes a :class:`repro.core.collectives.
    FusedAllreduceSpec`: gradient chunks live stacked as a ``(k, m)``
    array and every global round issues one ``ppermute`` per *wave* over
    the union of all k trees' messages -- depth-of-deepest-tree rounds of
    concurrent tree traffic instead of sum-of-all-trees serial hops.
    Per-wave routing tables (which chunk row a vertex ships, where an
    arrival lands) are precomputed NumPy constants in the spec, and
    on-device accumulation of arrivals runs through the
    ``repro.kernels.tree_combine`` Pallas op;
  * the **per-tree** executor (:func:`run_tree_program`, via a
    :class:`TreeAllreduceSpec`) lowers each tree as its own serial
    ppermute chain.  It is kept as the A/B baseline
    (``benchmarks/allreduce_bench.py``) and for weighted striping over
    retired trees.

Vertex ids are the row-major flattened index over the mesh axes being
reduced (``jax.lax.axis_index(axes)``), which matches how
``repro.core.topologies.device_topology`` numbers the fabric.

``ppermute`` needs unique sources *and* destinations per call, so schedule
rounds that fan in (several children -> one parent) or fan out (one parent
-> several children) are statically split into sub-rounds/waves; the tree
semantics are unchanged (reduction is associative, broadcast idempotent).

With ``quantize=True`` every hop ships int8 chunks with the per-chunk f32
scale bit-packed into a 4-byte payload tail, so a quantized hop is ONE
collective (it used to be two: payload + scale) at ~4x fewer wire bytes
for f32 gradients.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.collectives import FusedAllreduceSpec
from ..kernels.tree_combine.ops import combine


# ---------------------------------------------------------------------------
# static spec (per-tree baseline form)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TreeProgram:
    """One tree's rounds, each a tuple of (src, dst) pairs with unique
    sources and destinations (ppermute-legal)."""
    root: int
    reduce_rounds: tuple
    bcast_rounds: tuple


@dataclass(frozen=True)
class TreeAllreduceSpec:
    n: int                 # fabric size = product of the reduced axis sizes
    axes: tuple            # mesh axis names the allreduce runs over
    trees: tuple           # tuple[TreeProgram]

    @property
    def k(self) -> int:
        return len(self.trees)

    @property
    def depth(self) -> int:
        return max((len(t.bcast_rounds) for t in self.trees), default=0)


def _split_unique(msgs):
    """Partition one round's (src, dst) messages into ppermute-legal
    sub-rounds: within a sub-round no vertex repeats as src or as dst."""
    out = []
    remaining = list(msgs)
    while remaining:
        srcs, dsts, taken, rest = set(), set(), [], []
        for s, d in remaining:
            if s in srcs or d in dsts:
                rest.append((s, d))
            else:
                srcs.add(s)
                dsts.add(d)
                taken.append((s, d))
        out.append(tuple(taken))
        remaining = rest
    return out


def _compile_rounds(rounds):
    out = []
    for msgs in rounds:
        out.extend(_split_unique(msgs))
    return tuple(out)


def spec_from_schedule(sched, axis_names) -> TreeAllreduceSpec:
    """Compile an :class:`repro.core.collectives.AllreduceSchedule` into a
    static per-tree spec bound to the given mesh axis names.  (The fused
    round-major form comes from
    :func:`repro.core.collectives.fused_spec_from_schedule`.)"""
    trees = tuple(
        TreeProgram(root=ts.root,
                    reduce_rounds=_compile_rounds(ts.reduce_rounds),
                    bcast_rounds=_compile_rounds(ts.bcast_rounds))
        for ts in sched.trees)
    return TreeAllreduceSpec(n=sched.n, axes=tuple(axis_names), trees=trees)


# ---------------------------------------------------------------------------
# chunk apportioning (shared by uniform and weighted striping)
# ---------------------------------------------------------------------------

def chunk_sizes(total: int, fractions) -> tuple:
    """Apportion ``total`` elements to trees by largest-remainder rounding;
    sizes sum exactly to ``total`` (a retired tree -- fraction 0 -- gets 0)."""
    raw = [f * total for f in fractions]
    sizes = [int(np.floor(r)) for r in raw]
    leftover = total - sum(sizes)
    order = sorted(range(len(raw)), key=lambda i: (sizes[i] - raw[i], i))
    for i in order[:leftover]:
        sizes[i] += 1
    return tuple(sizes)


# ---------------------------------------------------------------------------
# wire format (shared by both executors)
# ---------------------------------------------------------------------------

def _axis_arg(spec):
    return spec.axes[0] if len(spec.axes) == 1 else tuple(spec.axes)


def _pack_q8(x):
    """Quantize a chunk to int8 and bit-pack its f32 scale into a 4-byte
    tail, so the whole hop is one ppermute payload."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    tail = jax.lax.bitcast_convert_type(scale.astype(jnp.float32), jnp.int8)
    return jnp.concatenate([q, tail])


def _unpack_q8(p, dtype):
    """Inverse of :func:`_pack_q8`.  A device nobody sent to holds zeros:
    the zero-bit scale dequantizes it back to exact zeros."""
    scale = jax.lax.bitcast_convert_type(p[-4:], jnp.float32)
    return p[:-4].astype(dtype) * scale.astype(dtype)


def _send(x, axis, perm, quantize: bool):
    """ppermute a chunk; devices nobody sends to receive zeros.  With
    ``quantize`` the payload travels as int8 with the f32 scale packed in
    its tail -- one collective per hop, 4x fewer wire bytes for f32."""
    if not quantize:
        return jax.lax.ppermute(x, axis, list(perm))
    p_r = jax.lax.ppermute(_pack_q8(x), axis, list(perm))
    return _unpack_q8(p_r, x.dtype)


# ---------------------------------------------------------------------------
# per-tree execution (inside shard_map) -- the A/B baseline
# ---------------------------------------------------------------------------

def _dst_mask(perm, n: int, axis):
    """Traced bool: is this device a destination of ``perm``?"""
    table = [False] * n
    for _, d in perm:
        table[d] = True
    idx = jax.lax.axis_index(axis)
    return jnp.asarray(table)[idx]


def run_tree_program(c, tree: TreeProgram, n: int, axis,
                     quantize: bool = False):
    """Reduce chunk ``c`` up ``tree`` and broadcast the total back down.

    The per-tree building block: tree j's whole chain completes before
    tree j+1 starts in program order.  Kept for the executor A/B
    benchmark and for striping with retired (fraction-0) trees; the fused
    executor below is the default engine.
    """
    # reduce: every non-root sends its accumulated value to its parent
    # exactly once, deepest level first, so parents accumulate complete
    # subtree sums before forwarding
    for perm in tree.reduce_rounds:
        c = c + _send(c, axis, perm, quantize)
    # broadcast: the root's total overwrites down the levels
    for perm in tree.bcast_rounds:
        recv = _send(c, axis, perm, quantize)
        c = jnp.where(_dst_mask(perm, n, axis), recv, c)
    return c


def per_tree_allreduce(x, spec: TreeAllreduceSpec, quantize: bool = False):
    """Allreduce (sum) of ``x`` over ``spec.axes``, one serial ppermute
    chain per tree (the pre-fusion executor)."""
    if spec.k == 0:
        return x
    axis = _axis_arg(spec)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % spec.k
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(spec.k, -1)

    outs = [run_tree_program(chunks[j], tree, spec.n, axis, quantize)
            for j, tree in enumerate(spec.trees)]

    out = jnp.concatenate(outs) if spec.k > 1 else outs[0]
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused global-round execution (inside shard_map) -- the default engine
# ---------------------------------------------------------------------------

def _wave_rows(rnd):
    """Static (senders' rows, receivers' rows) of one wave.  Single-row
    waves (every message from the same tree -- common, since fan-in
    splits produce them) specialize to static indexing below."""
    srcs = np.array([s for s, _ in rnd.perm], np.int64)
    dsts = np.array([d for _, d in rnd.perm], np.int64)
    return (np.unique(rnd.send_row[srcs]), np.unique(rnd.recv_row[dsts]))


def _fused_send(chunks, rnd, idx, axis, quantize: bool):
    """One wave: every vertex ships the chunk row its table says, the
    single ppermute moves all trees' round-r traffic at once, and the
    receive tables say where (and whether) the arrival lands."""
    send_rows, recv_rows = _wave_rows(rnd)
    if len(send_rows) == 1:
        payload = chunks[int(send_rows[0])]
    else:
        payload = chunks[jnp.asarray(rnd.send_row)[idx]]
    if quantize:
        payload = _pack_q8(payload)
    recv = jax.lax.ppermute(payload, axis, list(rnd.perm))
    if quantize:
        recv = _unpack_q8(recv, chunks.dtype)
    flag = jnp.asarray(rnd.recv_flag)[idx]
    return recv, flag, recv_rows


def fused_tree_allreduce(x, spec: FusedAllreduceSpec, quantize: bool = False,
                         fractions=None):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``
    with the fused global-round program.

    Must run inside a ``shard_map`` whose manual axes include
    ``spec.axes``.  ``x`` is flattened and striped into k chunk rows
    (uniform split, or ``chunk_sizes(size, fractions)`` when weighted
    striping is requested); rows are padded to a common width so the
    stacked ``(k, m)`` state ships through shared waves.  Returns the
    summed array in the original shape (replicated across the fabric).
    """
    if spec.k == 0 or x.size == 0:
        return x
    if fractions is not None and len(fractions) != spec.k:
        raise ValueError(f"{len(fractions)} fractions for k={spec.k} trees; "
                         "spec and striping must come from the same schedule")
    axis = _axis_arg(spec)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    k = spec.k
    if fractions is None:
        m = -(-flat.size // k)
        sizes = (m,) * k
        chunks = jnp.pad(flat, (0, m * k - flat.size)).reshape(k, m)
    else:
        sizes = chunk_sizes(flat.size, fractions)
        m = max(sizes)
        rows, off = [], 0
        for s in sizes:
            c = flat[off:off + s]
            off += s
            rows.append(c if s == m else jnp.pad(c, (0, m - s)))
        chunks = jnp.stack(rows)

    idx = jax.lax.axis_index(axis)
    rows_iota = jnp.arange(k)

    # reduce accumulation: the tree_combine kernel accumulates in f32
    # (on-chip on TPU), which is what gradient payloads (f32/bf16/f16)
    # want; wider or integer dtypes, where f32 would round, add natively
    if chunks.dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
        def acc(partial, update):
            return combine(update[None, :], partial)
    else:
        def acc(partial, update):
            return partial + update

    # reduce: arrivals accumulate into their tree's row.  Single-row
    # waves combine just that row; multi-row waves scatter the arrival to
    # a one-hot (k, m) contribution first.
    for rnd in spec.reduce_rounds:
        recv, flag, recv_rows = _fused_send(chunks, rnd, idx, axis, quantize)
        masked = jnp.where(flag, recv, jnp.zeros_like(recv))
        if len(recv_rows) == 1:
            r0 = int(recv_rows[0])
            chunks = chunks.at[r0].set(acc(chunks[r0], masked))
        else:
            row = jnp.asarray(rnd.recv_row)[idx]
            contrib = (rows_iota == row).astype(chunks.dtype)[:, None] \
                * masked[None, :]
            chunks = acc(chunks.reshape(-1),
                         contrib.reshape(-1)).reshape(k, m)

    # broadcast: arrivals overwrite their tree's row on destinations
    for rnd in spec.bcast_rounds:
        recv, flag, recv_rows = _fused_send(chunks, rnd, idx, axis, quantize)
        if len(recv_rows) == 1:
            r0 = int(recv_rows[0])
            chunks = chunks.at[r0].set(jnp.where(flag, recv, chunks[r0]))
        else:
            row = jnp.asarray(rnd.recv_row)[idx]
            sel = ((rows_iota == row) & flag)[:, None]
            chunks = jnp.where(sel, recv[None, :], chunks)

    if fractions is None:
        out = chunks.reshape(-1)[:flat.size]
    else:
        parts = [chunks[j, :s] for j, s in enumerate(sizes) if s > 0]
        out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return out.reshape(shape).astype(dtype)


def tree_allreduce(x, spec, quantize: bool = False):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``.

    Dispatches on the spec form: a
    :class:`repro.core.collectives.FusedAllreduceSpec` runs the fused
    global-round engine, a :class:`TreeAllreduceSpec` the per-tree
    baseline chains.  Both return the summed array in the original shape
    (replicated across the fabric).
    """
    if isinstance(spec, FusedAllreduceSpec):
        return fused_tree_allreduce(x, spec, quantize)
    return per_tree_allreduce(x, spec, quantize)
