"""k-tree allreduce under ``shard_map`` (the paper's Sec. 1.1 payoff, run).

Three executors share this module:

  * the **pipelined segmented** executor (:func:`pipelined_tree_allreduce`,
    the default engine) consumes a :class:`repro.core.collectives.
    PipelinedAllreduceSpec`: the dependency-DAG list schedule packs every
    tree's messages -- both phases -- into the fewest ppermute-legal
    waves, and the payload streams down the trees in S segments so wave w
    moves segment ``t - w`` at step t.  ``segments="auto"`` picks S from
    the :class:`repro.core.collectives.CostModel` calibrated for the
    backend (alpha-dominated hosts unroll S=1; bandwidth-dominated
    fabrics stream ``(waves + S - 1) * (m/S)``), and S > 1 executes as a
    ``jax.lax.fori_loop`` over the step index so HLO size and trace time
    stay flat in S * depth;
  * the **fused global-round** executor (:func:`fused_tree_allreduce`)
    consumes a :class:`repro.core.collectives.FusedAllreduceSpec`: round
    r of every tree merged into shared waves over a stacked ``(k, m)``
    state.  Kept as the round-aligned A/B baseline;
  * the **per-tree** executor (:func:`run_tree_program`, via a
    :class:`TreeAllreduceSpec`) lowers each tree as its own serial
    ppermute chain -- the original baseline.

Vertex ids are the row-major flattened index over the mesh axes being
reduced (``jax.lax.axis_index(axes)``), which matches how
``repro.core.topologies.device_topology`` numbers the fabric.

``ppermute`` needs unique sources *and* destinations per call, so fan-in
and fan-out are statically split into waves by the schedule compilers;
the tree semantics are unchanged (reduction is associative, broadcast
idempotent).  ``ppermute`` hands devices nobody sent to a zero payload,
which the executors exploit: a wave whose every arrival accumulates into
one chunk row is a single unmasked add.

With ``quantize=True`` hops ship int8 chunks with the per-chunk f32 scale
bit-packed into a 4-byte payload tail (one collective per hop, ~4x fewer
wire bytes for f32), through the fused Pallas codec in
``repro.kernels.tree_combine``.  The codec is phase-aware: the broadcast
phase quantizes each tree's total ONCE and forwards the packed bytes down
the tree (one codec invocation amortized over depth hops, and a single
quantization error instead of one per hop).  Reduce hops must re-code per
hop (partials accumulate in f32), so their wire obeys the ``codec``
policy: ``"full"`` compresses them too (the default where bandwidth
dominates, i.e. real fabrics), ``"bcast"`` leaves them f32 (the default
on alpha-dominated host backends, where per-hop codec work costs more
than the wire bytes it saves).
"""
from __future__ import annotations

import os
from contextlib import nullcontext
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.collectives import (CostModel, FusedAllreduceSpec,
                                PipelinedAllreduceSpec,
                                StripedCollectiveSpec, chunk_sizes,
                                verify_compiled_spec, wave_wire_bytes)
from ..kernels.tree_combine.ops import (combine, q8_combine, q8_pack,
                                        q8_pack_rows, q8_unpack,
                                        q8_unpack_rows)
from ..telemetry import metrics as _metrics


# ---------------------------------------------------------------------------
# static spec (per-tree baseline form)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TreeProgram:
    """One tree's rounds, each a tuple of (src, dst) pairs with unique
    sources and destinations (ppermute-legal).  ``bcast_dst[r][v]`` is the
    precompiled is-destination table of broadcast round r -- built once at
    spec-compile time, not per executor call."""
    root: int
    reduce_rounds: tuple
    bcast_rounds: tuple
    bcast_dst: tuple = ()   # tuple[tuple[bool, ...]] aligned with bcast_rounds


@dataclass(frozen=True)
class TreeAllreduceSpec:
    n: int                 # fabric size = product of the reduced axis sizes
    axes: tuple            # mesh axis names the allreduce runs over
    trees: tuple           # tuple[TreeProgram]

    @property
    def k(self) -> int:
        return len(self.trees)

    @property
    def depth(self) -> int:
        return max((len(t.bcast_rounds) for t in self.trees), default=0)


def _split_unique(msgs):
    """Partition one round's (src, dst) messages into ppermute-legal
    sub-rounds: within a sub-round no vertex repeats as src or as dst."""
    out = []
    remaining = list(msgs)
    while remaining:
        srcs, dsts, taken, rest = set(), set(), [], []
        for s, d in remaining:
            if s in srcs or d in dsts:
                rest.append((s, d))
            else:
                srcs.add(s)
                dsts.add(d)
                taken.append((s, d))
        out.append(tuple(taken))
        remaining = rest
    return out


def _compile_rounds(rounds):
    out = []
    for msgs in rounds:
        out.extend(_split_unique(msgs))
    return tuple(out)


def _dst_tables(rounds, n: int):
    out = []
    for perm in rounds:
        table = [False] * n
        for _, d in perm:
            table[d] = True
        out.append(tuple(table))
    return tuple(out)


def spec_from_schedule(sched, axis_names, verify=None) -> TreeAllreduceSpec:
    """Compile an :class:`repro.core.collectives.AllreduceSchedule` into a
    static per-tree spec bound to the given mesh axis names.  (The fused
    and pipelined forms come from ``repro.core.collectives``.)  Like
    those compilers, the fresh spec is statically verified per
    ``verify=`` (``repro.analysis.verify``; level resolved from
    ``REPRO_VERIFY_SPECS``) before being returned."""
    trees = []
    for ts in sched.trees:
        bcast = _compile_rounds(ts.bcast_rounds)
        trees.append(TreeProgram(root=ts.root,
                                 reduce_rounds=_compile_rounds(ts.reduce_rounds),
                                 bcast_rounds=bcast,
                                 bcast_dst=_dst_tables(bcast, sched.n)))
    spec = TreeAllreduceSpec(n=sched.n, axes=tuple(axis_names),
                             trees=tuple(trees))
    return verify_compiled_spec(spec, verify, "spec_from_schedule")


# chunk apportioning: the canonical largest-remainder helper lives in
# repro.core.collectives (owner-stripe assignment needs it at the core
# layer); imported above and re-exported here because the executors and
# repro.dist.fault historically import it from this module.


# ---------------------------------------------------------------------------
# wire codec policy (shared by all executors)
# ---------------------------------------------------------------------------

def _axis_arg(spec):
    return spec.axes[0] if len(spec.axes) == 1 else tuple(spec.axes)

def resolve_codec(codec=None) -> str:
    """The quantized-wire policy:

      * ``"full"`` -- int8 + scale tail on every hop, through the fused
        Pallas codec; the broadcast phase packs each tree's total ONCE
        and forwards the wire verbatim.  4x fewer wire bytes: the
        default where bandwidth dominates, i.e. real fabrics;
      * ``"hybrid"`` -- bf16 reduce wires (f32 accumulation), int8
        pack-once broadcast: 2x/4x fewer bytes at two casts per reduce
        hop;
      * ``"bcast"`` -- f32 reduce wires, int8 pack-once broadcast only;
      * ``"off"`` -- no compression: ``quantize=True`` compiles the
        identical program as ``quantize=False``.

    ``"auto"`` resolves by the same calibration as the segment
    autotuner: on alpha-dominated host backends every codec variant was
    measured slower than shipping f32 (the per-op dispatch of
    quantize/dequantize -- and bf16's software emulation -- costs more
    than the wire bytes saved, at every payload size), so compression is
    model-disabled there; bandwidth-dominated backends take ``"full"``.
    """
    if codec in (None, "auto"):
        # same split as CostModel.for_backend: only the serialized-
        # collective "cpu" host disables compression; GPU/TPU fabrics
        # take the full int8 wire
        return "off" if jax.default_backend() == "cpu" else "full"
    if codec not in ("full", "hybrid", "bcast", "off"):
        raise ValueError(f"codec {codec!r} not in "
                         "('auto', 'full', 'hybrid', 'bcast', 'off')")
    return codec


_REDUCE_WIRE = {"full": "q8", "hybrid": "bf16", "bcast": None, "off": None}

_FLOATS = (jnp.float32, jnp.bfloat16, jnp.float16)


# ---------------------------------------------------------------------------
# wave-level observability (shared by all executors)
# ---------------------------------------------------------------------------

_WAVE_SCOPES = os.environ.get("REPRO_WAVE_SCOPES", "1") != "0"


def set_wave_scopes(enabled: bool) -> bool:
    """Toggle the ``jax.named_scope`` wave labels (``edst/t{j}/w{w}/{op}``)
    the executors attach so XLA device profiles attribute time to waves;
    returns the previous setting.  Labels are pure HLO metadata -- the
    compiled executable is identical either way -- but the toggle only
    affects FUTURE traces, so re-jit after flipping it mid-process."""
    global _WAVE_SCOPES
    prev, _WAVE_SCOPES = _WAVE_SCOPES, bool(enabled)
    return prev


def _scope(label: str):
    return jax.named_scope(label) if _WAVE_SCOPES else nullcontext()


def _wave_label(w: int, wv) -> str:
    """``edst/t{tree}/w{wave}/{op}`` for a pipelined wave: the tree when
    the wave ships a single chunk row, ``t*`` for merged waves."""
    tree = f"t{wv.rows[0]}" if len(wv.rows) == 1 else "t*"
    red = bool(np.any(wv.reduce_flag))
    bc = bool(np.any(wv.bcast_flag))
    op = "mixed" if red and bc else ("reduce" if red else "bcast")
    return f"edst/{tree}/w{w}/{op}"


def _note_trace(engine: str, spec, x, codec=None, fractions=None) -> None:
    """Executor-entry metrics hook.  Inside ``jit`` this Python runs at
    trace time only, so it counts compiled program traces (the retrace
    detector), not steps -- and costs nothing per step."""
    try:
        itemsize = jnp.dtype(x.dtype).itemsize
        wires = wave_wire_bytes(spec, x.size * itemsize, itemsize, fractions)
        _metrics.note_program(engine, getattr(spec, "key", None) or spec,
                              waves=len(wires), wire_bytes=sum(wires),
                              codec=codec)
    except Exception:       # pragma: no cover - telemetry never breaks a step
        pass


def _pack_wire32(x):
    """Quantize chunk rows into an f32-lane wire: ``(..., m) float ->
    (..., ceil(m/4) + 1) f32`` holding the int8 payload bit-packed four
    to a lane plus the scale lane.  The broadcast phase forwards THIS
    form: every gather/mask op and every hop then touches 4x fewer
    elements than the unpacked rows, and zero-filled ppermute arrivals
    decode to exact zeros (zero scale)."""
    m = x.shape[-1]
    pad = -m % 4
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    w8 = q8_pack_rows(x) if x.ndim == 2 else q8_pack(x)
    return jax.lax.bitcast_convert_type(
        w8.reshape(*w8.shape[:-1], -1, 4), jnp.float32)


def _unpack_wire32(w32, dtype, m):
    """Inverse of :func:`_pack_wire32` back to ``(..., m)`` rows."""
    w8 = jax.lax.bitcast_convert_type(w32, jnp.int8)
    w8 = w8.reshape(*w8.shape[:-2], -1)
    out = q8_unpack_rows(w8, dtype) if w8.ndim == 2 else q8_unpack(w8, dtype)
    return out[..., :m]


def _acc(partial, update):
    """Reduce accumulation: through the Pallas tree-combine (f32 on-chip
    accumulation) for float gradients on TPU, a plain add elsewhere."""
    if jax.default_backend() == "tpu" and partial.dtype in (
            jnp.float32, jnp.bfloat16, jnp.float16):
        return combine(update[None, :], partial)
    return partial + update


def _send(x, axis, perm, wire=None):
    """ppermute a chunk; devices nobody sends to receive zeros.  ``wire``
    compresses the hop: ``"q8"`` ships int8 with the f32 scale packed in
    its tail (one collective per hop, 4x fewer bytes for f32), ``"bf16"``
    casts on and off the wire (2x fewer bytes).  Integer payloads always
    travel verbatim -- compression would corrupt them."""
    if wire is not None and x.dtype not in _FLOATS:
        wire = None
    if wire == "q8":
        w = jax.lax.ppermute(q8_pack(x), axis, list(perm))
        return q8_unpack(w, x.dtype)
    if wire == "bf16":
        return jax.lax.ppermute(x.astype(jnp.bfloat16), axis,
                                list(perm)).astype(x.dtype)
    return jax.lax.ppermute(x, axis, list(perm))


# ---------------------------------------------------------------------------
# per-tree execution (inside shard_map) -- the A/B baseline
# ---------------------------------------------------------------------------

def run_tree_program(c, tree: TreeProgram, n: int, axis,
                     quantize: bool = False, codec=None,
                     scope_tree: int = 0):
    """Reduce chunk ``c`` up ``tree`` and broadcast the total back down.

    The per-tree building block: tree j's whole chain completes before
    tree j+1 starts in program order.  Kept for the executor A/B
    benchmark; the pipelined executor below is the default engine.
    ``scope_tree`` only names the profiler scopes (``edst/t{j}/...``).
    """
    codec = resolve_codec(codec) if quantize else "off"
    wire = _REDUCE_WIRE[codec]
    idx = jax.lax.axis_index(axis)
    # reduce: every non-root sends its accumulated value to its parent
    # exactly once, deepest level first, so parents accumulate complete
    # subtree sums before forwarding
    for w, perm in enumerate(tree.reduce_rounds):
        with _scope(f"edst/t{scope_tree}/w{w}/reduce"):
            c = c + _send(c, axis, perm, wire)
    # broadcast: the root's total overwrites down the levels.  Quantized,
    # the total is packed ONCE and the int8 wire forwards verbatim.
    if not tree.bcast_rounds:
        return c
    base = len(tree.reduce_rounds)
    if codec != "off" and c.dtype in _FLOATS:
        packed = _pack_wire32(c)
        for w, (perm, table) in enumerate(zip(tree.bcast_rounds,
                                              tree.bcast_dst)):
            with _scope(f"edst/t{scope_tree}/w{base + w}/bcast"):
                recv = jax.lax.ppermute(packed, axis, list(perm))
                packed = jnp.where(jnp.asarray(table)[idx], recv, packed)
        return _unpack_wire32(packed, c.dtype, c.shape[0])
    for w, (perm, table) in enumerate(zip(tree.bcast_rounds,
                                          tree.bcast_dst)):
        with _scope(f"edst/t{scope_tree}/w{base + w}/bcast"):
            recv = jax.lax.ppermute(c, axis, list(perm))
            c = jnp.where(jnp.asarray(table)[idx], recv, c)
    return c


def per_tree_allreduce(x, spec: TreeAllreduceSpec, quantize: bool = False):
    """Allreduce (sum) of ``x`` over ``spec.axes``, one serial ppermute
    chain per tree (the pre-fusion executor)."""
    if spec.k == 0:
        return x
    _note_trace("per_tree", spec, x,
                codec=resolve_codec(None) if quantize else None)
    axis = _axis_arg(spec)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % spec.k
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(spec.k, -1)

    outs = [run_tree_program(chunks[j], tree, spec.n, axis, quantize,
                             scope_tree=j)
            for j, tree in enumerate(spec.trees)]

    out = jnp.concatenate(outs) if spec.k > 1 else outs[0]
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fused global-round execution (inside shard_map) -- round-aligned baseline
# ---------------------------------------------------------------------------

def _wave_rows(rnd):
    """Static (senders' rows, receivers' rows) of one wave.  Single-row
    waves (every message from the same tree -- common, since fan-in
    splits produce them) specialize to static indexing below."""
    srcs = np.array([s for s, _ in rnd.perm], np.int64)
    dsts = np.array([d for _, d in rnd.perm], np.int64)
    return (np.unique(rnd.send_row[srcs]), np.unique(rnd.recv_row[dsts]))


def _fused_send(chunks, rnd, idx, axis, wire=None):
    """One wave: every vertex ships the chunk row its table says, the
    single ppermute moves all trees' round-r traffic at once, and the
    receive tables say where (and whether) the arrival lands."""
    send_rows, recv_rows = _wave_rows(rnd)
    if chunks.ndim == 1:
        payload = chunks
    elif len(send_rows) == 1:
        payload = chunks[int(send_rows[0])]
    else:
        payload = chunks[jnp.asarray(rnd.send_row)[idx]]
    recv = _send(payload, axis, rnd.perm, wire)
    flag = jnp.asarray(rnd.recv_flag)[idx]
    return recv, flag, recv_rows


def fused_tree_allreduce(x, spec: FusedAllreduceSpec, quantize: bool = False,
                         fractions=None, codec=None):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``
    with the fused global-round program.

    Must run inside a ``shard_map`` whose manual axes include
    ``spec.axes``.  ``x`` is flattened and striped into k chunk rows
    (uniform split, or ``chunk_sizes(size, fractions)`` when weighted
    striping is requested); rows are padded to a common width so the
    stacked ``(k, m)`` state ships through shared waves.  Single-tree
    specs skip the row stacking/indexing machinery entirely and run on
    the flat chunk.  Returns the summed array in the original shape
    (replicated across the fabric).
    """
    if spec.k == 0 or x.size == 0:
        return x
    if fractions is not None and len(fractions) != spec.k:
        raise ValueError(f"{len(fractions)} fractions for k={spec.k} trees; "
                         "spec and striping must come from the same schedule")
    codec = resolve_codec(codec) if quantize else "off"
    _note_trace("fused", spec, x, codec=codec if quantize else None,
                fractions=fractions)
    r_wire = _REDUCE_WIRE[codec]
    axis = _axis_arg(spec)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    k = spec.k
    if fractions is None:
        m = -(-flat.size // k)
        sizes = (m,) * k
        padded = jnp.pad(flat, (0, m * k - flat.size))
        chunks = padded if k == 1 else padded.reshape(k, m)
    else:
        sizes = chunk_sizes(flat.size, fractions)
        m = max(sizes)
        rows, off = [], 0
        for s in sizes:
            c = flat[off:off + s]
            off += s
            rows.append(c if s == m else jnp.pad(c, (0, m - s)))
        chunks = rows[0] if k == 1 else jnp.stack(rows)

    idx = jax.lax.axis_index(axis)
    rows_iota = jnp.arange(k)

    # reduce: arrivals accumulate into their tree's row.  k=1 and
    # single-row waves need no masking at all (ppermute zero-fills
    # devices nobody sent to); multi-row waves scatter the arrival to a
    # one-hot (k, m) contribution first.
    for w, rnd in enumerate(spec.reduce_rounds):
        with _scope(f"edst/t*/w{w}/reduce"):
            recv, flag, recv_rows = _fused_send(chunks, rnd, idx, axis,
                                                r_wire)
            if k == 1:
                chunks = _acc(chunks, recv)
            elif len(recv_rows) == 1:
                r0 = int(recv_rows[0])
                chunks = chunks.at[r0].set(_acc(chunks[r0], recv))
            else:
                row = jnp.asarray(rnd.recv_row)[idx]
                masked = jnp.where(flag, recv, jnp.zeros_like(recv))
                contrib = (rows_iota == row).astype(chunks.dtype)[:, None] \
                    * masked[None, :]
                chunks = _acc(chunks.reshape(-1),
                              contrib.reshape(-1)).reshape(k, m)

    # broadcast: arrivals overwrite their tree's row on destinations.
    # Quantized, the per-row totals are packed ONCE here into the
    # f32-lane wire and forwarded verbatim down the levels (codec cost
    # amortized over depth hops, one quantization error instead of one
    # per hop, and 4x fewer elements under every wave's row machinery).
    q_bcast = codec != "off" and bool(spec.bcast_rounds) and dtype in _FLOATS
    if q_bcast:
        chunks = _pack_wire32(chunks)
    base = len(spec.reduce_rounds)
    for w, rnd in enumerate(spec.bcast_rounds):
        with _scope(f"edst/t*/w{base + w}/bcast"):
            recv, flag, recv_rows = _fused_send(chunks, rnd, idx, axis)
            if k == 1:
                chunks = jnp.where(flag, recv, chunks)
            elif len(recv_rows) == 1:
                r0 = int(recv_rows[0])
                chunks = chunks.at[r0].set(jnp.where(flag, recv,
                                                     chunks[r0]))
            else:
                row = jnp.asarray(rnd.recv_row)[idx]
                sel = ((rows_iota == row) & flag)[:, None]
                chunks = jnp.where(sel, recv[None, :], chunks)
    if q_bcast:
        chunks = _unpack_wire32(chunks, dtype, m)

    if k == 1:
        out = chunks[:flat.size] if fractions is None else chunks[:sizes[0]]
    elif fractions is None:
        out = chunks.reshape(-1)[:flat.size]
    else:
        parts = [chunks[j, :s] for j, s in enumerate(sizes) if s > 0]
        out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return out.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# pipelined segmented execution (inside shard_map) -- the default engine
# ---------------------------------------------------------------------------

def auto_segments(spec: PipelinedAllreduceSpec, row_elems: int,
                  itemsize: int = 4) -> int:
    """The segment count the backend-calibrated cost model picks for
    ``row_elems``-element chunk rows (see ``CostModel.for_backend``)."""
    cm = CostModel.for_backend(jax.default_backend())
    nbytes = row_elems * itemsize * max(1, spec.k)
    return max(1, min(cm.best_segments(nbytes, spec), row_elems or 1))


def _gather(table, idx):
    return jnp.asarray(table)[idx]


def _select_payload(rows, wv, idx):
    """The wave's outgoing chunk: most waves ship one row statically;
    multi-row waves select per device via the spec's send-row table."""
    payload = rows[wv.rows[0]]
    for r in wv.rows[1:]:
        payload = jnp.where(_gather(wv.send_row == r, idx), rows[r], payload)
    return payload


def _apply_wave(rows, wv, recv, idx, valid=None):
    """Land one wave's arrival: accumulate into reduce destinations,
    overwrite broadcast destinations, leave everyone else untouched.
    ``wv.sole_add`` waves skip masking (zero payload on non-destinations);
    ``valid`` gates fill/drain steps of the pipelined scan."""
    zero = jnp.zeros((), recv.dtype)
    for j in range(len(rows)):
        rf, bf = wv.reduce_flag[j], wv.bcast_flag[j]
        if not (rf.any() or bf.any()):
            continue
        if wv.sole_add == j and valid is None:
            rows[j] = _acc(rows[j], recv)
            continue
        base = rows[j]
        if rf.any():
            mask = _gather(rf, idx) if valid is None \
                else _gather(rf, idx) & valid
            if wv.sole_add == j:
                base = _acc(base, jnp.where(valid, recv, zero))
            else:
                base = _acc(base, jnp.where(mask, recv, zero))
        if bf.any():
            mask = _gather(bf, idx) if valid is None \
                else _gather(bf, idx) & valid
            base = jnp.where(mask, recv, base)
        rows[j] = base
    return rows


def _rows_of(flat, k, sizes, mrow):
    rows, off = [], 0
    for s in sizes:
        c = flat[off:off + s]   # the last row may run short of its size
        off += s
        rows.append(c if c.shape[0] == mrow
                    else jnp.pad(c, (0, mrow - c.shape[0])))
    return rows


def _rows_out(rows, sizes, size):
    """Row widths may exceed the logical stripe sizes (segment padding),
    so each row is cut back to its stripe before reassembly."""
    parts = [rows[j][:s] for j, s in enumerate(sizes) if s > 0]
    out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    return out[:size]


def pipelined_tree_allreduce(x, spec: PipelinedAllreduceSpec,
                             quantize: bool = False, segments="auto",
                             fractions=None, codec=None):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``
    with the pipelined segmented wave program (the default engine).

    Must run inside a ``shard_map`` whose manual axes include
    ``spec.axes``.  ``x`` is flattened and striped into k chunk rows
    (uniform, or weighted by ``fractions`` via ``chunk_sizes``), padded
    to a common row width.  ``segments`` splits each row into S pipeline
    segments: S=1 unrolls the wave list directly (no pipelining
    overhead); S>1 runs a ``fori_loop`` over ``waves + S - 1`` steps in
    which wave w moves segment ``t - w`` -- steady state keeps every
    tree edge busy and the HLO holds each wave's collective exactly
    once, whatever S is.  ``"auto"`` asks the backend-calibrated cost
    model (:func:`auto_segments`).  ``quantize``/``codec`` select the
    int8 wire (see module docstring).
    """
    if spec.k == 0 or x.size == 0:
        return x
    if fractions is not None and len(fractions) != spec.k:
        raise ValueError(f"{len(fractions)} fractions for k={spec.k} trees; "
                         "spec and striping must come from the same schedule")
    codec = resolve_codec(codec) if quantize else "off"
    if x.dtype not in _FLOATS:
        codec = "off"       # integer payloads always travel verbatim
    if codec == "off":
        quantize = False    # model-disabled codec: identical f32 program
    axis = _axis_arg(spec)
    idx = jax.lax.axis_index(axis)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    k = spec.k
    if fractions is None:
        mrow = -(-flat.size // k)
        sizes = (mrow,) * k
    else:
        sizes = chunk_sizes(flat.size, fractions)
        mrow = max(sizes)
    if segments == "auto" or segments is None:
        segments = auto_segments(spec, mrow, dtype.itemsize)
    segments = max(1, min(int(segments), mrow))
    msub = -(-mrow // segments)
    mrow = msub * segments
    _note_trace("pipelined", spec, x, codec=codec if quantize else None,
                fractions=fractions)
    rows = _rows_of(flat, k, sizes, mrow)

    if segments == 1:
        if quantize:
            rows = _q8_unrolled(rows, spec, idx, axis, codec)
        else:
            for w, wv in enumerate(spec.waves):
                with _scope(_wave_label(w, wv)):
                    recv = jax.lax.ppermute(_select_payload(rows, wv, idx),
                                            axis, list(wv.perm))
                    rows = _apply_wave(rows, wv, recv, idx)
    else:
        rows = _scanned(rows, spec, idx, axis, segments, msub,
                        codec if quantize else None, dtype)

    out = _rows_out(rows, sizes, flat.size)
    return out.reshape(shape).astype(dtype)


def _q8_unrolled(rows, spec, idx, axis, codec):
    """S=1 quantized program: phase-separated waves; reduce hops' wire
    per the codec policy, then every row packs ONCE at the reduce/
    broadcast boundary and the int8 wire forwards verbatim down the
    trees."""
    dtype = rows[0].dtype
    r_wire = _REDUCE_WIRE[codec]
    bnd = spec.q8_boundary
    for w, wv in enumerate(spec.q8_waves[:bnd]):
        with _scope(_wave_label(w, wv)):
            payload = _select_payload(rows, wv, idx)
            if r_wire == "q8" and payload.dtype in _FLOATS:
                wire = jax.lax.ppermute(q8_pack(payload), axis,
                                        list(wv.perm))
                if wv.sole_add >= 0:
                    rows[wv.sole_add] = q8_combine(wire, rows[wv.sole_add])
                    continue
                recv = q8_unpack(wire, dtype)
            else:
                recv = _send(payload, axis, wv.perm, r_wire)
            rows = _apply_wave(rows, wv, recv, idx)
    if bnd == len(spec.q8_waves) or dtype not in _FLOATS:
        for w, wv in enumerate(spec.q8_waves[bnd:]):
            with _scope(_wave_label(bnd + w, wv)):
                recv = jax.lax.ppermute(_select_payload(rows, wv, idx),
                                        axis, list(wv.perm))
                rows = _apply_wave(rows, wv, recv, idx)
        return rows
    mrow = rows[0].shape[0]
    if len(rows) == 1:
        packed = [_pack_wire32(rows[0])]
    else:
        packed = list(_pack_wire32(jnp.stack(rows)))
    for w, wv in enumerate(spec.q8_waves[bnd:]):
        with _scope(_wave_label(bnd + w, wv)):
            recv = jax.lax.ppermute(_select_payload(packed, wv, idx),
                                    axis, list(wv.perm))
            for j in range(len(packed)):
                if wv.bcast_flag[j].any():
                    packed[j] = jnp.where(_gather(wv.bcast_flag[j], idx),
                                          recv, packed[j])
    if len(packed) == 1:
        return [_unpack_wire32(packed[0], dtype, mrow)]
    return list(_unpack_wire32(jnp.stack(packed), dtype, mrow))


def _scanned(rows, spec, idx, axis, segments, msub, codec, dtype):
    """S>1: software-pipeline the wave program with a ``fori_loop`` over
    the step index.  The carry holds the ``(k, S, msub)`` segmented state
    (plus the packed broadcast state when quantized); the body issues
    every wave once on segment ``t - stage(w)``, so the compiled HLO
    holds one collective per wave however many segments stream through.
    Out-of-range segments clamp and their arrivals are masked off, which
    makes the fill/drain steps no-ops for inactive waves."""
    k = len(rows)
    st = jnp.stack(rows).reshape(k, segments, msub)
    waves = spec.waves if codec is None else spec.q8_waves
    boundary = len(waves) if codec is None else spec.q8_boundary
    # quantized scans insert a pack pseudo-stage at the phase boundary,
    # shifting broadcast waves one step later
    stage = [w if (codec is None or w < boundary) else w + 1
             for w in range(len(waves))]
    nsteps = (len(waves) if codec is None else len(waves) + 1) + segments - 1
    pst = jnp.zeros((k, segments, msub + 4), jnp.int8) if codec is not None \
        else None

    def seg_slice(arr, j, seg):
        return jax.lax.dynamic_slice(
            arr, (j, seg, 0), (1, 1, arr.shape[-1])).reshape(-1)

    def seg_update(arr, j, seg, val):
        return jax.lax.dynamic_update_slice(
            arr, val.reshape(1, 1, -1), (j, seg, 0))

    def body(t, carry):
        st, pst = carry
        for w, wv in enumerate(waves):
            with _scope(_wave_label(w, wv)):
                seg = t - stage[w]
                valid = (seg >= 0) & (seg < segments)
                segc = jnp.clip(seg, 0, segments - 1)
                bcast_wave = codec is not None and w >= boundary
                src = pst if bcast_wave else st
                cur = [seg_slice(src, j, segc) for j in range(k)]
                payload = _select_payload(cur, wv, idx)
                recv = _send(payload, axis, wv.perm,
                             None if bcast_wave
                             else _REDUCE_WIRE.get(codec))
                new = _apply_wave(list(cur), wv, recv, idx, valid=valid)
                for j in range(k):
                    if new[j] is not cur[j]:
                        if bcast_wave:
                            pst = seg_update(pst, j, segc, new[j])
                        else:
                            st = seg_update(st, j, segc, new[j])
        if codec is not None:
            # pack pseudo-stage: segment t - boundary crosses into bcast
            seg = t - boundary
            valid = (seg >= 0) & (seg < segments)
            segc = jnp.clip(seg, 0, segments - 1)
            for j in range(k):
                wire = q8_pack(seg_slice(st, j, segc))
                old = seg_slice(pst, j, segc)
                pst = seg_update(pst, j, segc,
                                 jnp.where(valid, wire, old))
        return st, pst

    st, pst = jax.lax.fori_loop(0, nsteps, body, (st, pst))
    if codec is not None:
        scales = jax.lax.bitcast_convert_type(
            pst[:, :, msub:], jnp.float32).reshape(k, segments, 1)
        st = (pst[:, :, :msub].astype(jnp.float32) * scales).astype(st.dtype)
    return [st[j].reshape(-1) for j in range(k)]


def tree_allreduce(x, spec, quantize: bool = False, segments="auto"):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``.

    Dispatches on the spec form: a
    :class:`repro.core.collectives.PipelinedAllreduceSpec` runs the
    pipelined segmented engine (the default the rest of the stack
    compiles), a :class:`repro.core.collectives.StripedCollectiveSpec`
    the striped reduce-scatter/allgather engine
    (:mod:`repro.dist.striped`; stripe windows replace segment streaming,
    so ``segments`` does not apply), a
    :class:`repro.core.collectives.FusedAllreduceSpec` the fused
    global-round baseline, a :class:`TreeAllreduceSpec` the per-tree
    chains.  All return the summed array in the original shape
    (replicated across the fabric).
    """
    if isinstance(spec, PipelinedAllreduceSpec):
        return pipelined_tree_allreduce(x, spec, quantize, segments)
    if isinstance(spec, StripedCollectiveSpec):
        from .striped import striped_allreduce  # late: striped imports us
        return striped_allreduce(x, spec, quantize=quantize)
    if isinstance(spec, FusedAllreduceSpec):
        return fused_tree_allreduce(x, spec, quantize)
    return per_tree_allreduce(x, spec, quantize)