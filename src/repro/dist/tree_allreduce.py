"""k-tree allreduce under ``shard_map`` (the paper's Sec. 1.1 payoff, run).

``repro.core.collectives.allreduce_schedule`` turns a set of k edge-disjoint
spanning trees into per-tree reduce (leaves->root) and broadcast
(root->leaves) rounds over *vertex ids*.  ``spec_from_schedule`` compiles
those rounds into a static :class:`TreeAllreduceSpec` keyed to mesh axis
names; ``tree_allreduce`` executes the spec inside a ``shard_map`` body with
``jax.lax.ppermute``, striping the (flattened) gradient into k chunks --
chunk j travels tree j, so the k trees use disjoint physical links and run
concurrently.

Vertex ids are the row-major flattened index over the mesh axes being
reduced (``jax.lax.axis_index(axes)``), which matches how
``repro.core.topologies.device_topology`` numbers the fabric.

``ppermute`` needs unique sources *and* destinations per call, so schedule
rounds that fan in (several children -> one parent) or fan out (one parent
-> several children) are statically split into sub-rounds here; the tree
semantics are unchanged (reduction is associative, broadcast idempotent).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# static spec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TreeProgram:
    """One tree's rounds, each a tuple of (src, dst) pairs with unique
    sources and destinations (ppermute-legal)."""
    root: int
    reduce_rounds: tuple
    bcast_rounds: tuple


@dataclass(frozen=True)
class TreeAllreduceSpec:
    n: int                 # fabric size = product of the reduced axis sizes
    axes: tuple            # mesh axis names the allreduce runs over
    trees: tuple           # tuple[TreeProgram]

    @property
    def k(self) -> int:
        return len(self.trees)

    @property
    def depth(self) -> int:
        return max((len(t.bcast_rounds) for t in self.trees), default=0)


def _split_unique(msgs):
    """Partition one round's (src, dst) messages into ppermute-legal
    sub-rounds: within a sub-round no vertex repeats as src or as dst."""
    out = []
    remaining = list(msgs)
    while remaining:
        srcs, dsts, taken, rest = set(), set(), [], []
        for s, d in remaining:
            if s in srcs or d in dsts:
                rest.append((s, d))
            else:
                srcs.add(s)
                dsts.add(d)
                taken.append((s, d))
        out.append(tuple(taken))
        remaining = rest
    return out


def _compile_rounds(rounds):
    out = []
    for msgs in rounds:
        out.extend(_split_unique(msgs))
    return tuple(out)


def spec_from_schedule(sched, axis_names) -> TreeAllreduceSpec:
    """Compile an :class:`repro.core.collectives.AllreduceSchedule` into a
    static spec bound to the given mesh axis names."""
    trees = tuple(
        TreeProgram(root=ts.root,
                    reduce_rounds=_compile_rounds(ts.reduce_rounds),
                    bcast_rounds=_compile_rounds(ts.bcast_rounds))
        for ts in sched.trees)
    return TreeAllreduceSpec(n=sched.n, axes=tuple(axis_names), trees=trees)


# ---------------------------------------------------------------------------
# execution (inside shard_map)
# ---------------------------------------------------------------------------

def _axis_arg(spec: TreeAllreduceSpec):
    return spec.axes[0] if len(spec.axes) == 1 else tuple(spec.axes)


def _send(x, axis, perm, quantize: bool):
    """ppermute a chunk; devices nobody sends to receive zeros.  With
    ``quantize`` the payload travels as int8 with a per-chunk f32 scale
    (two collectives), cutting wire bytes 4x for f32 gradients."""
    if not quantize:
        return jax.lax.ppermute(x, axis, list(perm))
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_r = jax.lax.ppermute(q, axis, list(perm))
    s_r = jax.lax.ppermute(scale.astype(jnp.float32), axis, list(perm))
    return q_r.astype(x.dtype) * s_r.astype(x.dtype)


def _dst_mask(perm, n: int, axis):
    """Traced bool: is this device a destination of ``perm``?"""
    table = [False] * n
    for _, d in perm:
        table[d] = True
    idx = jax.lax.axis_index(axis)
    return jnp.asarray(table)[idx]


def run_tree_program(c, tree: TreeProgram, n: int, axis,
                     quantize: bool = False):
    """Reduce chunk ``c`` up ``tree`` and broadcast the total back down.

    The building block shared by :func:`tree_allreduce` (uniform striping)
    and :func:`repro.dist.fault.striped_tree_allreduce` (weighted striping
    over a degraded tree set).
    """
    # reduce: every non-root sends its accumulated value to its parent
    # exactly once, deepest level first, so parents accumulate complete
    # subtree sums before forwarding
    for perm in tree.reduce_rounds:
        c = c + _send(c, axis, perm, quantize)
    # broadcast: the root's total overwrites down the levels
    for perm in tree.bcast_rounds:
        recv = _send(c, axis, perm, quantize)
        c = jnp.where(_dst_mask(perm, n, axis), recv, c)
    return c


def tree_allreduce(x, spec: TreeAllreduceSpec, quantize: bool = False):
    """Allreduce (sum) of the per-device array ``x`` over ``spec.axes``.

    Must run inside a ``shard_map`` whose manual axes include ``spec.axes``.
    ``x`` is flattened, zero-padded to a multiple of k and split into k
    chunks; chunk j is reduced up and broadcast down tree j.  Returns the
    summed array in the original shape (replicated across the fabric).
    """
    if spec.k == 0:
        return x
    axis = _axis_arg(spec)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % spec.k
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(spec.k, -1)

    outs = [run_tree_program(chunks[j], tree, spec.n, axis, quantize)
            for j, tree in enumerate(spec.trees)]

    out = jnp.concatenate(outs) if spec.k > 1 else outs[0]
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)
