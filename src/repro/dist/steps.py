"""Sharded train steps with selectable gradient synchronization.

``make_train_step`` builds ``step(params, opt_state, batch) -> (params,
opt_state, metrics)`` for a mesh, with ``mode`` choosing how data-parallel
gradients are combined:

  * ``"gspmd"``   -- no manual collectives: the loss is computed on the
    global batch and XLA's SPMD partitioner inserts whatever all-reduces the
    (optional FSDP) shardings imply;
  * ``"psum_dp"`` -- explicit ``shard_map`` over the data axes with a
    ``jax.lax.psum`` gradient all-reduce (the TPU-native baseline);
  * ``"edst"``    -- the same ``shard_map``, but gradients travel the k-tree
    allreduce built from the paper's edge-disjoint spanning trees on the DP
    fabric (:func:`edst_spec_for_mesh`), chunks striped across trees.

All three modes compute identical gradients (up to float reassociation), so
they can be A/B'd freely; ``grad_accum`` microbatches the local batch and
``quantize`` sends int8 chunks over the trees.  Passing ``fault_runtime``
(see :mod:`repro.dist.fault`) makes the ``edst`` mode failure-event aware:
the step takes a traced ``schedule_id`` selecting among precompiled
healthy/degraded/rebuilt tree programs, so link failures are handled by a
scalar flip instead of a retrace.

``zero1=True`` (``mode="edst"``, striped engine) replaces the gradient
allreduce + dense optimizer with the ZeRO-1 pipeline: reduce-scatter the
gradients onto owner stripes, run the sharded AdamW of
:mod:`repro.optim.sharded` in the scattered domain, and allgather only
the updated params -- fewer collective waves per step than the composed
``striped_allreduce`` and ~n-fold less optimizer memory.

``edst_spec_for_mesh`` maps a device mesh to the star-product decomposition
of its data-parallel fabric.  By default the DP axes themselves are taken as
the torus dimensions; ``dp_torus_shape`` overrides that for pods whose
logical mesh flattens a different physical topology (e.g. a pure-DP (16, 1)
mesh that is physically a 4x4 torus -- the override recovers the 2-EDST
maximal packing where the flat view would see only a 16-ring).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as P

from ..core import topologies as topo
from ..optim.sharded import ShardedAdamW, ShardedOptState, decay_mask
from ..core.collectives import (FusedAllreduceSpec, PipelinedAllreduceSpec,
                                StripedCollectiveSpec, allreduce_schedule,
                                fused_spec_from_schedule,
                                pipelined_spec_from_schedule,
                                striped_spec_from_schedule, wave_wire_bytes)
from ..core.edst_star import star_edsts
from . import sharding as shd
from .compat import shard_map
from .fault import FaultAwareAllreduce
from .striped import stripe_slices, tree_allgather, tree_reduce_scatter
from .tree_allreduce import tree_allreduce

SYNC_MODES = ("gspmd", "psum_dp", "edst")


# ---------------------------------------------------------------------------
# mesh introspection
# ---------------------------------------------------------------------------

def dp_axes_of(mesh):
    """The data-parallel mesh axes present, outermost first."""
    return tuple(a for a in tuple(mesh.axis_names) if a in shd.DATA_AXES)


def dp_size(mesh) -> int:
    sizes = shd._axis_sizes(mesh)
    n = 1
    for a in dp_axes_of(mesh):
        n *= sizes[a]
    return n


def dp_fabric_for_mesh(mesh_shape, axis_names, dp_torus_shape=None):
    """The data-parallel fabric of a device mesh: (star_product, dp_axis_names).

    The DP fabric is the sub-mesh spanned by the ("pod", "data") axes; its
    physical ICI graph is taken to be the torus over those extents (row-major
    vertex ids = flattened DP rank, matching ``device_topology``).
    ``dp_torus_shape`` overrides the physical shape when the logical mesh
    flattens it (product must equal the DP extent).
    """
    axis_names = tuple(axis_names)
    dims = [int(s) for a, s in zip(axis_names, mesh_shape)
            if a in shd.DATA_AXES]
    names = tuple(a for a in axis_names if a in shd.DATA_AXES)
    n = int(np.prod(dims)) if dims else 1
    if n <= 1:
        raise ValueError("mesh has no data-parallel extent to sync over")
    phys = tuple(int(d) for d in dp_torus_shape) if dp_torus_shape \
        else tuple(d for d in dims if d > 1)
    if int(np.prod(phys)) != n:
        raise ValueError(f"dp_torus_shape {phys} != DP extent {n}")
    return topo.device_topology(phys), names


@functools.lru_cache(maxsize=None)
def _edst_spec_cached(mesh_shape, axis_names, dp_torus_shape, engine,
                      schedule):
    sp, names = dp_fabric_for_mesh(mesh_shape, axis_names, dp_torus_shape)
    if schedule == "composed":
        # the compositional path never materializes the flat message DAG:
        # factor EDSTs -> star trees -> ASAP wave placement, memoized on
        # StarProduct.cache_key()
        from ..core.product_schedule import composed_spec_for_star
        return composed_spec_for_star(sp, names, engine=engine)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    if engine == "fused":
        return fused_spec_from_schedule(sched, names, schedule=schedule)
    if engine == "striped":
        return striped_spec_from_schedule(sched, names, schedule=schedule)
    return pipelined_spec_from_schedule(sched, names, schedule=schedule)


ENGINES = ("pipelined", "fused", "striped")


def edst_spec_for_mesh(
        mesh_shape, axis_names, dp_torus_shape=None,
        engine: str = "pipelined", schedule: str = "greedy"
) -> PipelinedAllreduceSpec | FusedAllreduceSpec | StripedCollectiveSpec:
    """EDST allreduce spec for the data-parallel fabric of a device mesh
    (see :func:`dp_fabric_for_mesh` for the fabric choice).  ``engine``
    picks the compiled form: ``"pipelined"`` (default -- the list-
    scheduled segment-streaming wave program), ``"striped"`` (the
    reduce-scatter/allgather program of :mod:`repro.dist.striped`:
    stripe-sized wires for bandwidth-dominated fabrics) or ``"fused"``
    (the round-aligned A/B baseline).  ``schedule`` picks the
    wave-assembly strategy (``repro.core.collectives.SCHEDULES``):
    ``"greedy"`` list scheduling, ``"search"`` the seeded hillclimb, or
    ``"composed"`` the compositional product-schedule compiler (near-
    linear compile on 10k+-node fabrics).  Specs are cached by
    (topology, axes, engine, schedule): repeated calls -- every
    train-step build, every elastic rescale probe -- return the same
    object, so jitted executors taking the spec statically never
    retrace."""
    if engine not in ENGINES:
        raise ValueError(f"engine {engine!r} not in {ENGINES}")
    return _edst_spec_cached(
        tuple(mesh_shape), tuple(axis_names),
        None if dp_torus_shape is None else tuple(dp_torus_shape), engine,
        schedule)


def fault_runtime_for_mesh(mesh_shape, axis_names, dp_torus_shape=None,
                           engine: str = "pipelined",
                           schedule: str = "greedy") -> FaultAwareAllreduce:
    """Elastic EDST runtime (precompiled degraded/rebuilt failure-class
    schedules) for the data-parallel fabric of a device mesh.  Pass the
    result to ``make_train_step(mode="edst", fault_runtime=...)`` and feed
    its schedule ids into the step's ``schedule_id`` argument.
    ``engine`` selects the compiled program form of every failure class
    (striped classes re-stripe ownership over the surviving trees);
    ``schedule`` the wave-assembly strategy of the healthy entry (failure
    classes always compile greedy: their fabrics are degraded one-offs)."""
    sp, names = dp_fabric_for_mesh(mesh_shape, axis_names, dp_torus_shape)
    return FaultAwareAllreduce.build(sp.product(), star_edsts(sp).trees,
                                     names, engine=engine,
                                     schedule=schedule)


# ---------------------------------------------------------------------------
# train step factory
# ---------------------------------------------------------------------------

_WIRE_TABLE_CACHE: dict = {}


def _entry_wire_table(entries, nbytes: int, itemsize: int):
    """Per-entry total wire bytes of a fault runtime's precompiled
    schedules as an (E,) f32 table, memoized on (spec keys, payload) so
    traced closures index it without rebuilding per trace."""
    key = (tuple((e.spec.key, e.fractions) for e in entries),
           int(nbytes), int(itemsize))
    hit = _WIRE_TABLE_CACHE.get(key)
    if hit is None:
        hit = np.asarray(
            [float(sum(wave_wire_bytes(e.spec, nbytes, itemsize,
                                       e.fractions or None)))
             for e in entries], np.float32)
        _WIRE_TABLE_CACHE[key] = hit
    return hit


def make_train_step(api, opt, mesh, mode: str = "gspmd", fsdp: bool = True,
                    grad_accum: int = 1, quantize: bool = False,
                    dp_torus_shape=None, fault_runtime=None,
                    segments="auto", engine: str = "pipelined",
                    zero1: bool = False, codec=None,
                    telemetry: bool = False):
    """Build the jittable train step.  See module docstring for ``mode``.

    ``telemetry=True`` adds a structured in-graph metrics dict (all
    scalars, no extra collectives beyond the checksum):

      * ``"sync_dev"`` -- the integrity check on the synchronized
        gradients that feeds :class:`repro.dist.health.HealthMonitor`:
        for the replicating paths (``psum_dp`` / dense ``edst``) the
        cross-replica :func:`repro.dist.health.replication_divergence`
        of a payload checksum (~0 when every replica holds identical
        sums), for the ZeRO-1 path the scattered-domain
        :func:`repro.dist.striped.rs_conservation_gap`;
      * ``"sync_grad_norm"`` -- global L2 norm of the synchronized
        gradients (the ZeRO-1 path already emits ``"grad_norm"``);
      * ``"sync_schedule_id"`` -- the traced schedule id the sync ran on
        (0 without a fault runtime);
      * ``"sync_wire_bytes"`` -- static per-step wire bytes of the EDST
        sync program (``repro.core.collectives.wave_wire_bytes`` summed;
        with a fault runtime, a precompiled per-entry table indexed by
        the traced id -- so flips move the gauge without a retrace;
        0 for ``psum_dp``/``gspmd``, whose wire XLA owns).

    Every key is present in every mode (zero-valued where it does not
    apply), so downstream consumers never branch on dict shape.

    ``engine`` (``mode="edst"``, ignored when a ``fault_runtime`` carries
    its own engine) selects the compiled allreduce form -- see
    :func:`edst_spec_for_mesh`.

    ``zero1=True`` (``mode="edst"``, striped engine only) switches to the
    ZeRO-1 step: gradients are ``tree_reduce_scatter``'d onto owner
    stripes, :class:`repro.optim.sharded.ShardedAdamW` updates params in
    the scattered domain (global-norm clip via a stripe-local partial
    norm + one scalar psum), and only the updated params are
    ``tree_allgather``'d back -- strictly fewer collective waves per
    step than the composed ``striped_allreduce`` and ~n-fold less
    optimizer memory.  The step's ``opt_state`` is then a
    :class:`repro.optim.sharded.ShardedOptState` (build it with
    ``ShardedAdamW(opt).init_for(params, spec_or_runtime, ndp)``); with a
    ``fault_runtime`` a schedule-id flip re-stripes the collectives in
    the step while ``fault_runtime.reshard_owned`` moves ``mu``/``nu``
    to the new owners outside it, both retrace-free.  ``codec`` overrides
    the gradient-wire codec policy (params always allgather full
    precision).

    ``fault_runtime`` (a :class:`repro.dist.fault.FaultAwareAllreduce`,
    ``mode="edst"`` only) makes the step failure-event aware: its signature
    becomes ``step(params, opt_state, batch, schedule_id)`` where
    ``schedule_id`` is a traced ``jnp.int32`` scalar selecting among the
    runtime's precompiled healthy/degraded/rebuilt programs -- the driver
    maps a failure-event stream to ids via ``fault_runtime.on_failure`` and
    flips the scalar, never triggering a retrace.

    ``segments`` (``mode="edst"``) streams gradient chunks down the trees
    in that many pipeline segments (``"auto"``: backend-calibrated cost
    model; see :func:`repro.dist.tree_allreduce.pipelined_tree_allreduce`).
    """
    if mode not in SYNC_MODES:
        raise ValueError(f"mode {mode!r} not in {SYNC_MODES}")
    if fault_runtime is not None and mode != "edst":
        raise ValueError("fault_runtime requires mode='edst'")
    dp = dp_axes_of(mesh)
    ndp = dp_size(mesh)
    dp_arg = dp[0] if len(dp) == 1 else tuple(dp)
    manual_dp = mode in ("psum_dp", "edst") and ndp > 1

    if zero1:
        if mode != "edst":
            raise ValueError("zero1=True requires mode='edst'")
        if not manual_dp:
            raise ValueError("zero1=True needs a data-parallel extent > 1 "
                             "to shard optimizer state over")
        if fault_runtime is None and engine != "striped":
            raise ValueError("zero1=True requires engine='striped' (the "
                             "reduce-scatter/allgather split)")

    tree_spec = fault_sync = z_rs = z_sl = z_ag = None
    if mode == "edst" and manual_dp:
        if fault_runtime is not None:
            if fault_runtime.graph.n != ndp:
                raise ValueError(
                    f"fault_runtime fabric n={fault_runtime.graph.n} != "
                    f"DP extent {ndp}; rebuild it with fault_runtime_for_mesh")
            if zero1:
                z_rs, z_sl, z_ag = fault_runtime.make_zero1_sync(quantize,
                                                                 codec)
            else:
                fault_sync = fault_runtime.make_allreduce(quantize,
                                                          segments=segments)
        else:
            tree_spec = edst_spec_for_mesh(tuple(mesh.devices.shape),
                                           tuple(mesh.axis_names),
                                           dp_torus_shape, engine=engine)
            if zero1:
                # same three primitives as the fault runtime's switched
                # forms, on the single healthy spec (sid ignored); params
                # allgather full precision (see make_zero1_sync)
                def z_rs(flat, sid):
                    return tree_reduce_scatter(flat, tree_spec,
                                               quantize=quantize, codec=codec)

                def z_sl(flat, sid):
                    return stripe_slices(flat, tree_spec)

                def z_ag(owned, sid, shape):
                    return tree_allgather(owned, tree_spec, shape)

    # FSDP is expressed through the shardings callers place params/opt state
    # with (``sharding.tree_shardings(..., fsdp=fsdp)``, e.g. as jit
    # in_shardings) -- the step body itself adds no sharding constraints:
    # on this jaxlib, in-step constraints propagate into the remat'd scan
    # backward and the SPMD partitioner miscompiles it (wrong gradients
    # alongside "Involuntary full rematerialization" warnings).
    del fsdp

    def _tree_grad_norm(grads):
        return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in jax.tree.leaves(grads)))

    def _wire_gauge(nbytes, itemsize, sid):
        """Static wire bytes of the sync program this step runs.  With a
        fault runtime the per-entry totals are a compile-time table the
        traced schedule id indexes, so schedule flips move the gauge
        without retracing."""
        if fault_runtime is not None:
            vals = _entry_wire_table(fault_runtime.entries, nbytes, itemsize)
            table = jnp.asarray(vals, jnp.float32)
            return table[jnp.clip(sid, 0, len(fault_runtime.entries) - 1)]
        if tree_spec is not None:
            return jnp.float32(sum(wave_wire_bytes(tree_spec, nbytes,
                                                   itemsize)))
        return jnp.float32(0.0)

    def loss_of(p, b):
        loss, metrics = api.loss_fn(p, b)
        return loss, metrics

    vg = jax.value_and_grad(loss_of, has_aux=True)

    def local_loss_and_grads(params, batch):
        """Loss + grads on the (device-local) batch, microbatched when
        grad_accum > 1 (mean of microbatch grads == full-batch grad)."""
        if grad_accum == 1:
            (loss, aux), grads = vg(params, batch)
            return loss, aux, grads
        micro = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            loss_sum, grads_sum = carry
            (loss, aux), grads = vg(params, mb)
            return (loss_sum + loss,
                    jax.tree.map(jnp.add, grads_sum, grads)), aux

        zeros = jax.tree.map(lambda p_: jnp.zeros(p_.shape, p_.dtype), params)
        (loss_sum, grads_sum), auxs = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        loss = loss_sum / grad_accum
        grads = jax.tree.map(lambda g: g / grad_accum, grads_sum)
        aux = jax.tree.map(jnp.mean, auxs)
        return loss, aux, grads

    def synced_loss_and_grads(params, batch, schedule_id=None):
        if not manual_dp:
            loss, aux, grads = local_loss_and_grads(params, batch)
            if telemetry:  # nothing synchronized; divergence vacuously 0
                zero = jnp.zeros((), jnp.float32)
                return loss, aux, grads, {
                    "sync_dev": zero,
                    "sync_grad_norm": _tree_grad_norm(grads),
                    "sync_schedule_id": jnp.int32(0),
                    "sync_wire_bytes": zero}
            return loss, aux, grads

        def local(p, b, sid):
            loss, aux, grads = local_loss_and_grads(p, b)
            loss = jax.lax.pmean(loss, dp_arg)
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, dp_arg), aux)
            if mode == "psum_dp":
                grads = jax.tree.map(
                    lambda g: jax.lax.psum(g, dp_arg) / ndp, grads)
                flat = ravel_pytree(grads)[0] if telemetry else None
            else:
                flat, unravel = ravel_pytree(grads)
                if fault_sync is not None:
                    flat = fault_sync(flat, sid)
                else:
                    flat = tree_allreduce(flat, tree_spec, quantize=quantize,
                                          segments=segments)
                grads = unravel(flat / ndp)
            if telemetry:
                from .health import payload_checksum, replication_divergence
                dev = replication_divergence(payload_checksum(flat), dp_arg)
                itemsize = jnp.dtype(flat.dtype).itemsize
                wire = (_wire_gauge(flat.size * itemsize, itemsize, sid)
                        if mode == "edst" else jnp.float32(0.0))
                return loss, aux, grads, {
                    "sync_dev": dev,
                    "sync_grad_norm": _tree_grad_norm(grads),
                    "sync_schedule_id": jnp.asarray(sid, jnp.int32),
                    "sync_wire_bytes": wire}
            return loss, aux, grads

        # Fully-manual shard_map: params replicate and the model axis is
        # unused inside, so TP/FSDP do not compose with the manual sync
        # modes here.  Keeping only the DP axes Manual (axis_names=set(dp))
        # is the right composition but hard-crashes this jaxlib's XLA
        # ("Check failed: sharding.IsManualSubgroup()") on the remat'd scan
        # -- revisit when the toolchain moves past 0.4.x.  Production
        # TP+FSDP meshes should use mode="gspmd" meanwhile.
        if schedule_id is None:
            schedule_id = jnp.int32(0)
        outs = (P(), P(), P()) + ((P(),) if telemetry else ())
        return shard_map(local, mesh=mesh,
                         in_specs=(P(), P(dp_arg), P()),
                         out_specs=outs,
                         check_rep=False)(params, batch, schedule_id)

    if zero1:
        sopt = ShardedAdamW(opt)

        def zero1_local(p, b, sid, step_count, mu, nu):
            """The whole ZeRO-1 step body, inside shard_map: grads ->
            reduce-scatter -> sharded AdamW on owner stripes ->
            allgather of updated params only.  mu/nu arrive as this
            device's (1, kmax, smax) block of the global state."""
            loss, aux, grads = local_loss_and_grads(p, b)
            loss = jax.lax.pmean(loss, dp_arg)
            aux = jax.tree.map(lambda a: jax.lax.pmean(a, dp_arg), aux)
            flat_g, _ = ravel_pytree(grads)
            flat_p, unravel = ravel_pytree(p)
            owned_g = z_rs(flat_g, sid) / ndp
            f32 = flat_p.astype(jnp.float32)
            owned_p = z_sl(f32, sid)
            owned_d = z_sl(decay_mask(p, opt.weight_decay), sid)
            new_count = step_count + 1
            gnorm = jnp.sqrt(jax.lax.psum(sopt.partial_sumsq(owned_g),
                                          dp_arg))
            new_op, new_mu, new_nu, lr = sopt.update_stripes(
                owned_p, owned_g, owned_d, mu[0], nu[0], new_count, gnorm)
            new_flat = z_ag(new_op, sid, f32.shape)
            new_params = unravel(new_flat.astype(flat_p.dtype))
            om = {"grad_norm": gnorm, "lr": lr}
            if telemetry:
                from .striped import rs_conservation_gap
                om["sync_dev"] = rs_conservation_gap(flat_g / ndp, owned_g,
                                                     dp_arg)
                itemsize = jnp.dtype(flat_g.dtype).itemsize
                om["sync_grad_norm"] = gnorm
                om["sync_schedule_id"] = jnp.asarray(sid, jnp.int32)
                om["sync_wire_bytes"] = _wire_gauge(
                    flat_g.size * itemsize, itemsize, sid)
            return loss, aux, new_params, new_mu[None], new_nu[None], om

        def _zstep(params, opt_state, batch, schedule_id=None):
            if schedule_id is None:
                schedule_id = jnp.int32(0)
            loss, aux, new_params, new_mu, new_nu, om = shard_map(
                zero1_local, mesh=mesh,
                in_specs=(P(), P(dp_arg), P(), P(), P(dp_arg), P(dp_arg)),
                out_specs=(P(), P(), P(), P(dp_arg), P(dp_arg), P()),
                check_rep=False)(params, batch, schedule_id,
                                 opt_state.step, opt_state.mu, opt_state.nu)
            new_state = ShardedOptState(opt_state.step + 1, new_mu, new_nu)
            metrics = {"loss": loss, **om, **aux}
            return new_params, new_state, metrics

        if fault_runtime is None:
            def zstep(params, opt_state, batch):
                return _zstep(params, opt_state, batch)
            return zstep

        def zfault_step(params, opt_state, batch, schedule_id):
            return _zstep(params, opt_state, batch, schedule_id)
        return zfault_step

    def _step(params, opt_state, batch, schedule_id=None):
        out = synced_loss_and_grads(params, batch, schedule_id)
        loss, aux, grads = out[:3]
        new_params, new_state, om = opt.apply(params, grads, opt_state)
        metrics = {"loss": loss, **om, **aux}
        if telemetry:
            metrics.update(out[3])
        return new_params, new_state, metrics

    if fault_runtime is None:
        def step(params, opt_state, batch):
            return _step(params, opt_state, batch)
        return step

    # fault-aware contract: always 4 args, even when the mesh has no DP
    # extent (schedule_id is then accepted and ignored -- nothing to sync)
    def fault_step(params, opt_state, batch, schedule_id):
        return _step(params, opt_state, batch, schedule_id)
    return fault_step
