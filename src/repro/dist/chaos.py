"""Seeded chaos: deterministic fault traces injected at the telemetry
boundary.

The recovery loop (:mod:`repro.dist.health` detects,
:mod:`repro.dist.recovery` escalates, :mod:`repro.dist.fault` recovers)
is only trustworthy if it survives *sustained* injected failure.  This
module generates reproducible fault traces against a
:class:`repro.dist.fault.FaultAwareAllreduce` and replays them through
the heartbeat probe's traced ``fault_mask`` -- wire faults are injected
where a real fabric would report them, without patching any collective,
so the detection/recovery path exercised is exactly the production one.

A trace is a tuple of :class:`ChaosEvent`, one per fault, chosen so
every rung of the escalation ladder fires:

  * ``flap``   -- one edge dead for a single detection tick (transient);
  * ``kill``   -- one edge dead forever, chosen to stay inside the
    precompiled failure classes (a scalar schedule-id flip recovers it);
  * ``burst``  -- a multi-link burst grown by :func:`out_of_class_burst`
    until NO precompiled class survives but the residual fabric is still
    connected, forcing the background ``with_rebuild`` + hot-swap path;
  * ``straggler``  -- wall-clock dilation of reported step times;
  * ``corruption`` -- checksum divergence injected into the telemetry
    stream (a healthy host fabric cannot corrupt payloads physically,
    so corruption enters at the detector output; the checksum machinery
    itself is unit-tested on genuinely divergent arrays);
  * ``node``   -- every link incident to one vertex dead (the probe
    signature of node loss), driving checkpoint + elastic rescale.

:class:`ChaosInjector` replays a trace tick by tick and answers the four
questions the soak harness asks each tick: which links to mask in the
probe, how much to dilate the reported step time, what checksum
deviation to report, and which node (if any) just died.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.fault import FailureEvent
from ..core.graph import canon
from ..telemetry import metrics as _metrics
from .health import LinkProbeSpec, runtime_links

KINDS = ("flap", "kill", "burst", "straggler", "corruption", "node")


@dataclass(frozen=True)
class ChaosEvent:
    """One injected fault.  ``duration`` counts detection ticks; ``-1``
    means permanent.  ``magnitude`` is the straggler time-dilation factor
    or the injected checksum deviation."""
    tick: int
    kind: str
    links: tuple = ()            # canonical undirected edges
    node: int | None = None
    duration: int = -1
    magnitude: float = 0.0

    def describe(self) -> str:
        what = {"flap": f"flap {list(self.links)}",
                "kill": f"kill {list(self.links)}",
                "burst": f"burst x{len(self.links)} {list(self.links)}",
                "straggler": f"straggler x{self.magnitude:.1f}",
                "corruption": f"corruption dev={self.magnitude:g}",
                "node": f"node {self.node} lost"}[self.kind]
        return f"t={self.tick}: {what}"


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

def out_of_class_burst(runtime, rng, already_dead=frozenset()) -> tuple:
    """Grow a random multi-link burst until no precompiled failure class
    of ``runtime`` survives it (``valid_ids == []``) while the residual
    fabric stays connected -- the smallest chaos that forces the
    ``with_rebuild`` Roskind-Tarjan path instead of a schedule flip."""
    edges = sorted({canon(s, d) for s, d in runtime_links(runtime)})
    order = [e for e in edges if e not in already_dead]
    rng.shuffle(order)
    dead = set(already_dead)
    picked = []
    for e in order:
        trial = frozenset(dead | {e})
        ev = FailureEvent(links=trial)
        residual = runtime.graph.without_edges(ev.dead_links(runtime.graph))
        if not residual.is_connected():
            continue
        dead.add(e)
        picked.append(e)
        if not runtime.valid_ids(ev):
            return tuple(picked)
    raise ValueError(
        "no connected out-of-class burst exists on this fabric "
        f"(n={runtime.graph.n}, k={runtime.k})")


def _alive_edge(runtime, rng, dead, in_class: bool):
    """A random probed edge whose death keeps the residual connected;
    ``in_class=True`` additionally requires some precompiled schedule to
    survive (so the event recovers via a flip, not a rebuild)."""
    edges = sorted({canon(s, d) for s, d in runtime_links(runtime)})
    order = [e for e in edges if e not in dead]
    rng.shuffle(order)
    for e in order:
        ev = FailureEvent(links=frozenset(dead | {e}))
        residual = runtime.graph.without_edges(ev.dead_links(runtime.graph))
        if not residual.is_connected():
            continue
        if in_class and not runtime.valid_ids(ev):
            continue
        return e
    raise ValueError("no eligible edge left on the fabric")


def make_trace(runtime, n_ticks: int, seed: int = 0, kinds=KINDS,
               gap: int = 5) -> tuple:
    """Seeded fault trace for ``runtime``: one event per requested kind,
    in order, spaced ``gap`` (+ seeded jitter) detection ticks apart so
    each recovery settles before the next fault lands.  Events are
    constrained against the INITIAL runtime -- kinds after ``burst`` or
    ``node`` land on whatever fabric recovery produced, which is exactly
    the point of a soak."""
    rng = np.random.default_rng(seed)
    events = []
    dead: set = set()
    t = 2
    for kind in kinds:
        if kind not in KINDS:
            raise ValueError(f"unknown chaos kind {kind!r} (not in {KINDS})")
        if kind == "flap":
            e = _alive_edge(runtime, rng, dead, in_class=True)
            events.append(ChaosEvent(t, "flap", links=(e,), duration=1))
        elif kind == "kill":
            e = _alive_edge(runtime, rng, dead, in_class=True)
            dead.add(e)
            events.append(ChaosEvent(t, "kill", links=(e,)))
        elif kind == "burst":
            picked = out_of_class_burst(runtime, rng, frozenset(dead))
            dead.update(picked)
            events.append(ChaosEvent(t, "burst", links=tuple(picked)))
        elif kind == "straggler":
            events.append(ChaosEvent(t, "straggler", duration=2,
                                     magnitude=float(rng.uniform(3.0, 5.0))))
        elif kind == "corruption":
            events.append(ChaosEvent(t, "corruption", duration=1,
                                     magnitude=1.0))
        elif kind == "node":
            v = int(rng.integers(runtime.graph.n))
            events.append(ChaosEvent(t, "node", node=v))
        t += gap + int(rng.integers(0, 2))
    if events and events[-1].tick + gap > n_ticks:
        raise ValueError(
            f"trace needs >= {events[-1].tick + gap} ticks to settle; "
            f"got n_ticks={n_ticks}")
    return tuple(events)


def trace_summary(trace) -> str:
    return "\n".join(ev.describe() for ev in trace)


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

@dataclass
class ChaosInjector:
    """Tick-by-tick replay of a trace.  Call :meth:`advance` once per
    detection tick, then query the injection surfaces: ``fault_mask``
    (for the heartbeat probe), ``time_dilation`` (multiply the measured
    step time), ``checksum_injection`` (add to the reported checksum
    deviation).  After an elastic rescale removed the dead node from the
    fabric, call :meth:`clear_fabric_state` -- the replacement fabric's
    wires are healthy."""
    trace: tuple
    tick: int = -1
    dead_edges: set = field(default_factory=set)
    dead_nodes: set = field(default_factory=set)
    fired: list = field(default_factory=list)
    _expiry: dict = field(default_factory=dict)   # edge -> expiry tick
    _straggle_until: int = -1
    _straggle_mag: float = 1.0
    _corrupt_until: int = -1
    _corrupt_mag: float = 0.0

    def __post_init__(self):
        self.trace = tuple(sorted(self.trace, key=lambda e: e.tick))

    @property
    def done(self) -> bool:
        return len(self.fired) == len(self.trace)

    def advance(self) -> tuple:
        """Enter the next tick; expire transient faults, fire new events.
        Returns the events that began this tick."""
        self.tick += 1
        for e, until in list(self._expiry.items()):
            if self.tick >= until:
                self.dead_edges.discard(e)
                del self._expiry[e]
        fired = tuple(ev for ev in self.trace if ev.tick == self.tick)
        for ev in fired:
            if ev.kind in ("flap", "kill", "burst"):
                self.dead_edges.update(ev.links)
                if ev.duration > 0:
                    for e in ev.links:
                        self._expiry[e] = self.tick + ev.duration
            elif ev.kind == "node":
                self.dead_nodes.add(ev.node)
            elif ev.kind == "straggler":
                self._straggle_until = self.tick + ev.duration
                self._straggle_mag = ev.magnitude
            elif ev.kind == "corruption":
                self._corrupt_until = self.tick + ev.duration
                self._corrupt_mag = ev.magnitude
        self.fired.extend(fired)
        for ev in fired:
            _metrics.counter("edst_chaos_events_total",
                             "injected chaos events by kind"
                             ).inc(kind=ev.kind)
        return fired

    def fault_mask(self, plan: LinkProbeSpec) -> np.ndarray:
        """(L,) float mask over ``plan.links``: 0.0 on wires this tick's
        fault state kills (either direction of a dead edge, or any wire
        touching a dead node)."""
        mask = np.ones(plan.num_links, np.float32)
        for i, (s, d) in enumerate(plan.links):
            if (canon(s, d) in self.dead_edges or s in self.dead_nodes
                    or d in self.dead_nodes):
                mask[i] = 0.0
        return mask

    def time_dilation(self) -> float:
        return self._straggle_mag if self.tick < self._straggle_until else 1.0

    def checksum_injection(self) -> float:
        return self._corrupt_mag if self.tick < self._corrupt_until else 0.0

    def clear_fabric_state(self) -> None:
        """The fabric was replaced (elastic rescale): dead wires and the
        lost node are no longer part of it."""
        self.dead_edges.clear()
        self.dead_nodes.clear()
        self._expiry.clear()
