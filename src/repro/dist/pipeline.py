"""GPipe pipeline parallelism over a mesh 'stage' axis.

``pipeline_apply`` runs the classic fill-steady-drain microbatch schedule
inside a ``shard_map``: stage s holds its own weights (in_spec sharded over
the stage axis), microbatch m enters stage 0 at step m and reaches stage s
at step m + s, activations hop stage->stage+1 with ``ppermute``.  After
n_micro + n_stages - 1 steps the last stage has every output; a masked
``psum`` replicates the (n_micro, mb, d) result across stages so the
``out_specs=P()`` contract holds.

``bubble_fraction`` is the idle fraction of the schedule,
(S - 1) / (M + S - 1) -- the standard GPipe bubble; it is what the roofline
charges pipeline-parallel cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Idle fraction of the GPipe schedule (0 when n_stages == 1)."""
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn, stage_params, x, axis_name):
    """Apply ``n_stages`` chained stages to ``n_micro`` microbatches.

    Must run inside a ``shard_map`` manual over ``axis_name``.

    stage_fn: ``(local_params, h) -> h`` for one stage (local_params keeps
    its sharded leading stage dim, length 1 per device).
    stage_params: per-stage weights, in_spec ``P(axis_name)``.
    x: ``(n_micro, mb, ...)`` microbatched input, replicated (``P()``).
    Returns the final-stage outputs ``(n_micro, mb, ...)``, replicated.
    """
    n_micro = x.shape[0]
    n_stages = jax.lax.psum(1, axis_name)          # static under shard_map
    sid = jax.lax.axis_index(axis_name)
    is_first = sid == 0
    is_last = sid == n_stages - 1
    fwd = [(s, s + 1) for s in range(n_stages - 1)]

    recv = jnp.zeros(x.shape[1:], x.dtype)
    outputs = jnp.zeros_like(x)
    for t in range(n_micro + n_stages - 1):
        # stage 0 injects microbatch t; everyone else consumes last hop
        x_t = x[t] if t < n_micro else jnp.zeros(x.shape[1:], x.dtype)
        h = stage_fn(stage_params, jnp.where(is_first, x_t, recv))
        m = t - (n_stages - 1)
        if m >= 0:   # the last stage just finished microbatch m
            outputs = jnp.where(is_last, outputs.at[m].set(h), outputs)
        if t < n_micro + n_stages - 2:
            recv = jax.lax.ppermute(h, axis_name, fwd)
    # replicate the last stage's collected outputs to every stage
    return jax.lax.psum(jnp.where(is_last, outputs, 0.0), axis_name)
