"""Closed-loop recovery: classify detector output, walk the escalation
ladder, journal every transition.

:class:`RecoveryController` is the state machine between
:mod:`repro.dist.health` (detect) and :mod:`repro.dist.fault` (recover).
One ``observe(report)`` call per detection tick returns a
:class:`Decision` telling the training driver what to do *this* tick;
the controller owns the runtime handle, retry counters, and the
structured journal.

The escalation ladder (most transitions are per-cause; see
``dist/README.md`` for the full diagram):

  1. **transient flap** -- a link fails one probe: the link becomes a
     *suspect* and the decision is ``retry`` (stall this tick, bounded
     backoff, re-probe).  If the next probe is clean the flap is
     journaled (cause ``link-flap``) and training resumes on the same
     schedule -- no flip, no recompile.
  2. **persistent link kill** -- a suspect outlives
     ``policy.flap_tolerance`` probes: it is confirmed dead, classified
     into a ``FailureEvent``, and recovered with
     ``runtime.on_failure`` -- a scalar schedule-id flip to the best
     precompiled degraded/rebuilt class (``flip``).
  3. **out-of-class failure** (multi-link burst spanning trees): no
     precompiled class avoids every dead link, so ``with_rebuild`` -- a
     Roskind-Tarjan repack of the actual residual fabric -- runs in a
     background thread while the driver holds position (``stall`` ticks,
     counted as steps degraded); when the repack lands it is hot-swapped
     in (``hot-swap``) and the driver re-jits its step against the new
     runtime's switch.
  4. **payload corruption** -- replication/conservation checksum
     divergence: the just-executed step is discarded (``redo_step``) and
     retried; ``policy.max_retries`` consecutive corrupt retries
     escalate to a full rebuild of the same fabric (a corrupt wire the
     probe cannot localize).
  5. **node loss** -- every probed link of a vertex dead: atomic
     checkpoint (``on_checkpoint``) then elastic rescale
     (``on_rescale`` -> new mesh + runtime), replacing the bare
     ``NoScheduleError`` the runtime alone would raise.

Every transition appends a :class:`JournalEntry` (cause, action,
schedule ids, steps degraded, wall-clock MTTR).  The journal is
*replayable*: :func:`replay_journal` recomputes the final (generation,
schedule-id) pair from the entries alone, so a recovery log can be
audited offline against the runtime state it claims to have produced.
With ``journal_path=`` every entry is ALSO appended to a JSONL file
(monotonic ``seq`` numbers, one flush per entry) so post-mortems survive
the process; ``replay_journal`` accepts the file form directly, and the
same choke point increments
``edst_recovery_transitions_total{cause,action}`` in
:mod:`repro.telemetry.metrics` -- journal and counters reconcile by
construction.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field

from ..analysis.verify import check_schedule_id
from ..core.fault import FailureEvent
from ..telemetry import metrics as _metrics
from .fault import NoScheduleError

CAUSES = ("link-flap", "link-kill", "link-burst", "payload-corruption",
          "straggler", "node-loss")
ACTIONS = ("retry", "flip", "rebuild", "hot-swap", "rescale", "observe")


@dataclass(frozen=True)
class Decision:
    """What the training driver should do this tick."""
    action: str                 # "none" | one of ACTIONS
    schedule_id: int            # id to feed the step's traced switch
    stall: bool = False        # do not run a train step this tick
    redo_step: bool = False    # last step's result is suspect: roll back
    backoff_s: float = 0.0     # driver-side sleep before the next tick
    runtime_changed: bool = False  # re-jit: the switch's entries changed
    detail: dict = field(default_factory=dict)


@dataclass
class JournalEntry:
    """One structured recovery-journal row."""
    step: int                  # detection tick
    cause: str                 # one of CAUSES
    action: str                # one of ACTIONS
    from_schedule: int
    to_schedule: int
    generation: int            # runtime generation AFTER the action
    steps_degraded: int = 0    # observe ticks from detection to recovery
    mttr_s: float | None = None  # wall-clock detection -> recovered
    detail: dict = field(default_factory=dict)

    def to_row(self) -> dict:
        return {"step": self.step, "cause": self.cause,
                "action": self.action,
                "from_schedule": self.from_schedule,
                "to_schedule": self.to_schedule,
                "generation": self.generation,
                "steps_degraded": self.steps_degraded,
                "mttr_s": self.mttr_s, "detail": dict(self.detail)}


def load_journal(path) -> list:
    """Parse a JSONL journal file back into :class:`JournalEntry` rows,
    asserting the ``seq`` numbers are strictly monotonic (a torn or
    re-ordered file is a corrupt post-mortem and raises)."""
    entries, last_seq = [], -1
    with open(path) as f:
        for ln, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            seq = row.pop("seq", None)
            if not isinstance(seq, int) or seq <= last_seq:
                raise ValueError(f"journal {path} line {ln + 1}: seq "
                                 f"{seq!r} not monotonic (last {last_seq})")
            last_seq = seq
            entries.append(JournalEntry(**row))
    return entries


def replay_journal(journal) -> tuple:
    """Re-derive the final ``(generation, schedule_id)`` from journal
    entries alone -- the offline audit the soak tests assert against the
    live controller state.  Accepts a list of :class:`JournalEntry` (or
    plain ``to_row()`` dicts) or the path of a JSONL journal file."""
    if isinstance(journal, (str, os.PathLike)):
        journal = load_journal(journal)
    gen, sid = 0, 0
    for e in journal:
        if isinstance(e, dict):
            e = JournalEntry(**{k: v for k, v in e.items() if k != "seq"})
        if e.action in ("flip", "hot-swap", "rescale"):
            gen, sid = e.generation, e.to_schedule
    return gen, sid


@dataclass
class RecoveryPolicy:
    """Escalation knobs (see the ladder in the module docstring)."""
    flap_tolerance: int = 1     # failed probes before a suspect is confirmed
    max_retries: int = 3        # consecutive corrupt redos before rebuild
    backoff_base_s: float = 0.05  # retry backoff: base * 2^attempt
    backoff_cap_s: float = 2.0
    checksum_tol: float = 1e-3
    background_rebuild: bool = True  # False: rebuild inline (deterministic)
    prefer: str = "max_k"       # on_failure preference

    def backoff(self, attempt: int) -> float:
        return min(self.backoff_base_s * (2 ** max(attempt - 1, 0)),
                   self.backoff_cap_s)


class RecoveryController:
    """The detect->classify->escalate->recover state machine.

    ``on_checkpoint()`` and ``on_rescale(event)`` are driver callbacks
    for the node-loss rung: the first must atomically persist training
    state, the second must deliver a NEW
    :class:`repro.dist.fault.FaultAwareAllreduce` for the rescaled
    fabric (and is free to swap the mesh/step behind the scenes).  With
    no rescale callback a node loss parks the controller in ``stall``
    and journals ``rescale`` as required-but-unavailable, so drivers
    without elasticity degrade to a loud no-progress state instead of an
    unhandled exception."""

    def __init__(self, runtime, policy: RecoveryPolicy | None = None,
                 on_checkpoint=None, on_rescale=None, clock=time.monotonic,
                 journal_path=None):
        self.runtime = runtime
        self.policy = policy or RecoveryPolicy()
        self.on_checkpoint = on_checkpoint
        self.on_rescale = on_rescale
        self.clock = clock
        self.generation = 0
        self.journal: list = []
        self.journal_path = journal_path   # JSONL sink (None: memory only)
        self._seq = 0
        self.state = "healthy"   # healthy | suspect | degraded | rebuilding
        #                          | stalled
        self._suspects: dict = {}     # edge -> (first_tick, first_time, count)
        self._dead: set = set()       # confirmed dead edges (this fabric)
        self._retries = 0             # consecutive corrupt redos
        self._rebuild: dict | None = None  # in-flight background rebuild
        self._stall_cause: tuple | None = None

    # -- public surface -----------------------------------------------------

    @property
    def schedule_id(self) -> int:
        return self.runtime.active

    def journal_rows(self) -> list:
        return [e.to_row() for e in self.journal]

    def observe(self, report) -> Decision:
        """Consume one :class:`repro.dist.health.HealthReport`; returns
        the decision for this tick.  Severity order: an adoptable
        finished rebuild first, then node loss, links, checksums,
        stragglers."""
        now = self.clock()
        adopted = self._maybe_adopt_rebuild(report.step, now)
        if adopted is not None:
            return adopted
        if self._rebuild is not None:
            return self._stall_decision(report.step)

        nodes = report.node_suspects()
        if nodes:
            return self._on_node_loss(report.step, nodes, now)

        decision = self._on_links(report, now)
        if decision is not None:
            return decision

        if not report.checksum_ok:
            return self._on_corruption(report, now)
        self._retries = 0

        if report.straggler:
            self._journal(report.step, "straggler", "observe",
                          self.schedule_id, self.schedule_id, 0, 0.0,
                          {"step_time": report.step_time})
        return Decision("none", self.schedule_id)

    # -- journal helpers ----------------------------------------------------

    def _journal(self, step, cause, action, from_sid, to_sid,
                 steps_degraded, mttr_s, detail=None) -> JournalEntry:
        bad = check_schedule_id(len(self.runtime.entries), to_sid)
        if bad is not None:  # defence in depth: never journal a bogus flip
            raise NoScheduleError(str(bad))
        e = JournalEntry(step=step, cause=cause, action=action,
                         from_schedule=from_sid, to_schedule=to_sid,
                         generation=self.generation,
                         steps_degraded=steps_degraded, mttr_s=mttr_s,
                         detail=detail or {})
        self.journal.append(e)
        _metrics.counter(
            "edst_recovery_transitions_total",
            "recovery journal transitions by cause and action"
        ).inc(cause=cause, action=action)
        if self.journal_path is not None:
            with open(self.journal_path, "a") as f:
                f.write(json.dumps({"seq": self._seq, **e.to_row()}) + "\n")
            self._seq += 1
        return e

    # -- links: flap / kill / burst -----------------------------------------

    def _on_links(self, report, now) -> Decision | None:
        failed = report.failed_edges() - self._dead
        cleared = [e for e in self._suspects if e not in failed]
        for edge in cleared:   # transient flap healed: journal + resume
            tick0, t0, count = self._suspects.pop(edge)
            self._journal(report.step, "link-flap", "retry",
                          self.schedule_id, self.schedule_id,
                          count, now - t0, {"link": list(edge)})
        confirmed = set()
        for edge in failed:
            tick0, t0, count = self._suspects.get(
                edge, (report.step, now, 0))
            count += 1
            self._suspects[edge] = (tick0, t0, count)
            if count > self.policy.flap_tolerance:
                confirmed.add(edge)
        if confirmed:
            return self._on_confirmed_dead(report.step, confirmed, now)
        if self._suspects:   # suspects pending: hold position, re-probe
            self.state = "suspect"
            attempt = max(c for _, _, c in self._suspects.values())
            return Decision("retry", self.schedule_id, stall=True,
                            backoff_s=self.policy.backoff(attempt),
                            detail={"suspects": sorted(
                                list(e) for e in self._suspects)})
        if self.state == "suspect":
            self.state = "degraded" if self._dead else "healthy"
        return None

    def _on_confirmed_dead(self, step, confirmed, now) -> Decision:
        tick0 = min(self._suspects[e][0] for e in confirmed)
        t0 = min(self._suspects[e][1] for e in confirmed)
        for e in confirmed:
            self._suspects.pop(e, None)
        self._dead |= confirmed
        cause = "link-burst" if len(self._dead) > 1 else "link-kill"
        event = FailureEvent(links=frozenset(self._dead))
        from_sid = self.schedule_id
        try:
            self.runtime = self.runtime.on_failure(
                event, prefer=self.policy.prefer)
        except NoScheduleError:
            # out of the precompiled classes: Roskind-Tarjan repack in
            # the background, hold position meanwhile
            self._start_rebuild(step, event, cause, tick0, t0)
            return self._stall_decision(step)
        self.state = "degraded"
        self._journal(step, cause, "flip", from_sid, self.schedule_id,
                      step - tick0, now - t0,
                      {"dead_links": sorted(list(e) for e in confirmed),
                       "entry": self.runtime.entry.name,
                       "k": self.runtime.entry.k})
        return Decision("flip", self.schedule_id,
                        detail={"entry": self.runtime.entry.name,
                                "from_schedule": from_sid})

    # -- out-of-class: background rebuild + hot swap ------------------------

    def _start_rebuild(self, step, event, cause, tick0, t0) -> None:
        self.state = "rebuilding"
        box = {"step": step, "cause": cause, "tick0": tick0, "t0": t0,
               "event": event, "result": None, "error": None,
               "thread": None}

        def work():
            try:
                box["result"] = self.runtime.with_rebuild(event)
            except Exception as exc:  # surfaced on adoption
                box["error"] = exc

        if self.policy.background_rebuild:
            th = threading.Thread(target=work, name="edst-rebuild",
                                  daemon=True)
            box["thread"] = th
            th.start()
        else:
            work()
        self._rebuild = box

    def _maybe_adopt_rebuild(self, step, now) -> Decision | None:
        box = self._rebuild
        if box is None:
            return None
        th = box["thread"]
        if th is not None and th.is_alive():
            return self._stall_decision(step)
        self._rebuild = None
        if box["error"] is not None:
            raise NoScheduleError(
                f"background rebuild failed: {box['error']}")
        from_sid = self.schedule_id
        self.runtime = box["result"]
        self.generation += 1
        self._dead = set()      # the rebuilt schedule avoids them by
        self._suspects = {}     # construction; fresh detection state
        self.state = "degraded"
        self._journal(step, box["cause"], "hot-swap", from_sid,
                      self.schedule_id, step - box["tick0"],
                      now - box["t0"],
                      {"k": self.runtime.k,
                       "dead_links": sorted(
                           list(e) for e in box["event"].links)})
        return Decision("hot-swap", self.schedule_id, runtime_changed=True,
                        detail={"k": self.runtime.k})

    def _stall_decision(self, step) -> Decision:
        return Decision("rebuild", self.schedule_id, stall=True,
                        backoff_s=self.policy.backoff(1),
                        detail={"state": self.state})

    # -- corruption ---------------------------------------------------------

    def _on_corruption(self, report, now) -> Decision:
        self._retries += 1
        if self._retries > self.policy.max_retries:
            # a wire corrupting every retry that no probe localizes:
            # recompile the whole fabric (same graph, fresh programs)
            event = FailureEvent(links=frozenset(self._dead))
            self._start_rebuild(report.step, event, "payload-corruption",
                                report.step, now)
            self._retries = 0
            return self._stall_decision(report.step)
        self._journal(report.step, "payload-corruption", "retry",
                      self.schedule_id, self.schedule_id, 1, 0.0,
                      {"checksum_dev": report.checksum_dev,
                       "attempt": self._retries})
        return Decision("retry", self.schedule_id, redo_step=True,
                        backoff_s=self.policy.backoff(self._retries),
                        detail={"checksum_dev": report.checksum_dev})

    # -- node loss: checkpoint + elastic rescale ----------------------------

    def _on_node_loss(self, step, nodes, now) -> Decision:
        event = FailureEvent(nodes=frozenset(nodes),
                             links=frozenset(self._dead))
        if self.on_rescale is None:
            self.state = "stalled"
            if self._stall_cause is None:   # journal once, stall forever
                self._stall_cause = ("node-loss", now)
                self._journal(step, "node-loss", "observe",
                              self.schedule_id, self.schedule_id, 0, None,
                              {"nodes": sorted(nodes),
                               "error": "no on_rescale callback"})
            return Decision("rescale", self.schedule_id, stall=True,
                            detail={"nodes": sorted(nodes)})
        from_sid = self.schedule_id
        if self.on_checkpoint is not None:
            self.on_checkpoint()
        new_runtime = self.on_rescale(event)
        if new_runtime is None:
            raise NoScheduleError(
                "on_rescale returned no runtime for node loss "
                f"{sorted(nodes)}")
        self.runtime = new_runtime
        self.generation += 1
        self._dead = set()
        self._suspects = {}
        self.state = "degraded"
        self._journal(step, "node-loss", "rescale", from_sid,
                      self.schedule_id, 0, self.clock() - now,
                      {"nodes": sorted(nodes), "n": new_runtime.graph.n,
                       "k": new_runtime.k})
        return Decision("rescale", self.schedule_id, runtime_changed=True,
                        detail={"nodes": sorted(nodes),
                                "n": new_runtime.graph.n})
