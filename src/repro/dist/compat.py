"""JAX API compatibility layer.

The distributed layer (and its tests) is written against the current JAX
surface: ``jax.shard_map(..., axis_names=..., check_vma=...)`` and
``jax.set_mesh(mesh)``.  Older jaxlibs (this container ships 0.4.x) spell
these ``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``
and activate a mesh with the ``with mesh:`` resource context.  This module
provides version-agnostic wrappers and, on import of :mod:`repro.dist`,
installs them onto ``jax`` when the new names are missing -- so driver
scripts and test snippets run unchanged on either version.

No behaviour is patched when the running JAX already has the new API.
"""
from __future__ import annotations

import contextlib

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None, check_rep=None, **kw):
    """Version-agnostic ``shard_map``.

    ``axis_names`` -- the set of mesh axes that are Manual inside ``f``
    (everything else stays Auto/GSPMD); maps to ``auto=`` on old JAX.
    ``check_vma`` (new) / ``check_rep`` (old) -- replication checking.
    """
    check = check_vma if check_vma is not None else check_rep
    native = getattr(jax, "shard_map", None)
    if native is not None and native is not _compat_shard_map:
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check is not None:
            kw["check_vma"] = check
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
    from jax.experimental.shard_map import shard_map as _sm
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    if check is not None:
        kw["check_rep"] = check
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def _compat_shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                      check_vma=None, check_rep=None, **kw):
    """Installed as ``jax.shard_map`` on old JAX: translate new-API kwargs
    down to ``jax.experimental.shard_map.shard_map``."""
    from jax.experimental.shard_map import shard_map as _sm
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    check = check_vma if check_vma is not None else check_rep
    if check is not None:
        kw["check_rep"] = check
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def set_mesh(mesh):
    """Version-agnostic mesh activation: usable as ``with set_mesh(mesh):``.

    New JAX has ``jax.set_mesh``; on old JAX a concrete ``Mesh`` is itself
    the resource-env context manager, so we just hand it back.
    """
    native = getattr(jax, "set_mesh", None)
    if native is not None and native is not _compat_set_mesh:
        return native(mesh)
    return _compat_set_mesh(mesh)


def _compat_set_mesh(mesh):
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def install():
    """Add ``jax.shard_map`` / ``jax.set_mesh`` when this JAX predates them."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _compat_shard_map
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _compat_set_mesh
