"""Elastic EDST runtime: precompiled failure-class schedules, no retracing.

``repro.core.fault`` knows *what* to do when links die (keep the surviving
edge-disjoint trees, repack the residual fabric with Roskind-Tarjan,
re-stripe chunks around stragglers) but is pure Python over ``Graph``
objects.  This module turns that machinery into runnable distributed
behavior under ``shard_map``:

  * :class:`FaultAwareAllreduce` compiles, up front, one ppermute program
    per *failure class*: the healthy k-tree schedule, one degraded
    (k-1)-tree schedule per tree (valid for ANY single-link failure inside
    that tree, because edge-disjointness means the dead link belongs to
    exactly one tree), and one rebuilt-EDST schedule per tree (Roskind-
    Tarjan repacking of the fabric minus that whole tree, so it is also
    valid for the entire class).
  * :func:`FaultAwareAllreduce.make_allreduce` wraps the programs in a
    single ``jax.lax.switch`` keyed by a *traced* integer schedule id, so
    flipping from the healthy schedule to a degraded or rebuilt one is a
    scalar update -- the jitted train step is never retraced.
  * Chunk striping is weighted by :func:`repro.core.fault.rebalance_chunks`
    (inverse critical-path cost), so when a tree dies the gradient
    re-stripes over the survivors and sync degrades from k-way to
    (k-1)-way bandwidth instead of failing.

Failures outside the precompiled classes (multiple trees hit at once, node
loss) go through :meth:`FaultAwareAllreduce.with_rebuild`, which repacks
the actual residual fabric into a NEW runtime -- one fresh compile,
amortized over the rest of the run (core.fault's "rebuild in the
background" step made concrete).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from ..core.collectives import (AllreduceSchedule, CostModel,
                                FusedAllreduceSpec, PipelinedAllreduceSpec,
                                StripedCollectiveSpec, allreduce_schedule,
                                empty_pipelined_spec, empty_striped_spec,
                                owner_element_map,
                                pipelined_spec_from_schedule,
                                simulate_allreduce,
                                striped_spec_from_schedule, striped_tables)
from ..core.edst_rt import max_edsts
from ..core.fault import FailureEvent, rebalance_chunks
from ..core.graph import Graph, canon
from ..telemetry import metrics as _metrics
from .tree_allreduce import (chunk_sizes,  # noqa: F401  (re-exported)
                             fused_tree_allreduce, pipelined_tree_allreduce)


class NoScheduleError(RuntimeError):
    """No precompiled schedule survives the failure; a dynamic rebuild
    (``with_rebuild``) or an elastic rescale (``repro.launch.elastic``) is
    required before the collective can resume."""


# ---------------------------------------------------------------------------
# schedule entries
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleEntry:
    """One precompiled failure-class program.  ``spec`` carries the
    runtime's engine form: the pipelined wave program by default, or the
    striped reduce-scatter/allgather program when the runtime was built
    with ``engine="striped"`` (a link kill then re-stripes ownership
    over the surviving k-1 trees instead of just re-weighting chunks)."""
    name: str                      # "full" | "degraded/tree<j>" | "rebuilt/tree<j>"
    spec: PipelinedAllreduceSpec | StripedCollectiveSpec
    fractions: tuple               # per-tree chunk fractions, sum 1
    sched: AllreduceSchedule | None  # core schedule (cost model / simulator)

    @property
    def k(self) -> int:
        return self.spec.k

    @property
    def depth(self) -> int:
        return self.spec.depth

    def uses_link(self, dead_links: set) -> bool:
        if self.sched is None:
            return False
        return any(set(ts.tree) & dead_links for ts in self.sched.trees)


def striped_tree_allreduce(x, spec, fractions, quantize: bool = False,
                           segments="auto"):
    """Weighted-stripe k-tree allreduce: contiguous slice j of the flattened
    array (``chunk_sizes(size, fractions)[j]`` elements) travels tree j.

    Dispatches on the spec form (pipelined wave program by default,
    striped reduce-scatter/allgather for ``engine="striped"`` runtimes,
    fused round-major for A/B runs); every engine runs the unequal
    slices padded to a common row width, so degraded (k-1)-striping
    shares the healthy program's wave structure.
    """
    if spec.k == 0:
        return x
    if isinstance(spec, StripedCollectiveSpec):
        from .striped import striped_allreduce
        return striped_allreduce(x, spec, quantize, fractions=fractions)
    if isinstance(spec, FusedAllreduceSpec):
        return fused_tree_allreduce(x, spec, quantize, fractions=fractions)
    return pipelined_tree_allreduce(x, spec, quantize, segments=segments,
                                    fractions=fractions)


def _pad_stripes(owned, kmax: int, smax: int):
    """Zero-pad a (k, s) stripe stack to the runtime-wide (kmax, smax)
    so every switch branch returns one common shape."""
    k, s = owned.shape
    if k == kmax and s == smax:
        return owned
    return jnp.pad(owned, ((0, kmax - k), (0, smax - s)))


def _entry(name: str, n: int, trees, axes,
           engine: str = "pipelined",
           schedule: str = "greedy") -> ScheduleEntry:
    trees = [frozenset(canon(*e) for e in t) for t in trees]
    empty = (empty_striped_spec if engine == "striped"
             else empty_pipelined_spec)
    compile_spec = (striped_spec_from_schedule if engine == "striped"
                    else pipelined_spec_from_schedule)
    if not trees:
        return ScheduleEntry(name, empty(n, axes), (), None)
    sched = allreduce_schedule(n, trees)
    fracs = tuple(rebalance_chunks(sched, {}))
    return ScheduleEntry(name, compile_spec(sched, axes, schedule=schedule),
                         fracs, sched)


# ---------------------------------------------------------------------------
# the runtime
# ---------------------------------------------------------------------------

@dataclass
class FaultAwareAllreduce:
    """Precompiled healthy/degraded/rebuilt EDST allreduce programs with a
    scalar schedule id selecting among them (see module docstring).

    Entry layout (k = healthy tree count):
      id 0          -- full k-tree schedule;
      id 1 .. k     -- degraded: tree j-1 lost, chunks re-striped over the
                       k-1 survivors;
      id k+1 .. 2k  -- rebuilt: max EDST repacking of the fabric minus all
                       of tree j-k-1's links (>= the degraded k-1, often k).
    """
    graph: Graph
    axes: tuple
    entries: tuple                 # tuple[ScheduleEntry]
    active: int = 0
    history: list = field(default_factory=list)
    engine: str = "pipelined"      # compiled form of every entry's spec
    # jitted stripe-permutation gathers, keyed (from_id, to_id, size);
    # shared across on_failure replaces so a flip never recompiles
    _reshard_cache: dict = field(default_factory=dict, repr=False)

    @classmethod
    def build(cls, graph: Graph, trees, axis_names,
              engine: str = "pipelined",
              schedule: str = "greedy") -> "FaultAwareAllreduce":
        """``schedule`` applies to the healthy (id 0) entry only -- the
        degraded/rebuilt classes are one-off fabrics where a search or
        composed compile buys nothing over greedy."""
        if engine not in ("pipelined", "striped"):
            raise ValueError(
                f"engine {engine!r} not in ('pipelined', 'striped')")
        trees = [frozenset(canon(*e) for e in t) for t in trees]
        axes = tuple(axis_names)
        k = len(trees)
        entries = [_entry("full", graph.n, trees, axes, engine,
                          schedule=schedule)]
        for j in range(k):
            keep = trees[:j] + trees[j + 1:]
            entries.append(_entry(f"degraded/tree{j}", graph.n, keep, axes,
                                  engine))
        for j in range(k):
            # class rebuild: drop ALL of tree j's links, so the repacked
            # trees avoid any single link failure attributable to tree j
            residual = graph.without_edges(trees[j])
            rebuilt = max_edsts(residual)[0] if residual.is_connected() else []
            if not rebuilt:  # k=1 fabrics: nothing to repack from
                rebuilt = trees[:j] + trees[j + 1:]
            entries.append(_entry(f"rebuilt/tree{j}", graph.n, rebuilt, axes,
                                  engine))
        return cls(graph, axes, tuple(entries), engine=engine)

    @property
    def k(self) -> int:
        return self.entries[0].k

    @property
    def entry(self) -> ScheduleEntry:
        return self.entries[self.active]

    # -- failure handling ---------------------------------------------------

    def valid_ids(self, event: FailureEvent) -> list:
        """Precompiled schedules whose trees avoid every dead link."""
        dead = event.dead_links(self.graph)
        return [i for i, e in enumerate(self.entries)
                if e.k > 0 and not e.uses_link(dead)]

    def on_failure(self, event: FailureEvent,
                   prefer: str = "max_k") -> "FaultAwareAllreduce":
        """Select the recovery schedule for ``event`` -- a scalar id flip,
        never a retrace.  ``prefer="max_k"`` picks the surviving program
        with the most trees (rebuilt classes usually restore k);
        ``prefer="degraded"`` picks the lowest valid id (the plain
        surviving-tree program, mirroring core.fault's immediate degraded
        mode).  Raises :class:`NoScheduleError` when no precompiled program
        survives (multi-tree wipeout, node loss) -- use ``with_rebuild``.
        """
        if event.nodes:
            raise NoScheduleError(
                "node loss changes the fabric; rescale via repro.launch.elastic")
        valid = self.valid_ids(event)
        if not valid:
            raise NoScheduleError(
                "no precompiled schedule survives; use with_rebuild(event)")
        if prefer == "degraded":
            pick = valid[0]
        else:
            pick = max(valid, key=lambda i: (self.entries[i].k,
                                             -self.entries[i].depth, -i))
        hist = self.history + [(self.entries[pick].name, self.entries[pick].k)]
        _metrics.counter("edst_schedule_flips_total",
                         "precompiled schedule-id flips on failure"
                         ).inc(prefer=prefer)
        return replace(self, active=pick, history=hist)

    def with_rebuild(self, event: FailureEvent) -> "FaultAwareAllreduce":
        """Dynamic fallback for failures outside the precompiled classes:
        Roskind-Tarjan repack of the ACTUAL residual fabric into a fresh
        runtime (one new compile, then switching is free again)."""
        if event.nodes:
            raise NoScheduleError(
                "node loss changes the fabric; rescale via repro.launch.elastic")
        dead = event.dead_links(self.graph)
        residual = self.graph.without_edges(dead)
        if not residual.is_connected():
            raise NoScheduleError("residual fabric disconnected")
        trees, _ = max_edsts(residual)
        if not trees:
            raise NoScheduleError("residual fabric packs no spanning tree")
        rebuilt = FaultAwareAllreduce.build(residual, trees, self.axes,
                                           engine=self.engine)
        rebuilt.history = self.history + [("with_rebuild", len(trees))]
        _metrics.counter("edst_rebuilds_total",
                         "dynamic Roskind-Tarjan schedule rebuilds").inc()
        return rebuilt

    # -- execution ----------------------------------------------------------

    def make_allreduce(self, quantize: bool = False, segments="auto",
                       debug: bool | None = None):
        """``allreduce(x, schedule_id)`` for use inside ``shard_map``: a
        ``jax.lax.switch`` over the precompiled programs.  Pass
        ``schedule_id`` as a traced ``jnp.int32`` scalar so every program
        compiles into the one executable and switching never retraces
        (a Python int would constant-fold the switch away).  ``segments``
        streams chunks down the trees in that many pipeline segments
        (``"auto"``: backend-calibrated cost model) -- degraded and
        rebuilt programs pipeline exactly like the healthy one.

        ``lax.switch`` clamps its index into range, so an out-of-range
        ``schedule_id`` would silently run the WRONG failure-class
        program.  ``debug=True`` (default from ``REPRO_DEBUG_SWITCH=1``)
        adds the traced bounds guard -- the ``sid-out-of-range``
        verifier invariant (:func:`repro.analysis.verify
        .check_schedule_id`) enforced in-graph: a ``checkify.debug_check``
        (a real error under ``checkify.checkify``) plus a NaN-poisoned
        result so the violation is loud even in plain-jit runs where
        debug_check is a no-op."""
        entries = self.entries
        if debug is None:
            import os
            debug = os.environ.get("REPRO_DEBUG_SWITCH", "0") == "1"

        def branch(e: ScheduleEntry):
            if e.k == 0:
                return lambda v: v  # unreachable via on_failure; identity
            return lambda v: striped_tree_allreduce(v, e.spec, e.fractions,
                                                    quantize, segments)

        branches = [branch(e) for e in entries]
        num = len(branches)

        def allreduce(x, schedule_id):
            out = jax.lax.switch(schedule_id, branches, x)
            if debug:
                from jax.experimental import checkify
                ok = (schedule_id >= 0) & (schedule_id < num)
                checkify.debug_check(
                    ok, "sid-out-of-range: schedule id {sid} outside the "
                        f"precompiled entry table [0, {num})",
                    sid=schedule_id)
                # no debug-callback here: host callbacks under manual
                # sharding crash XLA; the NaN poison below is the signal
                poison = jnp.where(ok, jnp.zeros((), out.dtype),
                                   jnp.full((), jnp.nan, out.dtype)
                                   if jnp.issubdtype(out.dtype, jnp.floating)
                                   else jnp.zeros((), out.dtype))
                out = out + poison
            return out

        return allreduce

    # -- ZeRO-1: scattered-domain primitives --------------------------------

    def _require_striped(self):
        if self.engine != "striped":
            raise ValueError(
                "zero1 needs the reduce-scatter/allgather split: build the "
                "runtime with engine='striped'")

    def zero1_geometry(self, size: int) -> tuple:
        """(kmax, smax): the padded stripe-stack shape covering every
        precompiled failure class for a ``size``-element payload -- the
        shape of the zero1 optimizer state (see
        :func:`repro.optim.sharded.zero1_geometry`)."""
        self._require_striped()
        kmax = max(e.k for e in self.entries)
        smax = max(striped_tables(e.spec, size, e.fractions).smax
                   for e in self.entries if e.k > 0)
        return kmax, smax

    def zero1_element_map(self, size: int,
                          entry_id: int | None = None) -> np.ndarray:
        """Element ownership of one failure class, padded to the
        runtime-wide ``(n, kmax, smax)`` (``-1`` = padding): row ``v``
        names the flat payload indices device ``v`` owns under that
        schedule.  This is the stripe geometry sharded checkpoints save
        alongside the moment stripes."""
        kmax, smax = self.zero1_geometry(size)
        e = self.entries[self.active if entry_id is None else entry_id]
        out = np.full((self.graph.n, kmax, smax), -1, np.int64)
        if e.k > 0:
            m = owner_element_map(e.spec, size, e.fractions)
            out[:, :m.shape[1], :m.shape[2]] = m
        return out

    def owned_permutation(self, from_id: int, to_id: int,
                          size: int) -> np.ndarray:
        """The precompiled stripe permutation between two failure
        classes: ``perm[v, j, i]`` is the linear index into the
        flattened ``(n, kmax, smax)`` ``from_id``-layout state of the
        element that lands at ``[v, j, i]`` under ``to_id`` (``-1`` =
        padding).  Pure NumPy over the cached element maps -- build it
        (and :meth:`reshard_owned`'s jit) ahead of the failure so the
        link-kill flip stays retrace-free end to end."""
        kmax, smax = self.zero1_geometry(size)
        map_a = self.zero1_element_map(size, from_id)
        map_b = self.zero1_element_map(size, to_id)
        inv = np.full(size, -1, np.int64)
        va, ja, ia = np.nonzero(map_a >= 0)
        inv[map_a[va, ja, ia]] = (va * kmax + ja) * smax + ia
        perm = np.full((self.graph.n, kmax, smax), -1, np.int64)
        vb, jb, ib = np.nonzero(map_b >= 0)
        perm[vb, jb, ib] = inv[map_b[vb, jb, ib]]
        return perm

    def reshard_owned(self, arr, from_id: int, to_id: int, size: int):
        """Re-shard ``(ndp, kmax, smax)`` owner-stripe state (zero1
        ``mu`` / ``nu``) from one failure class's ownership to
        another's: a single precompiled gather, exact (a permutation of
        the same elements).  Runs OUTSIDE the train step -- the step's
        ``schedule_id`` switch handles the collectives, this handles the
        moments the flip strands on old owners.  The jitted gather is
        cached per (from_id, to_id, size), so repeated flips (and the
        flip back) never recompile."""
        self._require_striped()
        key = (from_id, to_id, int(size))
        fn = self._reshard_cache.get(key)
        if fn is None:
            perm = jnp.asarray(self.owned_permutation(from_id, to_id, size))

            def _apply(a):
                flat = a.reshape(-1)
                out = flat[jnp.clip(perm, 0, flat.size - 1)]
                return jnp.where(perm >= 0, out,
                                 jnp.zeros((), a.dtype)).reshape(a.shape)

            fn = jax.jit(_apply)
            self._reshard_cache[key] = fn
        out = fn(arr)
        # keep the caller's placement: zero1 state lives sharded P(dp) in
        # the train step's jit cache, and a flip that hands back a
        # single-device array would force a recompile on the next step.
        sharding = getattr(arr, "sharding", None)
        if sharding is not None:
            out = jax.device_put(out, sharding)
        return out

    def make_zero1_sync(self, quantize: bool = False, codec=None):
        """The three scattered-domain primitives of the zero1 step, each
        a ``jax.lax.switch`` over the precompiled failure classes (same
        traced ``schedule_id`` contract as :meth:`make_allreduce`):

          * ``rs(flat, sid)``    -- gradient reduce-scatter -> (kmax, smax)
            summed owner stripes (codec policy applies to these wires);
          * ``slices(flat, sid)`` -- communication-free owner-stripe cut
            of a replicated vector (params, decay mask);
          * ``ag(owned, sid, shape)`` -- allgather of updated params.
            Always full precision: optimizer-state-derived params must
            not accumulate wire quantization error across steps, so the
            codec compresses only the transient gradient wires.

        Every branch pads to the runtime-wide geometry, so the jit cache
        stays flat across schedule-id flips; ``k=0`` entries (k=1
        fabrics with nothing to repack from, unreachable via
        ``on_failure``) return zeros."""
        self._require_striped()
        from .striped import stripe_slices, tree_allgather, \
            tree_reduce_scatter
        entries = self.entries

        def rs(flat, sid):
            kmax, smax = self.zero1_geometry(flat.size)

            def branch(e):
                if e.k == 0:
                    return lambda v: jnp.zeros((kmax, smax), v.dtype)
                return lambda v: _pad_stripes(
                    tree_reduce_scatter(v, e.spec, e.fractions, quantize,
                                        codec), kmax, smax)

            return jax.lax.switch(sid, [branch(e) for e in entries], flat)

        def slices(flat, sid):
            kmax, smax = self.zero1_geometry(flat.size)

            def branch(e):
                if e.k == 0:
                    return lambda v: jnp.zeros((kmax, smax), v.dtype)
                return lambda v: _pad_stripes(
                    stripe_slices(v, e.spec, e.fractions), kmax, smax)

            return jax.lax.switch(sid, [branch(e) for e in entries], flat)

        def ag(owned, sid, shape):
            size = 1
            for d in shape:
                size *= int(d)

            def branch(e):
                if e.k == 0:
                    return lambda o: jnp.zeros(shape, o.dtype)
                smax_e = striped_tables(e.spec, size, e.fractions).smax
                return lambda o: tree_allgather(
                    o[:e.spec.k, :smax_e], e.spec, shape, e.fractions)

            return jax.lax.switch(sid, [branch(e) for e in entries], owned)

        return rs, slices, ag

    # -- reporting ----------------------------------------------------------

    def effective_bandwidth(self, nbytes: float, entry_id: int | None = None,
                            cost_model: CostModel | None = None) -> float:
        """bytes/s the schedule sustains for an ``nbytes`` allreduce."""
        e = self.entries[self.active if entry_id is None else entry_id]
        if e.sched is None:
            return 0.0
        cm = cost_model or CostModel()
        return nbytes / cm.edst_tree_allreduce(nbytes, e.sched)

    def verify_entry(self, entry_id: int, d: int | None = None,
                     seed: int = 0, static: bool = False) -> bool:
        """Correctness of one precompiled program.  ``static=True`` runs
        the O(messages) static verifier (:mod:`repro.analysis.verify`)
        on the entry's compiled spec -- no simulation, the mode fleet
        controllers should use on large fabrics; the default replays the
        schedule through the NumPy packet simulator."""
        e = self.entries[entry_id]
        if e.sched is None:
            return False
        if static:
            from ..analysis.verify import verify_spec
            return verify_spec(e.spec, level="full").ok
        d = d or 8 * e.k
        vals = np.random.RandomState(seed).randn(self.graph.n, d)
        return simulate_allreduce(e.sched, vals).ok

    def report(self, nbytes: float = 64 << 20,
               cost_model: CostModel | None = None) -> dict:
        """One row per precompiled program: tree count, schedule depth,
        modelled allreduce cost and effective bandwidth."""
        cm = cost_model or CostModel()
        rows = []
        for i, e in enumerate(self.entries):
            # k=0 entries (k=1 fabrics with nothing to repack from) carry no
            # cost: report None/0, not inf -- json.dumps(inf) is invalid JSON
            cost = (cm.edst_tree_allreduce(nbytes, e.sched)
                    if e.sched is not None else None)
            rows.append({"id": i, "name": e.name, "k": e.k,
                         "depth": e.depth,
                         "cost_ms": None if cost is None else cost * 1e3,
                         "gbps": 0.0 if cost is None else nbytes / cost / 1e9})
        return {"n": self.graph.n, "k": self.k, "active": self.active,
                "nbytes": nbytes, "entries": rows}
