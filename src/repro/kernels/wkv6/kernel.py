"""Pallas TPU kernel for the RWKV-6 chunked WKV recurrence.

Grid: (B * H, num_chunks) with the chunk dimension innermost; the per-head
state S (key_dim x value_dim, f32) lives in VMEM scratch and persists across
chunks.  Each step does three small MXU matmuls -- (c,n)@(n,c) intra-chunk
scores, (c,c)@(c,n) intra output, (c,n)@(n,n) state application -- plus the
log-space decay algebra from the reference (exact, stable: all exponentials
are of non-positive numbers after the per-chunk shift).

Chunk length and head dim default to 64: tiles are (64, 64), aligned to the
f32 (8, 128) VMEM layout after Mosaic padding, and the whole working set
(4 inputs + scores + state) is < 1 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sout_ref, s_ref,
                 *, chunk):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)      # (c, n)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)    # log decay, <= 0
    u = u_ref[...].astype(jnp.float32)      # (1, n) bonus

    lcum = jnp.cumsum(lw, axis=0)
    lprev = lcum - lw
    # two-factor log-space shift; clamp is inert while the per-chunk
    # cumulative decay range stays < 85 (true for RWKV6's w parametrization
    # at chunk <= 128) and avoids inf*0 NaNs beyond (see ref for details)
    mx = jnp.max(-lcum, axis=0, keepdims=True)
    kd = k * jnp.exp(jnp.clip(-lcum + mx, -85.0, 85.0))
    rd = r * jnp.exp(jnp.clip(lprev - mx, -85.0, 85.0))
    scores = jax.lax.dot_general(rd, kd, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (c,c)
    rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols < rows, scores, 0.0)   # strictly lower triangle

    diag = jnp.sum(r * u * k, axis=1, keepdims=True)          # (c, 1)
    o = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o = o + diag * v
    o = o + jax.lax.dot_general(r * jnp.exp(lprev), s_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)

    lc = lcum[-1:, :]                                          # (1, n)
    kdecay = k * jnp.exp(lc - lcum)
    s_new = jnp.exp(lc).T * s_ref[...] + jax.lax.dot_general(
        kdecay, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(ci == nc - 1)
    def _emit_state():
        sout_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, *, chunk=64, interpret=False):
    """r,k,v,logw: (B, T, H, N); u: (H, N).
    Returns (out (B,T,H,N), final state (B,H,N,N))."""
    b, t, h, n = r.shape
    c = min(chunk, t)
    t_pad = -(-t // c) * c
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)
    nc = t_pad // c

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t_pad, n)

    rr, kk, vv, lw = map(to_bh, (r, k, v, logw))
    ub = jnp.tile(u, (b, 1)).reshape(b * h, 1, n)

    out, sfin = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=c),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, c, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, c, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, c, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, c, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, 1, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, c, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((None, n, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, t_pad, n), r.dtype),
            jax.ShapeDtypeStruct((b * h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, ub)

    out = out.reshape(b, h, t_pad, n).transpose(0, 2, 1, 3)[:, :t]
    return out, sfin.reshape(b, h, n, n)
