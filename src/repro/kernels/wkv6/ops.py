"""jit'd entry point for WKV6: Pallas on TPU, jnp-chunked elsewhere."""
from __future__ import annotations

import jax

from .kernel import wkv6
from .ref import wkv6_ref


def wkv(r, k, v, logw, u, *, chunk=64, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return wkv6(r, k, v, logw, u, chunk=chunk,
                    interpret=jax.default_backend() != "tpu")
    return wkv6_ref(r, k, v, logw, u, chunk=chunk)
