"""Oracle: the jnp chunked WKV6 (itself validated against the naive
sequential recurrence in tests)."""
from repro.models.rwkv6 import wkv6_chunked, wkv6_step  # noqa: F401


def wkv6_ref(r, k, v, logw, u, chunk=64):
    return wkv6_chunked(r, k, v, logw, u, chunk=chunk)
