"""jit'd entry point: Pallas kernel on TPU, interpret-mode kernel or jnp
oracle elsewhere."""
from __future__ import annotations

import jax

from .kernel import flash_attention
from .ref import attention_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, q_block=256,
              kv_block=512, use_pallas=None):
    """Dispatch: Pallas (TPU), Pallas-interpret (explicitly requested), or
    the jnp oracle (CPU default -- interpret mode is too slow for real use)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_block=q_block, kv_block=kv_block,
                               interpret=not on_tpu())
    return attention_ref(q, k, v, causal=causal, window=window)
