"""Pallas TPU flash attention (causal GQA, optional sliding window).

Grid: (batch * kv_heads, num_q_blocks, num_kv_blocks) -- the kv dimension is
innermost, so the online-softmax carry (m, l, acc) lives in VMEM scratch and
persists across kv steps.  GQA is handled by flattening the q-per-kv group
into the row dimension: the q tile is (q_block * group, head_dim), giving a
single (rows x d) @ (d x kv_block) MXU matmul per step.

Causal / windowed kv blocks that are entirely masked are skipped with
pl.when (no FLOPs on TPU, unlike a masked dense loop).  Default blocks
(q_block=256 rows, kv_block=512) keep tiles MXU-aligned (multiples of
(8,128) for f32/bf16 at head_dim 64..256) and the VMEM working set
(q + k + v + scores + acc) at a few MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal, window, kv_block, q_block, group):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    q_lo = pl.program_id(1) * q_block      # absolute position of q row 0
    k_lo = ki * kv_block

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level reachability (skip fully-masked kv blocks entirely)
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + q_block - 1)
    if window is not None:
        live = jnp.logical_and(live, k_lo + kv_block - 1 > q_lo - window)

    @pl.when(live)
    def _step():
        q = q_ref[...].astype(jnp.float32)        # (q_block*group, d)
        k = k_ref[...].astype(jnp.float32)        # (kv_block, d)
        v = v_ref[...].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)   # (rows, kv_block)
        scores = scores * (1.0 / np.sqrt(q.shape[-1]))

        rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        t_abs = q_lo + rows // group
        s_abs = k_lo + cols
        if causal:
            scores = jnp.where(s_abs <= t_abs, scores, NEG_INF)
        if window is not None:
            scores = jnp.where(s_abs > t_abs - window, scores, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    q_block=256, kv_block=512, interpret=False):
    """q: (B, S, H, D); k, v: (B, T, KV, D).  H = KV * group.
    Returns (B, S, H, D).  S, T padded internally to block multiples
    (padded q rows produce garbage that is sliced off; padded kv columns are
    masked by causality -- for causal=False the caller must pass T already
    block-aligned)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    qb = min(q_block, s)
    kb = min(kv_block, t)
    s_pad = -(-s // qb) * qb
    t_pad = -(-t // kb) * kb
    if not causal and t_pad != t:
        raise ValueError("causal=False requires block-aligned T")
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))

    # (B, S, KV, G, D) -> (B*KV, S*G, D): row = s * group + g
    qr = q.reshape(b, s_pad, kv, group, d).transpose(0, 2, 1, 3, 4) \
          .reshape(b * kv, s_pad * group, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, t_pad, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, t_pad, d)

    nq, nk = s_pad // qb, t_pad // kb
    rows = qb * group

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          kv_block=kb, q_block=qb, group=group),
        grid=(b * kv, nq, nk),
        in_specs=[
            pl.BlockSpec((None, rows, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, kb, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, kb, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, rows, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, s_pad * group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out.reshape(b, kv, s_pad, group, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, s_pad, h, d)[:, :s]
