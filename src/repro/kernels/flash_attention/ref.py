"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,S,H,D); k,v: (B,T,KV,D) -> (B,S,H,D).  Quadratic memory."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    tpos = jnp.arange(s)[:, None]
    spos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = mask & (spos <= tpos)
    if window is not None:
        mask = mask & (spos > tpos - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
