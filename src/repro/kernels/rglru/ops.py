"""jit'd entry point for the RG-LRU scan."""
from __future__ import annotations

import jax

from .kernel import rglru_scan
from .ref import rglru_ref


def lru_scan(a, bx, h0=None, *, chunk=128, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return rglru_scan(a, bx, h0, chunk=chunk,
                          interpret=jax.default_backend() != "tpu")
    return rglru_ref(a, bx, h0)
