"""Oracle: the associative-scan RG-LRU from the model (itself the jnp
reference path)."""
from repro.models.rglru import rg_lru_scan  # noqa: F401


def rglru_ref(a, bx, h0=None):
    h = rg_lru_scan(a, bx, h0=h0)
    return h, h[:, -1]
