"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

h_t = a_t * h_{t-1} + b_t, elementwise over the width dim -- lane-parallel,
sequential over time.  Grid: (B, num_width_tiles, num_chunks), chunks
innermost; the carry h lives in VMEM scratch and persists across chunks.
Within a chunk the recurrence runs as a fori_loop over rows of the (chunk,
width_tile) block -- the width_tile (default 512 lanes) keeps the VPU busy
while time stays sequential, which is how Griffin's own TPU kernel schedules
it (the recurrence is not associative-scanned on TPU either; see
arXiv:2402.19427 App. A: "a linear scan").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, h_ref, *, chunk):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(i, h):
        h = a_ref[i, :].astype(jnp.float32) * h + b_ref[i, :].astype(jnp.float32)
        o_ref[i, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[0:1, :][0])
    h_ref[...] = h[None]

    @pl.when(ci == nc - 1)
    def _emit():
        hlast_ref[...] = h[None].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "width_tile",
                                             "interpret"))
def rglru_scan(a, bx, h0=None, *, chunk=128, width_tile=512, interpret=False):
    """a, bx: (B, T, W) -> (h (B,T,W), h_last (B,W)).  h0: (B, W) or None."""
    b, t, w = a.shape
    c = min(chunk, t)
    wt = min(width_tile, w)
    t_pad = -(-t // c) * c
    w_pad = -(-w // wt) * wt
    pad = ((0, 0), (0, t_pad - t), (0, w_pad - w))
    if t_pad != t or w_pad != w:
        a = jnp.pad(a, pad, constant_values=1.0)   # a=1, b=0: h passes through
        bx = jnp.pad(bx, pad)
    h0 = jnp.zeros((b, w_pad), jnp.float32) if h0 is None else \
        jnp.pad(h0.astype(jnp.float32), ((0, 0), (0, w_pad - w)))

    out, hlast = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=c),
        grid=(b, w_pad // wt, t_pad // c),
        in_specs=[
            pl.BlockSpec((None, c, wt), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((None, c, wt), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((None, 1, wt), lambda bi, wi, ci: (bi, 0, wi)),
        ],
        out_specs=[
            pl.BlockSpec((None, c, wt), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((None, 1, wt), lambda bi, wi, ci: (bi, 0, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_pad, w_pad), a.dtype),
            jax.ShapeDtypeStruct((b, 1, w_pad), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, wt), jnp.float32)],
        interpret=interpret,
    )(a, bx, h0[:, None, :])

    return out[:, :t, :w], hlast[:, 0, :w]
