"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), <name>/ops.py (jit'd wrapper; interpret=True on CPU) and
<name>/ref.py (pure-jnp oracle used by the models and the tests).
"""
