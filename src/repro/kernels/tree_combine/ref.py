"""Oracle for the tree-combine kernel."""
import jax.numpy as jnp


def tree_combine_ref(recv, partial):
    return (partial.astype(jnp.float32)
            + recv.astype(jnp.float32).sum(0)).astype(partial.dtype)
