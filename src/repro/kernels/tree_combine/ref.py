"""Oracles for the tree-combine and int8 wire-codec kernels (also the
host-backend fast paths: plain jnp ops that XLA fuses)."""
import jax
import jax.numpy as jnp


def tree_combine_ref(recv, partial):
    return (partial.astype(jnp.float32)
            + recv.astype(jnp.float32).sum(0)).astype(partial.dtype)


def q8_scale(x, axis=None, keepdims=False):
    """The per-chunk f32 scale: max|x| maps to the top of the int8 range.
    The epsilon keeps |x|/scale strictly below 127.5 so the rounded
    quantizer never leaves [-127, 127] (no clip on the hot path).
    ``axis`` computes one scale per row for row-batched packs."""
    return (jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
            * (1.0 / 127.0) + 1e-30).astype(jnp.float32)


def q8_pack_ref(x, scale):
    q = jnp.round(x.astype(jnp.float32) * (1.0 / scale)).astype(jnp.int8)
    tail = jax.lax.bitcast_convert_type(scale, jnp.int8)
    return jnp.concatenate([q, tail])


def q8_combine_ref(wire, partial):
    scale = jax.lax.bitcast_convert_type(wire[-4:], jnp.float32)
    return (partial.astype(jnp.float32)
            + wire[:-4].astype(jnp.float32) * scale).astype(partial.dtype)


def q8_unpack_ref(wire, dtype=jnp.float32):
    scale = jax.lax.bitcast_convert_type(wire[-4:], jnp.float32)
    return (wire[:-4].astype(jnp.float32) * scale).astype(dtype)


def q8_pack_rows_ref(x):
    """Row-batched pack: (k, m) float -> (k, m+4) int8 wires, one fused
    op chain for all chunk rows (k codec invocations would cost k op
    dispatches each on host backends)."""
    scale = q8_scale(x, axis=1, keepdims=True)
    q = jnp.round(x.astype(jnp.float32) * (1.0 / scale)).astype(jnp.int8)
    tails = jax.lax.bitcast_convert_type(scale, jnp.int8).reshape(
        x.shape[0], 4)
    return jnp.concatenate([q, tails], axis=1)


def q8_unpack_rows_ref(wires, dtype=jnp.float32):
    """Inverse of :func:`q8_pack_rows_ref`: (k, m+4) int8 -> (k, m)."""
    scale = jax.lax.bitcast_convert_type(wires[:, -4:], jnp.float32)
    return (wires[:, :-4].astype(jnp.float32)
            * scale.reshape(-1, 1)).astype(dtype)
