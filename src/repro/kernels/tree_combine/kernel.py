"""Pallas TPU kernels for the EDST tree collectives: the multi-child
partial-sum combine and the int8 wire codec.

``tree_combine``: out = partial + sum_over_children(recv) over a length-L
flat buffer, tiled so each grid step streams one (children, tile) block
through VMEM.  f32 accumulation regardless of payload dtype (gradient
chunks are bf16 on the wire when quantization is off).

``q8_pack_wire`` / ``q8_combine_wire`` / ``q8_unpack_wire``: the quantized
wire format is ``(L + 4,) int8`` -- L quantized lanes followed by the
per-chunk f32 scale bit-packed into a 4-byte tail, so a quantized hop is
ONE ppermute payload.  Pack (quantize + tail write), unpack+accumulate
(dequantize fused into the partial-sum add) and plain unpack each run as
a single kernel, replacing the separate quantize / bitcast / concatenate
/ dequantize XLA op chains that made the q8 path a regression.  The wire
kernels process the whole buffer as one VMEM block; callers fall back to
the reference for buffers beyond VMEM reach (``ops.combine`` handles the
dispatch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(recv_ref, part_ref, o_ref):
    acc = part_ref[...].astype(jnp.float32)
    acc = acc + jnp.sum(recv_ref[...].astype(jnp.float32), axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def tree_combine(recv, partial, *, tile=65536, interpret=False):
    """recv: (n_children, L); partial: (L,) -> (L,)."""
    nch, l = recv.shape
    tl = min(tile, l)
    l_pad = -(-l // tl) * tl
    if l_pad != l:
        recv = jnp.pad(recv, ((0, 0), (0, l_pad - l)))
        partial = jnp.pad(partial, (0, l_pad - l))

    out = pl.pallas_call(
        _combine_kernel,
        grid=(l_pad // tl,),
        in_specs=[
            pl.BlockSpec((nch, tl), lambda i: (0, i)),
            pl.BlockSpec((tl,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l_pad,), partial.dtype),
        interpret=interpret,
    )(recv, partial)
    return out[:l]


# ---------------------------------------------------------------------------
# int8 wire codec
# ---------------------------------------------------------------------------

def _scale_tail(scale):
    return jax.lax.bitcast_convert_type(scale.astype(jnp.float32), jnp.int8)


def _tail_scale(tail):
    return jax.lax.bitcast_convert_type(tail, jnp.float32)


def _q8_pack_kernel(x_ref, s_ref, o_ref):
    l = x_ref.shape[0]
    scale = s_ref[0]
    # |x| <= 127 * scale by construction of the scale, so no clip needed
    o_ref[:l] = jnp.round(x_ref[...].astype(jnp.float32)
                          * (1.0 / scale)).astype(jnp.int8)
    o_ref[l:] = _scale_tail(scale)


@functools.partial(jax.jit, static_argnames=("interpret",))
def q8_pack_wire(x, scale, *, interpret=False):
    """x: (L,) float, scale: () f32 with max|x| <= 127*scale -> (L+4,) int8
    wire buffer (quantized lanes + bit-packed scale tail), one kernel."""
    (l,) = x.shape
    return pl.pallas_call(
        _q8_pack_kernel,
        out_shape=jax.ShapeDtypeStruct((l + 4,), jnp.int8),
        interpret=interpret,
    )(x, scale.reshape(1))


def _q8_combine_kernel(w_ref, part_ref, o_ref):
    l = part_ref.shape[0]
    scale = _tail_scale(w_ref[l:])
    o_ref[...] = (part_ref[...].astype(jnp.float32)
                  + w_ref[:l].astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def q8_combine_wire(wire, partial, *, interpret=False):
    """partial + dequantize(wire): the quantize-aware combine -- scale
    extraction, dequantize and accumulate fused into one kernel."""
    (l,) = partial.shape
    return pl.pallas_call(
        _q8_combine_kernel,
        out_shape=jax.ShapeDtypeStruct((l,), partial.dtype),
        interpret=interpret,
    )(wire, partial)


def _q8_unpack_kernel(w_ref, o_ref):
    l = o_ref.shape[0]
    scale = _tail_scale(w_ref[l:])
    o_ref[...] = (w_ref[:l].astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def q8_unpack_wire(wire, dtype=jnp.float32, *, interpret=False):
    """Plain dequantize of a wire buffer (the broadcast-phase epilogue)."""
    (lw,) = wire.shape
    return pl.pallas_call(
        _q8_unpack_kernel,
        out_shape=jax.ShapeDtypeStruct((lw - 4,), dtype),
        interpret=interpret,
    )(wire)
