"""Pallas TPU kernel: multi-child partial-sum combine for the EDST tree
reduce (the per-round "in-switch" reduction, executed on-chip on TPU).

out = partial + sum_over_children(recv) over a length-L flat buffer, tiled so
each grid step streams one (children, tile) block through VMEM.  f32
accumulation regardless of payload dtype (gradient chunks are bf16 on the
wire when quantization is off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(recv_ref, part_ref, o_ref):
    acc = part_ref[...].astype(jnp.float32)
    acc = acc + jnp.sum(recv_ref[...].astype(jnp.float32), axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def tree_combine(recv, partial, *, tile=65536, interpret=False):
    """recv: (n_children, L); partial: (L,) -> (L,)."""
    nch, l = recv.shape
    tl = min(tile, l)
    l_pad = -(-l // tl) * tl
    if l_pad != l:
        recv = jnp.pad(recv, ((0, 0), (0, l_pad - l)))
        partial = jnp.pad(partial, (0, l_pad - l))

    out = pl.pallas_call(
        _combine_kernel,
        grid=(l_pad // tl,),
        in_specs=[
            pl.BlockSpec((nch, tl), lambda i: (0, i)),
            pl.BlockSpec((tl,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l_pad,), partial.dtype),
        interpret=interpret,
    )(recv, partial)
    return out[:l]
