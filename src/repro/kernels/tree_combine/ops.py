"""jit'd entry points for the tree-combine and int8 wire-codec kernels.

Dispatch policy: the Pallas kernels run on TPU (and under interpret mode
when explicitly requested); host backends take the jnp references, which
XLA fuses into the surrounding program -- interpret-mode Pallas would be
strictly slower there.  The wire kernels additionally fall back to the
reference for buffers too large for a single VMEM block.
"""
from __future__ import annotations

import jax

from .kernel import (q8_combine_wire, q8_pack_wire, q8_unpack_wire,
                     tree_combine)
from .ref import (q8_combine_ref, q8_pack_ref, q8_pack_rows_ref, q8_scale,
                  q8_unpack_ref, q8_unpack_rows_ref, tree_combine_ref)

# one VMEM block must hold the wire + the f32 view with headroom
_WIRE_VMEM_ELEMS = 1 << 20


def _on_tpu(use_pallas):
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return use_pallas


def combine(recv, partial, *, use_pallas=None):
    if _on_tpu(use_pallas):
        return tree_combine(recv, partial,
                            interpret=jax.default_backend() != "tpu")
    return tree_combine_ref(recv, partial)


def q8_pack(x, scale=None, *, use_pallas=None):
    """Quantize ``x`` into the ``(L+4,) int8`` wire form (payload + scale
    tail).  ``scale`` defaults to :func:`q8_scale` of ``x``."""
    if scale is None:
        scale = q8_scale(x)
    if _on_tpu(use_pallas) and x.size <= _WIRE_VMEM_ELEMS:
        return q8_pack_wire(x, scale,
                            interpret=jax.default_backend() != "tpu")
    return q8_pack_ref(x, scale)


def q8_combine(wire, partial, *, use_pallas=None):
    """partial + dequantize(wire): the quantize-aware tree combine."""
    if _on_tpu(use_pallas) and wire.size <= _WIRE_VMEM_ELEMS:
        return q8_combine_wire(wire, partial,
                               interpret=jax.default_backend() != "tpu")
    return q8_combine_ref(wire, partial)


def q8_unpack(wire, dtype=None, *, use_pallas=None):
    """Dequantize a wire buffer back to ``dtype`` (default f32)."""
    import jax.numpy as jnp
    dtype = jnp.float32 if dtype is None else dtype
    if _on_tpu(use_pallas) and wire.size <= _WIRE_VMEM_ELEMS:
        return q8_unpack_wire(wire, dtype,
                              interpret=jax.default_backend() != "tpu")
    return q8_unpack_ref(wire, dtype)


def q8_pack_rows(x, *, use_pallas=None):
    """Pack every chunk row at once: (k, m) -> (k, m+4) int8 wires (the
    broadcast-phase pack-once point).  On TPU the pack kernel vmaps over
    rows; host backends take the row-batched reference."""
    if _on_tpu(use_pallas) and x.size <= _WIRE_VMEM_ELEMS:
        scales = q8_scale(x, axis=1)
        interpret = jax.default_backend() != "tpu"
        return jax.vmap(lambda r, s: q8_pack_wire(r, s, interpret=interpret)
                        )(x, scales)
    return q8_pack_rows_ref(x)


def q8_unpack_rows(wires, dtype=None, *, use_pallas=None):
    """Inverse of :func:`q8_pack_rows`: (k, m+4) int8 -> (k, m)."""
    import jax.numpy as jnp
    dtype = jnp.float32 if dtype is None else dtype
    if _on_tpu(use_pallas) and wires.size <= _WIRE_VMEM_ELEMS:
        interpret = jax.default_backend() != "tpu"
        return jax.vmap(lambda w: q8_unpack_wire(w, dtype,
                                                 interpret=interpret)
                        )(wires)
    return q8_unpack_rows_ref(wires, dtype)
