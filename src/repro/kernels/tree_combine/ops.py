"""jit'd entry point for tree_combine."""
from __future__ import annotations

import jax

from .kernel import tree_combine
from .ref import tree_combine_ref


def combine(recv, partial, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return tree_combine(recv, partial,
                            interpret=jax.default_backend() != "tpu")
    return tree_combine_ref(recv, partial)
