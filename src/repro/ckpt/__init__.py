from .checkpoint import (latest_step, load_checkpoint, restore,
                         save_checkpoint)
