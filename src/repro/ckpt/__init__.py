from .checkpoint import (latest_step, latest_steps, load_checkpoint, restore,
                         restore_sharded, save_checkpoint,
                         save_sharded_checkpoint)

__all__ = ["latest_step", "latest_steps", "load_checkpoint", "restore",
           "restore_sharded", "save_checkpoint", "save_sharded_checkpoint"]
