"""Atomic npz checkpointing with resume + elastic re-shard.

Layout: <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir and
os.rename'd (atomic on POSIX) so a crash mid-save never corrupts the latest
checkpoint -- the fault-tolerance contract: training can be killed at any
point and restarts from the last complete step.

Arrays are gathered to host (fully replicated view) on save and re-placed
with the *current* mesh's shardings on restore, so restores work across
different mesh shapes (elastic rescaling) as long as logical shapes match.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}{k}/")
                for k in template}
    if isinstance(template, (list, tuple)):
        typ = type(template)
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        if hasattr(typ, "_fields"):   # NamedTuple (e.g. OptState)
            return typ(*vals)
        return typ(vals) if typ is list else tuple(vals)
    return flat[prefix[:-1]]


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(arrays),
                       "extra": extra or {}}, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
    # keep the two most recent checkpoints
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-2]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
    return final


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int):
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    return {k: npz[k] for k in npz.files}, manifest


def restore(ckpt_dir: str, template, step: int | None = None,
            shardings=None):
    """Restore into ``template``'s structure; place with ``shardings`` (same
    structure) if given -- this is where elastic re-shard happens."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None, None
    flat, manifest = load_checkpoint(ckpt_dir, step)
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    # cast back to template dtypes (npz stores concrete dtypes already)
    return tree, step, manifest.get("extra", {})
