"""Atomic npz checkpointing with resume + elastic re-shard.

Layout: <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir and
os.rename'd (atomic on POSIX) so a crash mid-save never corrupts the latest
checkpoint -- the fault-tolerance contract: training can be killed at any
point and restarts from the last complete step.

Arrays are gathered to host (fully replicated view) on save and re-placed
with the *current* mesh's shardings on restore, so restores work across
different mesh shapes (elastic rescaling) as long as logical shapes match.

ZeRO-1 owner-stripe state gets its own pair of entry points
(:func:`save_sharded_checkpoint` / :func:`restore_sharded`): each host
writes one ``shard_<v>.npz`` holding only its ``(kmax, smax)`` stripe rows
of ``mu`` / ``nu`` plus the element-id map that says which flat payload
slot each stripe cell owns.  Restore re-assembles the flat vectors from
the saved maps and re-scatters them to the *target* fabric's map -- which
may be a different topology, a degraded k-1 fabric, or a different
(kmax, smax) geometry entirely -- so a checkpoint taken on a healthy
fabric restores cleanly onto a re-striped one.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib

import jax
import numpy as np

from ..optim.sharded import ShardedOptState


def _file_crc32(path: str) -> int:
    """CRC32 of a file's bytes (streamed): the per-shard content checksum
    recorded in the manifest and verified on :func:`restore_sharded`."""
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


def _esc(k) -> str:
    """Escape one tree key for the "/"-joined flat namespace.  Without
    this, ``{"a": {"b/c": x}}`` and ``{"a/b": {"c": x}}`` flatten to the
    same ``"a/b/c"`` key and silently clobber each other in the npz."""
    return str(k).replace("%", "%25").replace("/", "%2F")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_esc(k)}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}{_esc(k)}/")
                for k in template}
    if isinstance(template, (list, tuple)):
        typ = type(template)
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        if hasattr(typ, "_fields"):   # NamedTuple (e.g. OptState)
            return typ(*vals)
        return typ(vals) if typ is list else tuple(vals)
    return flat[prefix[:-1]]


def _commit_step_dir(ckpt_dir: str, step: int, write_fn) -> str:
    """Shared atomic-publish path: ``write_fn(tmp_dir)`` stages the files,
    then one os.rename makes the step visible; keeps the 2 newest steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        write_fn(tmp)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-2]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
    return final


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def write(tmp):
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(arrays),
                       "extra": extra or {}}, f)

    return _commit_step_dir(ckpt_dir, step, write)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int):
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    return {k: npz[k] for k in npz.files}, manifest


def restore(ckpt_dir: str, template, step: int | None = None,
            shardings=None):
    """Restore into ``template``'s structure; place with ``shardings`` (same
    structure) if given -- this is where elastic re-shard happens."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None, None
    flat, manifest = load_checkpoint(ckpt_dir, step)
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    # cast back to template dtypes (npz stores concrete dtypes already)
    return tree, step, manifest.get("extra", {})


# ---------------------------------------------------------------------------
# ZeRO-1 owner-stripe checkpoints
# ---------------------------------------------------------------------------

def save_sharded_checkpoint(ckpt_dir: str, step: int, params,
                            opt_state: ShardedOptState, elem_map, size: int,
                            extra: dict | None = None, hosts=None):
    """Sharded ZeRO-1 save: params (replicated) go to ``arrays.npz``; each
    owner host ``v`` writes ``shard_<v>.npz`` with its ``mu`` / ``nu``
    stripe rows and the ``(kmax, smax)`` element-id row saying which flat
    payload slots those cells hold (-1 = padding).  ``elem_map`` is the
    ``(n, kmax, smax)`` ownership map of the fabric the state was trained
    on -- :func:`repro.core.collectives.owner_element_map` for a plain
    spec, :meth:`repro.dist.fault.FaultAwareAllreduce.zero1_element_map`
    for the active failure class.  ``hosts`` restricts which shard files
    this process writes (multi-host: each process passes its own ranks);
    default writes all of them."""
    elem = np.asarray(elem_map)
    n = int(elem.shape[0])
    mu = np.asarray(jax.device_get(opt_state.mu))
    nu = np.asarray(jax.device_get(opt_state.nu))
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    ranks = range(n) if hosts is None else list(hosts)

    def write(tmp):
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        checksums = {}
        for v in ranks:
            name = f"shard_{int(v):05d}.npz"
            np.savez(os.path.join(tmp, name), mu=mu[v], nu=nu[v],
                     elem=elem[v])
            checksums[name] = _file_crc32(os.path.join(tmp, name))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(arrays),
                       "sharded": {
                           "size": int(size), "n": n,
                           "kmax": int(elem.shape[1]),
                           "smax": int(elem.shape[2]),
                           "opt_step": int(np.asarray(
                               jax.device_get(opt_state.step))),
                           "checksums": checksums},
                       "extra": extra or {}}, f)

    return _commit_step_dir(ckpt_dir, step, write)


def restore_sharded(ckpt_dir: str, params_template, elem_map,
                    step: int | None = None, param_shardings=None,
                    state_shardings=None):
    """Restore a sharded ZeRO-1 checkpoint onto the fabric described by
    ``elem_map`` (the *target* ``(n', kmax', smax')`` ownership map --
    pass the save-time map to get the saved layout back bitwise, or a
    different fabric's map to re-shard).  Re-assembles the flat ``mu`` /
    ``nu`` vectors from the per-host shard files via their saved element
    maps, then scatters them to the target map, so save and restore
    geometries never need to match.  Returns
    ``(params, ShardedOptState, step, extra)`` or ``(None,) * 4`` when
    the directory holds no checkpoint."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None, None, None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    geom = manifest["sharded"]
    size = int(geom["size"])

    # torn/corrupt shards from a crashed host fail loudly BEFORE any
    # state is assembled; checkpoints predating checksums load as before
    checksums = geom.get("checksums", {})
    mu_flat = np.zeros(size, np.float32)
    nu_flat = np.zeros(size, np.float32)
    for v in range(int(geom["n"])):
        name = f"shard_{v:05d}.npz"
        shard_path = os.path.join(path, name)
        if name in checksums and _file_crc32(shard_path) != checksums[name]:
            raise ValueError(
                f"sharded checkpoint corrupt: {shard_path} fails its "
                f"manifest CRC32 (expected {checksums[name]:#010x}); the "
                "shard was torn or altered after save -- restore an older "
                "step or re-save from a healthy replica")
        shard = np.load(shard_path)
        e = shard["elem"]
        mask = e >= 0
        mu_flat[e[mask]] = shard["mu"][mask]
        nu_flat[e[mask]] = shard["nu"][mask]

    tgt = np.asarray(elem_map)
    mu = np.zeros(tgt.shape, np.float32)
    nu = np.zeros(tgt.shape, np.float32)
    live = tgt >= 0
    mu[live] = mu_flat[tgt[live]]
    nu[live] = nu_flat[tgt[live]]

    npz = np.load(os.path.join(path, "arrays.npz"))
    params = _unflatten_into(params_template, {k: npz[k] for k in npz.files})
    if param_shardings is not None:
        params = jax.tree.map(lambda x, s: jax.device_put(x, s),
                              params, param_shardings)
    else:
        params = jax.tree.map(jax.numpy.asarray, params)

    state = ShardedOptState(
        jax.numpy.asarray(geom["opt_step"], jax.numpy.int32),
        jax.numpy.asarray(mu), jax.numpy.asarray(nu))
    if state_shardings is not None:
        state = jax.tree.map(lambda x, s: jax.device_put(x, s),
                             state, state_shardings)
    return params, state, step, manifest.get("extra", {})
