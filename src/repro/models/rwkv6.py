"""RWKV-6 "Finch" (attention-free, data-dependent decay) [arXiv:2404.05892].

Time-mix: token-shift ddlerp (5 streams r,k,v,w,g with a shared low-rank
data-dependent adjustment), per-channel data-dependent decay
w_t = exp(-exp(w0 + LoRA_w(x))) and bonus u; the WKV state recurrence

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T);   S_t = diag(w_t) S_{t-1} + k_t v_t^T

is evaluated with a *chunked* parallel algorithm (log-space relative decays
inside each chunk, lax.scan over chunks carrying S) for training/prefill and
as an exact single step for decode.  Channel-mix: squared-relu MLP with
receptance gate.  Decode state is O(1) per layer -- long_500k is runnable.

The Pallas kernel (repro.kernels.wkv6) implements the same chunk recurrence;
this module is the jnp reference path used on CPU and in the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import stack_layers

LORA_R = 32      # low-rank width of the ddlerp / decay adapters
N_STREAMS = 5    # r, k, v, w, g


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(cfg, key):
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    h = d // cfg.head_size
    p = {
        "ln1": L.init_layernorm(d)[0],
        "ln2": L.init_layernorm(d)[0],
        # ddlerp token-shift mixing
        "mu_x": L.zinit((d,)), "mu": L.zinit((N_STREAMS, d)),
        "tm_w1": L.ninit(ks[0], (d, N_STREAMS * LORA_R), scale=0.01),
        "tm_w2": L.ninit(ks[1], (N_STREAMS, LORA_R, d), scale=0.01),
        # projections
        "wr": L.ninit(ks[2], (d, d)), "wk": L.ninit(ks[3], (d, d)),
        "wv": L.ninit(ks[4], (d, d)), "wg": L.ninit(ks[5], (d, d)),
        "wo": L.ninit(ks[6], (d, d)),
        # decay: w0 + lora
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "dw1": L.ninit(ks[7], (d, 64), scale=0.01),
        "dw2": L.ninit(ks[8], (64, d), scale=0.01),
        "u": L.ninit(ks[9], (h, cfg.head_size), scale=0.5),
        "ln_x": jnp.ones((d,), jnp.float32),   # per-head group norm scale
        # channel mix
        "cm_mu_k": L.zinit((d,)), "cm_mu_r": L.zinit((d,)),
        "cm_wk": L.ninit(ks[10], (d, cfg.d_ff)),
        "cm_wv": L.ninit(ks[11], (cfg.d_ff, d)),
        "cm_wr": L.ninit(ks[10], (d, d)),
    }
    a = {
        "ln1": {"scale": ("embed",), "bias": ("embed",)},
        "ln2": {"scale": ("embed",), "bias": ("embed",)},
        "mu_x": ("embed",), "mu": (None, "embed"),
        "tm_w1": ("embed", None), "tm_w2": (None, None, "embed"),
        "wr": ("embed", "embed2"), "wk": ("embed", "embed2"),
        "wv": ("embed", "embed2"), "wg": ("embed", "embed2"),
        "wo": ("embed2", "embed"),
        "w0": ("embed",), "dw1": ("embed", None), "dw2": (None, "embed"),
        "u": ("heads", "head_dim"), "ln_x": ("embed",),
        "cm_mu_k": ("embed",), "cm_mu_r": ("embed",),
        "cm_wk": ("embed", "mlp"), "cm_wv": ("mlp", "embed"),
        "cm_wr": ("embed", "embed2"),
    }
    return p, a


def init_rwkv6_model(cfg, key):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(k1, cfg.vocab_padded, cfg.d_model)
    p["layers"], a["layers"] = stack_layers(lambda k: init_layer(cfg, k),
                                            cfg.n_layers, k2)
    p["final_norm"], a["final_norm"] = L.init_layernorm(cfg.d_model)
    return p, a


# ---------------------------------------------------------------------------
# WKV6 chunk recurrence (jnp reference; see kernels/wkv6 for the Pallas twin)
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, logw, u, s0=None, chunk=64):
    """r,k,v: (B,T,H,N); logw: (B,T,H,N) (log decay, <= 0); u: (H,N).
    Returns (out (B,T,H,N), final state (B,H,N,N) [key x value dims])."""
    b, t, h, n = r.shape
    c = min(chunk, t)
    t_pad = -(-t // c) * c
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)  # log w = 0 -> no decay on padding
    nc = t_pad // c
    rc = r.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4)   # (nc,B,H,C,N)
    kc = k.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4)
    wc = logw.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4).astype(jnp.float32)

    s_init = jnp.zeros((b, h, n, n), jnp.float32) if s0 is None else s0
    s_init = L.batch_hint(s_init)

    def chunk_step(s, inp):
        rr, kk, vv, lw = inp                      # (B,H,C,N)
        rr32, kk32, vv32 = (x.astype(jnp.float32) for x in (rr, kk, vv))
        lcum = jnp.cumsum(lw, axis=2)             # L_t (inclusive)
        # intra-chunk: scores[t,i] = (r_t * exp(L_{t-1} - L_i)) . k_i, i < t
        lprev = lcum - lw                         # L_{t-1}
        # scores[t,i] = (r_t exp(L_{t-1} - L_i)) . k_i for i<t.  Shift both
        # factors by the per-chunk max of -L so each exponent stays in
        # [-range, range] where range = per-chunk cumulative log-decay.
        # RWKV6's parametrization (logw = -exp(w0 + lora), w0 ~ -6) keeps
        # range << 80 at chunk <= 128; the clamp is inert there and prevents
        # inf*0 = NaN in the regime where the product underflows anyway.
        mx = jnp.max(-lcum, axis=2, keepdims=True)
        kd = kk32 * jnp.exp(jnp.clip(-lcum + mx, -85.0, 85.0))
        rd = rr32 * jnp.exp(jnp.clip(lprev - mx, -85.0, 85.0))
        scores = jnp.einsum("bhtn,bhin->bhti", rd, kd)
        tri = jnp.tril(jnp.ones((c, c), bool), -1)
        scores = jnp.where(tri[None, None], scores, 0.0)
        diag = jnp.einsum("bhtn,hn,bhtn->bht", rr32, u.astype(jnp.float32), kk32)
        o = jnp.einsum("bhti,bhin->bhtn", scores, vv32)
        o = o + diag[..., None] * vv32
        # inter-chunk: o += (r_t * exp(L_{t-1})) S
        o = o + jnp.einsum("bhtn,bhnm->bhtm", rr32 * jnp.exp(lprev), s)
        # state update: S' = diag(exp(L_C)) S + sum_i (k_i exp(L_C - L_i)) v_i^T
        lc = lcum[:, :, -1:, :]                   # (B,H,1,N)
        s_new = jnp.exp(lc.squeeze(2))[..., None] * s + jnp.einsum(
            "bhin,bhim->bhnm", kk32 * jnp.exp(lc - lcum), vv32)
        return s_new, o

    s_fin, outs = jax.lax.scan(chunk_step, s_init, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, t_pad, h, n)[:, :t]
    return out.astype(r.dtype), s_fin


def wkv6_step(r, k, v, logw, u, s):
    """Single-token exact recurrence.  r,k,v,logw: (B,H,N); s: (B,H,N,N)."""
    r32, k32, v32 = (x.astype(jnp.float32) for x in (r, k, v))
    kv = jnp.einsum("bhn,bhm->bhnm", k32, v32)
    o = jnp.einsum("bhn,bhnm->bhm", r32, s + u.astype(jnp.float32)[..., None] * kv)
    s_new = jnp.exp(logw.astype(jnp.float32))[..., None] * s + kv
    return o.astype(r.dtype), s_new


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _ddlerp(p, x, sx):
    """5-stream token-shift mixing.  x, sx: (B,S,d) -> tuple of 5 mixed."""
    base = x + sx * p["mu_x"].astype(x.dtype)
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(base), p["tm_w1"].astype(x.dtype))
    lora = lora.reshape(*lora.shape[:-1], N_STREAMS, LORA_R)
    adj = jnp.einsum("bszr,zrd->bszd", lora, p["tm_w2"].astype(x.dtype))
    mixed = x[..., None, :] + sx[..., None, :] * (p["mu"].astype(x.dtype) + adj)
    return [mixed[..., i, :] for i in range(N_STREAMS)]


def time_mix(cfg, p, x, *, state=None, last=None):
    """state: (B,H,N,N) wkv state; last: (B,d) previous token (decode).
    Returns (out, new_state, new_last)."""
    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.head_size
    xn = L.layernorm(p["ln1"], x)
    if s == 1 and last is not None:
        prev = last[:, None, :].astype(xn.dtype)
    else:
        prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        if last is not None:
            prev = prev.at[:, 0].set(last.astype(xn.dtype))
    sx = prev - xn
    xr, xk, xv, xw, xg = _ddlerp(p, xn, sx)

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype))
    dlora = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw.astype(jnp.float32)),
                       p["dw1"].astype(jnp.float32))
    logw = -jnp.exp(p["w0"] + jnp.einsum("bsr,rd->bsd", dlora,
                                         p["dw2"].astype(jnp.float32)))
    rh = r.reshape(b, s, h, n)
    kh = k.reshape(b, s, h, n)
    vh = v.reshape(b, s, h, n)
    wh = logw.reshape(b, s, h, n)

    if s == 1 and state is not None:
        o, new_state = wkv6_step(rh[:, 0], kh[:, 0], vh[:, 0], wh[:, 0],
                                 p["u"], state)
        o = o[:, None]
    else:
        o, new_state = wkv6_chunked(rh, kh, vh, wh, p["u"], s0=state)
    # per-head group norm then gate
    o = o.reshape(b, s, h, n)
    o32 = o.astype(jnp.float32)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, axis=-1, keepdims=True) + 1e-6)
    o = (o32.reshape(b, s, d) * p["ln_x"]).astype(x.dtype)
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", o, p["wo"].astype(x.dtype))
    return out, new_state, xn[:, -1]


def channel_mix(p, x, *, last=None):
    xn = L.layernorm(p["ln2"], x)
    if x.shape[1] == 1 and last is not None:
        prev = last[:, None, :].astype(xn.dtype)
    else:
        prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        if last is not None:
            prev = prev.at[:, 0].set(last.astype(xn.dtype))
    sx = prev - xn
    xk = xn + sx * p["cm_mu_k"].astype(x.dtype)
    xr = xn + sx * p["cm_mu_r"].astype(x.dtype)
    hidden = jnp.einsum("bsd,df->bsf", xk, p["cm_wk"].astype(x.dtype))
    hidden = jnp.square(jax.nn.relu(hidden))
    out = jnp.einsum("bsf,fd->bsd", hidden, p["cm_wv"].astype(x.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                      p["cm_wr"].astype(x.dtype)))
    return rgate * out, xn[:, -1]


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, *, caches=None, last_only=False,
            return_hidden=False):
    x = L.embed(params["embed"], tokens, dtype=cfg.act_dtype)
    decode_mode = caches is not None

    def body(carry, xs):
        hcur = carry
        lp = xs["lp"]
        st = xs.get("state") if decode_mode else None
        l1 = xs.get("last_tm") if decode_mode else None
        l2 = xs.get("last_cm") if decode_mode else None
        o, new_state, new_l1 = time_mix(cfg, lp, hcur, state=st, last=l1)
        hcur = hcur + o
        o2, new_l2 = channel_mix(lp, hcur, last=l2)
        hcur = hcur + o2
        ys = {"state": new_state, "last_tm": new_l1, "last_cm": new_l2}
        return hcur, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = {"lp": params["layers"]}
    if decode_mode:
        xs.update(caches)
    x, ys = jax.lax.scan(body_fn, x, xs)
    if last_only:
        x = x[:, -1:]
    x = L.layernorm(params["final_norm"], x)
    if return_hidden:
        return x, ys
    logits = L.unembed(params["embed"], x, cfg.vocab)
    return logits, ys


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    hidden, _ = forward(cfg, params, tokens[:, :-1], return_hidden=True)
    loss = L.chunked_unembed_xent(params["embed"], hidden, tokens[:, 1:],
                                  cfg.vocab)
    return loss, {"xent": loss}


def init_cache(cfg, batch, max_len=None, dtype=jnp.bfloat16):
    h, n, d = cfg.n_heads, cfg.head_size, cfg.d_model
    caches = {
        "state": jnp.zeros((cfg.n_layers, batch, h, n, n), jnp.float32),
        "last_tm": jnp.zeros((cfg.n_layers, batch, d), jnp.float32),
        "last_cm": jnp.zeros((cfg.n_layers, batch, d), jnp.float32),
    }
    axes = {
        "state": ("layers", "batch", "heads", None, None),
        "last_tm": ("layers", "batch", "embed"),
        "last_cm": ("layers", "batch", "embed"),
    }
    return caches, axes


def prefill(cfg, params, tokens):
    logits, ys = forward(cfg, params, tokens, caches=None, last_only=True)
    # states collected by scan even in train mode (ys carries them)
    return logits[:, -1], ys


def decode_step(cfg, params, caches, tokens, cache_len=None):
    logits, new_caches = forward(cfg, params, tokens, caches=caches)
    return logits[:, -1], new_caches
