"""Decoder-only transformer LM (llama/qwen/mistral/smollm/olmoe families).

Layers are parameter-stacked and iterated with ``lax.scan`` (one-layer HLO +
loop: fast compiles at 24-40 layers, standard for large-model JAX).  Blocks
are pre-norm GQA attention + (dense GLU MLP | MoE).  Supports KV-cache decode
and optional per-layer remat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .moe import MoECfg, init_moe, moe_layer


def attn_cfg(cfg) -> L.AttnCfg:
    return L.AttnCfg(d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                     head_dim=cfg.head_dim_, qkv_bias=cfg.qkv_bias,
                     qk_norm=cfg.qk_norm, window=cfg.window,
                     rope_theta=cfg.rope_theta)


def moe_cfg(cfg) -> MoECfg:
    return MoECfg(d_model=cfg.d_model, n_experts=cfg.n_experts,
                  n_experts_padded=cfg.n_experts_padded, top_k=cfg.top_k,
                  d_expert=cfg.d_expert, n_shared=cfg.n_shared,
                  group_size=cfg.moe_group_size,
                  capacity_factor=cfg.moe_capacity_factor)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(cfg, key):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_rmsnorm(cfg.d_model)
    p["attn"], a["attn"] = L.init_attention(ks[0], attn_cfg(cfg))
    p["ln2"], a["ln2"] = L.init_rmsnorm(cfg.d_model)
    if cfg.is_moe:
        p["moe"], a["moe"] = init_moe(ks[1], moe_cfg(cfg))
    else:
        p["mlp"], a["mlp"] = L.init_glu_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p, a


def stack_layers(init_one, n_layers, key):
    """vmap the single-layer init over per-layer keys -> leading 'layers' dim."""
    keys = jax.random.split(key, n_layers)
    _, axes = init_one(jax.random.PRNGKey(0))
    params = jax.vmap(lambda k: init_one(k)[0])(keys)
    axes = jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def init_lm(cfg, key):
    k_emb, k_layers = jax.random.split(key)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(k_emb, cfg.vocab_padded, cfg.d_model)
    p["layers"], a["layers"] = stack_layers(lambda k: init_layer(cfg, k),
                                            cfg.n_layers, k_layers)
    p["final_norm"], a["final_norm"] = L.init_rmsnorm(cfg.d_model)
    return p, a


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(cfg, lp, x, positions, kv_cache=None, cache_len=None):
    x = L.seq_hint(x)   # residual stream sequence-sharded between layers
    h, new_cache = L.attention(lp["attn"], attn_cfg(cfg), L.rmsnorm(lp["ln1"], x),
                               positions, kv_cache=kv_cache, cache_len=cache_len,
                               q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + h
    h2 = L.rmsnorm(lp["ln2"], x)
    if cfg.is_moe:
        out, aux = moe_layer(lp["moe"], moe_cfg(cfg), h2)
        if cfg.moe_seq_shard_out:
            # seq-shard the combine output: turns the EP partial-sum
            # all-reduce over "model" into a reduce-scatter (the residual
            # stream is already sequence-sharded)  [§Perf hillclimb 2]
            out = L.seq_hint(out)
    else:
        out, aux = L.glu_mlp(lp["mlp"], h2, cfg.mlp_kind), {}
    return x + out, new_cache, aux


def forward(cfg, params, tokens, *, cache=None, cache_len=None,
            last_only=False, return_hidden=False):
    """tokens: (B, S) int32.  cache: optional stacked (L, B, Smax, kv, hd) x2.
    last_only: emit logits for the final position only (prefill).
    Returns (logits, new_cache, aux)."""
    x = L.embed(params["embed"], tokens, dtype=cfg.act_dtype)
    s = tokens.shape[1]
    base = 0 if cache_len is None else cache_len
    positions = base + jnp.arange(s, dtype=jnp.int32)

    def body(carry, xs):
        h = carry
        lp = xs["lp"]
        kv = (xs["k"], xs["v"]) if cache is not None else None
        h, new_kv, aux = _block(cfg, lp, h, positions, kv_cache=kv,
                                cache_len=cache_len)
        ys = {"aux": aux}
        if cache is not None:
            ys["k"], ys["v"] = new_kv
        return h, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = {"lp": params["layers"]}
    if cache is not None:
        xs["k"], xs["v"] = cache
    x, ys = jax.lax.scan(body_fn, x, xs)
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x)
    new_cache = (ys["k"], ys["v"]) if cache is not None else None
    aux = {k: v.mean() for k, v in ys["aux"].items()}
    if return_hidden:
        return x, new_cache, aux
    logits = L.unembed(params["embed"], x, cfg.vocab)
    return logits, new_cache, aux


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    hidden, _, aux = forward(cfg, params, tokens[:, :-1], return_hidden=True)
    loss = L.chunked_unembed_xent(params["embed"], hidden, tokens[:, 1:],
                                  cfg.vocab)
    for k, v in aux.items():
        loss = loss + cfg.aux_loss_weight * v
    return loss, {"xent": loss, **aux}


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim_)
    axes = ("layers", "batch", None, "kv_heads", "head_dim")
    return ((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)),
            (axes, axes))


def decode_step(cfg, params, cache, tokens, cache_len):
    """One-token decode: tokens (B, 1)."""
    logits, new_cache, _ = forward(cfg, params, tokens, cache=cache,
                                   cache_len=cache_len)
    return logits[:, -1], new_cache


def prefill(cfg, params, tokens, max_len):
    """Prefill: run forward while writing the cache; returns last logits."""
    cache, _ = init_cache(cfg, tokens.shape[0], max_len)
    logits, new_cache, _ = forward(cfg, params, tokens, cache=cache,
                                   cache_len=0, last_only=True)
    return logits[:, -1], new_cache
