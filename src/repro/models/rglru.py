"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local (sliding-window)
MQA attention in a 1:2 pattern (rec, rec, attn) [arXiv:2402.19427].

The RG-LRU diagonal recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t*x_t)
is evaluated with `jax.lax.associative_scan` (log-depth, TPU-friendly) for
training/prefill and as a single step for decode.  The temporal conv1d is a
width-4 causal depthwise convolution expressed as shifted adds.

Decode state: fixed-size LRU state + conv tail + a *ring-buffer* window KV
cache (slot = position % window, absolute positions tracked for masking) --
total state is O(window), which is what makes long_500k runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .transformer import attn_cfg, stack_layers

C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness constant


def _lru_width(cfg):
    return cfg.lru_width or cfg.d_model


def _layer_kinds(cfg):
    pat = cfg.pattern or ("rec", "rec", "attn")
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_rec_layer(cfg, key):
    d, w = cfg.d_model, _lru_width(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "ln": L.init_rmsnorm(d)[0],
        "w_gate": L.ninit(ks[0], (d, w)),
        "w_rec": L.ninit(ks[1], (d, w)),
        "conv_w": L.ninit(ks[2], (cfg.conv_width, w), scale=0.1),
        "conv_b": L.zinit((w,)),
        "wa": L.ninit(ks[3], (w, w)),      # recurrence gate r_t
        "ba": L.zinit((w,)),
        "wi": L.ninit(ks[4], (w, w)),      # input gate i_t
        "bi": L.zinit((w,)),
        "lam": jnp.asarray(np.linspace(0.9, 4.0, w), jnp.float32),
        "wo": L.ninit(ks[5], (w, d)),
    }
    a = {
        "ln": {"scale": ("embed",)},
        "w_gate": ("embed", "mlp"), "w_rec": ("embed", "mlp"),
        "conv_w": (None, "mlp"), "conv_b": ("mlp",),
        "wa": ("mlp", "mlp2"), "ba": ("mlp",),
        "wi": ("mlp", "mlp2"), "bi": ("mlp",),
        "lam": ("mlp",), "wo": ("mlp", "embed"),
    }
    return p, a


def init_attn_layer(cfg, key):
    p, a = {}, {}
    p["ln"], a["ln"] = L.init_rmsnorm(cfg.d_model)
    p["attn"], a["attn"] = L.init_attention(key, attn_cfg(cfg))
    return p, a


def init_mlp_part(cfg, key):
    p, a = {}, {}
    p["ln"], a["ln"] = L.init_rmsnorm(cfg.d_model)
    p["mlp"], a["mlp"] = L.init_glu_mlp(key, cfg.d_model, cfg.d_ff)
    return p, a


def init_rglru_model(cfg, key):
    kinds = _layer_kinds(cfg)
    n_rec = sum(k == "rec" for k in kinds)
    n_att = max(sum(k == "attn" for k in kinds), 1)
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["embed"], a["embed"] = L.init_embedding(ks[0], cfg.vocab_padded, cfg.d_model)
    p["rec"], a["rec"] = stack_layers(lambda k: init_rec_layer(cfg, k), n_rec, ks[1])
    p["att"], a["att"] = stack_layers(lambda k: init_attn_layer(cfg, k), n_att, ks[2])
    p["mlp"], a["mlp"] = stack_layers(lambda k: init_mlp_part(cfg, k),
                                      cfg.n_layers, ks[3])
    p["final_norm"], a["final_norm"] = L.init_rmsnorm(cfg.d_model)
    return p, a


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rg_lru_scan(a, bx, h0=None):
    """h_t = a_t * h_{t-1} + bx_t via associative scan over axis 1 (seq)."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rec_block(cfg, lp, x, *, state=None, conv_buf=None):
    """Griffin recurrent block.  Returns (out, new_state, new_conv_tail)."""
    h = L.rmsnorm(lp["ln"], x)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, lp["w_gate"].astype(h.dtype)))
    u = jnp.einsum("bsd,dw->bsw", h, lp["w_rec"].astype(h.dtype))

    cw = cfg.conv_width
    if conv_buf is not None:
        ctx = jnp.concatenate([conv_buf.astype(u.dtype), u], axis=1)
    else:
        ctx = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(ctx[:, j: j + u.shape[1]] * lp["conv_w"][cw - 1 - j].astype(u.dtype)
               for j in range(cw))
    conv = conv + lp["conv_b"].astype(u.dtype)
    new_conv_tail = ctx[:, ctx.shape[1] - (cw - 1):]

    cf = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", cf,
                                  lp["wa"].astype(jnp.float32)) + lp["ba"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", cf,
                                  lp["wi"].astype(jnp.float32)) + lp["bi"])
    log_a = -C_RGLRU * jax.nn.softplus(lp["lam"]) * r    # <= 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = mult * (i * cf)

    if x.shape[1] == 1 and state is not None:            # decode: one step
        hs = (a[:, 0] * state + bx[:, 0])[:, None]
    else:
        hs = rg_lru_scan(a, bx, h0=state)
    new_state = hs[:, -1]
    out = jnp.einsum("bsw,wd->bsd", gate * hs.astype(gate.dtype),
                     lp["wo"].astype(gate.dtype))
    return out, new_state, new_conv_tail


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def forward(cfg, params, tokens, *, caches=None, cache_len=None,
            collect=False, last_only=False, return_hidden=False):
    """caches: decode-state dict (see init_cache) or None.
    collect=True (prefill): build fresh caches from a full forward pass."""
    kinds = _layer_kinds(cfg)
    x = L.embed(params["embed"], tokens, dtype=cfg.act_dtype)
    s = tokens.shape[1]
    base = 0 if cache_len is None else cache_len
    positions = base + jnp.arange(s, dtype=jnp.int32)
    wnd = cfg.window or s

    decode_mode = caches is not None
    if decode_mode:
        write_idx = cache_len % wnd
        kv_pos = jax.lax.dynamic_update_slice(
            caches["kv_pos"], cache_len[None].astype(jnp.int32), (write_idx,))
    out_caches = {"kv_k": [], "kv_v": [], "state": [], "conv": []}

    ri, ai = 0, 0
    for li, kind in enumerate(kinds):
        mlp_p = jax.tree.map(lambda v: v[li], params["mlp"])
        if kind == "rec":
            rec_p = jax.tree.map(lambda v: v[ri], params["rec"])
            state = caches["state"][ri] if decode_mode else None
            buf = caches["conv"][ri] if decode_mode else None

            def rec_step(x, rec_p=rec_p, state=state, buf=buf):
                return rec_block(cfg, rec_p, x, state=state, conv_buf=buf)

            step = jax.checkpoint(rec_step) if cfg.remat else rec_step
            o, new_state, new_buf = step(x)
            x = x + o
            out_caches["state"].append(new_state)
            out_caches["conv"].append(new_buf)
            ri += 1
        else:
            att_p = jax.tree.map(lambda v: v[ai], params["att"])

            def att_step(x, att_p=att_p, ai=ai):
                if decode_mode:
                    kv = (caches["kv_k"][ai], caches["kv_v"][ai])
                    return L.attention(att_p["attn"], attn_cfg(cfg),
                                       L.rmsnorm(att_p["ln"], x), positions,
                                       kv_cache=kv, cache_len=cache_len,
                                       cache_write_idx=write_idx,
                                       cache_positions=kv_pos,
                                       q_block=cfg.q_block, kv_block=cfg.kv_block)
                return L.attention(att_p["attn"], attn_cfg(cfg),
                                   L.rmsnorm(att_p["ln"], x), positions,
                                   q_block=cfg.q_block, kv_block=cfg.kv_block)

            step = jax.checkpoint(att_step) if cfg.remat else att_step
            o, new_kv = step(x)
            x = x + o
            if decode_mode:
                out_caches["kv_k"].append(new_kv[0])
                out_caches["kv_v"].append(new_kv[1])
            elif collect:
                # ring-buffer layout: slot = position % window
                k, v = new_kv
                take = min(wnd, s)
                slots = (positions[-take:] % wnd)
                kc = jnp.zeros((k.shape[0], wnd) + k.shape[2:], k.dtype)
                vc = jnp.zeros_like(kc)
                out_caches["kv_k"].append(kc.at[:, slots].set(k[:, -take:]))
                out_caches["kv_v"].append(vc.at[:, slots].set(v[:, -take:]))
            ai += 1

        def mlp_step(x, mlp_p=mlp_p):
            return x + L.glu_mlp(mlp_p["mlp"], L.rmsnorm(mlp_p["ln"], x),
                                 cfg.mlp_kind)

        step = jax.checkpoint(mlp_step) if cfg.remat else mlp_step
        x = step(x)

    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x)
    if return_hidden:
        logits = x
    else:
        logits = L.unembed(params["embed"], x, cfg.vocab)

    new_caches = None
    if decode_mode or collect:
        new_caches = {k: (jnp.stack(v) if v else jnp.zeros((0,)))
                      for k, v in out_caches.items()}
        if decode_mode:
            new_caches["kv_pos"] = kv_pos
        else:
            take = min(wnd, s)
            kvp = jnp.full((wnd,), 10 ** 9, jnp.int32)
            new_caches["kv_pos"] = kvp.at[positions[-take:] % wnd].set(
                positions[-take:])
    return logits, new_caches


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    hidden, _ = forward(cfg, params, tokens[:, :-1], return_hidden=True)
    loss = L.chunked_unembed_xent(params["embed"], hidden, tokens[:, 1:],
                                  cfg.vocab)
    return loss, {"xent": loss}


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    kinds = _layer_kinds(cfg)
    n_rec = sum(k == "rec" for k in kinds)
    n_att = sum(k == "attn" for k in kinds)
    w = _lru_width(cfg)
    wnd = min(cfg.window or max_len, max_len)
    caches = {
        "kv_k": jnp.zeros((n_att, batch, wnd, cfg.n_kv, cfg.head_dim_), dtype),
        "kv_v": jnp.zeros((n_att, batch, wnd, cfg.n_kv, cfg.head_dim_), dtype),
        "state": jnp.zeros((n_rec, batch, w), jnp.float32),
        "conv": jnp.zeros((n_rec, batch, cfg.conv_width - 1, w), dtype),
        "kv_pos": jnp.full((wnd,), 10 ** 9, jnp.int32),
    }
    axes = {
        "kv_k": ("layers", "batch", None, "kv_heads", "head_dim"),
        "kv_v": ("layers", "batch", None, "kv_heads", "head_dim"),
        "state": ("layers", "batch", "mlp"),
        "conv": ("layers", "batch", None, "mlp"),
        "kv_pos": (None,),
    }
    return caches, axes


def prefill(cfg, params, tokens):
    logits, caches = forward(cfg, params, tokens, collect=True, last_only=True)
    return logits[:, -1], caches


def decode_step(cfg, params, caches, tokens, cache_len):
    logits, new_caches = forward(cfg, params, tokens, caches=caches,
                                 cache_len=cache_len)
    return logits[:, -1], new_caches
