"""InternVL2-2B backbone: InternViT frontend STUB (precomputed patch
embeddings) projected and prepended to the InternLM2 token stream; loss on
text positions only.  Decode reuses the LM KV-cache path (image prefix lives
in the cache after prefill)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T


def init_vlm(cfg, key):
    k1, k2 = jax.random.split(key)
    p, a = T.init_lm(cfg, k1)
    p["patch_proj"] = {"w": L.ninit(k2, (cfg.d_model, cfg.d_model))}
    a["patch_proj"] = {"w": ("embed", "embed2")}
    return p, a


def forward(cfg, params, tokens, patches, *, cache=None, cache_len=None,
            last_only=False, return_hidden=False):
    """patches: (B, n_img, d) stub embeddings; tokens: (B, S_text)."""
    tok_emb = L.embed(params["embed"], tokens, dtype=cfg.act_dtype)
    img_emb = jnp.einsum("bnd,de->bne", patches.astype(cfg.act_dtype),
                         params["patch_proj"]["w"].astype(cfg.act_dtype))
    x = jnp.concatenate([img_emb, tok_emb], axis=1)
    s = x.shape[1]
    base = 0 if cache_len is None else cache_len
    positions = base + jnp.arange(s, dtype=jnp.int32)

    def body(carry, xs):
        h = carry
        kv = (xs["k"], xs["v"]) if cache is not None else None
        h, new_kv, _ = T._block(cfg, xs["lp"], h, positions, kv_cache=kv,
                                cache_len=cache_len)
        ys = {}
        if cache is not None:
            ys["k"], ys["v"] = new_kv
        return h, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = {"lp": params["layers"]}
    if cache is not None:
        xs["k"], xs["v"] = cache
    x, ys = jax.lax.scan(body_fn, x, xs)
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x)
    new_cache = (ys["k"], ys["v"]) if cache is not None else None
    if return_hidden:
        return x, new_cache
    logits = L.unembed(params["embed"], x, cfg.vocab)
    return logits, new_cache


def loss_fn(cfg, params, batch):
    tokens, patches = batch["tokens"], batch["patches"]
    hidden, _ = forward(cfg, params, tokens[:, :-1], patches,
                        return_hidden=True)
    n_img = patches.shape[1]
    loss = L.chunked_unembed_xent(params["embed"], hidden[:, n_img:],
                                  tokens[:, 1:], cfg.vocab)
    return loss, {"xent": loss}


init_cache = T.init_cache


def decode_step(cfg, params, cache, tokens, cache_len):
    """Image prefix already in cache from prefill; pure-text decode."""
    logits, new_cache, _ = T.forward(cfg, params, tokens, cache=cache,
                                     cache_len=cache_len)
    return logits[:, -1], new_cache
