"""Encoder-decoder backbone (seamless-m4t-large-v2).

The modality frontend is a stub: the encoder consumes precomputed frame
embeddings (B, S_enc, d).  Decoder: causal self-attention + cross-attention
over encoder states, KV-cache decode with precomputed cross K/V.
LayerNorm + GELU dense MLP, per the m4t transformer family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .transformer import attn_cfg, stack_layers


def _ccfg(cfg):
    """Cross-attention config: no rope, full mask."""
    import dataclasses
    return dataclasses.replace(attn_cfg(cfg), use_rope=False, causal=False)


def init_enc_layer(cfg, key):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_layernorm(cfg.d_model)
    p["attn"], a["attn"] = L.init_attention(ks[0], attn_cfg(cfg))
    p["ln2"], a["ln2"] = L.init_layernorm(cfg.d_model)
    p["mlp"], a["mlp"] = L.init_dense_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p, a


def init_dec_layer(cfg, key):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.init_layernorm(cfg.d_model)
    p["attn"], a["attn"] = L.init_attention(ks[0], attn_cfg(cfg))
    p["lnc"], a["lnc"] = L.init_layernorm(cfg.d_model)
    p["cross"], a["cross"] = L.init_attention(ks[1], _ccfg(cfg))
    p["ln2"], a["ln2"] = L.init_layernorm(cfg.d_model)
    p["mlp"], a["mlp"] = L.init_dense_mlp(ks[2], cfg.d_model, cfg.d_ff)
    return p, a


def init_encdec(cfg, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, a = {}, {}
    p["frame_proj"], a["frame_proj"] = (
        {"w": L.ninit(k1, (cfg.d_model, cfg.d_model))},
        {"w": ("embed", "embed2")})
    p["embed"], a["embed"] = L.init_embedding(k2, cfg.vocab_padded, cfg.d_model)
    p["enc"], a["enc"] = stack_layers(lambda k: init_enc_layer(cfg, k),
                                      cfg.n_layers, k3)
    p["dec"], a["dec"] = stack_layers(lambda k: init_dec_layer(cfg, k),
                                      cfg.n_dec_layers, k4)
    p["enc_norm"], a["enc_norm"] = L.init_layernorm(cfg.d_model)
    p["dec_norm"], a["dec_norm"] = L.init_layernorm(cfg.d_model)
    return p, a


def encode(cfg, params, frames):
    """frames: (B, S, d) precomputed frame embeddings (frontend stub)."""
    x = jnp.einsum("bsd,de->bse", frames.astype(cfg.act_dtype),
                   params["frame_proj"]["w"].astype(cfg.act_dtype))
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(h, lp):
        o, _ = L.attention(lp["attn"], attn_cfg(cfg), L.layernorm(lp["ln1"], h),
                           pos, mask_mode="full",
                           q_block=cfg.q_block, kv_block=cfg.kv_block)
        h = h + o
        h = h + L.dense_mlp(lp["mlp"], L.layernorm(lp["ln2"], h))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return L.layernorm(params["enc_norm"], x)


def _dec_block(cfg, lp, x, positions, enc_kv=None, enc_out=None,
               self_cache=None, cache_len=None):
    o, new_self = L.attention(lp["attn"], attn_cfg(cfg),
                              L.layernorm(lp["ln1"], x), positions,
                              kv_cache=self_cache, cache_len=cache_len,
                              q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + o
    # cross-attention: K/V either precomputed (serving) or computed here
    # from enc_out (training -- avoids a stacked (L,B,S,kv,hd) residual)
    if enc_kv is not None:
        ck, cv = enc_kv
    else:
        ck = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["cross"]["wk"].astype(enc_out.dtype))
        cv = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["cross"]["wv"].astype(enc_out.dtype))
    h = L.layernorm(lp["lnc"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"].astype(h.dtype))
    out = L.sdpa(q, ck.astype(h.dtype), cv.astype(h.dtype), positions,
                 jnp.arange(ck.shape[1], dtype=jnp.int32), _ccfg(cfg),
                 mask_mode="full", q_block=cfg.q_block, kv_block=cfg.kv_block)
    x = x + jnp.einsum("bshk,hkd->bsd", out, lp["cross"]["wo"].astype(h.dtype))
    x = x + L.dense_mlp(lp["mlp"], L.layernorm(lp["ln2"], x))
    return x, new_self


def cross_kv(cfg, params, enc_out):
    """Precompute (L_dec, B, S_enc, kv, hd) cross K/V from encoder output."""
    def one(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"].astype(enc_out.dtype))
        return k, v
    ck, cv = jax.vmap(one)(params["dec"])
    # hint on the stacked (L, B, S, KV, HD) tensors (inside vmap the
    # constraint's dims would be off by the mapped dim)
    return L.head_hint(ck, 3), L.head_hint(cv, 3)


def decode(cfg, params, tokens, enc_out=None, *, self_cache=None,
           cache_len=None, ckv=None, last_only=False, return_hidden=False):
    """tokens: (B, S_dec).  Returns (logits, new_self_cache).  Cross K/V may
    be passed precomputed (``ckv``, serving) or derived from ``enc_out``."""
    x = L.embed(params["embed"], tokens, dtype=cfg.act_dtype)
    s = tokens.shape[1]
    base = 0 if cache_len is None else cache_len
    positions = base + jnp.arange(s, dtype=jnp.int32)

    def body(h, xs):
        lp = xs["lp"]
        kv = (xs["k"], xs["v"]) if self_cache is not None else None
        enc_kv = (xs["ck"], xs["cv"]) if ckv is not None else None
        h, new_kv = _dec_block(cfg, lp, h, positions, enc_kv=enc_kv,
                               enc_out=enc_out, self_cache=kv,
                               cache_len=cache_len)
        ys = {}
        if self_cache is not None:
            ys["k"], ys["v"] = new_kv
        return h, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = {"lp": params["dec"]}
    if ckv is not None:
        xs["ck"], xs["cv"] = ckv
    if self_cache is not None:
        xs["k"], xs["v"] = self_cache
    x, ys = jax.lax.scan(body_fn, x, xs)
    if last_only:
        x = x[:, -1:]
    x = L.layernorm(params["dec_norm"], x)
    new_cache = (ys["k"], ys["v"]) if self_cache is not None else None
    if return_hidden:
        return x, new_cache
    logits = L.unembed(params["embed"], x, cfg.vocab)
    return logits, new_cache


def loss_fn(cfg, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    hidden, _ = decode(cfg, params, tokens[:, :-1], enc_out,
                       return_hidden=True)
    loss = L.chunked_unembed_xent(params["embed"], hidden, tokens[:, 1:],
                                  cfg.vocab)
    return loss, {"xent": loss}


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    shape = (cfg.n_dec_layers, batch, max_len, cfg.n_kv, cfg.head_dim_)
    axes = ("layers", "batch", None, "kv_heads", "head_dim")
    return ((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)), (axes, axes))


def decode_step(cfg, params, cache, tokens, cache_len, cross_cache):
    """cross_cache: precomputed (ck, cv) stacked over decoder layers."""
    logits, new_cache = decode(cfg, params, tokens, self_cache=cache,
                               cache_len=cache_len, ckv=cross_cache)
    return logits[:, -1], new_cache
