"""Shared layers: norms, rotary embeddings, GQA attention (optionally
qk-norm / qkv-bias / sliding-window / KV cache), gated MLPs, embeddings.

Parameters are plain dicts; every ``init_*`` returns ``(params, axes)`` where
``axes`` mirrors the param tree with tuples of logical axis names consumed by
``repro.dist.sharding``.  Logical axes used here:
  "embed" (d_model), "heads", "kv_heads", "head_dim", "mlp" (d_ff),
  "vocab", "layers" (scan-stacked leading dim, added by the stacker).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def batch_hint(x, batch_dim: int = 0):
    """Constrain an activation's batch dim to the DP mesh axes.

    GSPMD sharding propagation loses the batch sharding on values that enter
    scan carries from fresh broadcasts (zeros inits) -- without this hint the
    flash-attention online-softmax carries (and similar) come out replicated,
    inflating per-device temps by the DP factor.  No-op when: no Auto mesh is
    active, the DP axes are Manual (inside shard_map the arrays are already
    local), or the dim is not divisible.
    """
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return x
    if m is None or m.empty:
        return x
    names = []
    for a, t in zip(m.axis_names, m.axis_types):
        if a in ("pod", "data"):
            if "Auto" not in str(t):
                return x
            names.append(a)
    if not names:
        return x
    total = 1
    for a in names:
        total *= m.shape[a]
    if x.ndim <= batch_dim or x.shape[batch_dim] % total or \
            x.shape[batch_dim] < total:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = tuple(names) if len(names) > 1 else names[0]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def seq_hint(x, seq_dim: int = 1):
    """Megatron-SP-style hint: shard an activation's sequence dim over the
    "model" axis.  Applied to the residual stream at layer boundaries so the
    scan-AD saved carries (L, B, S, d) are sequence-sharded; XLA inserts the
    all-gather before attention and the reduce-scatter after.  No-op when no
    Auto "model" axis is active or S is not divisible."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return x
    if m is None or m.empty or "model" not in m.axis_names:
        return x
    t = dict(zip(m.axis_names, m.axis_types))["model"]
    if "Auto" not in str(t):
        return x
    n = m.shape["model"]
    if x.ndim <= seq_dim or x.shape[seq_dim] % n or x.shape[seq_dim] < n:
        return x
    spec = [None] * x.ndim
    spec[seq_dim] = "model"
    # keep any batch sharding on dim 0
    names = [a for a in ("pod", "data") if a in m.axis_names]
    if names and x.shape[0] % _prod_sizes(m, names) == 0 and seq_dim != 0:
        spec[0] = tuple(names) if len(names) > 1 else names[0]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def _prod_sizes(m, names):
    out = 1
    for a in names:
        out *= m.shape[a]
    return out


def head_hint(x, head_dim: int):
    """Shard dim ``head_dim`` of an activation over the "model" axis (plus
    batch over DP axes on dim 0 when divisible).  No-op outside an Auto mesh
    or when not divisible."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return x
    if m is None or m.empty or "model" not in m.axis_names:
        return x
    if "Auto" not in str(dict(zip(m.axis_names, m.axis_types))["model"]):
        return x
    n = m.shape["model"]
    if x.ndim <= head_dim or x.shape[head_dim] % n or x.shape[head_dim] < n:
        return batch_hint(x)
    spec = [None] * x.ndim
    spec[head_dim] = "model"
    names = [a for a in ("pod", "data") if a in m.axis_names]
    if names and head_dim != 0 and x.shape[0] % _prod_sizes(m, names) == 0:
        spec[0] = tuple(names) if len(names) > 1 else names[0]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def ninit(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def zinit(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


def init_layernorm(d):
    return ({"scale": jnp.ones((d,), jnp.float32), "bias": zinit((d,))},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta=10000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (np.arange(0, d, 2) / d))
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, D/2)
    ang = ang[..., None, :]                                  # (..., S, 1, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA), cache-aware
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int | None = None       # sliding-window size (None = full)
    causal: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True


def init_attention(key, cfg: AttnCfg):
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p = {
        "wq": ninit(ks[0], (d, h, hd)),
        "wk": ninit(ks[1], (d, kv, hd)),
        "wv": ninit(ks[2], (d, kv, hd)),
        "wo": ninit(ks[3], (h, hd, d), scale=1.0 / np.sqrt(h * hd)),
    }
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"], a["bq"] = zinit((h, hd)), ("heads", "head_dim")
        p["bk"], a["bk"] = zinit((kv, hd)), ("kv_heads", "head_dim")
        p["bv"], a["bv"] = zinit((kv, hd)), ("kv_heads", "head_dim")
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = jnp.ones((hd,)), ("head_dim",)
        p["k_norm"], a["k_norm"] = jnp.ones((hd,)), ("head_dim",)
    return p, a


def _headwise_rms(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def attention(p, cfg: AttnCfg, x, positions, *, kv_cache=None, cache_len=None,
              cache_write_idx=None, cache_positions=None,
              kv_x=None, kv_positions=None, mask_mode="causal",
              q_block=1024, kv_block=1024):
    """Returns (out, new_cache).

    x: (B, S, d).  positions: (S,) int32 (shared across batch).  kv_cache:
    optional (k_cache, v_cache) of shape (B, S_max, n_kv, hd) with valid
    length ``cache_len`` (decode: new kv written at cache_len).
    Ring-buffer caches (sliding window): pass ``cache_write_idx`` (slot) and
    ``cache_positions`` ((S_max,) absolute positions per slot, sentinel 1e9
    for unwritten).  kv_x: cross-attention source.  mask_mode: "causal" |
    "full" (encoder / cross).
    """
    b, s, _ = x.shape
    xkv = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _headwise_rms(q, p["q_norm"])
        k = _headwise_rms(k, p["k_norm"])
    if cfg.use_rope:
        kpos = kv_positions if kv_positions is not None else positions
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kpos, cfg.rope_theta)

    if kv_cache is not None:
        kc, vc = kv_cache
        wi = cache_len if cache_write_idx is None else cache_write_idx
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype),
                                                 wi, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype),
                                                 wi, axis=1)
        k_all, v_all = kc.astype(q.dtype), vc.astype(q.dtype)
        if cache_positions is not None:
            kv_pos = cache_positions
            valid_len = None   # sentinel + causal/window terms do the masking
        else:
            kv_pos = jnp.arange(kc.shape[1], dtype=jnp.int32)
            valid_len = cache_len + s
        new_cache = (kc, vc)
    else:
        k_all, v_all = k, v
        kv_pos = kv_positions if kv_positions is not None else positions
        new_cache = (k, v)
        valid_len = None

    out = sdpa(q, k_all, v_all, positions.astype(jnp.int32),
               kv_pos.astype(jnp.int32), cfg, mask_mode,
               valid_len=valid_len, q_block=q_block, kv_block=kv_block)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def _block_mask(qp, kp, cfg: AttnCfg, mask_mode, valid_len):
    """(qb, kb) bool mask from 1-D position blocks -- never materializes
    anything batch- or head-shaped."""
    m = kp[None, :] < 10 ** 9   # padded kv sentinel is +1e9: always masked
    m = jnp.broadcast_to(m, (qp.shape[0], kp.shape[0]))
    if mask_mode == "causal":
        m = m & (kp[None, :] <= qp[:, None])
        if cfg.window is not None:
            m = m & (kp[None, :] > qp[:, None] - cfg.window)
    if valid_len is not None:
        m = m & (kp[None, :] < valid_len)
    return m


def _attn_block(q, k, mask, scale):
    """Masked logits for one (q-block x kv-block) pair.
    q: (b,qb,kv,g,d), k: (b,kb,kv,d), mask: (qb,kb) -> (b,kv,g,qb,kb) f32."""
    logits = jnp.einsum("bqkgd,btkd->bkgqt", q, k) * scale
    return jnp.where(mask[None, None, None], logits.astype(jnp.float32), -1e30)


def sdpa(q, k, v, q_pos, kv_pos, cfg: AttnCfg, mask_mode="causal",
         valid_len=None, q_block=1024, kv_block=1024):
    """Blockwise (flash-style) attention in pure JAX: online softmax over KV
    blocks, O(block^2) live memory.  For causal masks the kv loop for query
    block i covers blocks [0, i] only -- no wasted block compute, matching
    what the Pallas kernel does on TPU with pl.when.

    q: (B,S,H,D); k,v: (B,T,KV,D); q_pos: (S,), kv_pos: (T,) int32.
    """
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / np.sqrt(d)
    qb = min(q_block, s)
    kb = min(kv_block, t)
    # pad to block multiples (static)
    s_pad, t_pad = -(-s // qb) * qb, -(-t // kb) * kb
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, s_pad - s), constant_values=-(10 ** 9))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, t_pad - t), constant_values=10 ** 9)
    nq, nk = s_pad // qb, t_pad // kb
    qr = batch_hint(q.reshape(b, nq, qb, kv, g, d))
    kr = batch_hint(k.reshape(b, nk, kb, kv, d))
    vr = batch_hint(v.reshape(b, nk, kb, kv, d))
    qpr = q_pos.reshape(nq, qb)
    kpr = kv_pos.reshape(nk, kb)

    def process_qblock(qi, n_kv_blocks):
        """Scan kv blocks [0, n_kv_blocks) for query block qi."""
        qcur, qp = qr[:, qi], qpr[qi]

        def step(carry, inputs):
            m, l, acc = carry
            kblk, vblk, kp = inputs
            logits = _attn_block(qcur, kblk,
                                 _block_mask(qp, kp, cfg, mask_mode, valid_len),
                                 scale)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p_ = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p_.astype(qcur.dtype),
                vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = batch_hint(jnp.full((b, kv, g, qb), -1e30, jnp.float32))
        l0 = batch_hint(jnp.zeros((b, kv, g, qb), jnp.float32))
        a0 = batch_hint(jnp.zeros((b, kv, g, qb, d), jnp.float32))
        # flash-style backward: recompute the (qb x kb) score block in the
        # bwd pass instead of saving it (only the online-softmax carries are
        # stored per step) -- keeps attention AD memory at O(S) not O(S^2)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(step), (m0, l0, a0),
            (kr[:, :n_kv_blocks].swapaxes(0, 1),
             vr[:, :n_kv_blocks].swapaxes(0, 1), kpr[:n_kv_blocks]))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qcur.dtype)
        return out.transpose(0, 3, 1, 2, 4)  # (b, qb, kv, g, d)

    if mask_mode == "causal" and nq > 1 and s == t:
        # triangle-exact: query block i only visits kv blocks [0, ceil((i+1)qb/kb))
        outs = [process_qblock(i, min(nk, -(-((i + 1) * qb) // kb)))
                for i in range(nq)]
    else:
        outs = [process_qblock(i, nk) for i in range(nq)]
    out = jnp.stack(outs, axis=1).reshape(b, s_pad, kv, g, d)[:, :s]
    return out.reshape(b, s, h, d)


def sdpa_reference(q, k, v, q_pos, kv_pos, cfg: AttnCfg, mask_mode="causal",
                   valid_len=None):
    """Quadratic-memory oracle (small shapes only; used by tests)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(d)
    mask = _block_mask(q_pos, kv_pos, cfg, mask_mode, valid_len)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, d)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_glu_mlp(key, d, f, kind="swiglu"):
    ks = jax.random.split(key, 3)
    p = {"wi_gate": ninit(ks[0], (d, f)), "wi_up": ninit(ks[1], (d, f)),
         "wo": ninit(ks[2], (f, d))}
    a = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
         "wo": ("mlp", "embed")}
    return p, a


def glu_mlp(p, x, kind="swiglu"):
    act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", act(g) * u, p["wo"].astype(x.dtype))


def init_dense_mlp(key, d, f):
    ks = jax.random.split(key, 2)
    return ({"wi": ninit(ks[0], (d, f)), "wo": ninit(ks[1], (f, d))},
            {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")})


def dense_mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embedding / unembedding (padded vocab for TP divisibility)
# ---------------------------------------------------------------------------

def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple


def init_embedding(key, vocab_padded, d):
    return ({"table": ninit(key, (vocab_padded, d), scale=0.02)},
            {"table": ("vocab", "embed")})


def embed(p, tokens, dtype=jnp.bfloat16):
    return batch_hint(p["table"].astype(dtype)[tokens])


def unembed(p, x, vocab: int):
    """Logits against the (tied) embedding table; padded slots masked."""
    logits = jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype))
    vp = p["table"].shape[0]
    if vp != vocab:
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(jnp.arange(vp)[None, None, :] < vocab, logits, neg)
    return logits


def chunked_unembed_xent(embed_p, x, labels, vocab: int, chunk: int = 512,
                         z_loss=1e-4):
    """Cross-entropy over tied-embedding logits, computed (and re-computed in
    the backward pass) in sequence chunks so the (tokens x vocab) logits
    tensor never materializes beyond one chunk.  x: (B, S, d), labels (B, S).
    """
    b, s, d = x.shape
    c = min(chunk, s)
    s_pad = -(-s // c) * c
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, s_pad - s)),
                         constant_values=-1)
    nch = s_pad // c
    xr = x.reshape(b, nch, c, d).swapaxes(0, 1)
    lr = labels.reshape(b, nch, c).swapaxes(0, 1)

    @jax.checkpoint
    def step(acc, inp):
        xc, lc = inp
        logits = unembed(embed_p, xc, vocab).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None],
                                 axis=-1)[..., 0]
        loss = lse - ll
        if z_loss:
            loss = loss + z_loss * lse ** 2
        valid = (lc >= 0).astype(jnp.float32)
        return (acc[0] + (loss * valid).sum(), acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (xr, lr))
    return tot / jnp.maximum(cnt, 1.0)


def softmax_xent(logits, labels, valid_mask=None, z_loss=1e-4):
    """Mean token cross-entropy in f32 with optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    if valid_mask is None:
        return loss.mean()
    w = valid_mask.astype(jnp.float32)
    return (loss * w).sum() / jnp.maximum(w.sum(), 1.0)
