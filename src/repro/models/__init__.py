"""Model zoo: pure-JAX scan-over-layers implementations of the assigned
architectures.  Parameters are nested dicts of arrays; a parallel tree of
logical-axis tuples drives sharding (see repro.dist.sharding)."""
