"""Mixture-of-Experts layer (OLMoE / Qwen2-MoE families).

Grouped GShard-style dispatch: tokens are processed in groups of
``group_size``; each group dispatches to per-expert capacity slots via one-hot
einsums (TPU-friendly dense dataflow, EP = experts sharded over the "model"
mesh axis by GSPMD).  Router uses top-k with optional softmax renorm, plus
load-balance and router-z auxiliary losses.  Expert count is padded to the
mesh divisor; padded experts are masked to -inf in the router.

Shared experts (Qwen2-MoE) run as an always-on GLU MLP with a sigmoid gate.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .layers import ninit


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    n_experts: int            # real expert count (router range)
    n_experts_padded: int     # padded for EP divisibility
    top_k: int
    d_expert: int             # per-expert ffn width
    n_shared: int = 0         # always-on shared experts (width n_shared*d_expert)
    group_size: int = 512
    capacity_factor: float = 1.0
    renorm: bool = True       # renormalize top-k gates (Qwen2-MoE: True)


def init_moe(key, cfg: MoECfg):
    ks = jax.random.split(key, 6)
    e, d, f = cfg.n_experts_padded, cfg.d_model, cfg.d_expert
    p = {
        "router": ninit(ks[0], (d, e), scale=0.02),
        "wi_gate": ninit(ks[1], (e, d, f)),
        "wi_up": ninit(ks[2], (e, d, f)),
        "wo": ninit(ks[3], (e, f, d)),
    }
    a = {
        "router": ("embed", "experts"),
        "wi_gate": ("experts", "embed", "mlp"),
        "wi_up": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if cfg.n_shared:
        fs = cfg.n_shared * f
        p["shared"] = {
            "wi_gate": ninit(ks[4], (d, fs)), "wi_up": ninit(ks[4], (d, fs)),
            "wo": ninit(ks[5], (fs, d)), "gate": ninit(ks[5], (d, 1), scale=0.02),
        }
        a["shared"] = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
                       "wo": ("mlp", "embed"), "gate": ("embed", None)}
    return p, a


def moe_layer(p, cfg: MoECfg, x):
    """x: (B, S, d) -> (out (B, S, d), aux_losses dict)."""
    b, s, d = x.shape
    e, k = cfg.n_experts_padded, cfg.top_k
    g = min(cfg.group_size, s)
    s_pad = -(-s // g) * g
    if s_pad != s:
        x_r = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
    else:
        x_r = x
    ng = s_pad // g
    xg = x_r.reshape(b, ng, g, d)

    logits = jnp.einsum("bgtd,de->bgte", xg, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.n_experts != e:   # mask padded experts
        logits = jnp.where(jnp.arange(e) < cfg.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)        # (b,ng,g,k)
    if cfg.renorm:
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    cap = int(np.ceil(g * k / cfg.n_experts * cfg.capacity_factor))
    cap = max(4, -(-cap // 4) * 4)

    # position of each (token, choice) in its expert's capacity buffer:
    # cumsum over the flattened (token, choice) order per expert
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)     # (b,ng,g,k,e)
    flat = onehot.reshape(b, ng, g * k, e)
    pos = (jnp.cumsum(flat, axis=2) * flat).reshape(b, ng, g, k, e)
    pos_tk = pos.sum(-1)                                      # (b,ng,g,k) 1-idx
    keep = (pos_tk > 0) & (pos_tk <= cap)
    slot_tk = jnp.clip(pos_tk - 1, 0, cap - 1)

    # dispatch/combine (b,ng,g,e,cap) via two one-hots contracted over k --
    # never materializes a (k, e, cap) product
    from .layers import batch_hint
    oh_e = onehot.astype(x.dtype)                             # (b,ng,g,k,e)
    oh_c = (jax.nn.one_hot(slot_tk, cap, dtype=x.dtype) *
            keep[..., None].astype(x.dtype))                  # (b,ng,g,k,cap)
    dispatch = batch_hint(jnp.einsum("bgtke,bgtkc->bgtec", oh_e, oh_c))
    combine = batch_hint(jnp.einsum(
        "bgtke,bgtkc->bgtec",
        oh_e * gate_vals[..., None].astype(x.dtype), oh_c))

    xin = jnp.einsum("bgtec,bgtd->bgecd", dispatch, xg)
    h_g = jnp.einsum("bgecd,edf->bgecf", xin, p["wi_gate"].astype(x.dtype))
    h_u = jnp.einsum("bgecd,edf->bgecf", xin, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    xout = jnp.einsum("bgecf,efd->bgecd", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("bgtec,bgecd->bgtd", combine, xout)

    out = out.reshape(b, s_pad, d)[:, :s]

    # aux losses (computed on real experts only)
    me = probs[..., : cfg.n_experts].mean(axis=(0, 1, 2))
    ce = (onehot.sum(3)[..., : cfg.n_experts] > 0).astype(jnp.float32).mean(
        axis=(0, 1, 2)) * cfg.n_experts / k
    lb_loss = cfg.n_experts * jnp.mean(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"moe_load_balance": lb_loss, "moe_router_z": z_loss}

    if cfg.n_shared:
        sp = p["shared"]
        sg = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["wi_gate"].astype(x.dtype)))
        su = jnp.einsum("bsd,df->bsf", x, sp["wi_up"].astype(x.dtype))
        sh = jnp.einsum("bsf,fd->bsd", sg * su, sp["wo"].astype(x.dtype))
        gate = jax.nn.sigmoid(jnp.einsum("bsd,dz->bsz", x, sp["gate"].astype(x.dtype)))
        out = out + gate * sh
    return out, aux
