"""Unified model API: one entry point per assigned architecture family.

``build(cfg)`` returns a :class:`ModelAPI` exposing init / loss / prefill /
decode plus ``input_specs(shape)`` (ShapeDtypeStruct stand-ins, the dry-run
contract) and logical batch axes for sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, rglru, rwkv6, transformer, vlm

ENC_LEN_FOR_DECODE = 4_096   # encoder length used by enc-dec decode cells


@dataclass
class ModelAPI:
    cfg: ArchConfig
    init: Callable          # key -> (params, axes)
    loss_fn: Callable       # (params, batch) -> (loss, metrics)
    prefill_fn: Callable    # (params, batch) -> (logits, caches)
    decode_fn: Callable     # (params, caches, batch) -> (logits, new_caches)
    init_cache: Callable    # (batch_size, max_len) -> (caches, cache_axes)

    # ---- dry-run stand-ins --------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct tree for every model input of this (arch, shape):
        weak-type-correct, shardable, no device allocation."""
        cfg, gb, s = self.cfg, shape.global_batch, shape.seq_len
        i32, act = jnp.int32, cfg.act_dtype
        f = cfg.family
        if shape.kind == "train":
            if f == "encdec":
                return {"frames": jax.ShapeDtypeStruct((gb, s, cfg.d_model), act),
                        "tokens": jax.ShapeDtypeStruct((gb, s + 1), i32)}
            if f == "vlm":
                n_txt = s - cfg.n_img_tokens
                return {"patches": jax.ShapeDtypeStruct(
                            (gb, cfg.n_img_tokens, cfg.d_model), act),
                        "tokens": jax.ShapeDtypeStruct((gb, n_txt + 1), i32)}
            return {"tokens": jax.ShapeDtypeStruct((gb, s + 1), i32)}
        if shape.kind == "prefill":
            if f == "encdec":
                return {"frames": jax.ShapeDtypeStruct((gb, s, cfg.d_model), act),
                        "tokens": jax.ShapeDtypeStruct((gb, s), i32)}
            if f == "vlm":
                return {"patches": jax.ShapeDtypeStruct(
                            (gb, cfg.n_img_tokens, cfg.d_model), act),
                        "tokens": jax.ShapeDtypeStruct((gb, s - cfg.n_img_tokens), i32)}
            return {"tokens": jax.ShapeDtypeStruct((gb, s), i32)}
        # decode: one new token against a cache of length s
        batch = {"tokens": jax.ShapeDtypeStruct((gb, 1), i32),
                 "cache_len": jax.ShapeDtypeStruct((), i32)}
        if f == "encdec":
            batch["cross_k"] = jax.ShapeDtypeStruct(
                (cfg.n_dec_layers, gb, ENC_LEN_FOR_DECODE, cfg.n_kv,
                 cfg.head_dim_), jnp.bfloat16)
            batch["cross_v"] = batch["cross_k"]
        return batch

    def batch_axes(self, shape: ShapeSpec) -> dict:
        """Logical axis names per batch input (for sharding rules)."""
        def spec(_):
            return ("batch", None, None, None, None)
        out = {}
        for k, v in self.input_specs(shape).items():
            if k == "cache_len":
                out[k] = ()
            elif k in ("cross_k", "cross_v"):
                out[k] = ("layers", "batch", None, "kv_heads", "head_dim")
            else:
                out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
        return out


def build(cfg: ArchConfig) -> ModelAPI:
    f = cfg.family
    if f in ("lm", "moe"):
        return ModelAPI(
            cfg,
            init=lambda key: transformer.init_lm(cfg, key),
            loss_fn=lambda p, b: transformer.loss_fn(cfg, p, b),
            prefill_fn=lambda p, b: transformer.prefill(
                cfg, p, b["tokens"], b["tokens"].shape[1]),
            decode_fn=lambda p, c, b: transformer.decode_step(
                cfg, p, c, b["tokens"], b["cache_len"]),
            init_cache=lambda bs, ml: transformer.init_cache(cfg, bs, ml),
        )
    if f == "encdec":
        def prefill_fn(p, b):
            enc_out = encdec.encode(cfg, p, b["frames"])
            logits, cache = encdec.decode(cfg, p, b["tokens"], enc_out,
                                          last_only=True)
            return logits[:, -1], cache
        return ModelAPI(
            cfg,
            init=lambda key: encdec.init_encdec(cfg, key),
            loss_fn=lambda p, b: encdec.loss_fn(cfg, p, b),
            prefill_fn=prefill_fn,
            decode_fn=lambda p, c, b: encdec.decode_step(
                cfg, p, c, b["tokens"], b["cache_len"],
                (b["cross_k"], b["cross_v"])),
            init_cache=lambda bs, ml: encdec.init_cache(cfg, bs, ml),
        )
    if f == "vlm":
        def prefill_fn(p, b):
            logits, _ = vlm.forward(cfg, p, b["tokens"], b["patches"],
                                    last_only=True)
            return logits[:, -1], None
        return ModelAPI(
            cfg,
            init=lambda key: vlm.init_vlm(cfg, key),
            loss_fn=lambda p, b: vlm.loss_fn(cfg, p, b),
            prefill_fn=prefill_fn,
            decode_fn=lambda p, c, b: vlm.decode_step(
                cfg, p, c, b["tokens"], b["cache_len"]),
            init_cache=lambda bs, ml: vlm.init_cache(cfg, bs, ml),
        )
    if f == "rglru":
        return ModelAPI(
            cfg,
            init=lambda key: rglru.init_rglru_model(cfg, key),
            loss_fn=lambda p, b: rglru.loss_fn(cfg, p, b),
            prefill_fn=lambda p, b: rglru.prefill(cfg, p, b["tokens"]),
            decode_fn=lambda p, c, b: rglru.decode_step(
                cfg, p, c, b["tokens"], b["cache_len"]),
            init_cache=lambda bs, ml: rglru.init_cache(cfg, bs, ml),
        )
    if f == "rwkv6":
        return ModelAPI(
            cfg,
            init=lambda key: rwkv6.init_rwkv6_model(cfg, key),
            loss_fn=lambda p, b: rwkv6.loss_fn(cfg, p, b),
            prefill_fn=lambda p, b: rwkv6.prefill(cfg, p, b["tokens"]),
            decode_fn=lambda p, c, b: rwkv6.decode_step(
                cfg, p, c, b["tokens"], b.get("cache_len")),
            init_cache=lambda bs, ml: rwkv6.init_cache(cfg, bs, ml),
        )
    raise ValueError(f"unknown family {f}")
