"""Core library: the paper's star-product EDST theory + collective schedules."""
from .collectives import (AllreduceSchedule, CostModel, FusedAllreduceSpec,
                          PipelinedAllreduceSpec, StripedCollectiveSpec,
                          TreeSchedule, allreduce_schedule, chunk_sizes,
                          fused_spec_from_schedule,
                          pipelined_spec_from_schedule, simulate_allreduce,
                          simulate_striped_program, simulate_wave_program,
                          striped_spec_from_schedule, striped_tables,
                          tree_schedule)
from .csr import CSRAdjacency, tree_center
from .edst_rt import max_edsts, pack_forests
from .edst_star import (StarEDSTs, maximal_edsts, one_sided_edsts,
                        property_461_edsts, star_edsts, universal_edsts)
from .factor_edsts import EDSTSet, edsts_for
from .fault import (FailureEvent, FaultTolerantAllreduce, rebalance_chunks,
                    rebuild_edsts, surviving_trees)
from .graph import Graph
from .star import StarProduct, cartesian, random_star, shift_star, star_with
from .topologies import (bundlefly, device_topology, edst_set_for, hyperx,
                         mesh_nd, polarstar, slimfly, torus)

__all__ = [
    "AllreduceSchedule", "CostModel", "FusedAllreduceSpec",
    "PipelinedAllreduceSpec", "StripedCollectiveSpec", "TreeSchedule",
    "allreduce_schedule", "chunk_sizes", "fused_spec_from_schedule",
    "pipelined_spec_from_schedule", "simulate_allreduce",
    "simulate_striped_program", "simulate_wave_program",
    "striped_spec_from_schedule", "striped_tables", "tree_schedule",
    "CSRAdjacency", "tree_center", "max_edsts",
    "pack_forests",
    "StarEDSTs", "maximal_edsts", "one_sided_edsts", "property_461_edsts",
    "star_edsts", "universal_edsts", "EDSTSet", "edsts_for", "FailureEvent",
    "FaultTolerantAllreduce", "rebalance_chunks", "rebuild_edsts",
    "surviving_trees", "Graph", "StarProduct", "cartesian", "random_star",
    "shift_star", "star_with", "bundlefly", "device_topology", "edst_set_for",
    "hyperx", "mesh_nd", "polarstar", "slimfly", "torus",
]
