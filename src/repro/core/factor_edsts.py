"""Maximal EDST sets for factor graphs (paper Table 4).

Explicit constructions where classical ones exist (Walecki decompositions for
complete graphs; trivial families), Roskind-Tarjan matroid union otherwise
(K_{q,q} [20], Paley [3], ER_q [17], MMS supernodes, IQ/BDF): the packing is
maximum, so it attains the Table-4 ``t`` whenever the cited existence results
hold -- asserted by tests across a parameter sweep.
"""
from __future__ import annotations

from dataclasses import dataclass

from .edst_rt import max_edsts
from .graph import Graph, canon, edges_are_spanning_tree


@dataclass
class EDSTSet:
    graph: Graph
    trees: list          # list[set[edge]]
    nontree: set         # non-tree edges N
    method: str

    @property
    def t(self) -> int:
        return len(self.trees)

    @property
    def r(self) -> int:
        return len(self.nontree)

    def verify(self) -> "EDSTSet":
        seen = set()
        for tr in self.trees:
            assert edges_are_spanning_tree(self.graph.n, tr)
            assert not (tr & seen), "trees share an edge"
            seen |= tr
        assert seen | self.nontree == self.graph.edges
        assert not (seen & self.nontree)
        return self


# -- explicit constructions ---------------------------------------------------

def _walecki_sequence(i: int, n2: int) -> list[int]:
    """Zigzag Hamiltonian sequence i, i+1, i-1, i+2, ... on Z_{n2} (n2 even)."""
    seq = [i % n2]
    for j in range(1, n2 // 2):
        seq.append((i + j) % n2)
        seq.append((i - j) % n2)
    seq.append((i + n2 // 2) % n2)
    return seq


def complete_graph_edsts(g: Graph) -> EDSTSet:
    """K_m: m even -> m/2 Hamiltonian paths (Walecki minus a vertex);
    m odd -> (m-1)/2 Hamiltonian cycles, each opened into a path."""
    m = g.n
    trees, nontree = [], set()
    if m % 2 == 0:
        n2 = m  # paths on Z_m directly?  Walecki: delete apex from K_{m+1}
        # K_{2n} = n Ham paths: zigzag sequences on Z_{2n}
        for i in range(m // 2):
            seq = _walecki_sequence(i, m)
            trees.append({canon(a, b) for a, b in zip(seq, seq[1:])})
    else:
        apex = m - 1
        n2 = m - 1
        for i in range(n2 // 2):
            seq = _walecki_sequence(i, n2)
            cyc = [apex] + seq + [apex]
            edges = {canon(a, b) for a, b in zip(cyc, cyc[1:])}
            # open the cycle: drop one edge into the non-tree pool
            drop = canon(apex, seq[-1])
            edges.discard(drop)
            nontree.add(drop)
            trees.append(edges)
    return EDSTSet(g, trees, nontree, "walecki").verify()


def cycle_edsts(g: Graph) -> EDSTSet:
    """C_n: one spanning tree (the cycle minus an edge), r = 1."""
    e = max(g.edges)
    return EDSTSet(g, [g.edges - {e}], {e}, "cycle").verify()


def tree_edsts(g: Graph) -> EDSTSet:
    """A graph that is already a tree (e.g. path): t=1, r=0."""
    return EDSTSet(g, [set(g.edges)], set(), "identity").verify()


def rt_edsts(g: Graph, k_hint: int | None = None) -> EDSTSet:
    trees, nontree = max_edsts(g, k_hint)
    return EDSTSet(g, trees, nontree, "roskind-tarjan").verify()


def edsts_for(g: Graph, method: str = "auto", k_hint: int | None = None) -> EDSTSet:
    """Dispatch on graph name/shape; falls back to Roskind-Tarjan."""
    if method == "rt":
        return rt_edsts(g, k_hint)
    name = g.name
    if name.startswith("K") and "," not in name and name[1:].isdigit():
        return complete_graph_edsts(g)
    if name.startswith("C") and name[1:].isdigit():
        return cycle_edsts(g)
    if name.startswith("L") and name[1:].isdigit():
        return tree_edsts(g)
    if g.m == g.n - 1 and g.is_connected():
        return tree_edsts(g)
    return rt_edsts(g, k_hint)
