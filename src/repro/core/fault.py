"""Fault tolerance on EDST collectives (paper Sec. 1.1: "improve
fault-tolerance of large systems").

Strategy mirrors the paper's motivation and PolarFly practice [17]:
  1. *Immediate degraded mode*: a failed link/node kills only the trees that
     use it; surviving trees keep running -- re-split chunks over them.
  2. *Rebuild*: Roskind-Tarjan repacking on the residual graph restores the
     maximum tree count the damaged fabric still supports.
  3. *Straggler mitigation*: chunk sizes are rebalanced so trees in which a
     slow node sits deep (or fans out wide) carry less traffic; a fully
     degenerate tree can be retired without stopping training.
"""
from __future__ import annotations

from dataclasses import dataclass

from .collectives import AllreduceSchedule, allreduce_schedule
from .edst_rt import max_edsts
from .graph import Graph, canon


@dataclass
class FailureEvent:
    links: frozenset = frozenset()   # set of canonical edges
    nodes: frozenset = frozenset()   # node ids (all incident links fail)

    def dead_links(self, g: Graph) -> set:
        dead = {canon(*e) for e in self.links}
        for v in self.nodes:
            for w in g.adj()[v]:
                dead.add(canon(v, w))
        return dead


def surviving_trees(trees, dead_links: set) -> list:
    return [t for t in trees if not (set(t) & dead_links)]


def rebuild_edsts(g: Graph, dead_links: set, k_hint: int | None = None):
    """Max EDST packing on the residual graph (Roskind-Tarjan)."""
    residual = g.without_edges(dead_links)
    if not residual.is_connected():
        return [], residual
    trees, _ = max_edsts(residual, k_hint)
    return trees, residual


@dataclass
class FaultTolerantAllreduce:
    """Schedule manager: degrade on failure, rebuild in the background."""
    graph: Graph
    schedule: AllreduceSchedule
    history: list = None

    def __post_init__(self):
        self.history = self.history or []

    @property
    def k(self) -> int:
        return self.schedule.k

    def on_failure(self, event: FailureEvent) -> "FaultTolerantAllreduce":
        """Link failures degrade to the surviving trees immediately; a node
        failure (which touches every spanning tree) falls through to an
        eager Roskind-Tarjan rebuild on the residual fabric, with the dead
        node excluded from the collective."""
        dead = event.dead_links(self.graph)
        residual = self.graph.without_edges(dead)
        keep = surviving_trees([ts.tree for ts in self.schedule.trees], dead)
        if not keep:
            if event.nodes:
                # drop dead nodes entirely: relabel the residual graph onto
                # the surviving chips and repack
                alive = [v for v in range(self.graph.n)
                         if v not in event.nodes]
                idx = {v: i for i, v in enumerate(alive)}
                sub = Graph(len(alive),
                            {(idx[u], idx[v]) for u, v in residual.edges
                             if u in idx and v in idx}, name="residual")
                trees, _ = max_edsts(sub)
                if not trees:
                    raise RuntimeError("residual fabric disconnected")
                self.history.append(("node-rebuilt", len(trees)))
                return FaultTolerantAllreduce(
                    sub, allreduce_schedule(sub.n, trees), self.history)
            raise RuntimeError("all trees lost; rebuild required before resume")
        degraded = allreduce_schedule(self.graph.n, keep)
        self.history.append(("degraded", len(keep)))
        return FaultTolerantAllreduce(residual, degraded, self.history)

    def rebuild(self, k_hint: int | None = None) -> "FaultTolerantAllreduce":
        trees, residual = rebuild_edsts(self.graph, set(), k_hint)
        if len(trees) <= self.k:
            return self  # current schedule already as good
        sched = allreduce_schedule(self.graph.n, trees)
        self.history.append(("rebuilt", len(trees)))
        return FaultTolerantAllreduce(self.graph, sched, self.history)


def rebalance_chunks(sched: AllreduceSchedule, node_delay: dict) -> list:
    """Straggler mitigation: per-tree chunk fractions, inversely proportional
    to each tree's critical-path delay through slow nodes.

    node_delay: node -> multiplicative slowdown (1.0 = healthy).
    Returns fractions summing to 1 (tree with fraction 0 = retired).
    """
    costs = []
    for ts in sched.trees:
        # critical path: depth rounds, each round slowed by its slowest node
        cost = 0.0
        for rnd in ts.reduce_rounds + ts.bcast_rounds:
            cost += max((node_delay.get(s, 1.0) + node_delay.get(d, 1.0)) / 2
                        for s, d in rnd)
        costs.append(cost)
    inv = [1.0 / c for c in costs]
    total = sum(inv)
    fracs = [x / total for x in inv]
    # retire trees that would carry less than 10% of a fair share
    fair = 1.0 / len(fracs)
    fracs = [0.0 if f < 0.1 * fair else f for f in fracs]
    s = sum(fracs)
    return [f / s for f in fracs]
