"""Multi-tree Allreduce schedules from EDST sets (paper Sec. 1.1 payoff).

A set of k EDSTs yields k contention-free reduction/broadcast trees: the
gradient is split into k chunks, chunk j is reduced leaves->root along tree j
and broadcast root->leaves, all trees concurrently.  Edge-disjointness
guarantees no two trees ever use the same physical link (asserted).

Also provides the alpha-beta cost model comparing EDST k-tree allreduce
against ring and single-tree baselines, in both "endpoint reduction" (TPU)
and "in-network reduction" (paper's switch-compute) modes, plus a NumPy
packet-level simulator used for correctness tests.
"""
from __future__ import annotations

import functools
import logging
import os
from dataclasses import dataclass, field

import numpy as np

from .csr import tree_center
from .graph import canon, tree_depth_levels

logger = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# chunk apportioning (the canonical largest-remainder striping helper)
# ---------------------------------------------------------------------------

def chunk_sizes(total: int, fractions) -> tuple:
    """Apportion ``total`` elements by largest-remainder rounding; sizes sum
    exactly to ``total`` (a retired tree -- fraction 0 -- gets 0).

    The single canonical striping helper: per-tree chunk widths
    (``repro.dist.tree_allreduce``), weighted fault re-striping
    (``repro.dist.fault``), and per-vertex owner stripes
    (:func:`striped_spec_from_schedule` / :func:`striped_tables`) all
    apportion through here, so every layer rounds identically."""
    raw = [f * total for f in fractions]
    sizes = [int(np.floor(r)) for r in raw]
    leftover = total - sum(sizes)
    order = sorted(range(len(raw)), key=lambda i: (sizes[i] - raw[i], i))
    for i in order[:leftover]:
        sizes[i] += 1
    return tuple(sizes)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

@dataclass
class TreeSchedule:
    """Reduce/broadcast rounds for one spanning tree."""
    n: int
    root: int
    tree: frozenset
    reduce_rounds: list   # list[rounds]; each round = list[(src, dst)]
    bcast_rounds: list

    @property
    def depth(self) -> int:
        return len(self.bcast_rounds)


def tree_schedule(n: int, tree, root: int | None = None) -> TreeSchedule:
    tree = frozenset(canon(*e) for e in tree)
    root = _best_root(n, tree) if root is None else root
    levels = tree_depth_levels(tree, root)  # levels[d] = [(parent, child)]
    reduce_rounds = [[(c, p) for p, c in lvl] for lvl in reversed(levels)]
    bcast_rounds = [list(lvl) for lvl in levels]
    return TreeSchedule(n, root, tree, reduce_rounds, bcast_rounds)


def _best_root(n: int, tree) -> int:
    """Root minimizing tree depth (a tree center), O(n) via the CSR
    double-BFS in :mod:`repro.core.csr` (three sweeps instead of the old
    every-vertex probe, which was O(n^2) and dominated schedule compiles
    on >= 1000-node fabrics)."""
    return tree_center(n, tree)[0]


def _best_root_probe(n: int, tree) -> int:
    """The historical O(n^2) every-vertex BFS probe.  Kept as the
    regression oracle for :func:`_best_root` (identical roots/depths are
    asserted in tests) and as the baseline timed by
    ``benchmarks/allreduce_bench.py``."""
    best, best_d = 0, 10**9
    adj: dict = {}
    for u, v in tree:
        adj.setdefault(u, []).append(v)
        adj.setdefault(v, []).append(u)

    def depth_from(r):
        seen = {r}
        d, frontier = 0, [r]
        while frontier:
            nxt = []
            for u in frontier:
                for w in adj.get(u, ()):
                    if w not in seen:
                        seen.add(w)
                        nxt.append(w)
            if nxt:
                d += 1
            frontier = nxt
        return d

    for r in range(n):
        d = depth_from(r)
        if d < best_d:
            best, best_d = r, d
    return best


@dataclass
class AllreduceSchedule:
    """k concurrent tree schedules (one chunk per tree)."""
    n: int
    trees: list  # list[TreeSchedule]

    @property
    def k(self) -> int:
        return len(self.trees)

    @property
    def depth(self) -> int:
        return max(t.depth for t in self.trees)

    def check_contention_free(self) -> bool:
        """No physical link is used by two different trees (EDST property)."""
        seen = set()
        for ts in self.trees:
            for e in ts.tree:
                if e in seen:
                    return False
                seen.add(e)
        return True

    def global_rounds(self, phase: str):
        """Round r = union of every tree's round-r messages, tagged by tree."""
        rounds_attr = "reduce_rounds" if phase == "reduce" else "bcast_rounds"
        nrounds = max(len(getattr(t, rounds_attr)) for t in self.trees)
        out = []
        for r in range(nrounds):
            msgs = []
            for j, ts in enumerate(self.trees):
                rr = getattr(ts, rounds_attr)
                if r < len(rr):
                    msgs.extend((j, s, d) for s, d in rr[r])
            out.append(msgs)
        return out


#: Wave-assembly strategies the spec compilers accept: ``"greedy"`` is the
#: flat critical-path list schedule, ``"search"`` the seeded hillclimb of
#: :mod:`repro.core.schedule_search` (never worse than greedy), and
#: ``"composed"`` the near-linear compositional assembly of
#: :mod:`repro.core.product_schedule`.
SCHEDULES = ("greedy", "search", "composed")


def allreduce_schedule(n: int, trees, roots=None) -> AllreduceSchedule:
    """Build the k-tree schedule.  ``roots`` may be explicit root ids,
    ``None`` (depth-minimizing tree centers via :func:`_best_root`), or
    ``"search"`` -- the strict-improvement root search of
    :mod:`repro.core.schedule_search`, which only replaces a center root
    when a candidate is strictly shallower (so searched roots are never
    deeper than :func:`_best_root`)."""
    if isinstance(roots, str):
        if roots != "search":
            raise ValueError(f"roots={roots!r}: expected explicit roots, "
                             "None, or 'search'")
        from .schedule_search import search_roots
        roots = search_roots(n, trees)
    roots = roots or [None] * len(trees)
    sched = AllreduceSchedule(n, [tree_schedule(n, t, r)
                                  for t, r in zip(trees, roots)])
    assert sched.check_contention_free(), "trees share a link"
    return sched


# ---------------------------------------------------------------------------
# compile-time static verification (repro.analysis.verify)
# ---------------------------------------------------------------------------
#
# Every spec compiler takes a ``verify=`` flag and hands the freshly
# built program to the static verifier BEFORE caching it, so an illegal
# schedule (e.g. a future schedule-search candidate with two trees on one
# link) is rejected at build time, not discovered as wrong numerics at
# step time.  ``verify=None`` resolves through the module global below /
# the ``REPRO_VERIFY_SPECS`` environment variable ("off" | "cheap" |
# "full"); production defaults to the O(messages) cheap assert mode,
# tests export REPRO_VERIFY_SPECS=full (see tests/conftest.py).

VERIFY_SPECS: str | None = None     # programmatic override of the env var


def _resolve_verify(verify) -> str:
    if verify is None:
        mode = VERIFY_SPECS or os.environ.get("REPRO_VERIFY_SPECS", "cheap")
    elif verify is True:
        mode = "full"
    elif verify is False:
        mode = "off"
    else:
        mode = verify
    if mode not in ("off", "cheap", "full"):
        raise ValueError(
            f"verify must be one of off/cheap/full (or bool/None), "
            f"got {mode!r}")
    return mode


def verify_compiled_spec(spec, verify=None, context: str = ""):
    """Run the static verifier (:mod:`repro.analysis.verify`) on a
    compiled spec at the resolved level; raises
    :class:`repro.analysis.verify.SpecVerificationError` on violations.
    Imported lazily: the verifier itself imports this module."""
    mode = _resolve_verify(verify)
    if mode == "off":
        return spec
    from ..analysis.verify import assert_valid
    assert_valid(spec, level=mode, context=context)
    return spec


# ---------------------------------------------------------------------------
# fused global-round program (the executor-facing compiled form)
# ---------------------------------------------------------------------------
#
# ``AllreduceSchedule`` is tree-major: tree j's rounds, then tree j+1's.
# Executed literally that is sum-of-all-trees serial hops.  The fused form
# is round-major: global round r carries round r of EVERY tree, and each
# global round is split into the fewest ppermute-legal waves (unique
# sources and destinations per wave) over the *union* of the trees'
# messages.  Because a wave's sources are unique, every sender ships
# exactly one tree's chunk, so one ppermute moves several trees' traffic
# at once -- the wire bytes are unchanged (edge-disjointness: each message
# still crosses its own link) but the collective count drops from
# sum-of-trees rounds to depth-of-deepest-tree waves.
#
# Per wave the compiler precomputes (n,)-shaped NumPy tables consumed by
# ``repro.dist.tree_allreduce.fused_tree_allreduce`` at trace time:
# ``send_row[v]`` = which chunk row vertex v ships, ``recv_row[v]`` /
# ``recv_flag[v]`` = where an arriving payload lands (and whether one
# arrives at all).  Nothing is rebuilt per call.

@dataclass(frozen=True, eq=False)
class FusedRound:
    """One ppermute-legal wave of a global round."""
    perm: tuple            # ((src, dst), ...) unique srcs, unique dsts
    send_row: np.ndarray   # (n,) int32: chunk row vertex v sends
    recv_row: np.ndarray   # (n,) int32: chunk row an arrival lands in
    recv_flag: np.ndarray  # (n,) bool: does vertex v receive this wave


@dataclass(frozen=True, eq=False)
class FusedAllreduceSpec:
    """Round-major allreduce program with precomputed per-wave tables.

    Hash/equality follow ``key`` (fabric size, axis names, rooted tree
    sets), so two compiles of the same (topology, axes) -- which
    :func:`fused_spec_from_schedule` also caches to the same object --
    never retrace a jitted executor that takes the spec statically.
    """
    n: int
    k: int
    axes: tuple            # mesh axis names the allreduce runs over
    depth: int             # deepest tree's level count
    reduce_rounds: tuple   # tuple[FusedRound], deepest level first
    bcast_rounds: tuple    # tuple[FusedRound], root level first
    key: tuple

    @property
    def num_collectives(self) -> int:
        """ppermutes one allreduce issues (1 per wave, quantized or not)."""
        return len(self.reduce_rounds) + len(self.bcast_rounds)

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return (isinstance(other, FusedAllreduceSpec)
                and self.key == other.key)


def _split_tagged(msgs):
    """Greedily split one global round's (tree, src, dst) messages into
    waves with unique sources and unique destinations (ppermute-legal)."""
    out, remaining = [], list(msgs)
    while remaining:
        srcs, dsts, taken, rest = set(), set(), [], []
        for m in remaining:
            _, s, d = m
            if s in srcs or d in dsts:
                rest.append(m)
            else:
                srcs.add(s)
                dsts.add(d)
                taken.append(m)
        out.append(taken)
        remaining = rest
    return out


def _fused_round(n: int, taken) -> FusedRound:
    send_row = np.zeros(n, np.int32)
    recv_row = np.zeros(n, np.int32)
    recv_flag = np.zeros(n, bool)
    perm = []
    for j, s, d in taken:
        perm.append((s, d))
        send_row[s] = j
        recv_row[d] = j
        recv_flag[d] = True
    return FusedRound(tuple(perm), send_row, recv_row, recv_flag)


def _sched_key(sched: AllreduceSchedule, axes: tuple) -> tuple:
    return (sched.n, axes, tuple((ts.root, ts.tree) for ts in sched.trees))


_FUSED_CACHE: dict = {}


def _routed_spec(engine: str, sched, axes, verify, schedule: str,
                 seed: int):
    """Dispatch a ``schedule=`` strategy (:data:`SCHEDULES`) to its
    compiler: ``"search"`` to :mod:`repro.core.schedule_search`,
    ``"composed"`` to the ASAP assemblers of
    :mod:`repro.core.product_schedule` (lazy imports -- both modules
    import this one).  Returns ``None`` for ``"greedy"``: the caller
    runs its own list-scheduled body."""
    if schedule == "greedy":
        return None
    if schedule == "search":
        from . import schedule_search as ss
        fn = {"fused": ss.search_fused_spec,
              "pipelined": ss.search_pipelined_spec,
              "striped": ss.search_striped_spec}[engine]
        return fn(sched, axes, verify, seed=seed)
    if schedule == "composed":
        from . import product_schedule as ps
        fn = {"fused": ps.asap_fused_spec,
              "pipelined": ps.asap_pipelined_spec,
              "striped": ps.asap_striped_spec}[engine]
        return fn(sched, axes, verify)
    raise ValueError(f"schedule={schedule!r}: expected one of {SCHEDULES}")


def fused_spec_from_schedule(sched: AllreduceSchedule,
                             axis_names,
                             verify=None, schedule: str = "greedy",
                             seed: int = 0) -> FusedAllreduceSpec:
    """Compile an :class:`AllreduceSchedule` into the round-major
    :class:`FusedAllreduceSpec`.  Compiles are cached by (fabric, rooted
    trees, axes): repeated calls for the same topology return the *same*
    object, keeping jit caches stable.  Fresh compiles are statically
    verified per ``verify=`` (see :func:`verify_compiled_spec`) before
    entering the cache; cache hits re-verify only on an explicit truthy
    ``verify``.  ``schedule`` picks the wave-assembly strategy
    (:data:`SCHEDULES`); non-greedy strategies append their tag (and
    ``seed``, for search) to the spec key, so each strategy keeps its own
    stable spec identity."""
    axes = tuple(axis_names)
    routed = _routed_spec("fused", sched, axes, verify, schedule, seed)
    if routed is not None:
        return routed
    key = _sched_key(sched, axes)
    hit = _FUSED_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "fused_spec_from_schedule")
        return hit
    phases = {}
    for phase in ("reduce", "bcast"):
        rounds = []
        for msgs in sched.global_rounds(phase):
            rounds.extend(_fused_round(sched.n, wave)
                          for wave in _split_tagged(msgs))
        phases[phase] = tuple(rounds)
    spec = FusedAllreduceSpec(n=sched.n, k=sched.k, axes=axes,
                              depth=sched.depth,
                              reduce_rounds=phases["reduce"],
                              bcast_rounds=phases["bcast"], key=key)
    verify_compiled_spec(spec, verify, "fused_spec_from_schedule")
    _FUSED_CACHE[key] = spec
    return spec


def empty_fused_spec(n: int, axis_names) -> FusedAllreduceSpec:
    """The k=0 program (no trees survive): executor passes data through."""
    axes = tuple(axis_names)
    return FusedAllreduceSpec(n=n, k=0, axes=axes, depth=0,
                              reduce_rounds=(), bcast_rounds=(),
                              key=(n, axes, ()))


# ---------------------------------------------------------------------------
# pipelined wave program (the segment-streaming compiled form)
# ---------------------------------------------------------------------------
#
# The fused form above is round-major but still *round-aligned*: global
# round r waits for every tree's round r-1, fan-in overflow waves stall
# whole rounds, and the broadcast phase cannot start until the deepest
# tree's reduce finishes.  The pipelined compiler drops the round
# alignment entirely: it builds the dependency DAG over every message of
# every tree and BOTH phases (a reduce send needs the sender's subtree
# complete; a broadcast send needs the sender to hold the final total)
# and list-schedules the DAG into the fewest ppermute-legal waves,
# longest-critical-path messages first.  A shallow tree's broadcast
# overlaps a deep tree's reduce tail, fan-in spill rides later waves, and
# the wave count drops from `2 * depth * k`-ish to within a couple of the
# DAG critical path (22 -> 12 on the 4x4 torus with k=2).
#
# The wave list doubles as the *pipeline stage* sequence: wave w only
# depends on waves < w, so payload segment s can run wave w while segment
# s+1 runs wave w-1.  Streaming S segments costs `waves + S - 1` steps of
# `m/S`-sized hops -- the classic `2*depth*m  ->  (2*depth + S - 1)*(m/S)`
# bandwidth-optimal tree pipeline -- and the executor's scan over the
# step index keeps HLO size and trace time independent of S.
#
# Quantized programs are compiled phase-separated (`q8_waves`): int8 and
# f32 payloads cannot share one ppermute, and a reduce/broadcast boundary
# lets the executor quantize each tree's total ONCE and forward the
# packed bytes down the tree instead of re-coding every hop.

REDUCE, BCAST = 1, 2


@dataclass(frozen=True, eq=False)
class PipeWave:
    """One ppermute-legal wave of the pipelined program.

    ``send_row[v]`` names the chunk row vertex v ships (senders only);
    ``reduce_flag[j, v]`` / ``bcast_flag[j, v]`` say whether the arrival
    at v accumulates into / overwrites row j.  ``rows`` is the static
    set of distinct sender rows (executors specialize on its size) and
    ``sole_add`` marks waves whose every arrival accumulates into one
    row -- there the executor may skip masking entirely, because
    ``ppermute`` hands devices nobody sent to a zero payload.
    """
    perm: tuple            # ((src, dst), ...) unique srcs, unique dsts
    send_row: np.ndarray   # (n,) int32
    reduce_flag: np.ndarray  # (k, n) bool
    bcast_flag: np.ndarray   # (k, n) bool
    rows: tuple            # distinct sender chunk rows, sorted
    sole_add: int          # row index if pure single-row reduce wave, else -1

    @property
    def has_bcast(self) -> bool:
        return bool(self.bcast_flag.any())


@dataclass(frozen=True, eq=False)
class PipelinedAllreduceSpec:
    """List-scheduled wave program with segment-pipelining metadata.

    ``waves`` is the phase-mixed program (fewest waves; the f32 engine);
    ``q8_waves`` the phase-separated program for quantized wires with
    ``q8_boundary`` marking the first broadcast wave (the pack-once
    point).  The stacked ``(R, n)`` tables (``send_rows`` / ``dst_table``
    / ``recv_rows`` / ``recv_kind``) are the canonical compiled form
    consumed by the packet simulator and the table-driven tests; the
    executors read the per-wave views.  Hash/equality follow ``key`` so
    cached recompiles never retrace a jitted executor.
    """
    n: int
    k: int
    axes: tuple            # mesh axis names the allreduce runs over
    depth: int             # deepest tree's level count
    waves: tuple           # tuple[PipeWave], dependency order
    q8_waves: tuple        # tuple[PipeWave], reduce waves then bcast waves
    q8_boundary: int       # index of the first bcast wave in q8_waves
    key: tuple

    @property
    def num_collectives(self) -> int:
        """ppermutes one unpipelined (S=1) allreduce issues."""
        return len(self.waves)

    def steps(self, segments: int) -> int:
        """Pipeline steps to stream ``segments`` payload segments."""
        return len(self.waves) + segments - 1

    def _stack(self, waves):
        r, n = len(waves), self.n
        send = np.zeros((r, n), np.int32)
        dst = np.full((r, n), -1, np.int32)
        recv = np.full((r, n), -1, np.int32)
        kind = np.zeros((r, n), np.int8)
        for w, wv in enumerate(waves):
            send[w] = wv.send_row
            for s, d in wv.perm:
                dst[w, s] = d
            for j in range(self.k):
                recv[w, wv.reduce_flag[j]] = j
                kind[w, wv.reduce_flag[j]] = REDUCE
                recv[w, wv.bcast_flag[j]] = j
                kind[w, wv.bcast_flag[j]] = BCAST
        return send, dst, recv, kind

    @property
    def tables(self):
        """Stacked ``(R, n)`` tables of the mixed program:
        ``(send_rows, dst_table, recv_rows, recv_kind)``."""
        return self._stack(self.waves)

    @property
    def q8_tables(self):
        return self._stack(self.q8_waves)

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return (isinstance(other, PipelinedAllreduceSpec)
                and self.key == other.key)


def _message_dag(sched: AllreduceSchedule):
    """Every (tree, kind, src, dst) message with its dependency set.

    reduce (c -> p) needs c's children's reduce messages delivered;
    broadcast (p -> c) needs p to hold tree j's final total: every reduce
    message into the root when p is the root, else the broadcast into p.
    Messages are appended children-before-parents (reduce) and
    roots-before-leaves (broadcast), so ids topologically order the DAG.
    """
    msgs, deps = [], []
    for j, ts in enumerate(sched.trees):
        children: dict = {}
        for lvl in ts.bcast_rounds:
            for p, c in lvl:
                children.setdefault(p, []).append(c)
        rid: dict = {}
        for lvl in ts.reduce_rounds:        # deepest level first
            for c, p in lvl:
                deps.append(frozenset(rid[x] for x in children.get(c, ())))
                rid[c] = len(msgs)
                msgs.append((j, REDUCE, c, p))
        into_root = frozenset(rid[x] for x in children.get(ts.root, ()))
        bid: dict = {}
        for lvl in ts.bcast_rounds:         # root level first
            for p, c in lvl:
                deps.append(into_root if p == ts.root else frozenset({bid[p]}))
                bid[c] = len(msgs)
                msgs.append((j, BCAST, p, c))
    return msgs, deps


def _list_schedule(msgs, deps, kinds=None, op_of=None, verify=False,
                   priority=None):
    """Greedy list scheduling of the message DAG into ppermute-legal
    waves (unique sources AND destinations per wave), critical-path
    height first.  A message becomes ready only once every dependency is
    delivered in a strictly earlier wave, which is exactly what the
    executors need: a sender's local value is complete by the time its
    wave reads it.  ``kinds`` restricts a pass to a subset of message
    kinds (the quantized program schedules reduce and broadcast
    separately).  ``op_of`` (message -> op class) keeps each wave
    homogeneous in arrival semantics: the striped program mixes
    accumulate (reduce-scatter) and overwrite (allgather) messages in
    one DAG, but an executor wave must apply a single op.  ``verify``
    re-checks the emitted waves against the scheduling contract (every
    selected message exactly once, per-wave ppermute legality, every
    dependency in a strictly earlier wave) -- the compilers enable it
    under full-level spec verification so schedule-search candidates
    cannot smuggle an illegal wave past the greedy selector."""
    ids = [i for i in range(len(msgs)) if kinds is None or msgs[i][1] in kinds]
    chosen = set(ids)
    dependents: dict = {i: [] for i in ids}
    for i in ids:
        for d in deps[i]:
            if d in chosen:
                dependents[d].append(i)
    height = {i: 0 for i in ids}
    for i in reversed(ids):                 # ids are topologically ordered
        for dep in dependents[i]:
            height[i] = max(height[i], height[dep] + 1)
    done: set = set(i for i in range(len(msgs)) if i not in chosen)
    pending = set(ids)
    waves = []
    while pending:
        if priority is None:
            ready = sorted((i for i in pending if deps[i] <= done),
                           key=lambda i: (-height[i], msgs[i][0], msgs[i][2]))
        else:
            ready = sorted((i for i in pending if deps[i] <= done),
                           key=lambda i: (-height[i], priority[i]))
        if op_of is not None and ready:
            wave_op = op_of(msgs[ready[0]])
            ready = [i for i in ready if op_of(msgs[i]) == wave_op]
        srcs, dsts, take = set(), set(), []
        for i in ready:
            _, _, s, d = msgs[i]
            if s not in srcs and d not in dsts:
                srcs.add(s)
                dsts.add(d)
                take.append(i)
        assert take, "list scheduler stalled (cyclic message DAG?)"
        waves.append(take)
        pending -= set(take)
        done |= set(take)
    if verify:
        _check_list_schedule(msgs, deps, ids, waves, op_of)
    return waves


def _check_list_schedule(msgs, deps, ids, waves, op_of=None) -> None:
    """Self-check of a list-scheduled wave program (see
    :func:`_list_schedule`); raises ``ValueError`` on any breach."""
    scheduled = [i for take in waves for i in take]
    if sorted(scheduled) != sorted(ids):
        raise ValueError("list schedule drops or duplicates messages")
    wave_of = {i: w for w, take in enumerate(waves) for i in take}
    chosen = set(ids)
    for w, take in enumerate(waves):
        srcs = [msgs[i][2] for i in take]
        dsts = [msgs[i][3] for i in take]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            raise ValueError(f"list schedule wave {w} is not ppermute-legal")
        if op_of is not None and len({op_of(msgs[i]) for i in take}) > 1:
            raise ValueError(f"list schedule wave {w} mixes arrival ops")
        for i in take:
            late = [d for d in deps[i] if d in chosen and wave_of[d] >= w]
            if late:
                raise ValueError(
                    f"list schedule wave {w}: message {msgs[i]} precedes "
                    f"its dependency {msgs[late[0]]}")


def _pipe_wave(n: int, k: int, msgs, take) -> PipeWave:
    send_row = np.zeros(n, np.int32)
    rflag = np.zeros((k, n), bool)
    bflag = np.zeros((k, n), bool)
    perm, rows = [], set()
    for i in take:
        j, kind, s, d = msgs[i]
        perm.append((s, d))
        send_row[s] = j
        rows.add(j)
        (rflag if kind == REDUCE else bflag)[j, d] = True
    sole = min(rows) if len(rows) == 1 and not bflag.any() else -1
    return PipeWave(tuple(perm), send_row, rflag, bflag,
                    tuple(sorted(rows)), sole)


_PIPE_CACHE: dict = {}


def pipelined_spec_from_schedule(sched: AllreduceSchedule,
                                 axis_names,
                                 verify=None, schedule: str = "greedy",
                                 seed: int = 0) -> PipelinedAllreduceSpec:
    """Compile an :class:`AllreduceSchedule` into the list-scheduled
    :class:`PipelinedAllreduceSpec`.  Cached by (fabric, rooted trees,
    axes) like :func:`fused_spec_from_schedule`: recompiles return the
    identical object, keeping jit caches stable.  Fresh compiles are
    statically verified per ``verify=`` before caching (full level also
    self-checks the list scheduler's waves).  ``schedule`` picks the
    wave-assembly strategy (:data:`SCHEDULES`); non-greedy strategies
    carry their own spec-key tag."""
    axes = tuple(axis_names)
    routed = _routed_spec("pipelined", sched, axes, verify, schedule, seed)
    if routed is not None:
        return routed
    key = (*_sched_key(sched, axes), "pipelined")
    hit = _PIPE_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "pipelined_spec_from_schedule")
        return hit
    deep = _resolve_verify(verify) == "full"
    msgs, deps = _message_dag(sched)
    n, k = sched.n, sched.k
    waves = tuple(_pipe_wave(n, k, msgs, take)
                  for take in _list_schedule(msgs, deps, verify=deep))
    red = [_pipe_wave(n, k, msgs, take)
           for take in _list_schedule(msgs, deps, kinds={REDUCE},
                                      verify=deep)]
    bc = [_pipe_wave(n, k, msgs, take)
          for take in _list_schedule(msgs, deps, kinds={BCAST},
                                     verify=deep)]
    spec = PipelinedAllreduceSpec(n=n, k=k, axes=axes, depth=sched.depth,
                                  waves=waves, q8_waves=tuple(red + bc),
                                  q8_boundary=len(red), key=key)
    verify_compiled_spec(spec, verify, "pipelined_spec_from_schedule")
    _PIPE_CACHE[key] = spec
    return spec


def empty_pipelined_spec(n: int, axis_names) -> PipelinedAllreduceSpec:
    """The k=0 program (no trees survive): executor passes data through."""
    axes = tuple(axis_names)
    return PipelinedAllreduceSpec(n=n, k=0, axes=axes, depth=0, waves=(),
                                  q8_waves=(), q8_boundary=0,
                                  key=(n, axes, (), "pipelined"))


def simulate_wave_program(spec, values: np.ndarray,
                          segments: int = 1, quantized: bool = False
                          ) -> SimResult:
    """Packet-level replay of the compiled wave program with the payload
    split into ``segments`` pipeline segments: at step t wave w moves
    segment ``t - w``, exactly as the scan executor does.  Checks that
    every vertex ends with the global sum and that no wave reuses a
    source or destination.  ``quantized`` replays ``q8_waves``.

    A :class:`StripedCollectiveSpec` dispatches to
    :func:`simulate_striped_program` (which additionally checks
    per-stripe conservation); striped programs carry stripe-sized
    payloads instead of segment-streaming, so ``segments``/``quantized``
    do not change their routing and are ignored."""
    if isinstance(spec, StripedCollectiveSpec):
        return simulate_striped_program(spec, values)
    n, d = values.shape
    k = spec.k
    if k == 0:
        return SimResult(False, 0, 0, {})
    assert n == spec.n
    m = -(-d // k)
    msub = -(-m // segments)
    padded = np.pad(values.astype(np.float64), ((0, 0), (0, k * m - d))) \
        .reshape(n, k, m)
    state = np.zeros((n, k, segments * msub))
    state[:, :, :m] = padded
    expected = padded.sum(0)
    waves = spec.q8_waves if quantized else spec.waves
    link_bytes: dict = {}
    max_load = 0
    steps = len(waves) + segments - 1
    for t in range(steps):
        staged = []
        loads: dict = {}
        for w, wv in enumerate(waves):
            seg = t - w
            if not 0 <= seg < segments:
                continue
            srcs = [s for s, _ in wv.perm]
            dsts = [d_ for _, d_ in wv.perm]
            assert len(set(srcs)) == len(srcs), "wave reuses a source"
            assert len(set(dsts)) == len(dsts), "wave reuses a destination"
            lo, hi = seg * msub, (seg + 1) * msub
            for s, d_ in wv.perm:
                row = int(wv.send_row[s])
                payload = state[s, row, lo:hi].copy()
                kind = (REDUCE if wv.reduce_flag[row, d_] else BCAST)
                staged.append((d_, row, lo, hi, kind, payload))
                # phase-mixed waves may drive one undirected link in both
                # directions at once (full duplex), so loads are DIRECTED
                loads[(s, d_)] = loads.get((s, d_), 0) + 1
                link_bytes[(s, d_)] = link_bytes.get((s, d_), 0) + (hi - lo)
        for d_, row, lo, hi, kind, payload in staged:
            if kind == REDUCE:
                state[d_, row, lo:hi] += payload
            else:
                state[d_, row, lo:hi] = payload
        if loads:
            max_load = max(max_load, max(loads.values()))
    final = state[:, :, :m]
    ok = bool(np.allclose(final, expected[None]))
    return SimResult(ok, steps, max_load, link_bytes)


# ---------------------------------------------------------------------------
# striped reduce-scatter / allgather wave program
# ---------------------------------------------------------------------------
#
# Every engine above ships the full m-sized chunk along every tree edge.
# The k EDSTs expose k edge-disjoint pathways precisely so collectives can
# *stripe*: assign each vertex an owner stripe per tree and restructure
# each tree's traffic as reduce-scatter (partial sums flow both rootward
# and leafward, but an edge only carries the stripes owned on the far
# side of it) followed by allgather (finished stripes fan back out, a
# pure gather -- arrivals overwrite, nothing accumulates).
#
# Owner stripes follow the tree's DFS *preorder*: the vertex with
# preorder index i owns stripe slot i, so every subtree is a contiguous
# slot interval [pre(c), pre(c)+size(c)) and its complement is a
# contiguous interval of the *circular* slot space.  Each message is then
# one circular window:
#
#   RS_UP   c -> p  carries the `above` window (slots owned outside
#                   subtree(c)): subtree(c)'s partial sums flow rootward;
#   RS_DOWN p -> c  carries the `below` window (slots owned inside
#                   subtree(c)): everyone else's partials flow leafward;
#   AG_UP   c -> p  carries `below`: finished subtree stripes gather up;
#   AG_DOWN p -> c  carries `above`: the rest of the totals gather down.
#
# After RS every vertex holds the finished total of its OWN stripe; after
# AG every vertex holds all of them.  An edge's window always excludes at
# least one slot (a subtree and its complement are both non-empty), so
# per-wave wire bytes drop from m to <= ceil(m/n) * slots-in-window --
# the bound `simulate_striped_program` checks.
#
# The four kinds of every tree form ONE dependency DAG and are
# list-scheduled together (op-homogeneous waves: reduce-scatter arrivals
# accumulate, allgather arrivals overwrite), so a shallow tree's gather
# overlaps a deep tree's scatter tail exactly like the pipelined engine.
# Standalone `rs_waves` / `ag_waves` programs (each phase's sub-DAG) back
# the first-class tree_reduce_scatter / tree_allgather collectives in
# ``repro.dist.striped``.
#
# The spec is m-independent: windows are compiled in SLOT units, and
# :func:`striped_tables` binds them to element offsets for a concrete
# payload via the canonical largest-remainder :func:`chunk_sizes` (the
# same helper that apportions per-tree chunk widths, so weighted fault
# re-striping composes with ownership for free).

RS_UP, RS_DOWN, AG_UP, AG_DOWN = 11, 12, 13, 14
_RS_KINDS = frozenset({RS_UP, RS_DOWN})


def _striped_op(msg):
    """Arrival semantics class: reduce-scatter accumulates, allgather
    overwrites (REDUCE/BCAST reuse the executor-facing constants)."""
    return REDUCE if msg[1] in _RS_KINDS else BCAST


@dataclass(frozen=True, eq=False)
class StripedTree:
    """One tree's ownership structure: DFS preorder slot per vertex."""
    root: int
    pre: np.ndarray      # (n,) int32: owner slot (preorder index) of v
    size: np.ndarray     # (n,) int32: subtree size of v
    parent: np.ndarray   # (n,) int32: parent vertex, -1 at the root


@dataclass(frozen=True, eq=False)
class StripedWave:
    """One ppermute-legal, op-homogeneous wave in SLOT units.

    ``send_slot[v]`` / ``send_nslot[v]`` name sender v's circular slot
    window (mod n) inside tree ``send_tree[v]``'s chunk; the ``recv_*``
    tables the matching window an arrival lands in (``recv_nslot[v]`` = 0
    when v receives nothing).  ``op`` is REDUCE (accumulate) or BCAST
    (overwrite) for every arrival of the wave."""
    perm: tuple            # ((src, dst), ...) unique srcs, unique dsts
    op: int                # REDUCE | BCAST
    msgs: tuple            # ((tree, kind, src, dst), ...)
    send_tree: np.ndarray  # (n,) int32
    send_slot: np.ndarray  # (n,) int32
    send_nslot: np.ndarray  # (n,) int32
    recv_tree: np.ndarray  # (n,) int32
    recv_slot: np.ndarray  # (n,) int32
    recv_nslot: np.ndarray  # (n,) int32


@dataclass(frozen=True, eq=False)
class StripedCollectiveSpec:
    """Compiled striped reduce-scatter / allgather program.

    ``waves`` is the composed allreduce (reduce-scatter ∘ allgather, one
    DAG); ``rs_waves`` / ``ag_waves`` the standalone phase programs.
    Windows are in slot units -- :func:`striped_tables` binds a concrete
    payload size (and optional per-tree fractions).  Hash/equality follow
    ``key`` so cached recompiles never retrace a jitted executor."""
    n: int
    k: int
    axes: tuple            # mesh axis names the collective runs over
    depth: int             # deepest tree's level count
    trees: tuple           # tuple[StripedTree]
    waves: tuple           # tuple[StripedWave], composed program
    rs_waves: tuple        # tuple[StripedWave], reduce-scatter only
    ag_waves: tuple        # tuple[StripedWave], allgather only
    key: tuple

    @property
    def num_collectives(self) -> int:
        """ppermutes one composed striped allreduce issues."""
        return len(self.waves)

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return (isinstance(other, StripedCollectiveSpec)
                and self.key == other.key)


def _striped_tree(n: int, ts: TreeSchedule) -> StripedTree:
    children: dict = {}
    for lvl in ts.bcast_rounds:
        for p, c in lvl:
            children.setdefault(p, []).append(c)
    pre = np.full(n, -1, np.int32)
    size = np.ones(n, np.int32)
    parent = np.full(n, -1, np.int32)
    order = []
    stack = [ts.root]
    while stack:                      # iterative DFS preorder
        v = stack.pop()
        pre[v] = len(order)
        order.append(v)
        for c in reversed(children.get(v, ())):
            parent[c] = v
            stack.append(c)
    for v in reversed(order):         # subtree sizes, leaves first
        if parent[v] >= 0:
            size[parent[v]] += size[v]
    assert len(order) == n, "tree does not span the fabric"
    return StripedTree(ts.root, pre, size, parent)


def _striped_dag(sched: AllreduceSchedule, trees):
    """Messages + dependency sets of the striped program.

    For edge (c, p) of tree j (c the child):
      RS_UP(c)   needs RS_UP(g -> c) for every child g of c;
      RS_DOWN(c) needs RS_UP(g -> p) for every OTHER child g of p, plus
                 RS_DOWN(p) unless p is the root (the window it ships --
                 subtree(c)'s slots -- must hold every contribution from
                 outside subtree(c) first);
      AG_UP(c)   needs c's reduce-scatter complete (all RS_UP into c and
                 RS_DOWN(c): c's own stripe is finished) plus AG_UP(g)
                 for every child (their subtree totals ride along);
      AG_DOWN(c) needs every RS_UP into p (p's own stripe finished),
                 AG_UP(g -> p) for every other child, and -- unless p is
                 the root -- RS_DOWN(p) and AG_DOWN(p).
    Message ids are appended in dependency-safe order per tree, keeping
    the topological-order contract of :func:`_list_schedule`."""
    msgs, deps = [], []
    for j, st in enumerate(trees):
        children: dict = {}
        for v in range(sched.n):
            if st.parent[v] >= 0:
                children.setdefault(int(st.parent[v]), []).append(v)
        for v in children:            # DFS preorder == slot order per level
            children[v].sort(key=lambda c: st.pre[c])
        rup: dict = {}
        rdn: dict = {}
        aup: dict = {}
        # down-kinds walk roots-before-leaves (decreasing subtree size:
        # every proper ancestor has a strictly larger subtree), up-kinds
        # children-before-parents (increasing) -- keeps appended ids
        # topologically ordered
        by_depth = sorted((v for v in range(sched.n) if st.parent[v] >= 0),
                          key=lambda v: -int(st.size[v]))
        for v in sorted(range(sched.n), key=lambda v: int(st.size[v])):
            if st.parent[v] < 0:
                continue
            deps.append(frozenset(rup[g] for g in children.get(v, ())))
            rup[v] = len(msgs)
            msgs.append((j, RS_UP, v, int(st.parent[v])))
        # RS_DOWN roots-before-leaves: walk by decreasing subtree size
        for v in by_depth:
            p = int(st.parent[v])
            d = {rup[g] for g in children.get(p, ()) if g != v}
            if st.parent[p] >= 0:
                d.add(rdn[p])
            deps.append(frozenset(d))
            rdn[v] = len(msgs)
            msgs.append((j, RS_DOWN, p, v))
        # AG_UP children-before-parents
        for v in sorted(range(sched.n), key=lambda v: int(st.size[v])):
            if st.parent[v] < 0:
                continue
            d = {rup[g] for g in children.get(v, ())} | {rdn[v]}
            d |= {aup[g] for g in children.get(v, ())}
            deps.append(frozenset(d))
            aup[v] = len(msgs)
            msgs.append((j, AG_UP, v, int(st.parent[v])))
        # AG_DOWN roots-before-leaves
        adn: dict = {}
        for v in by_depth:
            p = int(st.parent[v])
            d = {rup[g] for g in children.get(p, ())}
            d |= {aup[g] for g in children.get(p, ()) if g != v}
            if st.parent[p] >= 0:
                d |= {rdn[p], adn[p]}
            deps.append(frozenset(d))
            adn[v] = len(msgs)
            msgs.append((j, AG_DOWN, p, v))
    return msgs, deps


def _striped_wave(n: int, msgs, take, trees) -> StripedWave:
    send_tree = np.zeros(n, np.int32)
    send_slot = np.zeros(n, np.int32)
    send_nslot = np.zeros(n, np.int32)
    recv_tree = np.zeros(n, np.int32)
    recv_slot = np.zeros(n, np.int32)
    recv_nslot = np.zeros(n, np.int32)
    perm, taken = [], []
    op = _striped_op(msgs[take[0]])
    for i in take:
        j, kind, s, d = msgs[i]
        assert _striped_op(msgs[i]) == op, "mixed-op striped wave"
        st = trees[j]
        c = s if kind in (RS_UP, AG_UP) else d      # the child endpoint
        below = (int(st.pre[c]), int(st.size[c]))
        above = ((int(st.pre[c]) + int(st.size[c])) % n, n - int(st.size[c]))
        slot, nslot = below if kind in (RS_DOWN, AG_UP) else above
        perm.append((s, d))
        taken.append((j, kind, s, d))
        send_tree[s], send_slot[s], send_nslot[s] = j, slot, nslot
        recv_tree[d], recv_slot[d], recv_nslot[d] = j, slot, nslot
    return StripedWave(tuple(perm), op, tuple(taken), send_tree, send_slot,
                       send_nslot, recv_tree, recv_slot, recv_nslot)


_STRIPED_CACHE: dict = {}


def striped_spec_from_schedule(sched: AllreduceSchedule,
                               axis_names,
                               verify=None, schedule: str = "greedy",
                               seed: int = 0) -> StripedCollectiveSpec:
    """Compile an :class:`AllreduceSchedule` into the striped
    reduce-scatter / allgather :class:`StripedCollectiveSpec`.  Cached by
    (fabric, rooted trees, axes) like the other spec compilers:
    recompiles return the identical object, keeping jit caches stable.
    Fresh compiles are statically verified per ``verify=`` before
    caching (full level also self-checks the list scheduler's waves).
    ``schedule`` picks the wave-assembly strategy (:data:`SCHEDULES`);
    non-greedy strategies carry their own spec-key tag."""
    axes = tuple(axis_names)
    routed = _routed_spec("striped", sched, axes, verify, schedule, seed)
    if routed is not None:
        return routed
    key = (*_sched_key(sched, axes), "striped")
    hit = _STRIPED_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "striped_spec_from_schedule")
        return hit
    deep = _resolve_verify(verify) == "full"
    trees = tuple(_striped_tree(sched.n, ts) for ts in sched.trees)
    msgs, deps = _striped_dag(sched, trees)
    n = sched.n

    def waves_of(kinds=None):
        return tuple(_striped_wave(n, msgs, take, trees)
                     for take in _list_schedule(msgs, deps, kinds=kinds,
                                                op_of=_striped_op,
                                                verify=deep))

    spec = StripedCollectiveSpec(
        n=n, k=sched.k, axes=axes, depth=sched.depth, trees=trees,
        waves=waves_of(), rs_waves=waves_of(_RS_KINDS),
        ag_waves=waves_of(frozenset({AG_UP, AG_DOWN})), key=key)
    verify_compiled_spec(spec, verify, "striped_spec_from_schedule")
    _STRIPED_CACHE[key] = spec
    return spec


def empty_striped_spec(n: int, axis_names) -> StripedCollectiveSpec:
    """The k=0 program (no trees survive): executor passes data through."""
    axes = tuple(axis_names)
    return StripedCollectiveSpec(n=n, k=0, axes=axes, depth=0, trees=(),
                                 waves=(), rs_waves=(), ag_waves=(),
                                 key=(n, axes, (), "striped"))


# -- binding slot windows to a concrete payload -----------------------------

@dataclass(frozen=True, eq=False)
class BoundStripedWave:
    """A :class:`StripedWave` with slot windows resolved to element
    offsets for one payload size.  ``wire`` is the wave's padded wire
    length (max true window length over its surviving messages);
    windows are circular mod ``mrow``."""
    perm: tuple
    op: int
    wire: int
    send_tree: np.ndarray  # (n,) int32
    send_off: np.ndarray   # (n,) int32: element offset of v's window
    recv_tree: np.ndarray  # (n,) int32
    recv_off: np.ndarray   # (n,) int32
    recv_len: np.ndarray   # (n,) int32: true window length (0: no arrival)


@dataclass(frozen=True, eq=False)
class StripedTables:
    """Element-level tables of one (spec, payload size, fractions) bind.

    All trees stripe their PADDED row of width ``mrow`` through the same
    slot->offset table ``offsets`` (padding elements are zero everywhere,
    so reducing/gathering them is harmless and keeps every window a
    single circular interval even under weighted fractions)."""
    sizes: tuple           # per-tree true chunk widths (sum == payload size)
    mrow: int              # common padded row width == max(sizes)
    smax: int              # widest owner stripe, ceil(mrow / n)
    offsets: np.ndarray    # (n+1,) int32: slot i owns [offsets[i], offsets[i+1])
    own_off: np.ndarray    # (k, n) int32: offset of v's own stripe in tree j
    own_len: np.ndarray    # (k, n) int32: width of v's own stripe in tree j
    waves: tuple           # composed program, tuple[BoundStripedWave]
    rs_waves: tuple
    ag_waves: tuple


def _bind_waves(spec, waves, offsets, mrow):
    out = []
    n = spec.n
    for wv in waves:
        send_tree = np.zeros(n, np.int32)
        send_off = np.zeros(n, np.int32)
        recv_tree = np.zeros(n, np.int32)
        recv_off = np.zeros(n, np.int32)
        recv_len = np.zeros(n, np.int32)
        perm, wire = [], 0
        for (j, kind, s, d), (src, dst) in zip(wv.msgs, wv.perm):
            slot, nslot = int(wv.send_slot[s]), int(wv.send_nslot[s])
            off = int(offsets[slot])
            if slot + nslot <= n:
                length = int(offsets[slot + nslot]) - off
            else:                     # window wraps the circular slot space
                length = (mrow - off) + int(offsets[slot + nslot - n])
            if length == 0:
                continue              # every slot in the window is empty
            perm.append((src, dst))
            wire = max(wire, length)
            send_tree[src], send_off[src] = j, off
            recv_tree[dst], recv_off[dst], recv_len[dst] = j, off, length
        if perm:
            out.append(BoundStripedWave(tuple(perm), wv.op, wire, send_tree,
                                        send_off, recv_tree, recv_off,
                                        recv_len))
    return tuple(out)


@functools.lru_cache(maxsize=256)
def striped_tables(spec: StripedCollectiveSpec, size: int,
                   fractions=None) -> StripedTables:
    """Bind ``spec``'s slot windows to a concrete flattened payload of
    ``size`` elements (optionally striped across trees by ``fractions``).
    Owner stripes partition each tree's padded row exactly
    (largest-remainder :func:`chunk_sizes` over the n vertices); stripes
    can be empty when ``mrow < n`` and their messages are dropped.
    Cached by (spec, size, fractions): trace-time rebinds are free."""
    k = max(1, spec.k)
    fr = tuple(fractions) if fractions is not None else (1.0 / k,) * k
    if spec.k and len(fr) != spec.k:
        raise ValueError(f"{len(fr)} fractions for k={spec.k} trees")
    sizes = chunk_sizes(size, fr)
    mrow = max(1, max(sizes) if sizes else 0)
    n = max(1, spec.n)
    offsets = np.zeros(n + 1, np.int32)
    offsets[1:] = np.cumsum(chunk_sizes(mrow, (1.0 / n,) * n))
    widths = np.diff(offsets)
    own_off = np.zeros((spec.k, spec.n), np.int32)
    own_len = np.zeros((spec.k, spec.n), np.int32)
    for j, st in enumerate(spec.trees):
        own_off[j] = offsets[:-1][st.pre]
        own_len[j] = widths[st.pre]
    return StripedTables(
        sizes=sizes, mrow=mrow, smax=int(widths.max()) if n else 0,
        offsets=offsets, own_off=own_off, own_len=own_len,
        waves=_bind_waves(spec, spec.waves, offsets, mrow),
        rs_waves=_bind_waves(spec, spec.rs_waves, offsets, mrow),
        ag_waves=_bind_waves(spec, spec.ag_waves, offsets, mrow))


@functools.lru_cache(maxsize=256)
def owner_element_map(spec: StripedCollectiveSpec, size: int,
                      fractions=None) -> np.ndarray:
    """Element-level ownership of one (spec, payload size, fractions)
    bind: ``map[v, j, i]`` is the flat payload index of the ``i``-th
    element of vertex ``v``'s owner stripe in tree ``j`` (the exact
    layout ``tree_reduce_scatter`` hands back), or ``-1`` where the
    ``(k, smax)`` stripe stack is padding.  Every payload element
    appears exactly once, so the map converts owner-stripe state (ZeRO-1
    optimizer moments, sharded checkpoints) between any two stripe
    geometries -- healthy vs degraded fractions, k vs k-1 trees, or
    different fabrics entirely.  Cached and returned read-only."""
    t = striped_tables(spec, size, fractions)
    out = np.full((spec.n, spec.k, t.smax), -1, np.int64)
    chunk_off = np.zeros(spec.k + 1, np.int64)
    chunk_off[1:] = np.cumsum(t.sizes)
    for j in range(spec.k):
        for v in range(spec.n):
            # single-slot windows never wrap the circular row
            off, ln = int(t.own_off[j, v]), int(t.own_len[j, v])
            width = min(ln, int(t.sizes[j]) - off)   # trim row padding
            if width > 0:
                out[v, j, :width] = chunk_off[j] + off \
                    + np.arange(width, dtype=np.int64)
    out.setflags(write=False)
    return out


@dataclass
class StripedSimResult:
    ok: bool
    rounds: int
    max_link_load: int
    per_link_bytes: dict
    wire_elems: tuple       # per composed wave: padded wire length
    max_wire: int           # max over waves
    stripes_ok: bool        # per-stripe conservation held


def _replay_striped(state, bound_waves, mrow):
    link_bytes: dict = {}
    wire_elems = []
    max_load = 0
    for w, bw in enumerate(bound_waves):
        srcs = [s for s, _ in bw.perm]
        dsts = [d for _, d in bw.perm]
        assert len(set(srcs)) == len(srcs), "wave reuses a source"
        assert len(set(dsts)) == len(dsts), "wave reuses a destination"
        wire_elems.append(bw.wire)
        staged = []
        loads: dict = {}
        for s, d in bw.perm:
            j = int(bw.send_tree[s])
            off, length = int(bw.send_off[s]), int(bw.recv_len[d])
            idxs = (off + np.arange(length)) % mrow
            staged.append((d, j, idxs, state[s, j, idxs].copy()))
            # like the pipelined replay, loads are DIRECTED: a wave may
            # drive one undirected link both ways at once (full duplex)
            loads[(s, d)] = loads.get((s, d), 0) + 1
            link_bytes[(s, d)] = link_bytes.get((s, d), 0) + length
        for d, j, idxs, payload in staged:
            if bw.op == REDUCE:
                state[d, j, idxs] += payload
            else:
                state[d, j, idxs] = payload
        if loads:
            max_load = max(max_load, max(loads.values()))
    return link_bytes, tuple(wire_elems), max_load


def _check_stripe_conservation(spec: StripedCollectiveSpec) -> bool:
    """Per-stripe conservation over the composed program: every owner
    slot of every tree crosses each of the tree's n-1 edges exactly once
    during reduce-scatter and exactly once during allgather (in the one
    direction its ownership dictates), and never twice on one edge in
    one phase."""
    n = spec.n
    for j, st in enumerate(spec.trees):
        tally: dict = {}
        for wv in spec.waves:
            for (tj, kind, s, d) in wv.msgs:
                if tj != j:
                    continue
                c = s if kind in (RS_UP, AG_UP) else d
                lo, ns = ((int(st.pre[c]), int(st.size[c]))
                          if kind in (RS_DOWN, AG_UP) else
                          ((int(st.pre[c]) + int(st.size[c])) % n,
                           n - int(st.size[c])))
                phase = "rs" if kind in _RS_KINDS else "ag"
                for slot in ((lo + t) % n for t in range(ns)):
                    key = (slot, canon(s, d), phase)
                    tally[key] = tally.get(key, 0) + 1
                    if tally[key] > 1:
                        return False
        edges = {canon(int(st.parent[v]), v)
                 for v in range(n) if st.parent[v] >= 0}
        for slot in range(n):
            for phase in ("rs", "ag"):
                if sum(tally.get((slot, e, phase), 0) for e in edges) \
                        != n - 1:
                    return False
    return True


def simulate_striped_program(spec: StripedCollectiveSpec, values: np.ndarray,
                             fractions=None) -> StripedSimResult:
    """Packet-level replay of the composed striped allreduce: checks
    that every vertex ends with the global sum, that no wave reuses a
    source/destination, that per-stripe conservation holds (each owner
    slot crosses each tree edge exactly once per phase), and records the
    per-wave wire lengths (all <= ceil(m/n) * slots-per-window < m)."""
    n, d = values.shape
    if spec.k == 0:
        return StripedSimResult(False, 0, 0, {}, (), 0, False)
    assert n == spec.n
    bound = striped_tables(spec, d,
                           None if fractions is None else tuple(fractions))
    mrow = bound.mrow
    state = np.zeros((n, spec.k, mrow))
    off = 0
    for j, s in enumerate(bound.sizes):
        state[:, j, :s] = values[:, off:off + s]
        off += s
    expected = state.sum(0)
    link_bytes, wire_elems, max_load = _replay_striped(state, bound.waves,
                                                       mrow)
    ok = bool(np.allclose(state, expected[None]))
    return StripedSimResult(
        ok=ok, rounds=len(bound.waves),
        max_link_load=max_load, per_link_bytes=link_bytes,
        wire_elems=wire_elems,
        max_wire=max(wire_elems) if wire_elems else 0,
        stripes_ok=_check_stripe_conservation(spec))


# ---------------------------------------------------------------------------
# NumPy packet-level simulator (correctness + link-load accounting)
# ---------------------------------------------------------------------------

@dataclass
class SimResult:
    ok: bool
    rounds: int
    max_link_load: int      # max messages crossing one link in one round
    per_link_bytes: dict    # link -> total bytes carried


def simulate_allreduce(sched: AllreduceSchedule, values: np.ndarray,
                       chunk_bytes: int = 1) -> SimResult:
    """values: (n, d) per-node vectors, d divisible by k.  Executes the
    schedule literally and checks every node ends with the global sum."""
    n, d = values.shape
    k = sched.k
    assert d % k == 0
    m = d // k
    chunks = values.reshape(n, k, m).astype(np.float64).copy()
    expected = values.sum(axis=0)
    link_bytes: dict = {}
    max_load = 0
    rounds = 0

    for phase in ("reduce", "bcast"):
        for msgs in sched.global_rounds(phase):
            rounds += 1
            loads: dict = {}
            staged = []
            for j, s, dst in msgs:
                payload = chunks[s, j].copy()
                staged.append((j, dst, payload))
                e = canon(s, dst)
                loads[e] = loads.get(e, 0) + 1
                link_bytes[e] = link_bytes.get(e, 0) + m * chunk_bytes
            for j, dst, payload in staged:
                if phase == "reduce":
                    chunks[dst, j] += payload
                else:
                    chunks[dst, j] = payload
            if loads:
                max_load = max(max_load, max(loads.values()))

    final = chunks.reshape(n, d)
    ok = bool(np.allclose(final, expected[None, :].repeat(n, 0)))
    return SimResult(ok, rounds, max_load, link_bytes)


# ---------------------------------------------------------------------------
# alpha-beta cost model (paper Sec. 1.1: collective bandwidth)
# ---------------------------------------------------------------------------

def wave_wire_bytes(spec, nbytes: float, itemsize: int = 4,
                    fractions=None) -> tuple:
    """Per-wave wire bytes of any compiled spec, in program order.

    The chunk engines (pipelined / fused / per-tree) ship one padded
    ``mrow``-element row per hop, so every wave carries the same wire;
    the striped engine's waves carry their bound stripe-window widths
    (:func:`striped_tables`).  This is the static per-wave twin of the
    makespan methods below -- the telemetry layer renders it as span
    widths and the timing harness diffs it against measurement."""
    k = spec.k
    if k == 0:
        return ()
    elems = max(1, -(-int(nbytes) // itemsize))
    if isinstance(spec, StripedCollectiveSpec):
        fr = None if fractions is None else tuple(fractions)
        bound = striped_tables(spec, elems, fr)
        return tuple(int(w.wire) * itemsize for w in bound.waves)
    fracs = tuple(fractions) if fractions is not None else (1.0 / k,) * k
    row_bytes = max(chunk_sizes(elems, fracs)) * itemsize
    if isinstance(spec, PipelinedAllreduceSpec):
        nwaves = len(spec.waves)
    elif isinstance(spec, FusedAllreduceSpec):
        nwaves = len(spec.reduce_rounds) + len(spec.bcast_rounds)
    else:
        # the per-tree form lives in repro.dist.tree_allreduce (a
        # JAX-importing module), so it is duck-typed on its rounds
        nwaves = sum(len(t.reduce_rounds) + len(t.bcast_rounds)
                     for t in spec.trees)
    return (row_bytes,) * nwaves


@dataclass
class CostModel:
    link_bw: float = 50e9      # bytes/s per link (ICI default)
    alpha: float = 1e-6        # per-message latency (s)
    segment: int = 256 * 1024  # pipeline segment bytes
    overlap: bool = True       # can a step's disjoint-link waves overlap?

    # Measured calibrations registered at runtime (e.g. loaded from the
    # BENCH_allreduce.json "calibration/<backend>" rows) take precedence
    # over the built-in per-backend constants below.
    _MEASURED = {}          # plain class attrs, not dataclass fields
    _BUILTIN = {
        # XLA host backend (fake devices): every collective serializes at
        # high per-call latency, so alpha dominates and pipelining never
        # pays -- the autotuner then picks S=1, which the executor
        # unrolls with zero pipeline overhead.
        "cpu": {"link_bw": 2e8, "alpha": 5.5e-4, "overlap": False},
        # the class defaults model a real fabric (per-link DMA engines:
        # waves on disjoint links overlap), calibrated against TPU ICI
        "tpu": {},
    }
    _WARNED_BACKENDS = set()

    @classmethod
    def register_calibration(cls, backend: str, **constants) -> None:
        """Register measured constants (``link_bw`` / ``alpha`` /
        ``segment`` / ``overlap``) for a backend; subsequent
        :meth:`for_backend` calls -- and therefore the segment autotuner
        -- use them.  ``benchmarks/allreduce_bench.py`` persists its
        measurements as ``calibration/<backend>`` rows in
        ``BENCH_allreduce.json`` and re-registers them on load."""
        known = {f.name for f in cls.__dataclass_fields__.values()} \
            if hasattr(cls, "__dataclass_fields__") else set()
        bad = set(constants) - known
        if bad:
            raise ValueError(f"unknown CostModel constants {sorted(bad)}")
        cls._MEASURED[backend] = dict(constants)

    @classmethod
    def calibration_for(cls, backend: str | None) -> dict | None:
        """The constants :meth:`for_backend` would use, or ``None`` when
        the backend has neither a measured nor a built-in calibration."""
        if backend in cls._MEASURED:
            return cls._MEASURED[backend]
        return cls._BUILTIN.get(backend)

    @classmethod
    def _warn_no_calibration(cls, backend) -> None:
        """Log the unknown-backend fallback at most ONCE per backend
        name.  ``for_backend`` sits inside the segment-autotune and
        codec-policy loops, which probe it once per (payload, S)
        candidate -- an unguarded warning there floods the log with one
        line per candidate."""
        if backend in cls._WARNED_BACKENDS:
            return
        cls._WARNED_BACKENDS.add(backend)
        logger.warning(
            "CostModel has no calibration for backend %r; falling "
            "back to the default fabric constants (segments='auto' "
            "and codec='auto' may mispick).  Run "
            "benchmarks/allreduce_bench.py on this backend to "
            "measure and persist one into BENCH_allreduce.json.",
            backend)

    @classmethod
    def for_backend(cls, backend: str | None) -> "CostModel":
        """Constants calibrated for where the program actually runs:
        measured (``register_calibration``) first, then the built-in
        per-backend table.  A backend with NO calibration falls back to
        the default fabric constants *explicitly*: the fallback is
        logged (once per backend, via ``_warn_no_calibration``) because
        the segment autotuner and the codec policy both read these
        constants, and silently modelling an unknown backend as a
        TPU-like fabric is exactly how ``segments="auto"`` mispicks."""
        consts = cls.calibration_for(backend)
        if consts is None:
            cls._warn_no_calibration(backend)
            consts = {}
        return cls(**consts)

    def pipelined_allreduce(self, nbytes: float, spec,
                            segments: int) -> float:
        """Modelled cost of the wave program streaming S segments:
        ``(waves + S - 1)`` steps of ``(m/S)``-sized hops when a step's
        waves overlap (disjoint links -- the EDST property), or the full
        serialized collective count when they cannot (host backends,
        where the S>1 scan issues every wave each step)."""
        waves = max(1, spec.num_collectives)
        seg = nbytes / max(1, spec.k) / segments
        steps = spec.steps(segments) if hasattr(spec, "steps") \
            else waves + segments - 1
        if self.overlap:
            return steps * (self.alpha + seg / self.link_bw)
        ncoll = waves if segments == 1 else waves * steps
        return ncoll * (self.alpha + seg / self.link_bw)

    def striped_allreduce(self, nbytes: float, spec,
                          itemsize: int = 4) -> float:
        """Modelled cost of the composed striped program
        (:class:`StripedCollectiveSpec`): its waves run in dependency
        order, each shipping its bound wire length (stripe windows, not
        the full chunk), so the per-wave wire bytes fall from ``m``
        toward ``ceil(m/n) * slots-per-window`` at roughly twice the
        wave count of the pipelined engine.  Bandwidth-dominated fabrics
        win on the smaller wires; alpha-dominated hosts lose on the
        extra waves -- which is the engine-selection tradeoff
        ``repro.dist`` documents."""
        elems = max(1, int(nbytes // itemsize))
        bound = striped_tables(spec, elems)
        return sum(self.alpha + w.wire * itemsize / self.link_bw
                   for w in bound.waves)

    def wave_times(self, spec, nbytes: float, itemsize: int = 4,
                   fractions=None, segments: int = 1) -> tuple:
        """Predicted seconds per wave, in program order: ``alpha +
        wire/bw`` over :func:`wave_wire_bytes`.  The per-wave
        decomposition of the makespan methods above -- what the
        telemetry trace renders as predicted span durations and the
        wave-by-wave timing harness (``repro.telemetry.timing``) diffs
        against measurement.  ``segments`` > 1 (chunk engines only)
        repeats the wave sequence once per segment at ``1/S`` of the row
        bytes, the serialized-host reading of the streamed program."""
        wires = wave_wire_bytes(spec, nbytes, itemsize, fractions)
        if segments > 1 and not isinstance(spec, StripedCollectiveSpec):
            wires = tuple(-(-w // segments) for w in wires) * segments
        return tuple(self.alpha + w / self.link_bw for w in wires)

    def best_segments(self, nbytes: float, spec, smax: int = 64) -> int:
        """The segment count minimizing :meth:`pipelined_allreduce`
        (powers of two up to ``smax``)."""
        best, best_s = float("inf"), 1
        s = 1
        while s <= smax:
            t = self.pipelined_allreduce(nbytes, spec, s)
            if t < best:
                best, best_s = t, s
            s *= 2
        return best_s

    def ring_allreduce(self, nbytes: float, p: int) -> float:
        """bidirectional-ring reduce-scatter + all-gather."""
        steps = 2 * (p - 1)
        return steps * self.alpha + 2 * nbytes * (p - 1) / p / self.link_bw

    def edst_tree_allreduce(self, nbytes: float, sched: AllreduceSchedule,
                            in_network: bool = False) -> float:
        """k trees, chunk nbytes/k each, segment-pipelined along tree depth.

        endpoint mode (TPU): reduce up + broadcast down -> 2 traversals.
        in-network mode (paper's switches): single traversal each way but the
        switch reduces, so the endpoint link carries each chunk once -> the
        2x disappears into the fabric.
        """
        k = sched.k
        chunk = nbytes / k
        t = 0.0
        for ts in sched.trees:
            depth = max(ts.depth, 1)
            nseg = max(1, int(np.ceil(chunk / self.segment)))
            seg = chunk / nseg
            fill = depth * (self.alpha + seg / self.link_bw)
            stream = (nseg - 1) * seg / self.link_bw
            traversals = 1.0 if in_network else 2.0
            t = max(t, traversals * (fill + stream))
        return t

    def single_tree_allreduce(self, nbytes: float, sched_one: TreeSchedule,
                              in_network: bool = False) -> float:
        one = AllreduceSchedule(sched_one.n, [sched_one])
        return self.edst_tree_allreduce(nbytes, one, in_network)

    def speedup_vs_ring(self, nbytes: float, p: int,
                        sched: AllreduceSchedule) -> float:
        return self.ring_allreduce(nbytes, p) / self.edst_tree_allreduce(nbytes, sched)
