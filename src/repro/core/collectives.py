"""Multi-tree Allreduce schedules from EDST sets (paper Sec. 1.1 payoff).

A set of k EDSTs yields k contention-free reduction/broadcast trees: the
gradient is split into k chunks, chunk j is reduced leaves->root along tree j
and broadcast root->leaves, all trees concurrently.  Edge-disjointness
guarantees no two trees ever use the same physical link (asserted).

Also provides the alpha-beta cost model comparing EDST k-tree allreduce
against ring and single-tree baselines, in both "endpoint reduction" (TPU)
and "in-network reduction" (paper's switch-compute) modes, plus a NumPy
packet-level simulator used for correctness tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .csr import tree_center
from .graph import canon, tree_depth_levels


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

@dataclass
class TreeSchedule:
    """Reduce/broadcast rounds for one spanning tree."""
    n: int
    root: int
    tree: frozenset
    reduce_rounds: list   # list[rounds]; each round = list[(src, dst)]
    bcast_rounds: list

    @property
    def depth(self) -> int:
        return len(self.bcast_rounds)


def tree_schedule(n: int, tree, root: int | None = None) -> TreeSchedule:
    tree = frozenset(canon(*e) for e in tree)
    root = _best_root(n, tree) if root is None else root
    levels = tree_depth_levels(tree, root)  # levels[d] = [(parent, child)]
    reduce_rounds = [[(c, p) for p, c in lvl] for lvl in reversed(levels)]
    bcast_rounds = [list(lvl) for lvl in levels]
    return TreeSchedule(n, root, tree, reduce_rounds, bcast_rounds)


def _best_root(n: int, tree) -> int:
    """Root minimizing tree depth (a tree center), O(n) via the CSR
    double-BFS in :mod:`repro.core.csr` (three sweeps instead of the old
    every-vertex probe, which was O(n^2) and dominated schedule compiles
    on >= 1000-node fabrics)."""
    return tree_center(n, tree)[0]


def _best_root_probe(n: int, tree) -> int:
    """The historical O(n^2) every-vertex BFS probe.  Kept as the
    regression oracle for :func:`_best_root` (identical roots/depths are
    asserted in tests) and as the baseline timed by
    ``benchmarks/allreduce_bench.py``."""
    best, best_d = 0, 10**9
    adj: dict = {}
    for u, v in tree:
        adj.setdefault(u, []).append(v)
        adj.setdefault(v, []).append(u)

    def depth_from(r):
        seen = {r}
        d, frontier = 0, [r]
        while frontier:
            nxt = []
            for u in frontier:
                for w in adj.get(u, ()):
                    if w not in seen:
                        seen.add(w)
                        nxt.append(w)
            if nxt:
                d += 1
            frontier = nxt
        return d

    for r in range(n):
        d = depth_from(r)
        if d < best_d:
            best, best_d = r, d
    return best


@dataclass
class AllreduceSchedule:
    """k concurrent tree schedules (one chunk per tree)."""
    n: int
    trees: list  # list[TreeSchedule]

    @property
    def k(self) -> int:
        return len(self.trees)

    @property
    def depth(self) -> int:
        return max(t.depth for t in self.trees)

    def check_contention_free(self) -> bool:
        """No physical link is used by two different trees (EDST property)."""
        seen = set()
        for ts in self.trees:
            for e in ts.tree:
                if e in seen:
                    return False
                seen.add(e)
        return True

    def global_rounds(self, phase: str):
        """Round r = union of every tree's round-r messages, tagged by tree."""
        rounds_attr = "reduce_rounds" if phase == "reduce" else "bcast_rounds"
        nrounds = max(len(getattr(t, rounds_attr)) for t in self.trees)
        out = []
        for r in range(nrounds):
            msgs = []
            for j, ts in enumerate(self.trees):
                rr = getattr(ts, rounds_attr)
                if r < len(rr):
                    msgs.extend((j, s, d) for s, d in rr[r])
            out.append(msgs)
        return out


def allreduce_schedule(n: int, trees, roots=None) -> AllreduceSchedule:
    roots = roots or [None] * len(trees)
    sched = AllreduceSchedule(n, [tree_schedule(n, t, r)
                                  for t, r in zip(trees, roots)])
    assert sched.check_contention_free(), "trees share a link"
    return sched


# ---------------------------------------------------------------------------
# fused global-round program (the executor-facing compiled form)
# ---------------------------------------------------------------------------
#
# ``AllreduceSchedule`` is tree-major: tree j's rounds, then tree j+1's.
# Executed literally that is sum-of-all-trees serial hops.  The fused form
# is round-major: global round r carries round r of EVERY tree, and each
# global round is split into the fewest ppermute-legal waves (unique
# sources and destinations per wave) over the *union* of the trees'
# messages.  Because a wave's sources are unique, every sender ships
# exactly one tree's chunk, so one ppermute moves several trees' traffic
# at once -- the wire bytes are unchanged (edge-disjointness: each message
# still crosses its own link) but the collective count drops from
# sum-of-trees rounds to depth-of-deepest-tree waves.
#
# Per wave the compiler precomputes (n,)-shaped NumPy tables consumed by
# ``repro.dist.tree_allreduce.fused_tree_allreduce`` at trace time:
# ``send_row[v]`` = which chunk row vertex v ships, ``recv_row[v]`` /
# ``recv_flag[v]`` = where an arriving payload lands (and whether one
# arrives at all).  Nothing is rebuilt per call.

@dataclass(frozen=True, eq=False)
class FusedRound:
    """One ppermute-legal wave of a global round."""
    perm: tuple            # ((src, dst), ...) unique srcs, unique dsts
    send_row: np.ndarray   # (n,) int32: chunk row vertex v sends
    recv_row: np.ndarray   # (n,) int32: chunk row an arrival lands in
    recv_flag: np.ndarray  # (n,) bool: does vertex v receive this wave


@dataclass(frozen=True, eq=False)
class FusedAllreduceSpec:
    """Round-major allreduce program with precomputed per-wave tables.

    Hash/equality follow ``key`` (fabric size, axis names, rooted tree
    sets), so two compiles of the same (topology, axes) -- which
    :func:`fused_spec_from_schedule` also caches to the same object --
    never retrace a jitted executor that takes the spec statically.
    """
    n: int
    k: int
    axes: tuple            # mesh axis names the allreduce runs over
    depth: int             # deepest tree's level count
    reduce_rounds: tuple   # tuple[FusedRound], deepest level first
    bcast_rounds: tuple    # tuple[FusedRound], root level first
    key: tuple

    @property
    def num_collectives(self) -> int:
        """ppermutes one allreduce issues (1 per wave, quantized or not)."""
        return len(self.reduce_rounds) + len(self.bcast_rounds)

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return (isinstance(other, FusedAllreduceSpec)
                and self.key == other.key)


def _split_tagged(msgs):
    """Greedily split one global round's (tree, src, dst) messages into
    waves with unique sources and unique destinations (ppermute-legal)."""
    out, remaining = [], list(msgs)
    while remaining:
        srcs, dsts, taken, rest = set(), set(), [], []
        for m in remaining:
            _, s, d = m
            if s in srcs or d in dsts:
                rest.append(m)
            else:
                srcs.add(s)
                dsts.add(d)
                taken.append(m)
        out.append(taken)
        remaining = rest
    return out


def _fused_round(n: int, taken) -> FusedRound:
    send_row = np.zeros(n, np.int32)
    recv_row = np.zeros(n, np.int32)
    recv_flag = np.zeros(n, bool)
    perm = []
    for j, s, d in taken:
        perm.append((s, d))
        send_row[s] = j
        recv_row[d] = j
        recv_flag[d] = True
    return FusedRound(tuple(perm), send_row, recv_row, recv_flag)


def _sched_key(sched: AllreduceSchedule, axes: tuple) -> tuple:
    return (sched.n, axes, tuple((ts.root, ts.tree) for ts in sched.trees))


_FUSED_CACHE: dict = {}


def fused_spec_from_schedule(sched: AllreduceSchedule,
                             axis_names) -> FusedAllreduceSpec:
    """Compile an :class:`AllreduceSchedule` into the round-major
    :class:`FusedAllreduceSpec`.  Compiles are cached by (fabric, rooted
    trees, axes): repeated calls for the same topology return the *same*
    object, keeping jit caches stable."""
    axes = tuple(axis_names)
    key = _sched_key(sched, axes)
    hit = _FUSED_CACHE.get(key)
    if hit is not None:
        return hit
    phases = {}
    for phase in ("reduce", "bcast"):
        rounds = []
        for msgs in sched.global_rounds(phase):
            rounds.extend(_fused_round(sched.n, wave)
                          for wave in _split_tagged(msgs))
        phases[phase] = tuple(rounds)
    spec = FusedAllreduceSpec(n=sched.n, k=sched.k, axes=axes,
                              depth=sched.depth,
                              reduce_rounds=phases["reduce"],
                              bcast_rounds=phases["bcast"], key=key)
    _FUSED_CACHE[key] = spec
    return spec


def empty_fused_spec(n: int, axis_names) -> FusedAllreduceSpec:
    """The k=0 program (no trees survive): executor passes data through."""
    axes = tuple(axis_names)
    return FusedAllreduceSpec(n=n, k=0, axes=axes, depth=0,
                              reduce_rounds=(), bcast_rounds=(),
                              key=(n, axes, ()))


# ---------------------------------------------------------------------------
# NumPy packet-level simulator (correctness + link-load accounting)
# ---------------------------------------------------------------------------

@dataclass
class SimResult:
    ok: bool
    rounds: int
    max_link_load: int      # max messages crossing one link in one round
    per_link_bytes: dict    # link -> total bytes carried


def simulate_allreduce(sched: AllreduceSchedule, values: np.ndarray,
                       chunk_bytes: int = 1) -> SimResult:
    """values: (n, d) per-node vectors, d divisible by k.  Executes the
    schedule literally and checks every node ends with the global sum."""
    n, d = values.shape
    k = sched.k
    assert d % k == 0
    m = d // k
    chunks = values.reshape(n, k, m).astype(np.float64).copy()
    expected = values.sum(axis=0)
    link_bytes: dict = {}
    max_load = 0
    rounds = 0

    for phase in ("reduce", "bcast"):
        for msgs in sched.global_rounds(phase):
            rounds += 1
            loads: dict = {}
            staged = []
            for j, s, dst in msgs:
                payload = chunks[s, j].copy()
                staged.append((j, dst, payload))
                e = canon(s, dst)
                loads[e] = loads.get(e, 0) + 1
                link_bytes[e] = link_bytes.get(e, 0) + m * chunk_bytes
            for j, dst, payload in staged:
                if phase == "reduce":
                    chunks[dst, j] += payload
                else:
                    chunks[dst, j] = payload
            if loads:
                max_load = max(max_load, max(loads.values()))

    final = chunks.reshape(n, d)
    ok = bool(np.allclose(final, expected[None, :].repeat(n, 0)))
    return SimResult(ok, rounds, max_load, link_bytes)


# ---------------------------------------------------------------------------
# alpha-beta cost model (paper Sec. 1.1: collective bandwidth)
# ---------------------------------------------------------------------------

@dataclass
class CostModel:
    link_bw: float = 50e9      # bytes/s per link (ICI default)
    alpha: float = 1e-6        # per-message latency (s)
    segment: int = 256 * 1024  # pipeline segment bytes

    def ring_allreduce(self, nbytes: float, p: int) -> float:
        """bidirectional-ring reduce-scatter + all-gather."""
        steps = 2 * (p - 1)
        return steps * self.alpha + 2 * nbytes * (p - 1) / p / self.link_bw

    def edst_tree_allreduce(self, nbytes: float, sched: AllreduceSchedule,
                            in_network: bool = False) -> float:
        """k trees, chunk nbytes/k each, segment-pipelined along tree depth.

        endpoint mode (TPU): reduce up + broadcast down -> 2 traversals.
        in-network mode (paper's switches): single traversal each way but the
        switch reduces, so the endpoint link carries each chunk once -> the
        2x disappears into the fabric.
        """
        k = sched.k
        chunk = nbytes / k
        t = 0.0
        for ts in sched.trees:
            depth = max(ts.depth, 1)
            nseg = max(1, int(np.ceil(chunk / self.segment)))
            seg = chunk / nseg
            fill = depth * (self.alpha + seg / self.link_bw)
            stream = (nseg - 1) * seg / self.link_bw
            traversals = 1.0 if in_network else 2.0
            t = max(t, traversals * (fill + stream))
        return t

    def single_tree_allreduce(self, nbytes: float, sched_one: TreeSchedule,
                              in_network: bool = False) -> float:
        one = AllreduceSchedule(sched_one.n, [sched_one])
        return self.edst_tree_allreduce(nbytes, one, in_network)

    def speedup_vs_ring(self, nbytes: float, p: int,
                        sched: AllreduceSchedule) -> float:
        return self.ring_allreduce(nbytes, p) / self.edst_tree_allreduce(nbytes, sched)
