"""Finite-field arithmetic GF(p^n) for the Galois constructions in the paper.

Slim Fly / MMS graphs (paper Ex. 2.4.2), Paley graphs QR(q) (App. B.1) and the
Erdos-Renyi polarity graph ER_q (App. B.7) all need GF(q) arithmetic for prime
powers q.  Elements are represented as integers in [0, q) encoding polynomial
coefficients base p;  add/mul tables are precomputed (q is small: <= a few
hundred for every topology we instantiate).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

# Irreducible (Conway-ish) polynomials over GF(p), as coefficient tuples of
# x^n = -(c_0 + c_1 x + ... + c_{n-1} x^{n-1}); stored lowest degree first.
_IRREDUCIBLE = {
    (2, 2): (1, 1),        # x^2 + x + 1
    (2, 3): (1, 1, 0),     # x^3 + x + 1
    (2, 4): (1, 1, 0, 0),  # x^4 + x + 1
    (2, 5): (1, 0, 1, 0, 0),
    (3, 2): (1, 2),        # x^2 + 2x + 1? no: x^2 = -(1 + 2x) = 2 + x  -> x^2+2x+1 reducible; use x^2+1? p=3: x^2+1 irreducible
    (5, 2): (2, 4),
    (7, 2): (3, 6),
}
# Fix (3,2): x^2 + 1 is irreducible mod 3 (since -1 is not a QR mod 3).
_IRREDUCIBLE[(3, 2)] = (1, 0)
# (5,2): x^2 + 2 irreducible mod 5 (2 is a non-residue mod 5).
_IRREDUCIBLE[(5, 2)] = (2, 0)
# (7,2): x^2 + 1 irreducible mod 7 (-1 non-residue since 7 % 4 == 3).
_IRREDUCIBLE[(7, 2)] = (1, 0)


def _factor_prime_power(q: int) -> tuple[int, int]:
    for p in range(2, q + 1):
        if q % p == 0:
            n = 0
            m = q
            while m % p == 0:
                m //= p
                n += 1
            if m != 1:
                raise ValueError(f"{q} is not a prime power")
            return p, n
    raise ValueError(f"{q} is not a prime power")


@dataclass(frozen=True)
class GF:
    """GF(q) with integer-encoded elements and precomputed tables."""

    q: int
    p: int
    n: int
    add_table: tuple  # add_table[a][b]
    mul_table: tuple
    neg_table: tuple
    inv_table: tuple  # inv_table[a] for a != 0 (inv_table[0] = 0 sentinel)
    primitive: int    # a generator of GF(q)*

    # -- arithmetic ---------------------------------------------------------
    def add(self, a: int, b: int) -> int:
        return self.add_table[a][b]

    def sub(self, a: int, b: int) -> int:
        return self.add_table[a][self.neg_table[b]]

    def mul(self, a: int, b: int) -> int:
        return self.mul_table[a][b]

    def neg(self, a: int) -> int:
        return self.neg_table[a]

    def inv(self, a: int) -> int:
        if a == 0:
            raise ZeroDivisionError("inverse of 0 in GF(q)")
        return self.inv_table[a]

    def div(self, a: int, b: int) -> int:
        return self.mul(a, self.inv(b))

    def pow(self, a: int, e: int) -> int:
        out, base = 1, a
        e = int(e)
        if e < 0:
            base, e = self.inv(a), -e
        while e:
            if e & 1:
                out = self.mul(out, base)
            base = self.mul(base, base)
            e >>= 1
        return out

    # -- derived sets --------------------------------------------------------
    def quadratic_residues(self) -> set[int]:
        """Nonzero squares of GF(q)."""
        return {self.mul(a, a) for a in range(1, self.q)}

    def elements(self) -> range:
        return range(self.q)


def _poly_mul_mod(a: int, b: int, p: int, n: int, red: tuple) -> int:
    """Multiply base-p encoded polynomials mod the irreducible polynomial."""
    # decode
    ca = [(a // p**i) % p for i in range(n)]
    cb = [(b // p**i) % p for i in range(n)]
    prod = [0] * (2 * n - 1)
    for i, x in enumerate(ca):
        if x:
            for j, y in enumerate(cb):
                prod[i + j] = (prod[i + j] + x * y) % p
    # reduce: x^n = -(red[0] + red[1] x + ...)
    for d in range(2 * n - 2, n - 1, -1):
        c = prod[d]
        if c:
            prod[d] = 0
            for j, r in enumerate(red):
                prod[d - n + j] = (prod[d - n + j] - c * r) % p
    return sum(c * p**i for i, c in enumerate(prod[:n]))


@functools.lru_cache(maxsize=None)
def gf(q: int) -> GF:
    """Build (and cache) GF(q) for prime power q."""
    p, n = _factor_prime_power(q)
    if n == 1:
        add = tuple(tuple((a + b) % p for b in range(p)) for a in range(p))
        mul = tuple(tuple((a * b) % p for b in range(p)) for a in range(p))
    else:
        red = _IRREDUCIBLE.get((p, n))
        if red is None:
            red = _find_irreducible(p, n)
        def padd(a, b):
            return sum((((a // p**i) % p + (b // p**i) % p) % p) * p**i
                       for i in range(n))
        add = tuple(tuple(padd(a, b) for b in range(q)) for a in range(q))
        mul = tuple(tuple(_poly_mul_mod(a, b, p, n, red) for b in range(q))
                    for a in range(q))
    neg = tuple(next(b for b in range(q) if add[a][b] == 0) for a in range(q))
    inv = [0] * q
    for a in range(1, q):
        inv[a] = next(b for b in range(1, q) if mul[a][b] == 1)
    # find a primitive element
    primitive = None
    for g in range(2, q):
        seen, x = set(), 1
        for _ in range(q - 1):
            x = mul[x][g]
            seen.add(x)
        if len(seen) == q - 1:
            primitive = g
            break
    if primitive is None:  # q == 2
        primitive = 1
    return GF(q, p, n, add, mul, neg, tuple(inv), primitive)


def _find_irreducible(p: int, n: int) -> tuple:
    """Brute-force search for a degree-n irreducible polynomial over GF(p)."""
    import itertools

    def eval_mod(coeffs, x):  # coeffs lowest-first of monic poly of degree n
        # value of x^n + sum coeffs[i] x^i  mod p  ... need full poly division
        raise NotImplementedError

    # Try all monic polynomials; test irreducibility by having no roots is
    # insufficient for n >= 4, so do trial division by all monic polys of
    # degree <= n//2 (coefficients in small p, fine for table sizes).
    def poly_mod(num, den):
        num = list(num)
        dn = len(den) - 1
        while len(num) - 1 >= dn and any(num):
            shift = len(num) - 1 - dn
            c = num[-1]
            if c:
                for i, d in enumerate(den):
                    num[shift + i] = (num[shift + i] - c * d) % p
            num.pop()
        while num and num[-1] == 0:
            num.pop()
        return num

    for tail in itertools.product(range(p), repeat=n):
        cand = list(tail) + [1]  # monic degree n
        if cand[0] == 0:
            continue
        irreducible = True
        for deg in range(1, n // 2 + 1):
            for dtail in itertools.product(range(p), repeat=deg):
                den = list(dtail) + [1]
                if not poly_mod(cand, den):
                    irreducible = False
                    break
            if not irreducible:
                break
        if irreducible:
            return tuple(cand[:n])
    raise RuntimeError(f"no irreducible polynomial found for GF({p}^{n})")
