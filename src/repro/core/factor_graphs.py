"""Factor graphs used by the paper (Appendix B, Table 4).

Every constructor returns a :class:`~repro.core.graph.Graph` whose vertex
count / edge count match the paper's Table 4 rows; tests assert this for a
sweep of parameters.
"""
from __future__ import annotations

import functools
import itertools

from .gf import gf
from .graph import Graph, canon


# -- elementary graphs -------------------------------------------------------

def path(n: int) -> Graph:
    return Graph(n, {(i, i + 1) for i in range(n - 1)}, name=f"L{n}")


def cycle(n: int) -> Graph:
    if n < 3:
        raise ValueError("cycle needs n >= 3")
    return Graph(n, {(i, (i + 1) % n) for i in range(n)}, name=f"C{n}")


def complete(m: int) -> Graph:
    return Graph(m, set(itertools.combinations(range(m), 2)), name=f"K{m}")


def complete_bipartite(q: int, r: int | None = None) -> Graph:
    r = q if r is None else r
    return Graph(q + r, {(i, q + j) for i in range(q) for j in range(r)},
                 name=f"K{q},{r}")


def hypercube(d: int) -> Graph:
    n = 1 << d
    return Graph(n, {(v, v ^ (1 << b)) for v in range(n) for b in range(d)
                     if v < (v ^ (1 << b))}, name=f"Q{d}")


def circulant(n: int, diffs) -> Graph:
    edges = set()
    for v in range(n):
        for d in diffs:
            edges.add(canon(v, (v + d) % n))
    return Graph(n, edges, name=f"Circ{n}{sorted(set(d % n for d in diffs))}")


def petersen() -> Graph:
    outer = {(i, (i + 1) % 5) for i in range(5)}
    spokes = {(i, i + 5) for i in range(5)}
    inner = {(5 + i, 5 + (i + 2) % 5) for i in range(5)}
    return Graph(10, outer | spokes | inner, name="Petersen")


# -- Galois-field graphs ------------------------------------------------------

def paley(q: int) -> Graph:
    """Paley graph QR(q), q = 4k+1 prime power: x ~ y iff x-y is a nonzero QR."""
    if q % 4 != 1:
        raise ValueError("Paley graph needs q = 1 mod 4")
    F = gf(q)
    qr = F.quadratic_residues()
    edges = {canon(x, y) for x in range(q) for y in range(q)
             if x != y and F.sub(x, y) in qr}
    return Graph(q, edges, name=f"QR({q})")


@functools.lru_cache(maxsize=None)
def mms_connection_sets(q: int) -> tuple[frozenset, int, frozenset]:
    """Connection sets (X, c, X' = cX) for the MMS supernode Cayley graphs C(q).

    q = 4k+1: X = quadratic residues, X' = xi * X = non-residues
    (McKay-Miller-Siran).  q = 4k or 4k-1: Hafner [13] gives explicit sets; we
    recover valid ones by searching symmetric sets of the right size
    (|X| = (q - delta)/2 with q = 4k + delta) and a multiplier c with
    X' = cX such that H_q is connected with diameter 2 -- the defining MMS
    property.  The multiplier form guarantees Cayley(X) ~ Cayley(X') so both
    supernode sides are relabelings of the same supernode graph (needed for
    the star-product representation).  Sizes are tiny; the search is cached.
    """
    F = gf(q)
    if q % 4 == 1:
        x = frozenset(F.quadratic_residues())
        c = F.primitive
        xp = frozenset(F.mul(c, e) for e in x)
        assert xp == frozenset(set(range(1, q)) - set(x))
        return x, c, xp
    size = q // 2 if q % 4 == 0 else (q + 1) // 2
    # candidate symmetric subsets of GF(q)^* of given size, paired with a
    # multiplier c such that X' = cX also works
    pairs, singles = [], []
    seen = set()
    for a in range(1, q):
        if a in seen:
            continue
        na = F.neg(a)
        seen.add(a)
        seen.add(na)
        if na == a:
            singles.append((a,))
        else:
            pairs.append((a, na))
    units = pairs + singles
    for r in range(len(units) + 1):
        for combo in itertools.combinations(units, r):
            s = frozenset(x for unit in combo for x in unit)
            if len(s) != size:
                continue
            for c in range(2, q):
                xp = frozenset(F.mul(c, e) for e in s)
                h = _mms_graph(q, s, xp)
                if h.is_connected() and h.diameter() == 2:
                    return s, c, xp
    raise RuntimeError(f"no MMS connection sets found for q={q}")


def _mms_graph(q: int, x: frozenset, xp: frozenset) -> Graph:
    """Assemble H_q from connection sets (used by the search and slimfly())."""
    F = gf(q)
    # vertex (i, a, b) -> index i*q*q + a*q + b, i in {0,1}
    def vid(i, a, b):
        return i * q * q + a * q + b

    edges = set()
    for a in range(q):
        for b in range(q):
            for bp in range(q):
                if b < bp and F.sub(b, bp) in x:
                    edges.add(canon(vid(0, a, b), vid(0, a, bp)))
                if b < bp and F.sub(b, bp) in xp:
                    edges.add(canon(vid(1, a, b), vid(1, a, bp)))
    for xcoord in range(q):  # side 0 supernode index
        for m in range(q):   # side 1 supernode index
            for c in range(q):
                y = F.add(F.mul(m, xcoord), c)
                edges.add(canon(vid(0, xcoord, y), vid(1, m, c)))
    return Graph(2 * q * q, edges, name=f"H{q}")


def mms_supernode(q: int, side: int = 0) -> Graph:
    """C(q): the Cayley supernode graph of H_q (paper Table 4 rows 1-3)."""
    x, _, xp = mms_connection_sets(q)
    s = x if side == 0 else xp
    F = gf(q)
    edges = {canon(a, b) for a in range(q) for b in range(q)
             if a != b and F.sub(a, b) in s}
    return Graph(q, edges, name=f"C({q})s{side}")


def erdos_renyi_polarity(q: int) -> Graph:
    """ER_q: points of PG(2, q); u ~ v iff u . v = 0 (App. B.7)."""
    F = gf(q)
    # canonical projective points: last nonzero coordinate normalized to 1
    points = [(1, 0, 0)]
    points += [(x, 1, 0) for x in range(q)]
    points += [(x, y, 1) for x in range(q) for y in range(q)]
    assert len(points) == q * q + q + 1, (len(points), q)
    idx = {p: i for i, p in enumerate(points)}

    def dot(u, v):
        s = 0
        for a, b in zip(u, v):
            s = F.add(s, F.mul(a, b))
        return s

    edges = set()
    for i, u in enumerate(points):
        for j in range(i + 1, len(points)):
            if dot(u, points[j]) == 0:
                edges.add((i, j))
    g = Graph(len(points), edges, name=f"ER{q}")
    g.points = points  # type: ignore[attr-defined]
    g.point_index = idx  # type: ignore[attr-defined]
    return g


# -- PolarStar / BundleFly supernode stand-ins -------------------------------

def bdf(d: int) -> Graph:
    """Bermond-Delorme-Farhi graph of degree d: 2d vertices, d^2 edges.

    Implemented as the circulant on Z_{2d} with all odd differences (==
    K_{d,d} on the even/odd bipartition), matching the (v, e, degree,
    diameter 2) parameters of Table 4.  See DESIGN.md for the stand-in note.
    """
    return Graph(2 * d,
                 {canon(u, v) for u in range(2 * d) for v in range(2 * d)
                  if u < v and (u - v) % 2 == 1},
                 name=f"BDF({d})")


def inductive_quad(d: int) -> Graph:
    """IQ(d) stand-in: d-regular graph on 2d+2 vertices with d(d+1) edges.

    The true Inductive-Quad construction is internal to PolarStar [18]; the
    EDST theory consumes only (v, e, t, r, connectivity), which this circulant
    matches (verified by tests).  d must be 4m or 4m+3 per the paper.
    """
    if d % 4 not in (0, 3):
        raise ValueError("IQ(d) defined for d = 4m or 4m+3")
    n = 2 * d + 2
    if d % 2 == 0:
        diffs = list(range(1, d // 2 + 1))
    else:
        diffs = list(range(1, (d - 1) // 2 + 1)) + [n // 2]
    g = circulant(n, diffs)
    g.name = f"IQ({d})"
    assert g.m == d * (d + 1) and g.max_degree() == d, (g.m, d * (d + 1))
    return g
