"""Roskind-Tarjan style maximum edge-disjoint spanning-forest packing.

The paper (Sec. 1.2) cites Roskind & Tarjan's O(n^2 k^2) algorithm as the
general-purpose way to find k EDSTs in an arbitrary graph.  We implement the
classic matroid-union augmentation: maintain k edge-disjoint forests; for each
graph edge run a BFS over (edge, forest) exchange moves; an augmenting
sequence ends at a forest where the edge closes no cycle.  The final packing
maximizes total forest size, hence contains t spanning trees whenever t
edge-disjoint spanning trees exist (Nash-Williams / Tutte).

Used for: factor graphs without explicit constructions (K_{q,q}, ER_q, C(q),
IQ(d), BDF(d)), and fault-tolerant rebuild after link failures (core/fault.py).
"""
from __future__ import annotations

from collections import deque

from .graph import Graph, canon, edges_are_spanning_tree


class _Forest:
    """One forest of the packing with O(n) path queries (BFS, graphs are small)."""

    def __init__(self, n: int):
        self.n = n
        self.adj = [set() for _ in range(n)]
        self.edges = set()

    def add(self, u: int, v: int):
        self.adj[u].add(v)
        self.adj[v].add(u)
        self.edges.add(canon(u, v))

    def remove(self, u: int, v: int):
        self.adj[u].discard(v)
        self.adj[v].discard(u)
        self.edges.discard(canon(u, v))

    def path(self, s: int, t: int):
        """Vertex path s..t inside the forest, or None if disconnected."""
        if s == t:
            return [s]
        prev = {s: s}
        dq = deque([s])
        while dq:
            u = dq.popleft()
            for w in self.adj[u]:
                if w not in prev:
                    prev[w] = u
                    if w == t:
                        out = [t]
                        while out[-1] != s:
                            out.append(prev[out[-1]])
                        return out[::-1]
                    dq.append(w)
        return None

    def connected(self, s: int, t: int) -> bool:
        return self.path(s, t) is not None


def pack_forests(g: Graph, k: int) -> list[set]:
    """Maximum packing of ``g``'s edges into k edge-disjoint forests."""
    forests = [_Forest(g.n) for _ in range(k)]
    where = {}  # edge -> forest index currently holding it

    for e0 in sorted(g.edges):
        _augment(forests, where, e0, k)
    return [set(f.edges) for f in forests]


def _augment(forests, where, e0, k) -> bool:
    """Try to add e0 to the packing via matroid-union augmentation (BFS)."""
    label = {e0: None}   # edge -> (pred_edge, forest_that_cycled)
    queue = deque([e0])
    tried = set()        # (edge, forest) pairs examined

    while queue:
        e = queue.popleft()
        u, v = e
        for fi in range(k):
            if (e, fi) in tried:
                continue
            tried.add((e, fi))
            f = forests[fi]
            if where.get(e) == fi:
                continue
            pth = f.path(u, v)
            if pth is None:
                _apply(forests, where, label, e, fi)
                return True
            # label cycle edges
            cyc = list(zip(pth, pth[1:]))
            for a, b in cyc:
                ce = canon(a, b)
                if ce not in label:
                    label[ce] = (e, fi)
                    queue.append(ce)
    return False


def _apply(forests, where, label, e, fi):
    """Walk the augmenting chain: insert e into forest fi, cascade swaps."""
    cur, into = e, fi
    while True:
        pred = label[cur]
        prev_forest = where.get(cur)
        forests[into].add(*cur)
        where[cur] = into
        if prev_forest is not None and prev_forest != into:
            forests[prev_forest].remove(*cur)
        if pred is None:
            # cur == e0: newly inserted edge, nothing held it before
            break
        pred_edge, cyc_forest = pred
        # cur previously lived in cyc_forest blocking pred_edge's insertion
        assert prev_forest == cyc_forest, (cur, prev_forest, cyc_forest)
        cur, into = pred_edge, cyc_forest


def max_edsts(g: Graph, k_hint: int | None = None):
    """Maximum set of edge-disjoint *spanning trees* of g.

    Returns (trees, nontree_edges).  Tries k from the combinatorial upper
    bound floor(m/(n-1)) downward; the first k whose packing yields k spanning
    forests is the answer (matroid union gives the maximum packing size, so
    if t trees exist the k=t run finds them).
    """
    if g.n <= 1:
        return [], set(g.edges)
    ub = g.m // (g.n - 1)
    if k_hint is not None:
        ub = min(ub, k_hint)
    for k in range(ub, 0, -1):
        forests = pack_forests(g, k)
        if all(len(f) == g.n - 1 for f in forests):
            trees = forests
            used = set().union(*trees) if trees else set()
            for t in trees:
                assert edges_are_spanning_tree(g.n, t)
            return trees, g.edges - used
    return [], set(g.edges)
