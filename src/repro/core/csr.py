"""NumPy CSR adjacency + linear-time BFS helpers for the EDST hot path.

``Graph.adj()``'s list-of-lists and the dict-based BFS in the schedule
compiler are fine for toy fabrics but quadratic habits creep in around
them (``_best_root`` probed every vertex).  This module gives the compile
side an O(n + m) representation shared by :mod:`repro.core.graph` and
:mod:`repro.core.collectives`:

  * :class:`CSRAdjacency` -- immutable indptr/indices arrays over vertex
    ids ``0..n-1`` (both edge directions stored);
  * :meth:`CSRAdjacency.bfs_distances` -- frontier-vectorized BFS, every
    level a handful of NumPy gathers instead of a Python dict walk;
  * :func:`tree_center` -- the classic double-BFS: for a tree, the
    eccentricity of any vertex equals its distance to the farther of the
    two endpoints of a diametral path found by two sweeps, so the
    depth-minimizing root falls out of three BFS passes, O(n) total,
    instead of the n-pass probe.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CSRAdjacency:
    """Undirected adjacency in CSR form: neighbors of ``v`` are
    ``indices[indptr[v]:indptr[v + 1]]``."""
    n: int
    indptr: np.ndarray   # (n + 1,) int32
    indices: np.ndarray  # (2m,) int32

    @classmethod
    def from_edges(cls, n: int, edges) -> "CSRAdjacency":
        edges = np.asarray(sorted(edges), dtype=np.int64).reshape(-1, 2)
        if edges.size:
            src = np.concatenate([edges[:, 0], edges[:, 1]])
            dst = np.concatenate([edges[:, 1], edges[:, 0]])
        else:
            src = dst = np.zeros(0, np.int64)
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n + 1, np.int32)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return cls(n, indptr, dst.astype(np.int32))

    @property
    def degrees(self) -> np.ndarray:
        return self.indptr[1:] - self.indptr[:-1]

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def bfs_distances(self, root: int) -> np.ndarray:
        """Hop distances from ``root``; -1 for unreachable vertices."""
        dist = np.full(self.n, -1, np.int32)
        dist[root] = 0
        frontier = np.array([root], np.int32)
        d = 0
        while frontier.size:
            starts = self.indptr[frontier]
            counts = self.indptr[frontier + 1] - starts
            total = int(counts.sum())
            if not total:
                break
            # flat gather of every frontier vertex's neighbor slice
            base = np.repeat(starts, counts)
            step = np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                                counts)
            nbrs = self.indices[base + step]
            nbrs = np.unique(nbrs[dist[nbrs] < 0])
            d += 1
            dist[nbrs] = d
            frontier = nbrs
        return dist

    def eccentricity(self, v: int) -> int:
        return int(self.bfs_distances(v).max())


def tree_center(n: int, edges) -> tuple[int, int]:
    """Depth-minimizing root of a tree and that minimum depth, via
    double-BFS: sweep to a diametral endpoint ``a``, sweep again to the
    opposite endpoint ``b``, and read every vertex's eccentricity off
    ``max(d(v, a), d(v, b))``.  Ties break to the smallest vertex id
    (matching the historical full probe).  O(n) for a spanning tree.
    """
    csr = CSRAdjacency.from_edges(n, edges)
    return csr_tree_center(csr)


def csr_tree_center(csr: CSRAdjacency) -> tuple[int, int]:
    if csr.n <= 1 or csr.indices.size == 0:
        return 0, 0
    a = int(np.argmax(csr.bfs_distances(0)))
    dist_a = csr.bfs_distances(a)
    b = int(np.argmax(dist_a))
    dist_b = csr.bfs_distances(b)
    ecc = np.maximum(dist_a, dist_b)
    root = int(np.argmin(ecc))  # argmin takes the first = smallest id
    return root, int(ecc[root])
