"""Lightweight undirected simple-graph type used by all EDST machinery.

Vertices are integers 0..n-1.  Edges are canonical ``(u, v)`` tuples with
``u < v``.  The class is immutable-ish (treat as frozen after construction);
every EDST routine returns *new* edge sets rather than mutating graphs.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


def canon(u: int, v: int) -> tuple[int, int]:
    return (u, v) if u < v else (v, u)


@dataclass
class Graph:
    n: int
    edges: set = field(default_factory=set)  # set[tuple[int,int]] canonical
    name: str = "G"

    def __post_init__(self):
        self.edges = {canon(*e) for e in self.edges}
        for u, v in self.edges:
            if u == v:
                raise ValueError(f"self-loop {u}")
            if not (0 <= u < self.n and 0 <= v < self.n):
                raise ValueError(f"edge {(u, v)} out of range n={self.n}")
        self._adj = None
        self._csr = None

    # -- basic accessors ----------------------------------------------------
    @property
    def m(self) -> int:
        return len(self.edges)

    def adj(self) -> list:
        if self._adj is None:
            a = [[] for _ in range(self.n)]
            for u, v in self.edges:
                a[u].append(v)
                a[v].append(u)
            self._adj = a
        return self._adj

    def csr(self):
        """CSR adjacency (:class:`repro.core.csr.CSRAdjacency`), cached;
        the linear-time representation behind ``diameter`` and the
        schedule compiler's center finding."""
        if self._csr is None:
            from .csr import CSRAdjacency
            self._csr = CSRAdjacency.from_edges(self.n, self.edges)
        return self._csr

    def degree(self, v: int) -> int:
        return len(self.adj()[v])

    def max_degree(self) -> int:
        return max((len(x) for x in self.adj()), default=0)

    def has_edge(self, u: int, v: int) -> bool:
        return canon(u, v) in self.edges

    # -- algorithms ----------------------------------------------------------
    def components(self) -> list:
        seen = [False] * self.n
        comps = []
        adj = self.adj()
        for s in range(self.n):
            if seen[s]:
                continue
            comp = [s]
            seen[s] = True
            dq = deque([s])
            while dq:
                u = dq.popleft()
                for w in adj[u]:
                    if not seen[w]:
                        seen[w] = True
                        comp.append(w)
                        dq.append(w)
            comps.append(comp)
        return comps

    def is_connected(self) -> bool:
        return self.n <= 1 or len(self.components()) == 1

    def bfs_tree(self, root: int = 0) -> set:
        """Edges of a BFS spanning tree of *this graph's* component of root."""
        adj = self.adj()
        seen = [False] * self.n
        seen[root] = True
        dq = deque([root])
        tree = set()
        while dq:
            u = dq.popleft()
            for w in adj[u]:
                if not seen[w]:
                    seen[w] = True
                    tree.add(canon(u, w))
                    dq.append(w)
        return tree

    def diameter(self) -> int:
        """Exact diameter via n CSR-BFS passes (each pass O(n + m))."""
        csr = self.csr()
        best = 0
        for s in range(self.n):
            dist = csr.bfs_distances(s)
            d = int(dist.max())
            if (dist < 0).any():
                return -1  # disconnected
            best = max(best, d)
        return best

    def subgraph_of_edges(self, edges, name: str = "sub") -> "Graph":
        return Graph(self.n, set(edges), name=name)

    def without_edges(self, edges) -> "Graph":
        drop = {canon(*e) for e in edges}
        return Graph(self.n, self.edges - drop, name=self.name + "-minus")

    def copy(self) -> "Graph":
        return Graph(self.n, set(self.edges), name=self.name)


# ---------------------------------------------------------------------------
# helpers on plain edge sets (used for trees that live inside a bigger graph)
# ---------------------------------------------------------------------------

def edges_are_spanning_tree(n: int, edges) -> bool:
    edges = {canon(*e) for e in edges}
    if len(edges) != n - 1:
        return False
    return _spans(n, edges)


def edges_are_spanning_connected(n: int, edges) -> bool:
    """Spanning + connected (may contain cycles)."""
    return _spans(n, {canon(*e) for e in edges})


def _spans(n: int, edges) -> bool:
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    comps = n
    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
            comps -= 1
    return comps == 1


def bfs_treeify(n: int, edges, root: int = 0) -> set:
    """Remark 4.5.7: reduce a connected spanning edge set to a spanning tree."""
    g = Graph(n, {canon(*e) for e in edges})
    tree = g.bfs_tree(root)
    assert len(tree) == n - 1, "subgraph was not spanning/connected"
    return tree


def pairwise_edge_disjoint(tree_list) -> bool:
    seen = set()
    for t in tree_list:
        for e in t:
            e = canon(*e)
            if e in seen:
                return False
            seen.add(e)
    return True


def directed_rooted(tree_edges, root: int):
    """Orient a tree away from ``root``: returns list of (parent, child)."""
    adj = {}
    for u, v in tree_edges:
        adj.setdefault(u, []).append(v)
        adj.setdefault(v, []).append(u)
    out = []
    seen = {root}
    dq = deque([root])
    while dq:
        u = dq.popleft()
        for w in adj.get(u, ()):
            if w not in seen:
                seen.add(w)
                out.append((u, w))
                dq.append(w)
    assert len(out) == len(set(map(tuple, (canon(*e) for e in tree_edges)))), \
        "tree not connected from root"
    return out


def tree_depth_levels(tree_edges, root: int):
    """BFS levels of a rooted tree: list of lists of (parent, child) per depth."""
    adj = {}
    for u, v in tree_edges:
        adj.setdefault(u, []).append(v)
        adj.setdefault(v, []).append(u)
    levels = []
    seen = {root}
    frontier = [root]
    while frontier:
        nxt, lvl = [], []
        for u in frontier:
            for w in adj.get(u, ()):
                if w not in seen:
                    seen.add(w)
                    lvl.append((u, w))
                    nxt.append(w)
        if lvl:
            levels.append(lvl)
        frontier = nxt
    return levels
