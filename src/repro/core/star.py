"""The star product G* = G_s * G_n (paper Def. 2.3.1).

Vertices of the product are ``(x, y)`` encoded as ``x * |V_n| + y``.  For every
*directed* structure edge ``(x, x')`` a bijection ``f_(x,x')`` on supernode
vertices is stored (with ``f_(x',x) = f_(x,x')^{-1}`` enforced).  The Cartesian
product is the special case of identity bijections.
"""
from __future__ import annotations

import random as _random
from dataclasses import dataclass, field

from .graph import Graph, canon


def _invert(perm: tuple) -> tuple:
    inv = [0] * len(perm)
    for i, p in enumerate(perm):
        inv[p] = i
    return tuple(inv)


@dataclass
class StarProduct:
    gs: Graph                     # structure graph
    gn: Graph                     # supernode graph
    bijections: dict = field(default_factory=dict)  # (x, x') -> tuple perm
    name: str = "star"

    def __post_init__(self):
        ident = tuple(range(self.gn.n))
        full = {}
        for u, v in self.gs.edges:
            p = self.bijections.get((u, v))
            if p is None:
                pinv = self.bijections.get((v, u))
                p = _invert(tuple(pinv)) if pinv is not None else ident
            p = tuple(p)
            assert sorted(p) == list(range(self.gn.n)), f"not a bijection on {(u, v)}"
            full[(u, v)] = p
            full[(v, u)] = _invert(p)
        self.bijections = full
        self._product = None
        self._key = None

    # -- indexing -------------------------------------------------------------
    @property
    def ns(self) -> int:
        return self.gs.n

    @property
    def nn(self) -> int:
        return self.gn.n

    @property
    def n(self) -> int:
        return self.gs.n * self.gn.n

    def vid(self, x: int, y: int) -> int:
        return x * self.gn.n + y

    def coords(self, v: int) -> tuple[int, int]:
        return divmod(v, self.gn.n)

    def cache_key(self) -> tuple:
        """Stable value key of the product (factor edge sets + bijections):
        two ``StarProduct`` objects with equal keys define the same product
        graph vertex-for-vertex.  Computed once and memoized -- the
        compositional schedule compiler (:mod:`repro.core.product_schedule`)
        keys its composed-schedule and spec caches on it, so elastic
        rescales and fault-runtime rebuilds that land on an
        already-compiled fabric reuse the schedule instead of recompiling.
        """
        if self._key is None:
            bij = tuple(sorted(
                (e, p) for e, p in self.bijections.items() if e[0] < e[1]))
            self._key = (self.ns, self.nn, frozenset(self.gs.edges),
                         frozenset(self.gn.edges), bij)
        return self._key

    def f(self, x: int, xp: int) -> tuple:
        """Bijection mapping supernode-x coordinates to supernode-xp coordinates."""
        return self.bijections[(x, xp)]

    def finv(self, x: int, xp: int) -> tuple:
        return self.bijections[(xp, x)]

    # -- product graph ----------------------------------------------------------
    def product(self) -> Graph:
        if self._product is None:
            edges = set()
            for x in range(self.ns):
                base = x * self.nn
                for y, yp in self.gn.edges:
                    edges.add(canon(base + y, base + yp))
            for x, xp in self.gs.edges:
                fmap = self.f(x, xp)
                for y in range(self.nn):
                    edges.add(canon(self.vid(x, y), self.vid(xp, fmap[y])))
            self._product = Graph(self.n, edges, name=self.name)
        return self._product

    # -- structure-edge expansion (used by the EDST constructions) --------------
    def bundle(self, x: int, xp: int):
        """All |V_n| product edges realizing structure edge (x, x')."""
        fmap = self.f(x, xp)
        return [canon(self.vid(x, y), self.vid(xp, fmap[y])) for y in range(self.nn)]

    def cross_edge(self, x: int, xp: int, sink_vertex: int):
        """The unique product edge over (x, x') whose endpoint in supernode x'
        is ``sink_vertex`` (paper's edge sets (3)/(7)/(11)/(14)...)."""
        finv = self.finv(x, xp)
        return canon(self.vid(x, finv[sink_vertex]), self.vid(xp, sink_vertex))


# -- constructors -------------------------------------------------------------

def cartesian(gs: Graph, gn: Graph, name: str | None = None) -> StarProduct:
    return StarProduct(gs, gn, {}, name=name or f"{gs.name}x{gn.name}")


def star_with(gs: Graph, gn: Graph, bij_fn, name: str = "star") -> StarProduct:
    """bij_fn(x, x') -> permutation tuple for each canonical structure edge."""
    bij = {(u, v): tuple(bij_fn(u, v)) for u, v in gs.edges}
    return StarProduct(gs, gn, bij, name=name)


def random_star(gs: Graph, gn: Graph, seed: int = 0, name: str = "rand-star") -> StarProduct:
    rng = _random.Random(seed)

    def mk(u, v):
        p = list(range(gn.n))
        rng.shuffle(p)
        return tuple(p)

    return star_with(gs, gn, mk, name=name)


def block_preserving_star(gs: Graph, gn: Graph, v1: set, v2: set,
                          seed: int = 0,
                          name: str = "blk-star") -> StarProduct:
    """A NON-Cartesian star product satisfying Property 4.6.1: every
    bijection permutes within the vertex classes ``v1`` and ``v2`` (and
    fixes their intersection), so f(V(S1)) = V(S1) and f(V(S2)) = V(S2)
    for partitions with those vertex classes.  Demonstrates the paper's
    remark that "some star products" (not just Cartesian ones) admit the
    Thm 4.6.2 construction."""
    import random as _r
    rng = _r.Random(seed)
    inter = set(v1) & set(v2)
    only1 = sorted(set(v1) - inter)
    only2 = sorted(set(v2) - inter)

    def mk(u, v):
        p = list(range(gn.n))
        a = only1[:]
        rng.shuffle(a)
        for src, dst in zip(only1, a):
            p[src] = dst
        b = only2[:]
        rng.shuffle(b)
        for src, dst in zip(only2, b):
            p[src] = dst
        return tuple(p)

    return star_with(gs, gn, mk, name=name)


def shift_star(gs: Graph, gn: Graph, name: str = "shift-star") -> StarProduct:
    """Cyclic-shift bijections: f_(x,x')(y) = y + (x + x') mod |V_n|.

    A cheap structured family of non-identity bijections (used for BundleFly /
    PolarStar assemblies where the P*/R* internals are out of scope)."""
    nn = gn.n

    def mk(u, v):
        s = (u + v) % nn
        return tuple((y + s) % nn for y in range(nn))

    return star_with(gs, gn, mk, name=name)
