"""Anytime wave-schedule search (seeded, deterministic local search).

The greedy list scheduler (:func:`repro.core.collectives._list_schedule`)
packs the message DAG critical-path first with a fixed deterministic
tiebreak.  That tiebreak is one point in a large legal-schedule space:
which ready message wins a contended (source, destination) slot decides
both the final wave count and -- for the striped engine, whose waves ship
their *longest* member window -- the per-wave wire length.  This module
hillclimbs that space in the spirit of ``benchmarks/hillclimb.py``:

  * **candidates** are greedy schedules under perturbed ready-queue
    tiebreaks -- seeded ``numpy.random.RandomState`` permutations plus,
    for the striped engine, deterministic window-length orders (longest-
    and shortest-window first), handed to ``_list_schedule(priority=...)``
    so every candidate is still a legal critical-path schedule;
  * **scoring** is the compiled artifact's own cost: wave count for the
    pipelined/fused engines (their :class:`CostModel` cost is monotone in
    waves), and ``(waves, CostModel().striped_allreduce)`` for the
    striped engine, whose makespan depends on how windows are packed into
    waves, not just on how many waves there are;
  * **acceptance** is strict improvement only; otherwise the *greedy spec
    object itself* is returned, so a search that finds nothing keeps jit
    caches keyed to the identical incumbent;
  * every winner is re-verified (:func:`verify_compiled_spec`) before it
    is cached -- an illegal candidate cannot replace a legal incumbent.

Search results are memoized per (schedule key, engine, seed); the whole
pass is deterministic for a fixed seed.  Root search
(:func:`search_roots`, the ``allreduce_schedule(..., roots="search")``
hook) is the same strict-improvement rule one level up: a center root
(depth-optimal by the tree-center theorem) is replaced only by a strictly
shallower neighbor, so searched roots are never deeper than
``_best_root``'s.
"""
from __future__ import annotations

import numpy as np

from .collectives import (AG_DOWN, AG_UP, BCAST, REDUCE, RS_DOWN, RS_UP,
                          CostModel,
                          AllreduceSchedule, _best_root, _fused_round,
                          _list_schedule, _message_dag, _pipe_wave,
                          _resolve_verify, _sched_key, _split_tagged,
                          _striped_dag, _striped_op, _striped_tree,
                          _striped_wave, _RS_KINDS, FusedAllreduceSpec,
                          PipelinedAllreduceSpec, StripedCollectiveSpec,
                          fused_spec_from_schedule,
                          pipelined_spec_from_schedule,
                          striped_spec_from_schedule, verify_compiled_spec)
from .graph import tree_depth_levels

#: payload the striped makespan is scored at (64 MiB of f32 -- large
#: enough that window packing, not alpha, decides the ranking)
SCORE_NBYTES = 64 * 1024 * 1024

#: random-restart count per engine (on top of the deterministic
#: window-order candidates); every restart is one greedy re-pack
RESTARTS = 6

_SEARCH_CACHE: dict = {}


# ---------------------------------------------------------------------------
# root search
# ---------------------------------------------------------------------------

def _depth_of(tree, root) -> int:
    return len(tree_depth_levels(tree, root))


def search_roots(n: int, trees) -> list:
    """Strict-improvement root search per tree: start from the tree
    center (``_best_root``, depth-optimal), probe its tree neighbors, and
    move only to a strictly shallower root.  Never returns a root deeper
    than the center's depth -- the property test pins this against
    ``_best_root_probe``."""
    roots = []
    for t in trees:
        tree = frozenset(t)
        best = _best_root(n, tree)
        best_d = _depth_of(tree, best)
        improved = True
        while improved:
            improved = False
            nbrs = sorted({v for e in tree if best in e for v in e}
                          - {best})
            for cand in nbrs:
                d = _depth_of(tree, cand)
                if d < best_d:
                    best, best_d = cand, d
                    improved = True
                    break
        roots.append(best)
    return roots


# ---------------------------------------------------------------------------
# wave-schedule search
# ---------------------------------------------------------------------------

def _priorities(rng, m, extra=()):
    """Candidate tiebreak streams: seeded random permutations plus the
    engine's deterministic ``extra`` orders (each an int sequence of
    length m; lower wins a contended slot)."""
    for pr in extra:
        yield pr
    for _ in range(RESTARTS):
        yield rng.permutation(m)


def search_pipelined_spec(sched: AllreduceSchedule, axis_names,
                          verify=None, seed: int = 0
                          ) -> PipelinedAllreduceSpec:
    """Hillclimb of the pipelined wave program.  Pipelined cost is
    monotone in wave count (``steps = waves + S - 1``), so the score is
    the mixed program's wave count; candidates must also not lengthen
    the quantized program.  Returns the greedy spec object itself when no
    candidate strictly improves."""
    axes = tuple(axis_names)
    key = (*_sched_key(sched, axes), "pipelined", "search", seed)
    hit = _SEARCH_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "search_pipelined_spec")
        return hit
    greedy = pipelined_spec_from_schedule(sched, axes, verify)
    msgs, deps = _message_dag(sched)
    rng = np.random.RandomState(seed)
    best_take, best_score = None, len(greedy.waves)
    best_pr = None
    for pr in _priorities(rng, len(msgs)):
        take = _list_schedule(msgs, deps, priority=pr)
        if len(take) < best_score:
            best_take, best_score, best_pr = take, len(take), pr
    if best_take is None:
        _SEARCH_CACHE[key] = greedy
        return greedy
    n, k = sched.n, sched.k
    deep = _resolve_verify(verify) == "full"
    red = _list_schedule(msgs, deps, kinds={REDUCE}, priority=best_pr,
                         verify=deep)
    bc = _list_schedule(msgs, deps, kinds={BCAST}, priority=best_pr,
                        verify=deep)
    if len(red) + len(bc) > len(greedy.q8_waves):
        red = _list_schedule(msgs, deps, kinds={REDUCE}, verify=deep)
        bc = _list_schedule(msgs, deps, kinds={BCAST}, verify=deep)
    waves = tuple(_pipe_wave(n, k, msgs, t) for t in best_take)
    q8 = tuple(_pipe_wave(n, k, msgs, t) for t in red + bc)
    spec = PipelinedAllreduceSpec(n=n, k=k, axes=axes, depth=sched.depth,
                                  waves=waves, q8_waves=q8,
                                  q8_boundary=len(red), key=key)
    verify_compiled_spec(spec, verify, "search_pipelined_spec")
    _SEARCH_CACHE[key] = spec
    return spec


def _striped_makespan(spec) -> float:
    return CostModel().striped_allreduce(SCORE_NBYTES, spec)


def search_striped_spec(sched: AllreduceSchedule, axis_names,
                        verify=None, seed: int = 0
                        ) -> StripedCollectiveSpec:
    """Hillclimb of the striped wave program.  Score is lexicographic
    ``(waves, modelled makespan)``: the makespan
    (:meth:`CostModel.striped_allreduce`) sums each wave's *longest*
    member window, so packing long and short stripe windows into separate
    waves beats the greedy mix even at equal wave counts.  Deterministic
    window-length orders (longest-/shortest-window first) seed the
    candidate set alongside the random restarts."""
    axes = tuple(axis_names)
    key = (*_sched_key(sched, axes), "striped", "search", seed)
    hit = _SEARCH_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "search_striped_spec")
        return hit
    greedy = striped_spec_from_schedule(sched, axes, verify)
    n, k = sched.n, sched.k
    trees = greedy.trees
    msgs, deps = _striped_dag(sched, trees)
    m = len(msgs)

    def win(i):
        j, kind, s, d = msgs[i]
        c = s if kind in (RS_UP, AG_UP) else d    # the child endpoint
        size = int(trees[j].size[c])
        return size if kind in (RS_DOWN, AG_UP) else n - size

    wins = [win(i) for i in range(m)]
    extra = ([-w for w in wins], wins)            # longest / shortest first
    rng = np.random.RandomState(seed)

    def build(pr, tag):
        deep = _resolve_verify(verify) == "full"
        kinds_sets = (None, _RS_KINDS, frozenset({AG_UP, AG_DOWN}))
        programs = [tuple(_striped_wave(n, msgs, t, trees)
                          for t in _list_schedule(msgs, deps, kinds=ks,
                                                  op_of=_striped_op,
                                                  priority=pr,
                                                  verify=deep))
                    for ks in kinds_sets]
        return StripedCollectiveSpec(
            n=n, k=k, axes=axes, depth=sched.depth, trees=trees,
            waves=programs[0], rs_waves=programs[1], ag_waves=programs[2],
            key=(*key, tag))

    best, best_score = None, (len(greedy.waves), _striped_makespan(greedy))
    for tag, pr in enumerate(_priorities(rng, m, extra)):
        cand = build(pr, tag)
        score = (len(cand.waves), _striped_makespan(cand))
        if score < best_score:
            best, best_score = cand, score
    if best is None:
        _SEARCH_CACHE[key] = greedy
        return greedy
    spec = StripedCollectiveSpec(
        n=n, k=k, axes=axes, depth=sched.depth, trees=trees,
        waves=best.waves, rs_waves=best.rs_waves, ag_waves=best.ag_waves,
        key=key)
    verify_compiled_spec(spec, verify, "search_striped_spec")
    _SEARCH_CACHE[key] = spec
    return spec


def search_fused_spec(sched: AllreduceSchedule, axis_names,
                      verify=None, seed: int = 0) -> FusedAllreduceSpec:
    """Hillclimb of the round-major fused program: permute each global
    round's message order before the greedy ppermute split
    (``_split_tagged`` keeps the first legal message per slot, so order
    decides the fan-in overflow sub-round count).  Score is total
    rounds."""
    axes = tuple(axis_names)
    key = (*_sched_key(sched, axes), "fused", "search", seed)
    hit = _SEARCH_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "search_fused_spec")
        return hit
    greedy = fused_spec_from_schedule(sched, axes, verify)
    rng = np.random.RandomState(seed)

    def build(shuffle):
        phases = {}
        for phase in ("reduce", "bcast"):
            rounds = []
            for ms in sched.global_rounds(phase):
                ms = list(ms)
                if shuffle:
                    ms = [ms[i] for i in rng.permutation(len(ms))]
                rounds.extend(_fused_round(sched.n, wave)
                              for wave in _split_tagged(ms))
            phases[phase] = tuple(rounds)
        return phases

    best, best_score = None, greedy.num_collectives
    for _ in range(RESTARTS):
        phases = build(True)
        score = len(phases["reduce"]) + len(phases["bcast"])
        if score < best_score:
            best, best_score = phases, score
    if best is None:
        _SEARCH_CACHE[key] = greedy
        return greedy
    spec = FusedAllreduceSpec(n=sched.n, k=sched.k, axes=axes,
                              depth=sched.depth,
                              reduce_rounds=best["reduce"],
                              bcast_rounds=best["bcast"], key=key)
    verify_compiled_spec(spec, verify, "search_fused_spec")
    _SEARCH_CACHE[key] = spec
    return spec
