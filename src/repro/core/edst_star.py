"""EDST constructions on star products (paper Section 4).

Implements, with full verification:
  * Lemma 4.4.1    -- U-sets from non-tree subgraphs (+ swap repair so that
                      the non-tree subgraph provides enough escape capacity);
  * Thm 4.3.1      -- universal t1 + t2 - 2 construction (4.3.2 / 4.3.3);
  * Thm 4.5.1/4.5.2-- maximal t1 + t2 when r1 >= t1 and r2 >= t2
                      (Constructions 4.5.3, 4.5.4, 4.5.5, 4.5.6);
  * Thm 4.5.9      -- one-sided t1 + t2 - 1;
  * Thm 4.6.2      -- Property-4.6.1 route to t1 + t2 - 1 when r1 < t1 and
                      r2 < t2 (Constructions 4.6.4, 4.6.5, 4.6.6);
plus the auto-dispatcher used by the runtime and benchmarks.

All subgraph constructions go through Remark 4.5.7 (BFS tree-ification) and a
final verifier: every output tree is a spanning tree of the product and the
set is pairwise edge-disjoint.
"""
from __future__ import annotations

from dataclasses import dataclass

from .factor_edsts import EDSTSet, edsts_for
from .graph import (Graph, bfs_treeify, canon, directed_rooted,
                    edges_are_spanning_connected, edges_are_spanning_tree,
                    pairwise_edge_disjoint)
from .star import StarProduct


# ---------------------------------------------------------------------------
# Lemma 4.4.1: U-sets
# ---------------------------------------------------------------------------

def u_capacity(n: int, nontree: set) -> int:
    """Max |U| obtainable from non-tree subgraph N: sum over components of
    (|C| - 1) (leave one escape vertex per component)."""
    comps = Graph(n, nontree).components()
    return sum(len(c) - 1 for c in comps if len(c) > 1)


def choose_u_set(n: int, nontree: set, need: int) -> list[int]:
    """U of size ``need``: vertices with an N-path to a vertex outside U."""
    comps = [c for c in Graph(n, nontree).components() if len(c) > 1]
    u: list[int] = []
    for c in comps:
        take = min(len(c) - 1, need - len(u))
        u.extend(sorted(c)[:take])
        if len(u) == need:
            return u
    raise ValueError(f"U capacity {u_capacity(n, nontree)} < {need}")


def repair_for_u(factor: EDSTSet, need: int, max_iter: int = 200) -> EDSTSet:
    """Swap tree/non-tree edges (as in [16]) until U-capacity >= need.

    When N contains a cycle, a cycle edge (u, v) can replace an edge f on the
    u..v path of any tree T_i (T_i stays spanning); f joins N instead.  We
    greedily pick the swap that maximizes resulting capacity.
    """
    g, trees, nontree = factor.graph, [set(t) for t in factor.trees], set(factor.nontree)
    for _ in range(max_iter):
        if u_capacity(g.n, nontree) >= need:
            return EDSTSet(g, trees, nontree, factor.method + "+repair").verify()
        cyc = _find_cycle_edge(g.n, nontree)
        if cyc is None:
            break
        (u, v) = cyc
        best = None
        for ti, tr in enumerate(trees):
            path = _tree_path(g.n, tr, u, v)
            for f in zip(path, path[1:]):
                f = canon(*f)
                cand = (nontree - {canon(u, v)}) | {f}
                cap = u_capacity(g.n, cand)
                if best is None or cap > best[0]:
                    best = (cap, ti, f)
        if best is None:
            break
        _, ti, f = best
        trees[ti] = (trees[ti] - {f}) | {canon(u, v)}
        nontree = (nontree - {canon(u, v)}) | {f}
    cap = u_capacity(g.n, nontree)
    if cap >= need:
        return EDSTSet(g, trees, nontree, factor.method + "+repair").verify()
    raise ValueError(f"could not reach U capacity {need} (got {cap}) on {g.name}")


def _find_cycle_edge(n: int, edges: set):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in sorted(edges):
        ru, rv = find(u), find(v)
        if ru == rv:
            return (u, v)
        parent[ru] = rv
    return None


def _tree_path(n: int, tree: set, s: int, t: int) -> list[int]:
    from collections import deque
    adj = {}
    for a, b in tree:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, []).append(a)
    prev = {s: s}
    dq = deque([s])
    while dq:
        x = dq.popleft()
        if x == t:
            break
        for w in adj.get(x, ()):
            if w not in prev:
                prev[w] = x
                dq.append(w)
    assert t in prev, "disconnected tree"
    out = [t]
    while out[-1] != s:
        out.append(prev[out[-1]])
    return out[::-1]


# ---------------------------------------------------------------------------
# Shared construction pieces (paper edge sets, by equation number)
# ---------------------------------------------------------------------------

def _supernode_copy(sp: StarProduct, x: int, edges: set) -> set:
    """Edges of a factor-G_n edge set instantiated inside supernode x."""
    base = x * sp.nn
    return {canon(base + y, base + yp) for y, yp in edges}


def _all_bundles(sp: StarProduct, structure_edges) -> set:
    """Eq. (2)/(6)/(17): every product edge over each structure edge."""
    out = set()
    for x, xp in structure_edges:
        out.update(sp.bundle(x, xp))
    return out


def _sink_edges(sp: StarProduct, xbar1, sink_vertex: int) -> set:
    """Eq. (3)/(7)/(14): one product edge per directed X1 edge, incident to
    ``sink_vertex`` inside the sink supernode."""
    return {sp.cross_edge(x, xp, sink_vertex) for x, xp in xbar1}


# -- Construction 4.3.2 / 4.5.3: T_i via X_i and Y_1 --------------------------

def construct_A(sp: StarProduct, x_trees, y1: set, u_list) -> list[set]:
    out = []
    for xi, ui in zip(x_trees, u_list):
        t = _supernode_copy(sp, ui, y1) | _all_bundles(sp, xi)
        out.append(t)
    return out


# -- Construction 4.3.3 / 4.5.4: T'_i via Y_i and X_1 ------------------------

def construct_B(sp: StarProduct, xbar1, y_trees, v_list) -> list[set]:
    out = []
    for yi, vi in zip(y_trees, v_list):
        t = _sink_edges(sp, xbar1, vi)
        for g_ in range(sp.ns):
            t |= _supernode_copy(sp, g_, yi)
        out.append(t)
    return out


# -- Construction 4.5.5: extra tree via Y1@o, N_n elsewhere, sinks V_n \ U_n --

def construct_extra_nn(sp: StarProduct, xbar1, o: int, y1: set, nn_edges: set,
                       un: set) -> set:
    t = _supernode_copy(sp, o, y1)
    for x in range(sp.ns):
        if x != o:
            t |= _supernode_copy(sp, x, nn_edges)
    for v in range(sp.nn):
        if v not in un:
            t |= _sink_edges(sp, xbar1, v)
    return t


# -- Construction 4.5.6: extra tree via Y1@(V_s\U_s), N_s bundles, sink o' ----

def construct_extra_ns(sp: StarProduct, xbar1, o_prime: int, y1: set,
                       ns_edges: set, us: set) -> set:
    t = set()
    for x in range(sp.ns):
        if x not in us:
            t |= _supernode_copy(sp, x, y1)
    t |= _all_bundles(sp, ns_edges)
    t |= _sink_edges(sp, xbar1, o_prime)
    return t


# ---------------------------------------------------------------------------
# Result container + verification
# ---------------------------------------------------------------------------

@dataclass
class StarEDSTs:
    sp: StarProduct
    trees: list            # list[set[edge]] spanning trees of the product
    theorem: str
    t1: int
    t2: int
    r1: int
    r2: int

    @property
    def count(self) -> int:
        return len(self.trees)

    @property
    def upper_bound(self) -> int:
        g = self.sp.product()
        return g.m // (g.n - 1)

    @property
    def maximal(self) -> bool:
        return self.count == self.upper_bound

    def verify(self) -> "StarEDSTs":
        g = self.sp.product()
        assert pairwise_edge_disjoint(self.trees), "trees overlap"
        for t in self.trees:
            assert t <= g.edges, "tree uses non-product edge"
            assert edges_are_spanning_tree(g.n, t), "not a spanning tree"
        return self


def _treeify_all(sp: StarProduct, subgraphs, check: bool = True) -> list[set]:
    """Remark 4.5.7 over every construction subgraph.  ``check=False`` is
    the compositional fast path (:mod:`repro.core.product_schedule`): a
    subgraph with exactly N-1 edges is an exact spanning tree by the
    construction's own edge count (Construction A: (ns-1)*nn bundle edges
    + (nn-1) supernode edges; Construction B: (ns-1) sink edges +
    ns*(nn-1) supernode edges), so tree-ification is the identity and the
    O(N) spanning-connected scan is skipped.  Subgraphs with more edges
    still go through :func:`bfs_treeify`, whose own edge-count assert
    catches a non-spanning input; neither branch touches
    ``sp.product()``."""
    n = sp.n
    out = []
    for sub in subgraphs:
        if not check and len(sub) == n - 1:
            out.append(set(sub))
            continue
        if check:
            assert edges_are_spanning_connected(n, sub), \
                "subgraph not spanning"
        out.append(bfs_treeify(n, sub))
    return out


# ---------------------------------------------------------------------------
# Theorem-level constructions
# ---------------------------------------------------------------------------

def universal_edsts(sp: StarProduct, Es: EDSTSet, En: EDSTSet,
                    verify: bool = True) -> StarEDSTs:
    """Thm 4.3.1: t1 + t2 - 2 trees, no conditions."""
    t1, t2 = Es.t, En.t
    x_rest, y_rest = Es.trees[1:], En.trees[1:]
    u_list = list(range(min(sp.ns, t1 - 1 + 1)))[:t1 - 1]  # arbitrary distinct
    o = 0
    xbar1 = directed_rooted(Es.trees[0], o)
    v_list = list(range(t2 - 1))                            # arbitrary distinct
    trees = construct_A(sp, x_rest, En.trees[0], u_list)
    trees += construct_B(sp, xbar1, y_rest, v_list)
    res = StarEDSTs(sp, _treeify_all(sp, trees, check=verify), "4.3.1",
                    t1, t2, Es.r, En.r)
    return res.verify() if verify else res


def maximal_edsts(sp: StarProduct, Es: EDSTSet, En: EDSTSet,
                  verify: bool = True) -> StarEDSTs:
    """Thms 4.5.1/4.5.2: t1 + t2 trees when r1 >= t1 and r2 >= t2."""
    t1, t2 = Es.t, En.t
    Es = repair_for_u(Es, t1)
    En = repair_for_u(En, t2)
    us = choose_u_set(sp.ns, Es.nontree, t1)
    un = choose_u_set(sp.nn, En.nontree, t2)
    o, o_prime = us[0], un[0]
    u_list = [u for u in us if u != o][:t1 - 1]
    v_list = [v for v in un if v != o_prime][:t2 - 1]
    xbar1 = directed_rooted(Es.trees[0], o)
    y1 = En.trees[0]

    trees = construct_A(sp, Es.trees[1:], y1, u_list)
    trees += construct_B(sp, xbar1, En.trees[1:], v_list)
    trees.append(construct_extra_nn(sp, xbar1, o, y1, En.nontree, set(un)))
    trees.append(construct_extra_ns(sp, xbar1, o_prime, y1, Es.nontree, set(us)))
    res = StarEDSTs(sp, _treeify_all(sp, trees, check=verify), "4.5.1",
                    t1, t2, Es.r, En.r)
    return res.verify() if verify else res


def one_sided_edsts(sp: StarProduct, Es: EDSTSet, En: EDSTSet,
                    verify: bool = True) -> StarEDSTs:
    """Thm 4.5.9: t1 + t2 - 1 trees when r1 >= t1 or r2 >= t2."""
    t1, t2 = Es.t, En.t
    es_repaired = None
    if Es.r >= t1:
        try:
            es_repaired = repair_for_u(Es, t1)
        except ValueError:
            es_repaired = None
    if es_repaired is not None:
        # extra tree from N_s (Construction 4.5.6)
        Es = es_repaired
        us = choose_u_set(sp.ns, Es.nontree, t1)
        o = us[0]
        o_prime = 0
        u_list = [u for u in us if u != o][:t1 - 1]
        v_list = [v for v in range(sp.nn) if v != o_prime][:t2 - 1]
        xbar1 = directed_rooted(Es.trees[0], o)
        y1 = En.trees[0]
        trees = construct_A(sp, Es.trees[1:], y1, u_list)
        trees += construct_B(sp, xbar1, En.trees[1:], v_list)
        trees.append(construct_extra_ns(sp, xbar1, o_prime, y1,
                                        Es.nontree, set(us)))
    elif En.r >= t2:
        # extra tree from N_n (Construction 4.5.5)
        En = repair_for_u(En, t2)
        un = choose_u_set(sp.nn, En.nontree, t2)
        o_prime = un[0]
        o = 0
        u_list = [u for u in range(sp.ns) if u != o][:t1 - 1]
        v_list = [v for v in un if v != o_prime][:t2 - 1]
        xbar1 = directed_rooted(Es.trees[0], o)
        y1 = En.trees[0]
        trees = construct_A(sp, Es.trees[1:], y1, u_list)
        trees += construct_B(sp, xbar1, En.trees[1:], v_list)
        trees.append(construct_extra_nn(sp, xbar1, o, y1, En.nontree, set(un)))
    else:
        raise ValueError("one-sided construction needs r1 >= t1 or r2 >= t2")
    res = StarEDSTs(sp, _treeify_all(sp, trees, check=verify), "4.5.9",
                    t1, t2, Es.r, En.r)
    return res.verify() if verify else res


# ---------------------------------------------------------------------------
# Property 4.6.1 route (r1 < t1 and r2 < t2; all Cartesian products qualify)
# ---------------------------------------------------------------------------

def _subtree_vertices(children: dict, w: int) -> list[int]:
    out, stack = [], [w]
    while stack:
        v = stack.pop()
        out.append(v)
        stack.extend(children.get(v, ()))
    return out


def partition_y1(y1: set, o_prime: int, t2: int):
    """Edge bipartition (S1 bottom-forest, S2 top-subtree) of Y1 rooted at o'
    with |S1|, |S2| >= t2 - 2 + |I| and cut vertices I an antichain.

    Returns (S1, S2, V1, V2, I) or None."""
    directed = directed_rooted(y1, o_prime)
    children: dict = {}
    parent_edge = {}
    for p, c in directed:
        children.setdefault(p, []).append(c)
        parent_edge[c] = canon(p, c)
    nodes = [c for _, c in directed]

    import itertools
    # try antichains of growing size
    for size in (1, 2, 3):
        for cut in itertools.combinations(nodes, size):
            # cut vertices must have children (else no S1 edges at them) and
            # form an antichain (no cut vertex inside another's subtree)
            ok = all(children.get(w) for w in cut)
            for w in cut:
                if not ok:
                    break
                sub = set(_subtree_vertices(children, w))
                if any(w2 in sub for w2 in cut if w2 != w):
                    ok = False
            if not ok:
                continue
            s1, v1 = set(), set()
            for w in cut:
                subv = _subtree_vertices(children, w)
                v1.update(subv)
                for v in subv:
                    for c in children.get(v, ()):
                        s1.add(canon(v, c))
            s2 = set(y1) - s1
            i_set = set(cut)
            need = t2 - 2 + len(i_set)
            if len(s1) >= need and len(s2) >= need and s2:
                v2 = {a for e in s2 for a in e}
                # V(S1) = vertices incident to S1 edges; with cut vertices
                v1 = {a for e in s1 for a in e} | i_set
                if v1 & v2 != i_set:
                    continue
                return s1, s2, v1, v2, i_set
    return None


def check_property_461(sp: StarProduct, x_trees, v1: set, v2: set) -> bool:
    """f_(x,x')(V(Sj)) = V(Sj) for every edge of every X_i (Property 4.6.1)."""
    for xt in x_trees:
        for x, xp in xt:
            fmap = sp.f(x, xp)
            if {fmap[y] for y in v1} != v1 or {fmap[y] for y in v2} != v2:
                return False
    return True


def property_461_edsts(sp: StarProduct, Es: EDSTSet, En: EDSTSet,
                       verify: bool = True) -> StarEDSTs:
    """Thm 4.6.2: t1 + t2 - 1 trees under Property 4.6.1."""
    t1, t2 = Es.t, En.t
    o = 0
    o_prime = 0
    part = None
    for op_candidate in range(sp.nn):
        part = partition_y1(En.trees[0], op_candidate, t2)
        if part is not None:
            s1, s2, v1, v2, i_set = part
            if check_property_461(sp, Es.trees, v1, v2):
                o_prime = op_candidate
                break
            part = None
    if part is None:
        raise ValueError("Property 4.6.1 not satisfied for any Y1 rooting")
    s1, s2, v1, v2, i_set = part

    # balanced partition R1, R2 of V_s \ {o}
    rest = [x for x in range(sp.ns) if x != o]
    r1_set = set(rest[: len(rest) // 2 + len(rest) % 2])
    r2_set = set(rest) - r1_set
    if min(len(r1_set), len(r2_set)) < t1 - 1:
        raise ValueError("structure graph too small for balanced R1/R2")

    a_list = sorted(r1_set)[: t1 - 1]
    b_list = sorted(r2_set)[: t1 - 1]
    c_list = sorted(v1 - i_set)[: t2 - 1]
    d_list = sorted(v2 - i_set)[: t2 - 1]
    if len(c_list) < t2 - 1 or len(d_list) < t2 - 1:
        raise ValueError("S1/S2 vertex classes too small")

    xbar1 = directed_rooted(Es.trees[0], o)
    trees = []
    # Construction 4.6.4: T_i = S1@a_i + S2@b_i + all X_i bundles
    for xi, ai, bi in zip(Es.trees[1:], a_list, b_list):
        trees.append(_supernode_copy(sp, ai, s1) |
                     _supernode_copy(sp, bi, s2) |
                     _all_bundles(sp, xi))
    # Construction 4.6.5: T'_i = Y_i everywhere + split sinks c_i/d_i
    for yi, ci, di in zip(En.trees[1:], c_list, d_list):
        t = set()
        for g_ in range(sp.ns):
            t |= _supernode_copy(sp, g_, yi)
        for x, xp in xbar1:
            t.add(sp.cross_edge(x, xp, di if xp in r1_set else ci))
        trees.append(t)
    # Construction 4.6.6: T = Y1@o + S2@R1 + S1@R2 + class-sinks
    t = _supernode_copy(sp, o, set(En.trees[0]))
    for r in r1_set:
        t |= _supernode_copy(sp, r, s2)
    for r in r2_set:
        t |= _supernode_copy(sp, r, s1)
    for x, xp in xbar1:
        sinks = v1 if xp in r1_set else v2
        for sv in sinks:
            t.add(sp.cross_edge(x, xp, sv))
    trees.append(t)
    res = StarEDSTs(sp, _treeify_all(sp, trees, check=verify), "4.6.2",
                    t1, t2, Es.r, En.r)
    return res.verify() if verify else res


# ---------------------------------------------------------------------------
# Auto dispatcher
# ---------------------------------------------------------------------------

def star_edsts(sp: StarProduct, Es: EDSTSet | None = None,
               En: EDSTSet | None = None, strategy: str = "auto",
               verify: bool = True) -> StarEDSTs:
    """Theorem dispatch.  ``verify=False`` is the compositional fast path
    (used by :mod:`repro.core.product_schedule`): the constructions'
    guarantees are trusted -- no product-graph materialization, no
    per-tree spanning/disjointness scan -- and the compiled wave program
    is vetted by the static verifier instead."""
    Es = Es or edsts_for(sp.gs)
    En = En or edsts_for(sp.gn)
    t1, t2, r1, r2 = Es.t, En.t, Es.r, En.r
    if strategy == "universal":
        return universal_edsts(sp, Es, En, verify)
    if strategy == "maximal":
        return maximal_edsts(sp, Es, En, verify)
    if strategy == "one-sided":
        return one_sided_edsts(sp, Es, En, verify)
    if strategy == "property461":
        return property_461_edsts(sp, Es, En, verify)
    assert strategy == "auto", strategy

    if r1 >= t1 and r2 >= t2:
        try:
            return maximal_edsts(sp, Es, En, verify)
        except ValueError:
            pass
    if r1 >= t1 or r2 >= t2:
        try:
            return one_sided_edsts(sp, Es, En, verify)
        except ValueError:
            pass
    try:
        return property_461_edsts(sp, Es, En, verify)
    except ValueError:
        pass
    if t1 + t2 - 2 >= 1:
        return universal_edsts(sp, Es, En, verify)
    # degenerate fallback: a single BFS spanning tree of the product
    g = sp.product()
    return StarEDSTs(sp, [g.bfs_tree(0)], "bfs-fallback", t1, t2, r1, r2).verify()
