"""Named network topologies as star products (paper Section 2.4).

Every topology is returned as a :class:`StarProduct`, so the Section-4 EDST
constructions apply uniformly.  ``edst_set_for`` converts a star-product EDST
result back into an :class:`EDSTSet`, enabling the *recursive* use the paper
highlights in Sec. 4.1 (BundleFly's structure graph H_q is itself a star
product).
"""
from __future__ import annotations

import functools

from . import factor_graphs as fg
from .edst_star import StarEDSTs, star_edsts
from .factor_edsts import EDSTSet, edsts_for
from .gf import gf
from .graph import Graph
from .star import StarProduct, cartesian, shift_star, star_with


# ---------------------------------------------------------------------------
# Slim Fly (McKay-Miller-Siran H_q): K_{q,q} * C(q)   [paper Ex. 2.4.2]
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def slimfly(q: int) -> StarProduct:
    """H_q as an explicit star product over GF(q).

    Structure graph: K_{q,q} with side-0 vertices x in [0,q) and side-1
    vertices q+m.  Supernode: Cayley(GF(q), X).  Side-1 supernodes use the
    relabeling c = mult * u (X' = mult * X), so the bijection on structure
    edge (x, q+m) maps supernode coordinate y to u = mult^{-1} (y - m x),
    realizing the MMS adjacency y = m x + c.
    """
    F = gf(q)
    x_set, mult, _ = fg.mms_connection_sets(q)
    gs = fg.complete_bipartite(q)
    gn = fg.mms_supernode(q, side=0)
    minv = F.inv(mult)

    def bij(u, v):
        # canonical edge: u = x in [0,q), v = q + m
        x, m = u, v - q
        return tuple(F.mul(minv, F.sub(y, F.mul(m, x))) for y in range(q))

    sp = star_with(gs, gn, bij, name=f"SlimFly(q={q})")
    return sp


# ---------------------------------------------------------------------------
# BundleFly: H_q * QR(a)        [paper Ex. 2.4.3]
# ---------------------------------------------------------------------------

def bundlefly(q: int, a: int) -> StarProduct:
    if a % 4 != 1:
        raise ValueError("BundleFly supernode QR(a) needs a = 4k+1")
    hq = slimfly(q).product()
    sn = fg.paley(a)
    sp = shift_star(hq, sn, name=f"BundleFly(q={q},a={a})")
    return sp


# ---------------------------------------------------------------------------
# PolarStar: ER_q * QR(a)  or  ER_q * IQ(d)    [paper Ex. 2.4.4]
# ---------------------------------------------------------------------------

def polarstar(q: int, supernode: str = "qr", param: int | None = None) -> StarProduct:
    er = fg.erdos_renyi_polarity(q)
    if supernode == "qr":
        a = param if param is not None else 5
        sn = fg.paley(a)
    elif supernode == "iq":
        d = param if param is not None else 4
        sn = fg.inductive_quad(d)
    else:
        raise ValueError(supernode)
    return shift_star(er, sn, name=f"PolarStar(q={q},{supernode}{param})")


# ---------------------------------------------------------------------------
# Cartesian families: HyperX, mesh, torus    [paper Ex. 2.4.1]
# ---------------------------------------------------------------------------

def hyperx(lengths) -> StarProduct:
    """(L, {S_1..S_L}, 0, 0) HyperX: iterated Cartesian product of complete
    graphs; the structure graph of each level is K_{S_L}."""
    lengths = list(lengths)
    if len(lengths) < 2:
        raise ValueError("HyperX needs >= 2 dimensions")
    gn: Graph = fg.complete(lengths[0])
    sp = None
    for s in lengths[1:]:
        sp = cartesian(fg.complete(s), gn,
                       name=f"HyperX{lengths}" if s == lengths[-1] else None)
        gn = sp.product()
    return sp


def torus(dims) -> StarProduct:
    """n-D torus with ROW-MAJOR vertex ids (first dim slowest): vertex
    (i0..ik) has id i0*prod(d1..dk) + ... -- matches jax mesh flattening."""
    dims = list(dims)
    if len(dims) < 2:
        raise ValueError("torus needs >= 2 dims")

    def g(d):
        return fg.cycle(d) if d > 2 else fg.path(d)

    gn: Graph = g(dims[-1])
    sp = None
    for d in dims[-2::-1]:
        sp = cartesian(g(d), gn, name=f"Torus{dims}")
        gn = sp.product()
    return sp


def mesh_nd(dims) -> StarProduct:
    dims = list(dims)
    if len(dims) < 2:
        raise ValueError("mesh needs >= 2 dims")
    gn: Graph = fg.path(dims[-1])
    sp = None
    for d in dims[-2::-1]:
        sp = cartesian(fg.path(d), gn, name=f"Mesh{dims}")
        gn = sp.product()
    return sp


def device_topology(shape, wrap: bool = True) -> StarProduct:
    """The ICI graph of a TPU slice of logical shape ``shape`` (a torus for
    wrap=True, as on v5e pods; a mesh otherwise).  Vertex ids are row-major
    over ``shape``, matching the flattened jax mesh-axis index."""
    shape = [int(s) for s in shape if int(s) > 1]
    if len(shape) == 1:
        shape = [1] + shape
    return torus(shape) if wrap else mesh_nd(shape)


# ---------------------------------------------------------------------------
# EDST plumbing: factor EDSTs for any topology (recursive for star products)
# ---------------------------------------------------------------------------

def edst_set_for(sp_or_graph, strategy: str = "auto") -> EDSTSet:
    """EDSTSet for a topology: star-product construction when available
    (recursively), Roskind-Tarjan otherwise."""
    if isinstance(sp_or_graph, StarProduct):
        res = star_edsts(sp_or_graph, strategy=strategy)
        return star_result_to_set(res)
    return edsts_for(sp_or_graph)


def star_result_to_set(res: StarEDSTs) -> EDSTSet:
    g = res.sp.product()
    used = set().union(*res.trees) if res.trees else set()
    return EDSTSet(g, res.trees, g.edges - used,
                   f"star-{res.theorem}").verify()


def topology_edsts(sp: StarProduct, strategy: str = "auto",
                   structure_set: EDSTSet | None = None,
                   supernode_set: EDSTSet | None = None) -> StarEDSTs:
    """star_edsts with recursive handling of star-product structure graphs.

    BundleFly's structure graph is H_q; passing its star-construction EDSTs
    (rather than RT-found ones) exercises the paper's recursive maximality
    argument (Sec. 4.1)."""
    return star_edsts(sp, structure_set, supernode_set, strategy=strategy)
