"""Compositional star-product schedule compiler (compile-time perf layer).

The flat path to a compiled wave program materializes the product graph,
re-proves the EDST set edge-by-edge (``StarEDSTs.verify``), BFS-tree-ifies
every construction subgraph against it, and greedily list-schedules a
message DAG with an O(waves * messages) ready-scan -- minutes of Python on
10k-node SlimFly/BundleFly/PolarStar fabrics.  This module assembles the
same artifacts compositionally, straight from *cached factor-graph*
structure:

  * :func:`composed_star_trees` -- the paper's Construction A/B (and
    extra-tree) edge sets assembled from cached factor EDSTs through the
    star bijections (``star_edsts(..., verify=False)``): supernode copies
    from the Gn trees, bundle/cross edges packed by ``sp.bundle`` /
    ``sp.cross_edge``, never touching ``sp.product()``.  A/B outputs are
    exact spanning trees by edge count ((ns-1)*nn bundle edges + (nn-1)
    supernode edges = N-1), so tree-ification and the per-tree
    spanning/disjointness scan are skipped -- the *compiled program* is
    vetted by the static verifier instead (``repro.analysis.verify``).
  * :func:`composed_allreduce_schedule` -- an ordinary
    :class:`~repro.core.collectives.AllreduceSchedule` over those trees
    (depth-minimizing CSR tree-center roots), memoized on
    ``StarProduct.cache_key()`` so elastic rescales and fault-runtime
    rebuilds that land on an already-seen fabric reuse the composed
    schedule instead of recompiling.
  * :func:`asap_pipelined_spec` / :func:`asap_striped_spec` /
    :func:`asap_fused_spec` -- wave programs built by ASAP levelization:
    every message's earliest start is computed per tree in O(N) (reduce
    send = subtree height; broadcast send = reduce completion + depth;
    the four striped kinds via two leafward and two rootward sweeps),
    then messages are packed in ASAP order by earliest-wave placement:
    each message's earliest legal wave is one past the maximum wave of
    its dependencies (an O(1) per-vertex aggregate), and a short forward
    probe finds the first ppermute-legal (and, for striped,
    op-homogeneous) wave.  Every dependency lands in a strictly earlier
    wave, so the emitted order preserves happens-before -- the property
    the full-level verifier re-checks.  Total cost is near-linear in
    messages, replacing the O(waves x messages) greedy ready-scan.

``benchmarks/compile_bench.py`` records composed-vs-flat compile time
and wave counts in ``BENCH_compile.json``.
"""
from __future__ import annotations

from bisect import bisect_left
from itertools import chain

import numpy as np

from .collectives import (BCAST, REDUCE, RS_UP, RS_DOWN, AG_UP, AG_DOWN,
                          _RS_KINDS, AllreduceSchedule, FusedAllreduceSpec,
                          PipelinedAllreduceSpec, StripedCollectiveSpec,
                          StripedWave, _pipe_wave, _sched_key, _striped_op,
                          _striped_tree, _striped_wave, allreduce_schedule,
                          fused_spec_from_schedule, verify_compiled_spec)
from .edst_star import StarEDSTs, star_edsts
from .factor_edsts import EDSTSet, edsts_for
from .star import StarProduct

# ---------------------------------------------------------------------------
# cached factor EDSTs + composed schedules
# ---------------------------------------------------------------------------

_FACTOR_CACHE: dict = {}      # (n, frozenset(edges)) -> EDSTSet
_SCHED_CACHE: dict = {}       # (sp key, E keys, strategy, roots) -> schedule
_PIPE_CACHE: dict = {}        # composed-spec caches, keyed like the flat
_STRIPED_CACHE: dict = {}     # compilers' but tagged "composed"


def factor_edsts_cached(g) -> EDSTSet:
    """``edsts_for`` memoized by graph value: the same factor (an ER_q
    polarity graph, a Paley supernode, a cycle of a torus) is packed once
    per process no matter how many product fabrics reuse it."""
    key = (g.n, frozenset(g.edges))
    hit = _FACTOR_CACHE.get(key)
    if hit is None:
        hit = _FACTOR_CACHE[key] = edsts_for(g)
    return hit


def _edst_key(es: EDSTSet | None):
    return None if es is None else tuple(frozenset(t) for t in es.trees)


def composed_star_trees(sp: StarProduct, Es: EDSTSet | None = None,
                        En: EDSTSet | None = None,
                        strategy: str = "auto") -> StarEDSTs:
    """Product EDSTs assembled from (cached) factor EDSTs without
    materializing or re-verifying against the product graph."""
    Es = Es or factor_edsts_cached(sp.gs)
    En = En or factor_edsts_cached(sp.gn)
    return star_edsts(sp, Es, En, strategy=strategy, verify=False)


def composed_allreduce_schedule(sp: StarProduct, Es: EDSTSet | None = None,
                                En: EDSTSet | None = None,
                                strategy: str = "auto",
                                roots=None) -> AllreduceSchedule:
    """The composed :class:`AllreduceSchedule` of a star-product fabric,
    memoized on ``sp.cache_key()``: recompiles (elastic rescale probes,
    fault-runtime rebuilds, repeated spec lookups) return the identical
    object, which keys the spec caches below."""
    key = (sp.cache_key(), _edst_key(Es), _edst_key(En), strategy, roots)
    hit = _SCHED_CACHE.get(key)
    if hit is not None:
        return hit
    res = composed_star_trees(sp, Es, En, strategy)
    sched = allreduce_schedule(sp.n, res.trees, roots=roots)
    _SCHED_CACHE[key] = sched
    return sched


# ---------------------------------------------------------------------------
# ASAP levelization (O(messages) wave assembly)
# ---------------------------------------------------------------------------

class _WaveAlloc:
    """Incremental wave allocator for earliest-wave placement.

    ``place(i, s, d, ew, op)`` puts message ``i`` in the first wave at
    index >= ``ew`` whose source set misses ``s``, destination set misses
    ``d``, and (for the striped engine) whose op matches; a new wave is
    opened past the end otherwise.  Since ``ew`` is always one past the
    maximum wave of every dependency, the emitted wave order preserves
    happens-before while packing independent messages together the way
    the greedy list scheduler does."""

    def __init__(self):
        self.srcs, self.dsts, self.ops, self.waves = [], [], [], []

    def place(self, i, s, d, ew, op=0):
        w = ew
        while w < len(self.waves) and (self.ops[w] != op
                                       or s in self.srcs[w]
                                       or d in self.dsts[w]):
            w += 1
        if w == len(self.waves):
            self.srcs.append(set())
            self.dsts.append(set())
            self.ops.append(op)
            self.waves.append([])
        self.srcs[w].add(s)
        self.dsts[w].add(d)
        self.waves[w].append(i)
        return w


def _pipe_asap(sched: AllreduceSchedule):
    """Every (tree, kind, src, dst) pipelined message with its critical-
    path priority (negated height: longest dependent chain, the same
    priority the flat list scheduler sorts by), computed per tree in O(N).

    Per tree: a broadcast (p -> c) heads a chain of length hb(c), the
    bcast-subtree height of c; a reduce into v heads R(v) with
    R(root) = 1 + max child hb and R(v) = 1 + R(parent) below.  A
    dependency's height strictly exceeds its dependent's, so ascending
    priority order processes dependencies first -- the invariant
    earliest-wave placement needs.  ``q8_pri`` are the standalone-phase
    heights (cross-kind chains dropped) for the phase-separated quantized
    program.
    """
    msgs, pri, q8_pri = [], [], []
    for j, ts in enumerate(sched.trees):
        hb: dict = {}
        for lvl in reversed(ts.bcast_rounds):     # children before parents
            for p, c in lvl:
                hb.setdefault(c, 0)
                if hb[c] + 1 > hb.get(p, 0):
                    hb[p] = hb[c] + 1
        red: dict = {ts.root: 1 + hb.get(ts.root, 0)}
        dep: dict = {ts.root: 0}
        for lvl in ts.bcast_rounds:               # parents before children
            for p, c in lvl:
                red[c] = 1 + red[p]
                dep[c] = 1 + dep[p]
                msgs.append((j, REDUCE, c, p))
                pri.append(-red[p])
                q8_pri.append(-dep[c])            # red-only chain = depth
                msgs.append((j, BCAST, p, c))
                pri.append(-hb[c])
                q8_pri.append(-hb[c])
    return msgs, pri, q8_pri


def _pipe_place(sched: AllreduceSchedule, msgs, pri, ids, mixed: bool,
                tiebreak=None):
    """Earliest-wave placement of pipelined messages, processed in
    critical-path priority order (dependencies strictly first, longest
    chains grab slots first -- the flat scheduler's priority).
    Dependency waves aggregate into per-vertex maxima --
    ``maxw_red[(j, v)]`` is the last wave of a reduce into ``v`` and
    ``wave_bc[(j, v)]`` the wave of the broadcast into ``v`` -- making
    each earliest-wave bound O(1).  With ``mixed`` false the broadcast
    kind restarts from wave 0 (the standalone q8 phase drops cross-kind
    dependencies, mirroring the ``kinds`` filter of the flat list
    scheduler)."""
    alloc = _WaveAlloc()
    maxw_red: dict = {}
    wave_bc: dict = {}
    roots = [ts.root for ts in sched.trees]
    if tiebreak is None:
        order = sorted(ids, key=lambda i: (pri[i], msgs[i][1], msgs[i][0],
                                           msgs[i][2]))
    else:
        order = sorted(ids, key=lambda i: (pri[i], tiebreak[i]))
    for i in order:
        j, kind, s, d = msgs[i]
        if kind == REDUCE:
            w = alloc.place(i, s, d, maxw_red.get((j, s), -1) + 1)
            if w > maxw_red.get((j, d), -1):
                maxw_red[(j, d)] = w
        else:
            if s == roots[j]:
                base = maxw_red.get((j, s), -1) if mixed else -1
            else:
                base = wave_bc[(j, s)]
            wave_bc[(j, d)] = alloc.place(i, s, d, base + 1)
    return alloc.waves


def asap_pipelined_spec(sched: AllreduceSchedule, axis_names,
                        verify=None) -> PipelinedAllreduceSpec:
    """Compile an :class:`AllreduceSchedule` into a
    :class:`PipelinedAllreduceSpec` by ASAP levelization + earliest-wave
    placement (O(messages)) instead of the greedy list schedule.  Cached
    like the flat compiler but under a ``"composed"``-tagged key, so flat
    and composed programs of one fabric coexist (and the benchmark can
    compare them)."""
    axes = tuple(axis_names)
    key = (*_sched_key(sched, axes), "pipelined", "composed")
    hit = _PIPE_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "asap_pipelined_spec")
        return hit
    msgs, pri, q8_pri = _pipe_asap(sched)
    n, k = sched.n, sched.k
    ids = range(len(msgs))
    waves = tuple(_pipe_wave(n, k, msgs, take)
                  for take in _pipe_place(sched, msgs, pri, ids, True))
    red = [_pipe_wave(n, k, msgs, take) for take in _pipe_place(
        sched, msgs, q8_pri,
        [i for i in ids if msgs[i][1] == REDUCE], False)]
    bc = [_pipe_wave(n, k, msgs, take) for take in _pipe_place(
        sched, msgs, q8_pri,
        [i for i in ids if msgs[i][1] == BCAST], False)]
    spec = PipelinedAllreduceSpec(n=n, k=k, axes=axes, depth=sched.depth,
                                  waves=waves, q8_waves=tuple(red + bc),
                                  q8_boundary=len(red), key=key)
    verify_compiled_spec(spec, verify, "asap_pipelined_spec")
    _PIPE_CACHE[key] = spec
    return spec


def _striped_asap(sched: AllreduceSchedule, trees):
    """Every striped message with its critical-path priority (negated
    height over the superset dependency DAG -- sibling "other children"
    terms widened to all children, see ``_striped_dag`` for the true
    rules), computed per tree in four O(N) sweeps.

    With hu/hd/au/ad the heights of the RS_UP/RS_DOWN/AG_UP/AG_DOWN
    message attached to a (vertex -> parent) tree edge, transposing the
    superset rules gives (maxima over children c, parent p):

      ad(v) = 1 + max_c ad(c)                       (leafward sweep)
      au(v) = 1 + max(au(p), max_{c of p} ad(c))    (rootward sweep)
      hd(v) = 1 + max(max_c hd(c), au(v), max_c ad(c))   (leafward)
      hu(v) = 1 + max(hu(p), au(p), max_{c of p} hd(c), max_{c of p} ad(c))

    A superset dependency's height strictly exceeds its dependent's (and
    the true dependencies are a subset), so ascending priority order
    processes dependencies first.  ``solo_pri`` holds the phase-local
    heights (cross-phase terms dropped) for the standalone ``rs_waves`` /
    ``ag_waves`` programs.

    Alongside ``(msgs, pri, solo_pri, ops)`` the sweep returns the
    per-message slot-window arrays ``(j, s, d, slot, nslot)`` as int32
    numpy columns, precomputed here (2 tree-table reads per vertex
    instead of 6 per wave-build) so wave assembly can scatter them in
    bulk."""
    n = sched.n
    msgs, pri, solo_pri, ops = [], [], [], []
    c_j, c_s, c_d, c_slot, c_nslot = [], [], [], [], []
    for j, (ts, st) in enumerate(zip(sched.trees, trees)):
        parent = st.parent.tolist()
        pre = st.pre.tolist()
        size = st.size.tolist()
        down = [c for lvl in ts.bcast_rounds for _, c in lvl]
        rdown = down[::-1]
        ad = [0] * n
        mad = [0] * n         # max ad over children
        au = [0] * n
        hd = [0] * n
        mhd = [0] * n         # max hd over children
        hu = [0] * n
        ad2 = [0] * n
        mad2 = [0] * n
        au2 = [0] * n
        hd2 = [0] * n
        mhd2 = [0] * n
        hu2 = [0] * n
        for v in rdown:                            # children before parents
            a = ad[v] = 1 + mad[v]
            a2 = ad2[v] = 1 + mad2[v]
            p = parent[v]
            if a > mad[p]:
                mad[p] = a
            if a2 > mad2[p]:
                mad2[p] = a2
        for v in down:                             # parents before children
            p = parent[v]
            au[v] = 1 + (au[p] if au[p] > mad[p] else mad[p])
            au2[v] = 1 + (au2[p] if au2[p] > mad2[p] else mad2[p])
        for v in rdown:
            h = hd[v] = 1 + max(mhd[v], au[v], mad[v])
            h2 = hd2[v] = 1 + mhd2[v]
            p = parent[v]
            if h > mhd[p]:
                mhd[p] = h
            if h2 > mhd2[p]:
                mhd2[p] = h2
        for v in down:
            p = parent[v]
            hu[v] = 1 + max(hu[p], au[p], mhd[p], mad[p])
            hu2[v] = 1 + (hu2[p] if hu2[p] > mhd2[p] else mhd2[p])
        for v in down:
            p = parent[v]
            below_slot, below_n = pre[v], size[v]
            above_slot = (below_slot + below_n) % n
            above_n = n - below_n
            msgs.append((j, RS_UP, v, p))
            pri.append(-hu[v])
            solo_pri.append(-hu2[v])
            ops.append(REDUCE)
            c_j.append(j); c_s.append(v); c_d.append(p)
            c_slot.append(above_slot); c_nslot.append(above_n)
            msgs.append((j, RS_DOWN, p, v))
            pri.append(-hd[v])
            solo_pri.append(-hd2[v])
            ops.append(REDUCE)
            c_j.append(j); c_s.append(p); c_d.append(v)
            c_slot.append(below_slot); c_nslot.append(below_n)
            msgs.append((j, AG_UP, v, p))
            pri.append(-au[v])
            solo_pri.append(-au2[v])
            ops.append(BCAST)
            c_j.append(j); c_s.append(v); c_d.append(p)
            c_slot.append(below_slot); c_nslot.append(below_n)
            msgs.append((j, AG_DOWN, p, v))
            pri.append(-ad[v])
            solo_pri.append(-ad2[v])
            ops.append(BCAST)
            c_j.append(j); c_s.append(p); c_d.append(v)
            c_slot.append(above_slot); c_nslot.append(above_n)
    cols = tuple(np.asarray(c, np.int32)
                 for c in (c_j, c_s, c_d, c_slot, c_nslot))
    return msgs, pri, solo_pri, ops, cols


def _striped_place(order, kind_a, bs_a, bd_a, s_a, d_a, op_a, phase: str,
                   kn: int):
    """Earliest-wave placement of the four striped kinds (``phase`` is
    ``"mixed"``, ``"rs"`` or ``"ag"``), processed in critical-path
    priority order (``order``; the caller lexsorts, dependencies strictly
    first).  Per-vertex aggregates mirror the ``_striped_dag`` dependency
    rules with the same whole-children superset relaxation as the height
    sweeps: ``up``/``agup`` hold the last wave of an RS_UP/AG_UP into a
    vertex, ``rsdn``/``agdn`` the wave of its down-pass message (indexed
    ``tree * n + vertex``, the ``bs_a``/``bd_a`` columns).  The
    standalone phases drop cross-phase terms, matching the flat
    scheduler's ``kinds`` filter.  The wave allocator is inlined, fields
    stream in through one C-level ``zip``, and the forward probe walks
    only waves of the message's op (two bisected per-op index lists):
    this loop runs once per message per phase and dominates composed
    compile time."""
    up = [-1] * kn
    rsdn = [-1] * kn
    agup = [-1] * kn
    agdn = [-1] * kn
    srcs, dsts, waves = [], [], []
    red_w, bc_w = [], []        # wave ids per op, increasing
    ag_solo = phase == "ag"
    it = zip(order.tolist(), kind_a[order].tolist(), bs_a[order].tolist(),
             bd_a[order].tolist(), s_a[order].tolist(),
             d_a[order].tolist(), op_a[order].tolist())
    for i, kind, bs, bd, s, d, op in it:
        if kind == RS_UP:
            ew = up[bs] + 1
        elif kind == RS_DOWN:
            ew = 1 + (up[bs] if up[bs] > rsdn[bs] else rsdn[bs])
        elif kind == AG_UP:
            ew = 1 + agup[bs] if ag_solo else \
                1 + max(rsdn[bs], up[bs], agup[bs])
        else:
            if ag_solo:
                ew = 1 + (agup[bs] if agup[bs] > agdn[bs] else agdn[bs])
            else:
                ew = 1 + max(up[bs], agup[bs], rsdn[bs], agdn[bs])
        lst = red_w if op == REDUCE else bc_w
        pos = bisect_left(lst, ew)
        end = len(lst)
        w = -1
        while pos < end:
            wi = lst[pos]
            if s not in srcs[wi] and d not in dsts[wi]:
                w = wi
                break
            pos += 1
        if w < 0:
            w = len(waves)
            lst.append(w)
            srcs.append({s})
            dsts.append({d})
            waves.append([i])
        else:
            srcs[w].add(s)
            dsts[w].add(d)
            waves[w].append(i)
        if kind == RS_UP:
            if w > up[bd]:
                up[bd] = w
        elif kind == RS_DOWN:
            rsdn[bd] = w
        elif kind == AG_UP:
            if w > agup[bd]:
                agup[bd] = w
        else:
            agdn[bd] = w
    return waves


def _striped_batch(n, msgs, ops, cols, takes):
    """Build the :class:`StripedWave` tuple for one phase in bulk: the
    six per-wave (n,) slot tables become rows of (W, n) arrays filled by
    a single vectorized scatter from the precomputed per-message columns
    (equivalent to ``_striped_wave`` per wave, minus the per-message
    Python slot arithmetic)."""
    c_j, c_s, c_d, c_slot, c_nslot = cols
    nw = len(takes)
    counts = np.fromiter(map(len, takes), np.int64, nw)
    flat = np.fromiter(chain.from_iterable(takes), np.int64,
                       int(counts.sum()))
    w_arr = np.repeat(np.arange(nw), counts)
    send_tree = np.zeros((nw, n), np.int32)
    send_slot = np.zeros((nw, n), np.int32)
    send_nslot = np.zeros((nw, n), np.int32)
    recv_tree = np.zeros((nw, n), np.int32)
    recv_slot = np.zeros((nw, n), np.int32)
    recv_nslot = np.zeros((nw, n), np.int32)
    s_f, d_f, j_f = c_s[flat], c_d[flat], c_j[flat]
    sl_f, ns_f = c_slot[flat], c_nslot[flat]
    send_tree[w_arr, s_f] = j_f
    send_slot[w_arr, s_f] = sl_f
    send_nslot[w_arr, s_f] = ns_f
    recv_tree[w_arr, d_f] = j_f
    recv_slot[w_arr, d_f] = sl_f
    recv_nslot[w_arr, d_f] = ns_f
    perm_all = list(zip(c_s.tolist(), c_d.tolist()))
    msg_get = msgs.__getitem__
    perm_get = perm_all.__getitem__
    out = []
    for w, take in enumerate(takes):
        out.append(StripedWave(tuple(map(perm_get, take)), ops[take[0]],
                               tuple(map(msg_get, take)),
                               send_tree[w], send_slot[w], send_nslot[w],
                               recv_tree[w], recv_slot[w], recv_nslot[w]))
    return tuple(out)


def asap_striped_spec(sched: AllreduceSchedule, axis_names,
                      verify=None) -> StripedCollectiveSpec:
    """Compile an :class:`AllreduceSchedule` into a
    :class:`StripedCollectiveSpec` by ASAP levelization + earliest-wave
    placement of the four-kind striped DAG (O(messages)), with
    op-homogeneous waves."""
    axes = tuple(axis_names)
    key = (*_sched_key(sched, axes), "striped", "composed")
    hit = _STRIPED_CACHE.get(key)
    if hit is not None:
        if verify:
            verify_compiled_spec(hit, verify, "asap_striped_spec")
        return hit
    trees = tuple(_striped_tree(sched.n, ts) for ts in sched.trees)
    msgs, pri, solo_pri, ops, cols = _striped_asap(sched, trees)
    n, k = sched.n, sched.k
    c_j, c_s, c_d = cols[0], cols[1], cols[2]
    kind_a = np.fromiter((m[1] for m in msgs), np.int64, len(msgs))
    op_a = np.asarray(ops, np.int64)
    pri_a = np.asarray(pri, np.int64)
    solo_a = np.asarray(solo_pri, np.int64)
    bs_a = c_j.astype(np.int64) * n + c_s
    bd_a = c_j.astype(np.int64) * n + c_d

    def waves_of(sub, pr, phase):
        order = sub[np.lexsort((c_s[sub], kind_a[sub], c_j[sub],
                                op_a[sub], pr[sub]))]
        takes = _striped_place(order, kind_a, bs_a, bd_a,
                               c_s, c_d, op_a, phase, k * n)
        return _striped_batch(n, msgs, ops, cols, takes)

    everything = np.arange(len(msgs))
    spec = StripedCollectiveSpec(
        n=n, k=k, axes=axes, depth=sched.depth, trees=trees,
        waves=waves_of(everything, pri_a, "mixed"),
        rs_waves=waves_of(np.nonzero(kind_a < AG_UP)[0], solo_a, "rs"),
        ag_waves=waves_of(np.nonzero(kind_a >= AG_UP)[0], solo_a, "ag"),
        key=key)
    verify_compiled_spec(spec, verify, "asap_striped_spec")
    _STRIPED_CACHE[key] = spec
    return spec


def asap_fused_spec(sched: AllreduceSchedule, axis_names,
                    verify=None) -> FusedAllreduceSpec:
    """The fused engine is already round-levelized (global rounds are BFS
    levels; no list schedule), so the composed path reuses the flat
    compiler -- its savings come from the composed trees upstream."""
    return fused_spec_from_schedule(sched, axis_names, verify)


# ---------------------------------------------------------------------------
# star-product entry point
# ---------------------------------------------------------------------------

def composed_spec_for_star(sp: StarProduct, axis_names,
                           engine: str = "pipelined",
                           Es: EDSTSet | None = None,
                           En: EDSTSet | None = None,
                           strategy: str = "auto", roots=None, verify=None):
    """Composed trees + ASAP wave assembly in one call: the full
    compositional compile of a star-product fabric.  Every layer is
    memoized (factor EDSTs, composed schedule, spec), so a 10k-node
    PolarStar compiles in seconds and recompiles for free."""
    sched = composed_allreduce_schedule(sp, Es, En, strategy, roots)
    if engine == "fused":
        return asap_fused_spec(sched, axis_names, verify)
    if engine == "striped":
        return asap_striped_spec(sched, axis_names, verify)
    if engine != "pipelined":
        raise ValueError(f"engine {engine!r} not in "
                         "('pipelined', 'fused', 'striped')")
    return asap_pipelined_spec(sched, axis_names, verify)
