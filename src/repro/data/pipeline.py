"""Deterministic, host-shardable synthetic LM data pipeline.

Generates a structured token stream (a Zipf-ish unigram mix with short-range
Markov structure so the LM has something learnable), deterministically keyed
by (seed, step, host_shard): every host can produce exactly its shard of the
global batch with no coordination, and restarts resume bit-identically --
the property that matters for checkpoint/restart and elastic rescaling.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticLMStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    markov_order: int = 2

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.RandomState(self.seed)
        # fixed unigram (Zipf) and a sparse bigram successor table
        ranks = np.arange(1, self.vocab + 1)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._succ = rng.randint(0, self.vocab, size=(self.vocab, 4))

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> np.ndarray:
        """(host_batch, seq_len + 1) int32, deterministic in (seed, step, host)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 65_537 + self.host_id) % 2 ** 31)
        b, s = self.host_batch, self.seq_len + 1
        out = np.empty((b, s), np.int32)
        out[:, 0] = rng.choice(self.vocab, size=b, p=self._unigram)
        for t in range(1, s):
            use_markov = rng.random(b) < 0.7
            succ_pick = self._succ[out[:, t - 1], rng.randint(0, 4, b)]
            fresh = rng.choice(self.vocab, size=b, p=self._unigram)
            out[:, t] = np.where(use_markov, succ_pick, fresh)
        return out


def make_batch_for(cfg, shape, step: int = 0, seed: int = 0,
                   n_hosts: int = 1, host_id: int = 0) -> dict:
    """Concrete numpy batch matching ``ModelAPI.input_specs(shape)``."""
    rng = np.random.RandomState(seed * 7919 + step)
    gb, s = shape.global_batch, shape.seq_len
    f = cfg.family
    if shape.kind == "train":
        if f == "encdec":
            stream = SyntheticLMStream(cfg.vocab, s, gb, seed, n_hosts, host_id)
            return {"frames": rng.randn(gb // n_hosts, s, cfg.d_model)
                    .astype(np.float32), "tokens": stream.batch(step)}
        if f == "vlm":
            n_txt = s - cfg.n_img_tokens
            stream = SyntheticLMStream(cfg.vocab, n_txt, gb, seed, n_hosts, host_id)
            return {"patches": rng.randn(gb // n_hosts, cfg.n_img_tokens,
                                         cfg.d_model).astype(np.float32),
                    "tokens": stream.batch(step)}
        stream = SyntheticLMStream(cfg.vocab, s, gb, seed, n_hosts, host_id)
        return {"tokens": stream.batch(step)}
    raise ValueError("make_batch_for is a training-data helper; serving "
                     "inputs come from ModelAPI.input_specs")
