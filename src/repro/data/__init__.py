from .pipeline import SyntheticLMStream, make_batch_for
