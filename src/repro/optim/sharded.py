"""ZeRO-1 AdamW on EDST owner stripes: optimizer state lives scattered.

:class:`ShardedAdamW` wraps the dense :class:`repro.optim.adamw.AdamW`
so each device holds only its ``(k, smax)`` owner-stripe slice of the
first/second moments -- the stripe geometry of
:func:`repro.dist.striped.tree_reduce_scatter`.  A zero1 train step then
reduce-scatters gradients, updates params in the scattered domain, and
allgathers the updated *params only*: the gradient allgather of the
composed allreduce disappears, optimizer memory drops ~n-fold, and the
update math reproduces the dense optimizer exactly (bitwise in f32 up
to float reassociation of the global norm):

  * clipping is a stripe-local partial sum of squares
    (:meth:`ShardedAdamW.partial_sumsq`) + one scalar ``psum`` in the
    caller -- owner stripes partition the payload exactly (padding is
    zero), so the psum'd norm equals the dense global norm;
  * :meth:`ShardedAdamW.update_stripes` is elementwise on stripes and
    mirrors ``AdamW.apply`` term for term; padded entries carry
    ``p = g = decay = 0`` and stay exactly zero through the update;
  * per-leaf weight decay (2D+ leaves only) becomes the flat
    :func:`decay_mask` vector over ``ravel_pytree`` order, sliced into
    stripes alongside the params.

The module is mesh-agnostic: nothing here names an axis or runs a
collective.  The callers (:mod:`repro.dist.steps`,
:mod:`repro.dist.fault`) own the reduce-scatter/allgather wiring and the
one clipping psum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .adamw import AdamW


class ShardedOptState(NamedTuple):
    """ZeRO-1 optimizer state.  ``mu`` / ``nu`` are global
    ``(ndp, kmax, smax)`` f32 arrays whose leading axis is the owner
    device -- shard them with the owner-stripe PartitionSpec
    (:func:`repro.dist.sharding.owner_stripe_spec`) so device ``d``
    holds only row ``d``.  ``step`` is the replicated scalar count."""
    step: jax.Array
    mu: jax.Array
    nu: jax.Array


def zero1_geometry(spec_or_runtime, size: int, fractions=None):
    """``(kmax, smax)`` of the padded stripe stack a zero1 step carries
    for a ``size``-element payload.  For a plain
    :class:`StripedCollectiveSpec` this is its own bind; for a
    :class:`repro.dist.fault.FaultAwareAllreduce` it is the maximum over
    every precompiled failure-class entry, so one state shape serves all
    schedule ids (the switch branches pad to it)."""
    from ..core.collectives import striped_tables
    entries = getattr(spec_or_runtime, "entries", None)
    if entries is not None:
        kmax = max(e.k for e in entries)
        smax = max(striped_tables(e.spec, size, e.fractions).smax
                   for e in entries if e.k > 0)
        return kmax, smax
    fr = None if fractions is None else tuple(fractions)
    t = striped_tables(spec_or_runtime, size, fr)
    return spec_or_runtime.k, t.smax


def decay_mask(params, weight_decay: float) -> jax.Array:
    """The flat f32 weight-decay vector over ``ravel_pytree(params)``
    order: ``weight_decay`` on every element of a 2D+ leaf, 0 elsewhere
    -- the per-leaf rule of ``AdamW.apply`` in the flat domain.  Built
    from static leaf shapes, so calling it inside a traced step bakes a
    constant, never a computation."""
    parts = [np.full(int(np.prod(p.shape, dtype=np.int64)),
                     weight_decay if p.ndim >= 2 else 0.0, np.float32)
             for p in jax.tree.leaves(params)]
    if not parts:
        return jnp.zeros((0,), jnp.float32)
    return jnp.asarray(np.concatenate(parts))


@dataclass(frozen=True)
class ShardedAdamW:
    """Owner-stripe AdamW: the dense optimizer's math on ``(kmax, smax)``
    stripe stacks.  See module docstring for the equivalence argument."""
    base: AdamW

    def init(self, ndp: int, kmax: int, smax: int) -> ShardedOptState:
        zeros = jnp.zeros((ndp, kmax, smax), jnp.float32)
        return ShardedOptState(jnp.zeros((), jnp.int32), zeros, zeros)

    def init_for(self, params, spec_or_runtime, ndp: int,
                 fractions=None) -> ShardedOptState:
        """State sized for ``params`` sharded over ``ndp`` owner devices
        with the given stripe geometry source (spec or fault runtime)."""
        size = sum(int(np.prod(p.shape, dtype=np.int64))
                   for p in jax.tree.leaves(params))
        kmax, smax = zero1_geometry(spec_or_runtime, size, fractions)
        return self.init(ndp, kmax, smax)

    @staticmethod
    def partial_sumsq(owned_g) -> jax.Array:
        """This device's contribution to the squared global grad norm
        (stripe padding is zero, owner stripes partition the payload, so
        ``sqrt(psum(partial_sumsq))`` equals the dense global norm)."""
        g32 = owned_g.astype(jnp.float32)
        return jnp.sum(g32 * g32)

    def update_stripes(self, p, g, decay, mu, nu, step, gnorm):
        """One AdamW update on this device's stripes.

        ``p`` / ``g`` / ``decay`` / ``mu`` / ``nu`` are ``(kmax, smax)``
        f32 stripe stacks (params, mean grads, decay mask, moments);
        ``step`` is the post-increment count and ``gnorm`` the psum'd
        pre-clip global norm.  Returns ``(new_p, new_mu, new_nu, lr)``.
        """
        b = self.base
        scale = jnp.minimum(1.0, b.clip_norm / (gnorm + 1e-9))
        g32 = g.astype(jnp.float32) * scale
        t = step.astype(jnp.float32)
        m = b.b1 * mu + (1 - b.b1) * g32
        v = b.b2 * nu + (1 - b.b2) * g32 * g32
        mhat = m / (1 - b.b1 ** t)
        vhat = v / (1 - b.b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + b.eps)
        lr = self.base.lr_fn(step)
        new_p = p - lr * (delta + decay * p)
        return new_p, m, v, lr
