"""AdamW with cosine schedule, global-norm clipping and grad accumulation.

Hand-rolled (no optax in this environment).  Optimizer state mirrors the
param tree (same shardings apply leaf-wise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm_clip(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


@dataclass(frozen=True)
class AdamW:
    lr_fn: object
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return OptState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def apply(self, params, grads, state: OptState):
        grads, gnorm = global_norm_clip(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr_fn(step)
        b1, b2 = self.b1, self.b2

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            p32 = p.astype(jnp.float32)
            decay = self.weight_decay if p.ndim >= 2 else 0.0
            p32 = p32 - lr * (delta + decay * p32)
            return p32.astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
