from .adamw import AdamW, OptState, cosine_schedule, global_norm_clip
