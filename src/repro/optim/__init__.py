from .adamw import AdamW, OptState, cosine_schedule, global_norm_clip
from .sharded import (ShardedAdamW, ShardedOptState, decay_mask,
                      zero1_geometry)

__all__ = ["AdamW", "OptState", "cosine_schedule", "global_norm_clip",
           "ShardedAdamW", "ShardedOptState", "decay_mask",
           "zero1_geometry"]
