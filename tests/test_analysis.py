"""Loop-aware HLO analyzer: exactness fixtures (scan trip counts, nested
loops, DUS in-place accounting) + roofline term wiring."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import model_flops_for, roofline
from repro import configs


def test_scan_flops_exact():
    def g(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = analyze_hlo(jax.jit(g).lower(xs).compile().as_text())
    assert st.dot_flops == 2 * 128 ** 3 * 10


def test_nested_scan_flops_exact():
    def h(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out.sum()
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = analyze_hlo(jax.jit(h).lower(xs).compile().as_text())
    assert st.dot_flops == 2 * 128 ** 3 * 15


def test_trip_count_ignores_body_constants():
    def g(x):
        def body(c, _):
            return c @ x + 32768.0, None   # big constant in the body
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    st = analyze_hlo(jax.jit(g).lower(xs).compile().as_text())
    assert st.dot_flops == 2 * 64 ** 3 * 10


def test_dus_loop_not_overcounted():
    def h(buf, upd):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(b, upd, i, 0), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(50))
        return out
    c = jax.jit(h).lower(jax.ShapeDtypeStruct((100000, 64), jnp.float32),
                         jax.ShapeDtypeStruct((1, 64), jnp.float32)).compile()
    st = analyze_hlo(c.as_text())
    overcount = 100000 * 64 * 4 * 50          # full buffer x iterations
    assert st.bytes_touched < 0.2 * overcount


def test_collectives_counted_in_loops():
    import os
    # single-device: no collectives; just assert the field plumbing
    def g(x):
        return x * 2
    st = analyze_hlo(jax.jit(g).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text())
    assert st.total_collective_bytes == 0


def test_roofline_terms():
    cfg = configs.get("qwen3-8b")
    shape = cfg.shape("train_4k")
    t = roofline(cfg, shape, "16x16", 256, 1e15, 1e12, 1e10)
    assert t.compute_s > 0 and t.memory_s > 0 and t.collective_s > 0
    assert t.dominant == "compute"
    assert 0 < t.roofline_fraction <= 1.5


def test_model_flops_includes_attention():
    cfg = configs.get("smollm-135m")
    short = model_flops_for(cfg, cfg.shape("train_4k"), 256)
    # prefill at 32k has much higher per-token flops due to attention
    long_ = model_flops_for(cfg, cfg.shape("prefill_32k"), 256)
    per_tok_short = short / (256 * 4096 / 256)
    per_tok_long = long_ / (32 * 32768 / 256)
    assert per_tok_long > per_tok_short  # attention term grows with S
    # rwkv6 has no attention quadratic term
    r = configs.get("rwkv6-7b")
    a = model_flops_for(r, r.shape("prefill_32k"), 256) / (32 * 32768 / 256)
    b = model_flops_for(r, r.shape("train_4k"), 256) / (256 * 4096 / 256)
    assert abs(a * 3 - b) / b < 0.01   # 2ND vs 6ND only
