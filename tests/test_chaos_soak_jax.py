"""The acceptance soak: a seeded chaos trace with a flap, a link kill,
an out-of-class burst, and a node loss runs through a REAL train loop
(the chaos harness of ``benchmarks/chaos_soak.py``) on 16 fake devices
-- zero unhandled exceptions, every committed loss equal to the
fault-free ``psum_dp`` reference on the same batches, the node loss
checkpointing and elastically rescaling onto the 8 survivors, and the
journal covering every injected cause."""
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SOAK_CODE = f"""
import sys, tempfile
sys.path.insert(0, {REPO!r})
""" + r"""
from benchmarks.chaos_soak import run_soak

rows = run_soak("dense", ("flap", "kill", "burst", "node"), 30,
                ckpt_dir=tempfile.mkdtemp(prefix="soak_ck_"), verbose=True)
t = rows["soak/dense/totals"]
assert t["unhandled_exceptions"] == 0, t
assert t["committed"] > 0 and t["max_loss_diff"] < 1e-3, t
assert t["generations"] == 2, t          # burst hot-swap + node rescale
assert t["n_final"] == 8, t              # rescaled onto the survivors
causes = {row["cause"] for row in t["journal"]}
assert {"link-flap", "link-kill", "link-burst", "node-loss"} <= causes, causes
for kind in ("flap", "kill", "burst", "node"):
    row = rows[f"soak/dense/{kind}"]
    assert row["mttr_ticks"] <= 2 and row["events"] >= 1, (kind, row)
print("CHAOS_SOAK_OK")
"""


def test_chaos_soak_closed_loop(subproc):
    out = subproc(SOAK_CODE, 16)
    assert "CHAOS_SOAK_OK" in out
