"""The escalation ladder of :class:`repro.dist.recovery.RecoveryController`
driven by scripted :class:`HealthReport` ticks: flap -> retry, kill ->
precompiled flip, out-of-class burst -> rebuild + hot-swap, corruption ->
redo (escalating to rebuild), node loss -> checkpoint + rescale (or a
loud stall without callbacks).  Plus the journal-replay audit and the
``sid-out-of-range`` verifier code both the journal gate and the traced
debug switch share.  Controller tests are host-only; the traced debug
guard runs a 4-device subprocess (direct ``run_with_devices``, fast
tier)."""
import numpy as np
import pytest

from conftest import run_with_devices
from repro.analysis.verify import check_schedule_id
from repro.dist.chaos import out_of_class_burst
from repro.dist.fault import NoScheduleError
from repro.dist.health import HealthReport, compile_link_probe
from repro.dist.recovery import (RecoveryController, RecoveryPolicy,
                                 replay_journal)
from repro.dist.steps import fault_runtime_for_mesh
from repro.launch.elastic import rescale_after_node_loss


@pytest.fixture(scope="module")
def rt():
    return fault_runtime_for_mesh((16, 1), ("data", "model"),
                                  dp_torus_shape=(4, 4))


def _report(plan, step, dead_edges=(), checksum_dev=0.0, straggler=False):
    """A HealthReport as the probe would produce it with the given
    canonical edges dead (both directions fail)."""
    dead = frozenset(dead_edges)
    from repro.core.graph import canon
    ok = np.array([canon(s, d) not in dead for s, d in plan.links])
    return HealthReport(step=step, links=plan.links, link_ok=ok,
                        checksum_dev=checksum_dev, straggler=straggler)


def _tree_edge(rt, j=0):
    return next(iter(sorted(rt.entries[0].sched.trees[j].tree)))


def test_flap_retries_then_journals_clean(rt):
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(rt)
    edge = _tree_edge(rt)
    dec = ctrl.observe(_report(plan, 0, {edge}))
    assert dec.action == "retry" and dec.stall and dec.backoff_s > 0
    assert ctrl.state == "suspect" and not ctrl.journal
    dec = ctrl.observe(_report(plan, 1))           # next probe clean
    assert dec.action == "none" and not dec.stall
    assert ctrl.state == "healthy"
    (e,) = ctrl.journal
    assert e.cause == "link-flap" and e.action == "retry"
    assert e.steps_degraded == 1
    assert ctrl.schedule_id == 0                   # no flip for a flap


def test_kill_confirms_then_flips_schedule(rt):
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(rt)
    edge = _tree_edge(rt)
    assert ctrl.observe(_report(plan, 0, {edge})).stall
    dec = ctrl.observe(_report(plan, 1, {edge}))   # outlives tolerance
    assert dec.action == "flip" and not dec.stall
    assert dec.detail["from_schedule"] == 0
    assert ctrl.schedule_id != 0
    assert not ctrl.runtime.entry.uses_link(frozenset({edge}))
    (e,) = ctrl.journal
    assert e.cause == "link-kill" and e.action == "flip"
    assert e.steps_degraded == 1 and e.mttr_s >= 0
    assert replay_journal(ctrl.journal) == (ctrl.generation,
                                            ctrl.schedule_id)


def test_burst_escalates_to_rebuild_and_hot_swap(rt):
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(
        rt, RecoveryPolicy(background_rebuild=False))
    burst = out_of_class_burst(rt, np.random.default_rng(0))
    assert ctrl.observe(_report(plan, 0, burst)).stall     # suspects
    dec = ctrl.observe(_report(plan, 1, burst))            # confirmed
    assert dec.action == "rebuild" and dec.stall           # repacking
    dec = ctrl.observe(_report(plan, 2, burst))
    assert dec.action == "hot-swap" and dec.runtime_changed
    assert ctrl.generation == 1
    assert ctrl.runtime is not rt and ctrl.runtime.k >= 1
    # the repack avoids every dead link
    assert not ctrl.runtime.entry.uses_link(frozenset(burst))
    (e,) = ctrl.journal
    assert e.cause == "link-burst" and e.action == "hot-swap"
    assert replay_journal(ctrl.journal) == (ctrl.generation,
                                            ctrl.schedule_id)


def test_corruption_redoes_then_escalates(rt):
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(
        rt, RecoveryPolicy(max_retries=2, background_rebuild=False))
    dec = ctrl.observe(_report(plan, 0, checksum_dev=0.5))
    assert dec.action == "retry" and dec.redo_step and not dec.stall
    assert ctrl.journal[-1].cause == "payload-corruption"
    # a clean tick resets the retry budget
    assert ctrl.observe(_report(plan, 1)).action == "none"
    for s in (2, 3):
        assert ctrl.observe(_report(plan, s, checksum_dev=0.5)).redo_step
    dec = ctrl.observe(_report(plan, 4, checksum_dev=0.5))
    assert dec.action == "rebuild" and dec.stall   # budget exhausted
    dec = ctrl.observe(_report(plan, 5))
    assert dec.action == "hot-swap" and dec.runtime_changed
    assert ctrl.journal[-1].cause == "payload-corruption"
    assert ctrl.journal[-1].action == "hot-swap"


def test_straggler_is_journaled_not_recovered(rt):
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(rt)
    dec = ctrl.observe(_report(plan, 0, straggler=True))
    assert dec.action == "none" and not dec.stall
    (e,) = ctrl.journal
    assert e.cause == "straggler" and e.action == "observe"
    assert ctrl.schedule_id == 0


def test_node_loss_without_rescale_stalls_loudly(rt):
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(rt)
    v = plan.links[0][0]
    dead = {e for s, d in plan.links if v in (s, d)
            for e in [tuple(sorted((s, d)))]}
    rep = _report(plan, 0, dead)
    assert v in rep.node_suspects()
    for s in range(3):                 # stalls forever, journals once
        dec = ctrl.observe(_report(plan, s, dead))
        assert dec.action == "rescale" and dec.stall
        assert ctrl.state == "stalled"
    (e,) = ctrl.journal
    assert e.cause == "node-loss" and e.action == "observe"
    assert "error" in e.detail


def test_node_loss_checkpoints_then_rescales(rt):
    plan = compile_link_probe(rt)
    calls = []

    def on_checkpoint():
        calls.append("ckpt")

    def on_rescale(event):
        calls.append("rescale")
        new_rt, _ = rescale_after_node_loss(rt, event)
        return new_rt

    ctrl = RecoveryController(rt, on_checkpoint=on_checkpoint,
                              on_rescale=on_rescale)
    v = plan.links[0][0]
    dead = {tuple(sorted((s, d))) for s, d in plan.links if v in (s, d)}
    dec = ctrl.observe(_report(plan, 0, dead))
    assert dec.action == "rescale" and dec.runtime_changed
    assert calls == ["ckpt", "rescale"]      # checkpoint BEFORE rescale
    assert ctrl.generation == 1
    assert ctrl.runtime.graph.n == rt.graph.n - 1
    (e,) = ctrl.journal
    assert e.cause == "node-loss" and e.action == "rescale"
    assert replay_journal(ctrl.journal) == (ctrl.generation,
                                            ctrl.schedule_id)


def test_journal_replays_full_scenario(rt):
    """flap -> kill -> burst in one session: the journal alone recovers
    the final (generation, schedule id) the live controller holds."""
    plan = compile_link_probe(rt)
    ctrl = RecoveryController(
        rt, RecoveryPolicy(background_rebuild=False))
    edge = _tree_edge(rt)
    ctrl.observe(_report(plan, 0, {edge}))
    ctrl.observe(_report(plan, 1))                      # flap clears
    ctrl.observe(_report(plan, 2, {edge}))
    ctrl.observe(_report(plan, 3, {edge}))              # kill -> flip
    burst = out_of_class_burst(rt, np.random.default_rng(1),
                               already_dead=frozenset({edge}))
    dead = set(burst) | {edge}
    ctrl.observe(_report(plan, 4, dead))
    ctrl.observe(_report(plan, 5, dead))                # rebuild
    ctrl.observe(_report(plan, 6, dead))                # hot-swap
    assert [e.cause for e in ctrl.journal] == [
        "link-flap", "link-kill", "link-burst"]
    assert replay_journal(ctrl.journal) == (ctrl.generation,
                                            ctrl.schedule_id)
    assert ctrl.generation == 1


def test_check_schedule_id_names_the_violation(rt):
    assert check_schedule_id(5, 0) is None
    assert check_schedule_id(5, 4) is None
    for bad in (-1, 5, 99):
        v = check_schedule_id(5, bad)
        assert v is not None and v.code == "sid-out-of-range"
        assert str(bad) in v.detail
    # the journal gate: a controller can never record a bogus flip
    ctrl = RecoveryController(rt)
    with pytest.raises(NoScheduleError):
        ctrl._journal(0, "link-kill", "flip", 0, len(rt.entries), 0, 0.0)


DEBUG_SID_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist  # installs compat shard_map
from repro.dist.steps import fault_runtime_for_mesh

rt = fault_runtime_for_mesh((4, 1), ('data', 'model'), dp_torus_shape=(2, 2))
mesh = jax.make_mesh((4, 1), ('data', 'model'))

def harness(sync):
    def body(xs, sid):
        return sync(xs.reshape(xs.shape[1:]), sid)[None]
    return jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P('data'), P()),
                                 out_specs=P('data'), axis_names={'data'},
                                 check_vma=False))

x = jnp.ones((4, 8), jnp.float32)
bad_sid = jnp.int32(len(rt.entries) + 3)

f = harness(rt.make_allreduce(debug=True))
ok = f(x, jnp.int32(0))
assert bool(jnp.isfinite(ok).all()) and jnp.allclose(ok, 4.0), ok
poisoned = f(x, bad_sid)      # traced guard: NaN-poison, not a wrong sum
assert bool(jnp.isnan(poisoned).all()), poisoned

g = harness(rt.make_allreduce())   # debug off: lax.switch clamps silently
clamped = g(x, bad_sid)
assert bool(jnp.isfinite(clamped).all()), clamped
print("DEBUG_SID_OK")
"""


def test_debug_switch_poisons_out_of_range_sid():
    """S2: with ``debug=True`` the traced twin of ``check_schedule_id``
    turns lax.switch's silent clamp into a NaN-poisoned result (plus a
    device print); the default path keeps the clamp semantics."""
    out = run_with_devices(DEBUG_SID_CODE, 4)
    assert "DEBUG_SID_OK" in out


def test_rescale_onto_same_fabric_reuses_cached_specs(rt):
    """Elastic spec-cache reuse (the no-retrace contract): two rescales
    landing on the SAME surviving fabric share every compiled entry spec
    object -- jitted executors keyed on the spec never recompile -- while
    history stays per-runtime."""
    from repro.core.fault import FailureEvent
    ev = FailureEvent(nodes=frozenset({3}))
    a, rel_a = rescale_after_node_loss(rt, ev)
    b, rel_b = rescale_after_node_loss(rt, ev)
    assert rel_a == rel_b
    assert b is not a                       # fresh runtime per event...
    assert b.entries is a.entries           # ...sharing the cached entries
    assert all(ea.spec is eb.spec
               for ea, eb in zip(a.entries, b.entries))
    assert a.history == b.history == rt.history + [("rescaled",
                                                    rt.graph.n - 1)]


def test_edst_spec_for_mesh_schedule_strategies_cached():
    """``edst_spec_for_mesh`` returns the identical object per
    (mesh, engine, schedule) across calls for EVERY strategy, and the
    strategies compile distinct specs (distinct cache keys)."""
    from repro.dist.steps import edst_spec_for_mesh
    args = ((16, 1), ("data", "model"))
    specs = {}
    for schedule in ("greedy", "search", "composed"):
        s1 = edst_spec_for_mesh(*args, dp_torus_shape=(4, 4),
                                engine="striped", schedule=schedule)
        s2 = edst_spec_for_mesh(*args, dp_torus_shape=(4, 4),
                                engine="striped", schedule=schedule)
        assert s1 is s2
        specs[schedule] = s1
    assert len({s.key for s in specs.values()}) == 3
    assert specs["composed"].key[-1] == "composed"
    assert specs["search"].key[-2:] == ("search", 0)
    assert len(specs["search"].waves) <= len(specs["greedy"].waves)
