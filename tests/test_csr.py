"""CSR adjacency / double-BFS center regression and the fused global-round
compiler's invariants (fast unit tier; the executor runs under shard_map in
tests/test_fused_allreduce_jax.py)."""
import numpy as np
import pytest

from repro.core import topologies as topo
from repro.core.collectives import (_best_root, _best_root_probe,
                                    allreduce_schedule,
                                    fused_spec_from_schedule, tree_schedule)
from repro.core.csr import CSRAdjacency, tree_center
from repro.core.edst_star import star_edsts
from repro.core.graph import Graph, tree_depth_levels
from repro.dist.tree_allreduce import spec_from_schedule

pytestmark = pytest.mark.unit


# ---------------------------------------------------------------------------
# CSR adjacency + BFS
# ---------------------------------------------------------------------------

def _ref_bfs(g: Graph, root: int):
    from collections import deque
    dist = [-1] * g.n
    dist[root] = 0
    dq = deque([root])
    adj = g.adj()
    while dq:
        u = dq.popleft()
        for w in adj[u]:
            if dist[w] < 0:
                dist[w] = dist[u] + 1
                dq.append(w)
    return dist


def test_csr_bfs_matches_reference_on_random_graphs():
    rng = np.random.RandomState(0)
    for trial in range(10):
        n = int(rng.randint(2, 40))
        edges = {tuple(sorted(e)) for e in
                 rng.randint(0, n, size=(2 * n, 2)) if e[0] != e[1]}
        g = Graph(n, edges)
        csr = g.csr()
        for root in range(0, n, max(1, n // 4)):
            assert csr.bfs_distances(root).tolist() == _ref_bfs(g, root)


def test_csr_from_edges_degrees():
    g = topo.device_topology((4, 4)).product()
    csr = CSRAdjacency.from_edges(g.n, g.edges)
    assert csr.degrees.tolist() == [g.degree(v) for v in range(g.n)]
    for v in range(g.n):
        assert sorted(csr.neighbors(v).tolist()) == sorted(g.adj()[v])


def test_diameter_still_exact_via_csr():
    assert topo.device_topology((4, 4)).product().diameter() == 4
    assert topo.slimfly(5).product().diameter() == 2


# ---------------------------------------------------------------------------
# double-BFS center == the historical O(n^2) probe (regression)
# ---------------------------------------------------------------------------

PAPER_FABRICS = (
    lambda: topo.device_topology((4, 4)),
    lambda: topo.device_topology((2, 8)),
    lambda: topo.device_topology((8, 8)),
    lambda: topo.slimfly(5),
    lambda: topo.polarstar(3, "qr", 5),
)


def test_tree_center_matches_probe_on_paper_edsts():
    """The CSR double-BFS root must be bit-identical to the old
    every-vertex probe (same vertex, same depth) on the EDSTs of the
    paper's factor/product graphs -- schedules must not shift."""
    for mk in PAPER_FABRICS:
        sp = mk()
        for tree in star_edsts(sp).trees:
            root_csr, depth_csr = tree_center(sp.n, tree)
            root_probe = _best_root_probe(sp.n, tree)
            assert root_csr == root_probe
            assert depth_csr == len(tree_depth_levels(tree, root_probe))
            assert _best_root(sp.n, tree) == root_probe


def test_tree_center_on_paths_and_stars():
    # path 0-1-...-7: center = 3 (first of the two middles), depth 4
    path = [(i, i + 1) for i in range(7)]
    assert tree_center(8, path) == (3, 4)
    # star around 5: center = 5, depth 1
    star = [(5, v) for v in range(5)]
    assert tree_center(6, star) == (5, 1)
    # singleton
    assert tree_center(1, []) == (0, 0)


# ---------------------------------------------------------------------------
# fused global-round compiler
# ---------------------------------------------------------------------------

def _sched_for(dims):
    sp = topo.device_topology(dims)
    return allreduce_schedule(sp.n, star_edsts(sp).trees)


@pytest.mark.parametrize("dims", [(4, 4), (2, 8), (2, 4, 4)])
def test_fused_waves_are_ppermute_legal_and_conserve_messages(dims):
    sched = _sched_for(dims)
    spec = fused_spec_from_schedule(sched, ("data",))
    for phase, rounds in (("reduce", spec.reduce_rounds),
                          ("bcast", spec.bcast_rounds)):
        sent = []
        for rnd in rounds:
            srcs = [s for s, _ in rnd.perm]
            dsts = [d for _, d in rnd.perm]
            assert len(set(srcs)) == len(srcs), "duplicate src in wave"
            assert len(set(dsts)) == len(dsts), "duplicate dst in wave"
            for s, d in rnd.perm:
                j = int(rnd.send_row[s])
                assert int(rnd.recv_row[d]) == j, "send/recv row mismatch"
                assert bool(rnd.recv_flag[d])
                sent.append((j, s, d))
        want = [m for msgs in sched.global_rounds(phase) for m in msgs]
        assert sorted(sent) == sorted(want), f"{phase} messages differ"


def test_fused_wave_count_beats_per_tree_rounds():
    """The fused program's collective count is depth-of-deepest-tree
    waves, strictly below the per-tree sum for k >= 2 fabrics."""
    sched = _sched_for((4, 4))
    assert sched.k >= 2
    spec = fused_spec_from_schedule(sched, ("data",))
    legacy = spec_from_schedule(sched, ("data",))
    legacy_rounds = sum(len(t.reduce_rounds) + len(t.bcast_rounds)
                        for t in legacy.trees)
    assert spec.num_collectives < legacy_rounds
    # k = 1: nothing to fuse, counts coincide
    sched1 = _sched_for((2, 8))
    assert sched1.k == 1
    spec1 = fused_spec_from_schedule(sched1, ("data",))
    legacy1 = spec_from_schedule(sched1, ("data",))
    assert spec1.num_collectives == sum(
        len(t.reduce_rounds) + len(t.bcast_rounds) for t in legacy1.trees)


def test_fused_spec_cache_returns_identical_objects():
    """Two independently built (but equal) schedules compile to the SAME
    spec object -- jit caches keyed on the static spec stay stable."""
    a = fused_spec_from_schedule(_sched_for((4, 4)), ("data",))
    b = fused_spec_from_schedule(_sched_for((4, 4)), ("data",))
    assert a is b
    assert a == b and hash(a) == hash(b)
    c = fused_spec_from_schedule(_sched_for((4, 4)), ("dp",))
    assert c is not a and c != a


def test_edst_spec_for_mesh_cached_across_arg_spellings():
    from repro.dist.steps import edst_spec_for_mesh
    s1 = edst_spec_for_mesh((16, 1), ("data", "model"), dp_torus_shape=(4, 4))
    s2 = edst_spec_for_mesh([16, 1], ["data", "model"], dp_torus_shape=[4, 4])
    assert s1 is s2
    assert s1.k == 2
