"""Detection-layer units for :mod:`repro.dist.health`: probe-plan
construction (links from routing tables, ppermute-legal waves, slot
tables), checksum sensitivity, straggler baselines, and the report
classifications the recovery controller consumes.  All host-side -- the
shard_map execution of the probe is exercised by the fast subprocess
test in test_recovery.py and the chaos soak (test_chaos_soak_jax.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import canon
from repro.dist.health import (HealthReport, StragglerDetector,
                               _pack_probe_waves, compile_link_probe,
                               payload_checksum, program_links,
                               runtime_links)
from repro.dist.steps import fault_runtime_for_mesh


@pytest.fixture(scope="module")
def rt():
    return fault_runtime_for_mesh((16, 1), ("data", "model"),
                                  dp_torus_shape=(4, 4))


def test_program_links_read_from_routing_tables(rt):
    """The probe set for one compiled program is exactly the directed
    links its waves move payload over: every full-class tree edge shows
    up (in some direction), and every link is a sane vertex pair."""
    links = program_links(rt.entries[0].spec)
    n = rt.graph.n
    assert links == tuple(sorted(links))
    for s, d in links:
        assert 0 <= s < n and 0 <= d < n and s != d
    covered = {canon(s, d) for s, d in links}
    for ts in rt.entries[0].sched.trees:
        assert ts.tree <= covered


def test_runtime_links_union_covers_every_class(rt):
    union = set(runtime_links(rt))
    for e in rt.entries:
        if e.sched is not None:
            assert set(program_links(e.spec)) <= union


def test_pack_probe_waves_are_ppermute_legal(rt):
    links = runtime_links(rt)
    waves = _pack_probe_waves(links)
    seen = []
    for wave in waves:
        srcs = [s for s, _ in wave]
        dsts = [d for _, d in wave]
        assert len(set(srcs)) == len(srcs), "duplicate source in a wave"
        assert len(set(dsts)) == len(dsts), "duplicate dest in a wave"
        seen.extend(wave)
    assert sorted(seen) == sorted(links)


def test_compile_link_probe_slot_tables(rt):
    plan = compile_link_probe(rt)
    assert plan.num_links == len(plan.links)
    slot = {l: i for i, l in enumerate(plan.links)}
    for w, wave in enumerate(plan.waves):
        src, slt = plan.recv_src[w], plan.recv_slot[w]
        receivers = {d for _, d in wave}
        for s, d in wave:
            assert src[d] == s
            assert slt[d] == slot[(s, d)]
        for v in range(plan.n):
            if v not in receivers:
                assert src[v] == -1 and slt[v] == -1


def test_payload_checksum_moves_on_any_single_flip():
    x = jnp.asarray(np.random.RandomState(0).randn(7, 11), jnp.float32)
    base = payload_checksum(x)
    for idx in ((0, 0), (3, 5), (6, 10)):
        y = x.at[idx].add(1e-3)
        assert float(jnp.max(jnp.abs(payload_checksum(y) - base))) > 0


def test_straggler_detector_flags_and_keeps_baseline():
    det = StragglerDetector(window=8, ratio=2.5, min_samples=3)
    for _ in range(5):
        assert not det.observe(0.1)
    assert det.observe(0.5)          # 5x the median
    # flagged samples stay out of the baseline: a sustained straggler
    # keeps flagging instead of normalizing itself
    assert det.observe(0.5)
    assert abs(det.baseline() - 0.1) < 1e-9
    assert not det.observe(0.11)


def test_straggler_detector_warms_up_quietly():
    det = StragglerDetector(min_samples=5)
    assert not det.observe(10.0)     # no baseline yet: never flags


def _report(plan, dead_directed=(), step=0):
    ok = np.array([l not in dead_directed for l in plan.links])
    return HealthReport(step=step, links=plan.links, link_ok=ok)


def test_report_classifies_edges_and_nodes(rt):
    plan = compile_link_probe(rt)
    s, d = plan.links[0]
    # one dead direction is enough to fail the canonical edge
    rep = _report(plan, {(s, d)})
    assert not rep.all_links_ok
    assert rep.failed_edges() == frozenset({canon(s, d)})
    assert rep.node_suspects() == frozenset()
    # every probed link of a vertex dead = the node-loss signature
    v = plan.links[0][0]
    dead = {l for l in plan.links if v in l}
    rep = _report(plan, dead)
    assert v in rep.node_suspects()
    healthy = _report(plan)
    assert healthy.all_links_ok and healthy.checksum_ok


def test_report_checksum_tolerance():
    rep = HealthReport(step=0, links=(), link_ok=np.ones(0, bool),
                       checksum_dev=5e-4, checksum_tol=1e-3)
    assert rep.checksum_ok
    rep = HealthReport(step=0, links=(), link_ok=np.ones(0, bool),
                       checksum_dev=5e-3, checksum_tol=1e-3)
    assert not rep.checksum_ok
