"""Per-kernel interpret-mode validation: shape/dtype sweeps vs jnp oracles."""
import jax
import jax.numpy as jnp
import pytest

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.PRNGKey(k), shape, jnp.float32).astype(dtype)


# -- flash attention ----------------------------------------------------------

FLASH_CASES = [
    # b, s, h, kv, d, qb, kb, causal, window
    (2, 128, 8, 2, 64, 32, 64, True, None),
    (1, 100, 4, 4, 32, 32, 32, True, None),
    (2, 256, 8, 1, 128, 64, 128, True, 48),
    (1, 128, 2, 2, 64, 128, 128, False, None),
    (1, 64, 4, 2, 128, 16, 16, True, None),
]


@pytest.mark.parametrize("b,s,h,kv,d,qb,kb,causal,window", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, s, h, kv, d, qb, kb, causal, window, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q, k, v = rand((b, s, h, d), dtype, 1), rand((b, s, kv, d), dtype, 2), \
        rand((b, s, kv, d), dtype, 3)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=qb, kv_block=kb, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


# -- wkv6 ---------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,n,c", [(2, 100, 3, 16, 32), (1, 64, 2, 64, 64),
                                       (2, 33, 4, 8, 16)])
def test_wkv6_kernel_vs_naive(b, t, h, n, c):
    from repro.kernels.wkv6.kernel import wkv6
    from repro.models.rwkv6 import wkv6_step
    r, k, v = rand((b, t, h, n), k=1), rand((b, t, h, n), k=2), \
        rand((b, t, h, n), k=3)
    logw = -jnp.exp(rand((b, t, h, n), k=4) * 0.5 - 4.0)
    u = rand((h, n), k=5) * 0.5
    out, sfin = wkv6(r, k, v, logw, u, chunk=c, interpret=True)
    s = jnp.zeros((b, h, n, n))
    outs = []
    for i in range(t):
        o, s = wkv6_step(r[:, i], k[:, i], v[:, i], logw[:, i], u, s)
        outs.append(o)
    ref = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-4
    assert float(jnp.max(jnp.abs(sfin - s))) < 2e-4


def test_wkv6_jnp_chunked_vs_naive():
    from repro.models.rwkv6 import wkv6_chunked, wkv6_step
    b, t, h, n = 2, 53, 2, 8
    r, k, v = rand((b, t, h, n), k=1), rand((b, t, h, n), k=2), \
        rand((b, t, h, n), k=3)
    logw = -jnp.exp(rand((b, t, h, n), k=4) * 0.5 - 4.0)
    u = rand((h, n), k=5) * 0.5
    out, _ = wkv6_chunked(r, k, v, logw, u, chunk=16)
    s = jnp.zeros((b, h, n, n))
    ref = []
    for i in range(t):
        o, s = wkv6_step(r[:, i], k[:, i], v[:, i], logw[:, i], u, s)
        ref.append(o)
    assert float(jnp.max(jnp.abs(out - jnp.stack(ref, 1)))) < 2e-4


# -- rglru --------------------------------------------------------------------

@pytest.mark.parametrize("b,t,w,c,wt", [(2, 100, 48, 32, 16),
                                        (1, 64, 128, 64, 128),
                                        (3, 17, 8, 8, 8)])
def test_rglru_kernel(b, t, w, c, wt):
    from repro.kernels.rglru.kernel import rglru_scan
    from repro.kernels.rglru.ref import rglru_ref
    a = jax.nn.sigmoid(rand((b, t, w), k=1))
    bx = rand((b, t, w), k=2)
    h0 = rand((b, w), k=3)
    o1, hl1 = rglru_scan(a, bx, h0, chunk=c, width_tile=wt, interpret=True)
    o2, hl2 = rglru_ref(a, bx, h0)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-4
    assert float(jnp.max(jnp.abs(hl1 - hl2))) < 1e-4


def test_rglru_scan_matches_sequential():
    """The associative-scan reference equals the sequential recurrence."""
    from repro.models.rglru import rg_lru_scan
    b, t, w = 2, 29, 5
    a = jax.nn.sigmoid(rand((b, t, w), k=1))
    bx = rand((b, t, w), k=2)
    h0 = rand((b, w), k=3)
    hs = rg_lru_scan(a, bx, h0)
    h = h0
    for i in range(t):
        h = a[:, i] * h + bx[:, i]
        assert jnp.allclose(hs[:, i], h, atol=1e-5), i


# -- tree_combine -------------------------------------------------------------

@pytest.mark.parametrize("nch,l,tile", [(3, 1000, 256), (1, 64, 64), (5, 17, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_combine_kernel(nch, l, tile, dtype):
    from repro.kernels.tree_combine.kernel import tree_combine
    from repro.kernels.tree_combine.ref import tree_combine_ref
    recv = rand((nch, l), dtype, 1)
    part = rand((l,), dtype, 2)
    out = tree_combine(recv, part, tile=tile, interpret=True)
    ref = tree_combine_ref(recv, part)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


# -- int8 wire codec ----------------------------------------------------------

@pytest.mark.parametrize("l", [64, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_q8_wire_kernels_match_refs(l, dtype):
    from repro.kernels.tree_combine.kernel import (q8_combine_wire,
                                                   q8_pack_wire,
                                                   q8_unpack_wire)
    from repro.kernels.tree_combine.ref import (q8_combine_ref, q8_pack_ref,
                                                q8_scale, q8_unpack_ref)
    x = rand((l,), dtype, 1) * 3.3
    s = q8_scale(x)
    wire_k = q8_pack_wire(x, s, interpret=True)
    wire_r = q8_pack_ref(x, s)
    assert wire_k.dtype == jnp.int8 and wire_k.shape == (l + 4,)
    assert (jnp.asarray(wire_k) == jnp.asarray(wire_r)).all()

    part = rand((l,), jnp.float32, 2)
    out_k = q8_combine_wire(wire_k, part, interpret=True)
    assert float(jnp.max(jnp.abs(out_k - q8_combine_ref(wire_r, part)))) < 1e-6

    dec_k = q8_unpack_wire(wire_k, jnp.float32, interpret=True)
    dec_r = q8_unpack_ref(wire_r, jnp.float32)
    assert float(jnp.max(jnp.abs(dec_k - dec_r))) < 1e-6
    # quantization round-trip error bounded by half a step
    assert float(jnp.max(jnp.abs(dec_r - x.astype(jnp.float32)))) \
        <= float(s) * 0.51


def test_q8_row_batched_codec_roundtrip():
    from repro.kernels.tree_combine.ref import (q8_pack_ref, q8_pack_rows_ref,
                                                q8_scale, q8_unpack_rows_ref)
    x = rand((3, 257), jnp.float32, 5) * 2.1
    wires = q8_pack_rows_ref(x)
    assert wires.shape == (3, 261) and wires.dtype == jnp.int8
    # row-batched pack equals the per-row pack
    for j in range(3):
        assert (jnp.asarray(wires[j])
                == jnp.asarray(q8_pack_ref(x[j], q8_scale(x[j])))).all()
    dec = q8_unpack_rows_ref(wires, jnp.float32)
    scales = jnp.max(jnp.abs(x), axis=1) / 127.0
    assert float(jnp.max(jnp.abs(dec - x) / scales[:, None])) <= 0.51


def test_q8_ops_dispatch_and_zero_wire():
    from repro.kernels.tree_combine import ops
    x = rand((100,), jnp.float32, 3)
    w = ops.q8_pack(x)
    assert float(jnp.max(jnp.abs(ops.q8_unpack(w) - x))) < 0.05
    # an all-zero wire (what ppermute hands non-destinations) decodes to
    # exact zeros: the zero-bit scale annihilates the payload
    z = jnp.zeros_like(w)
    assert (jnp.asarray(ops.q8_unpack(z)) == 0).all()
    assert jnp.allclose(ops.q8_combine(z, x), x)


# -- blockwise jnp sdpa (the model's CPU path) ---------------------------------

@pytest.mark.parametrize("mode", ["causal", "full"])
@pytest.mark.parametrize("qb,kb", [(32, 16), (16, 32), (7, 13)])
def test_model_sdpa_blockwise(mode, qb, kb):
    from repro.models.layers import AttnCfg, sdpa, sdpa_reference
    cfg = AttnCfg(d_model=64, n_heads=8, n_kv=2, head_dim=16)
    pos = jnp.arange(100, dtype=jnp.int32)
    q, k, v = rand((2, 100, 8, 16), k=1), rand((2, 100, 2, 16), k=2), \
        rand((2, 100, 2, 16), k=3)
    o1 = sdpa(q, k, v, pos, pos, cfg, mode, q_block=qb, kv_block=kb)
    o2 = sdpa_reference(q, k, v, pos, pos, cfg, mode)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5
