"""The anytime wave-schedule search (:mod:`repro.core.schedule_search`):
seeded deterministic, legality-preserving (every candidate re-verified),
and never worse than the greedy incumbent -- with strict wins (fewer
waves or lower simulated makespan) on the asymmetric paper fabrics, the
acceptance bar ``benchmarks/compile_diff.py`` gates in CI.  Plus the
``roots="search"`` hook property: searched roots are never deeper than
the ``_best_root`` center, which the ``_best_root_probe`` oracle proves
depth-optimal."""
import pytest

from repro.analysis.verify import _topology_case, verify_spec
from repro.core import schedule_search as ss
from repro.core.collectives import (CostModel, _best_root,
                                    _best_root_probe, allreduce_schedule,
                                    fused_spec_from_schedule,
                                    pipelined_spec_from_schedule,
                                    striped_spec_from_schedule,
                                    tree_schedule)
from repro.core.edst_star import star_edsts
from repro.core.graph import tree_depth_levels

AXES = ("data",)
LABELS = ("torus4x4", "hyperx4x4", "slimfly_q5", "polarstar_er3_qr5",
          "bundlefly_q4_a5")

_SCHEDS: dict = {}


def _sched(label):
    if label not in _SCHEDS:
        sp, es = _topology_case(label)
        res = star_edsts(sp, Es=es) if es is not None else star_edsts(sp)
        _SCHEDS[label] = allreduce_schedule(sp.product().n, res.trees)
    return _SCHEDS[label]


def _depth(tree, root):
    return len(tree_depth_levels(frozenset(tree), root))


def _fused_rounds(spec):
    return len(spec.reduce_rounds) + len(spec.bcast_rounds)


@pytest.mark.parametrize("label", LABELS)
def test_search_never_worse_than_greedy(label):
    """The search accepts only strict improvements over the greedy
    incumbent, so on EVERY paper fabric and engine the searched program
    has at most the greedy wave count (and at most its makespan where
    waves tie)."""
    sched = _sched(label)
    cm = CostModel()
    nbytes = ss.SCORE_NBYTES
    gp = pipelined_spec_from_schedule(sched, AXES, verify=False)
    sp_ = ss.search_pipelined_spec(sched, AXES, verify=False)
    assert len(sp_.waves) <= len(gp.waves)
    gs = striped_spec_from_schedule(sched, AXES, verify=False)
    st = ss.search_striped_spec(sched, AXES, verify=False)
    assert len(st.waves) <= len(gs.waves)
    if len(st.waves) == len(gs.waves):
        assert cm.striped_allreduce(nbytes, st) \
            <= cm.striped_allreduce(nbytes, gs) + 1e-12
    gf = fused_spec_from_schedule(sched, AXES, verify=False)
    sf = ss.search_fused_spec(sched, AXES, verify=False)
    assert _fused_rounds(sf) <= _fused_rounds(gf)


@pytest.mark.parametrize("label", LABELS)
def test_searched_specs_verify_clean(label):
    sched = _sched(label)
    for spec in (ss.search_pipelined_spec(sched, AXES, verify=False),
                 ss.search_striped_spec(sched, AXES, verify=False),
                 ss.search_fused_spec(sched, AXES, verify=False)):
        rep = verify_spec(spec, level="full")
        assert rep.ok, rep.summary()


def test_search_strict_win_on_asymmetric_fabric():
    """The acceptance bar: on at least one asymmetric paper fabric the
    search strictly beats greedy -- fewer waves, or equal waves at a
    strictly lower simulated makespan (slimfly_q5 yields both a
    pipelined and a striped wave win)."""
    sched = _sched("slimfly_q5")
    gp = pipelined_spec_from_schedule(sched, AXES, verify=False)
    sp_ = ss.search_pipelined_spec(sched, AXES, verify=False)
    gs = striped_spec_from_schedule(sched, AXES, verify=False)
    st = ss.search_striped_spec(sched, AXES, verify=False)
    cm = CostModel()
    won = (len(sp_.waves) < len(gp.waves)
           or len(st.waves) < len(gs.waves)
           or cm.striped_allreduce(ss.SCORE_NBYTES, st)
           < cm.striped_allreduce(ss.SCORE_NBYTES, gs))
    assert won


def test_search_is_seeded_deterministic():
    """Same seed -> the identical cached spec object; and after a cold
    cache, the same wave structure (the search is a pure function of
    (schedule, axes, seed)).  A different seed may explore differently
    but must still be legal and never worse."""
    sched = _sched("torus4x4")
    a = ss.search_striped_spec(sched, AXES, verify=False, seed=0)
    b = ss.search_striped_spec(sched, AXES, verify=False, seed=0)
    assert a is b
    saved = dict(ss._SEARCH_CACHE)
    ss._SEARCH_CACHE.clear()
    try:
        c = ss.search_striped_spec(sched, AXES, verify=False, seed=0)
    finally:
        ss._SEARCH_CACHE.clear()
        ss._SEARCH_CACHE.update(saved)
    assert c.key == a.key
    assert [w.perm for w in c.waves] == [w.perm for w in a.waves]
    d = ss.search_striped_spec(sched, AXES, verify=False, seed=3)
    assert d.key != a.key
    gs = striped_spec_from_schedule(sched, AXES, verify=False)
    assert len(d.waves) <= len(gs.waves)


@pytest.mark.parametrize("label", ("torus4x4", "slimfly_q5",
                                   "polarstar_er3_qr5"))
def test_search_roots_property(label):
    """search_roots never returns a root deeper than the _best_root
    center, and the center is depth-optimal per the _best_root_probe
    O(n^2) oracle -- so searched depths equal the optimal depths."""
    sched = _sched(label)
    n = sched.n
    trees = [ts.tree for ts in sched.trees]
    searched = ss.search_roots(n, trees)
    for tree, r in zip(trees, searched):
        center_d = _depth(tree, _best_root(n, tree))
        probe_d = _depth(tree, _best_root_probe(n, tree))
        assert probe_d == center_d          # the center IS optimal
        assert _depth(tree, r) <= center_d  # search never regresses


def test_allreduce_schedule_roots_search_hook():
    """``allreduce_schedule(..., roots="search")`` builds a legal
    schedule no deeper than the default, and other strings raise."""
    sched = _sched("slimfly_q5")
    n = sched.n
    trees = [ts.tree for ts in sched.trees]
    searched = allreduce_schedule(n, trees, roots="search")
    assert searched.depth <= sched.depth
    assert [frozenset(ts.tree) for ts in searched.trees] \
        == [frozenset(ts.tree) for ts in sched.trees]
    with pytest.raises(ValueError, match="roots"):
        allreduce_schedule(n, trees, roots="random")


def test_schedule_kwarg_routes_to_search():
    """``striped_spec_from_schedule(..., schedule="search")`` returns the
    searched spec (seed-tagged key), identical object on repeat."""
    sched = _sched("torus4x4")
    a = striped_spec_from_schedule(sched, AXES, schedule="search")
    assert a is ss.search_striped_spec(sched, AXES)
    assert a.key[-2:] == ("search", 0)
    b = striped_spec_from_schedule(sched, AXES, schedule="search", seed=5)
    assert b.key[-2:] == ("search", 5)
