"""ZeRO-1 differential suite: the sharded train step against dense
``psum_dp``.

The claim under test is the module docstring of
:mod:`repro.optim.sharded`: reduce-scatter grads -> owner-stripe AdamW ->
allgather params reproduces the dense optimizer exactly (up to float
reassociation of the global norm).  Each test spawns a 16-fake-device
subprocess (4x4 torus DP fabric) and trains both steps side by side on
the same quadratic toy problem, asserting per-step loss / grad-norm
agreement:

  * fast tier -- f32 wires through the *fault runtime* path, including a
    mid-run link kill: flip the traced schedule id to the degraded
    class, re-shard ``mu`` / ``nu`` with
    :meth:`FaultAwareAllreduce.reshard_owned`, keep training, and assert
    the jit cache did not grow (the flip is retrace-free);
  * fast tier -- the wave-count acceptance: the compiled zero1 step's
    HLO carries ``rs_waves + ag_waves`` ppermutes, strictly fewer than
    the composed striped allreduce step's, checked with
    ``hlo_contract_for(phase=...)`` / ``lint_hlo``;
  * slow tier -- the int8 gradient wire (``codec="full"``; params
    allgather stays full precision by design) at loosened tolerance,
    and an ``m < n`` payload (7 elements on 16 devices) where most
    stripe rows are padding.

The fast tests call :func:`conftest.run_with_devices` directly (no
``subproc`` fixture) so they stay in the ``-m "not slow"`` CI tier.
"""
import pytest

from conftest import run_with_devices

# Toy problem + side-by-side runner shared by every subprocess: params
# {"w": shapes[0], "b": shapes[1]} give an uneven flat payload (53 for
# the default (6,8)+(5,): not a multiple of n=16, so stripe rows are
# ragged), and the quadratic loss has dense, well-scaled gradients.
_COMMON = r'''
import jax, jax.numpy as jnp, numpy as np
from repro.dist.steps import (make_train_step, edst_spec_for_mesh,
                              fault_runtime_for_mesh, dp_size)
from repro.optim import AdamW, cosine_schedule, ShardedAdamW

class QuadAPI:
    def loss_fn(self, params, batch):
        pred = jnp.einsum("bij,ij->b", batch["x"], params["w"]) \
            + batch["x2"] @ params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

def make_problem(shapes=((6, 8), (5,))):
    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.randn(*shapes[0]), jnp.float32) * 0.3,
              "b": jnp.asarray(rng.randn(*shapes[1]), jnp.float32) * 0.3}
    B = 32
    batch = {"x": jnp.asarray(rng.randn(B, *shapes[0]), jnp.float32),
             "x2": jnp.asarray(rng.randn(B, *shapes[1]), jnp.float32),
             "y": jnp.asarray(rng.randn(B), jnp.float32)}
    return QuadAPI(), params, batch

MESH_ARGS = ((16, 1), ("data", "model"))
TORUS = (4, 4)

def side_by_side(shapes=((6, 8), (5,)), steps=5, rtol_loss=1e-5,
                 rtol_g=1e-4, quantize=False, codec=None):
    """Train psum_dp and zero1 side by side; assert per-step agreement."""
    api, params, batch = make_problem(shapes)
    mesh = jax.make_mesh(*MESH_ARGS)
    opt = AdamW(cosine_schedule(1e-2, 2, 20))
    spec = edst_spec_for_mesh(*MESH_ARGS, TORUS, engine="striped")
    ref = jax.jit(make_train_step(api, opt, mesh, mode="psum_dp"))
    z = jax.jit(make_train_step(api, opt, mesh, mode="edst", zero1=True,
                                engine="striped", dp_torus_shape=TORUS,
                                quantize=quantize, codec=codec))
    zstate = ShardedAdamW(opt).init_for(params, spec, dp_size(mesh))
    rstate = opt.init(params)
    rp = zp = params
    descended = []
    for s in range(steps):
        rp, rstate, rm = ref(rp, rstate, batch)
        zp, zstate, zm = z(zp, zstate, batch)
        rl, zl = float(rm["loss"]), float(zm["loss"])
        rg, zg = float(rm["grad_norm"]), float(zm["grad_norm"])
        assert abs(rl - zl) <= rtol_loss * abs(rl), (s, rl, zl)
        assert abs(rg - zg) <= rtol_g * max(rg, 1e-9), (s, rg, zg)
        descended.append(zl)
    assert descended[-1] < descended[0], descended
'''


def test_zero1_matches_psum_dp_under_link_kill():
    """f32 differential through the fault runtime: 3 healthy steps, a
    link kill (flip to the degraded class + re-shard mu/nu), 3 more
    steps -- loss/gnorm track psum_dp throughout and the schedule-id
    flip compiles nothing new."""
    run_with_devices(_COMMON + r'''
from repro.core.fault import FailureEvent

api, params, batch = make_problem()
mesh = jax.make_mesh(*MESH_ARGS)
opt = AdamW(cosine_schedule(1e-2, 2, 20))
rt = fault_runtime_for_mesh(*MESH_ARGS, TORUS, engine="striped")
ref = jax.jit(make_train_step(api, opt, mesh, mode="psum_dp"))
z = jax.jit(make_train_step(api, opt, mesh, mode="edst", zero1=True,
                            fault_runtime=rt))
m = 53
zstate = ShardedAdamW(opt).init_for(params, rt, dp_size(mesh))
rstate = opt.init(params)
rp = zp = params
sid = jnp.int32(0)

def check(rm, zm, s):
    rl, zl = float(rm["loss"]), float(zm["loss"])
    rg, zg = float(rm["grad_norm"]), float(zm["grad_norm"])
    assert abs(rl - zl) <= 1e-5 * abs(rl), (s, rl, zl)
    assert abs(rg - zg) <= 1e-4 * max(rg, 1e-9), (s, rg, zg)

for s in range(3):
    rp, rstate, rm = ref(rp, rstate, batch)
    zp, zstate, zm = z(zp, zstate, batch, sid)
    check(rm, zm, s)
cache_before = z._cache_size()

# kill a link used by tree 0 of the full schedule -> degraded class
dead = next(iter(rt.entries[0].sched.trees[0].tree))
rt2 = rt.on_failure(FailureEvent(links=frozenset({dead})),
                    prefer="degraded")
assert rt2.active != rt.active
zstate = type(zstate)(zstate.step,
                      rt.reshard_owned(zstate.mu, 0, rt2.active, m),
                      rt.reshard_owned(zstate.nu, 0, rt2.active, m))
sid = jnp.int32(rt2.active)

for s in range(3, 6):
    rp, rstate, rm = ref(rp, rstate, batch)
    zp, zstate, zm = z(zp, zstate, batch, sid)
    check(rm, zm, s)
assert z._cache_size() == cache_before, (z._cache_size(), cache_before)
print("ZERO1 FAULT DIFF PASS")
''', 16)


def test_zero1_wave_count_contract():
    """The compiled zero1 step issues strictly fewer ppermute waves than
    the composed striped-allreduce step on the torus4x4 k=2 fabric:
    rs_waves + ag_waves < len(waves), asserted against the actual HLO
    with the phase-aware contract."""
    run_with_devices(_COMMON + r'''
from repro.analysis.verify import hlo_contract_for
from repro.analysis.hlo import lint_hlo

api, params, batch = make_problem()
mesh = jax.make_mesh(*MESH_ARGS)
opt = AdamW(cosine_schedule(1e-2, 2, 20))
spec = edst_spec_for_mesh(*MESH_ARGS, TORUS, engine="striped")
z = make_train_step(api, opt, mesh, mode="edst", zero1=True,
                    engine="striped", dp_torus_shape=TORUS)
s = make_train_step(api, opt, mesh, mode="edst",
                    engine="striped", dp_torus_shape=TORUS)
m = 53
zst = ShardedAdamW(opt).init_for(params, spec, dp_size(mesh))
sst = opt.init(params)
ztxt = jax.jit(z).lower(params, zst, batch).compile().as_text()
stxt = jax.jit(s).lower(params, sst, batch).compile().as_text()
zc = hlo_contract_for(spec, m=m, phase="zero1")
sc = hlo_contract_for(spec, m=m, phase="composed")
assert lint_hlo(ztxt, zc) == [], lint_hlo(ztxt, zc)
assert lint_hlo(stxt, sc) == [], lint_hlo(stxt, sc)
assert zc.ppermutes < sc.ppermutes, (zc.ppermutes, sc.ppermutes)
print("WAVES", zc.ppermutes, "<", sc.ppermutes)
''', 16)


def test_zero1_q8_wire(subproc):
    """int8 gradient wire (codec="full"): the RS waves quantize, the
    params allgather stays f32, and the run still tracks psum_dp at the
    quantization-noise tolerance while descending."""
    subproc(_COMMON + r'''
side_by_side(quantize=True, codec="full", rtol_loss=1e-3, rtol_g=1e-2)
print("ZERO1 Q8 PASS")
''', 16)


def test_zero1_payload_smaller_than_fabric(subproc):
    """m = 7 < n = 16: most owner stripes are empty padding and whole
    waves drop out of the bound program; the differential claim must
    hold unchanged."""
    subproc(_COMMON + r'''
side_by_side(shapes=((2, 2), (3,)))
print("ZERO1 SMALL PASS")
''', 16)
