"""The paper's star-product EDST constructions: correctness + maximality,
including hypothesis property tests over random star products."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import factor_graphs as fg
from repro.core import topologies as topo
from repro.core.edst_rt import max_edsts
from repro.core.edst_star import (maximal_edsts, one_sided_edsts,
                                  property_461_edsts, star_edsts,
                                  universal_edsts)
from repro.core.factor_edsts import edsts_for
from repro.core.graph import Graph
from repro.core.star import cartesian, random_star
from repro.core.topologies import edst_set_for


# -- theorem-by-theorem -------------------------------------------------------

def test_universal_construction_thm_431():
    """t1 + t2 - 2 trees with no conditions (random bijections)."""
    sp = random_star(fg.complete(6), fg.complete(5), seed=1)
    es, en = edsts_for(sp.gs), edsts_for(sp.gn)
    res = universal_edsts(sp, es, en)
    assert res.count == es.t + en.t - 2


def test_maximal_construction_thm_451():
    """t1 + t2 trees when r1 >= t1 and r2 >= t2."""
    sp = random_star(fg.complete(5), fg.cycle(5), seed=2)
    res = maximal_edsts(sp, edsts_for(sp.gs), edsts_for(sp.gn))
    assert res.count == res.t1 + res.t2
    assert res.maximal  # = floor(E/(V-1)) here


def test_one_sided_thm_459():
    """t1 + t2 - 1 when exactly one factor has r >= t."""
    # ER_3 has r=0 (tight), paley(5) has r=t=1
    sp = topo.polarstar(3, "qr", 5)
    res = one_sided_edsts(sp, edsts_for(sp.gs), edsts_for(sp.gn))
    assert res.count == res.t1 + res.t2 - 1
    assert res.maximal


def test_property_461_thm_462():
    """Cartesian products always satisfy Property 4.6.1."""
    sp = cartesian(fg.complete(4), fg.complete(4))
    res = property_461_edsts(sp, edsts_for(sp.gs), edsts_for(sp.gn))
    assert res.count == res.t1 + res.t2 - 1 == 3
    assert res.maximal


def test_property_461_fails_on_generic_star():
    sp = random_star(fg.complete(4), fg.complete(4), seed=7)
    with pytest.raises(ValueError):
        property_461_edsts(sp, edsts_for(sp.gs), edsts_for(sp.gn))


# -- Table 3 rows -------------------------------------------------------------

TABLE3 = [
    # (builder, expected trees, maximal?)
    (lambda: topo.slimfly(5), 3, True),    # q=4k+1, k=1 -> 3k
    (lambda: topo.slimfly(4), 3, True),    # q=4k,   k=1 -> 3k
    (lambda: topo.slimfly(7), 5, True),    # q=4k-1, k=2 -> 3k-1
    (lambda: topo.polarstar(2, "qr", 5), 2, True),   # floor(q/2)+k
    (lambda: topo.polarstar(3, "qr", 5), 2, True),
    (lambda: topo.polarstar(2, "iq", 4), 3, True),   # floor((q+d)/2)
    (lambda: topo.polarstar(3, "iq", 4), 3, True),
]


@pytest.mark.parametrize("builder,expected,maximal", TABLE3)
def test_table3_networks(builder, expected, maximal):
    res = star_edsts(builder())
    assert res.count == expected
    assert res.maximal == maximal


def test_bundlefly_recursive_maximality():
    """Sec 4.1: recursive star construction keeps BundleFly maximal; the
    universal solution would lose 2 trees per level."""
    sp = topo.bundlefly(4, 5)
    hq_set = edst_set_for(topo.slimfly(4))
    res = star_edsts(sp, Es=hq_set)
    assert res.count == 4 and res.maximal
    uni = universal_edsts(sp, hq_set, edsts_for(sp.gn))
    assert uni.count == res.count - 2


# -- device fabrics -----------------------------------------------------------

@pytest.mark.parametrize("shape,expected", [
    # upper bound floor(E/(V-1)): a (2,n) "torus" has E=3n, V=2n -> 1 tree
    ((4, 4), 2), ((16, 16), 2), ((2, 16, 16), 2), ((2, 8), 1), ((8, 8), 2)])
def test_device_topology_edsts(shape, expected):
    sp = topo.device_topology(shape)
    res = star_edsts(sp)
    assert res.count == expected
    assert res.maximal


def test_device_topology_row_major_ids():
    sp = topo.device_topology((2, 4))
    g = sp.product()
    # vertex (i, j) = i*4 + j; ring edges along j, path edge along i
    assert g.has_edge(0, 1) and g.has_edge(1, 2) and g.has_edge(3, 0)
    assert g.has_edge(0, 4) and g.has_edge(3, 7)


# -- property-based: random star products --------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    ns=st.integers(4, 7), nn=st.integers(4, 7),
    seed=st.integers(0, 10_000),
    fam_s=st.sampled_from(["complete", "cycle", "bipartite"]),
    fam_n=st.sampled_from(["complete", "cycle", "bipartite"]),
)
def test_star_edsts_always_valid(ns, nn, seed, fam_s, fam_n):
    """Invariant: for ANY star product of small factor graphs, the auto
    dispatcher returns pairwise edge-disjoint spanning trees, at least
    max(1, t1+t2-2) of them, never exceeding the combinatorial bound."""
    def mk(fam, n):
        if fam == "complete":
            return fg.complete(n)
        if fam == "cycle":
            return fg.cycle(max(n, 3))
        return fg.complete_bipartite(max(n // 2, 2))

    gs, gn = mk(fam_s, ns), mk(fam_n, nn)
    sp = random_star(gs, gn, seed=seed)
    es, en = edsts_for(gs), edsts_for(gn)
    res = star_edsts(sp, es, en)   # .verify() runs inside
    g = sp.product()
    assert res.count >= max(1, es.t + en.t - 2)
    assert res.count <= g.m // (g.n - 1)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 9), extra=st.integers(5, 15), seed=st.integers(0, 999))
def test_roskind_tarjan_maximum_packing(n, extra, seed):
    """RT finds a packing matching the Tutte/Nash-Williams-feasible count on
    random connected graphs: verified against the combinatorial bound and
    spanning-tree validity (verify() in edsts_for)."""
    import random
    rng = random.Random(seed)
    edges = {(i - 1, i) for i in range(1, n)}
    all_pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    rng.shuffle(all_pairs)
    for e in all_pairs:
        if len(edges) >= n - 1 + extra:
            break
        edges.add(e)
    g = Graph(n, edges)
    trees, nontree = max_edsts(g)
    assert len(trees) <= g.m // (g.n - 1)
    # packing accounts for every edge exactly once
    used = set().union(*trees) if trees else set()
    assert used | nontree == g.edges and not (used & nontree)


def test_property_461_on_noncartesian_star():
    """Paper Sec 4.6: Property 4.6.1 holds for 'some star products' beyond
    the Cartesian case -- construct one with class-preserving (non-identity)
    bijections and get the t1+t2-1 trees of Thm 4.6.2."""
    from repro.core.star import block_preserving_star
    gn = fg.complete(6)
    es = edsts_for(fg.complete(4))
    en = edsts_for(gn)
    # the bijection classes must match a rooted edge-partition of Y1: the
    # Walecki Y1 of K6 is the path 0-1-5-2-4-3; rooting at 0 and cutting at
    # vertex 5 gives S2 = {01, 15} (V(S2) = {0,1,5}), S1 = the subtree below
    # 5 (V(S1) = {5,2,4,3}), I = {5} -- bijections permute within each class
    # and fix the cut vertex.
    sp = block_preserving_star(fg.complete(4), gn,
                               v1={2, 3, 4, 5}, v2={0, 1, 5}, seed=3)
    # the bijections are genuinely non-identity
    assert any(sp.f(u, v) != tuple(range(gn.n))
               for u, v in sp.gs.edges)
    res = star_edsts(sp, es, en, strategy="property461")
    assert res.count == es.t + en.t - 1
    res.verify()


def test_hypercube_edsts_citation5():
    """Paper ref [5] (Barden et al.): hypercubes pack floor(d/2) EDSTs;
    Roskind-Tarjan attains the bound = floor(E/(V-1))."""
    for d in (3, 4, 5):
        g = fg.hypercube(d)
        E = edsts_for(g)
        bound = g.m // (g.n - 1)
        assert E.t == bound == d // 2
