"""Telemetry: Chrome-trace export (schema across engines x paper
topologies, flow binding, golden regression), the metrics registry, the
recovery journal's JSONL sink, and the telemetry train-step metrics
dict (no-retrace contract included)."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.analysis.verify import (ENGINES, PAPER_TOPOLOGIES,
                                   _compile_specs, _schedule_for)
from repro.telemetry import metrics as tm
from repro.telemetry import trace as tt

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")

_SCHEDS: dict = {}
_SPECS: dict = {}


def _spec(label: str, engine: str):
    """Compile (and cache) one engine spec per paper topology; skip when
    the engine declines the fabric (per_tree without jax, etc.)."""
    if label not in _SCHEDS:
        _SCHEDS[label] = _schedule_for(label)
    key = (label, engine)
    if key not in _SPECS:
        _SPECS[key] = _compile_specs(_SCHEDS[label], (engine,))[engine]
    spec = _SPECS[key]
    if isinstance(spec, str):
        pytest.skip(spec)
    return spec


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("label", PAPER_TOPOLOGIES)
def test_trace_schema_valid(label, engine):
    """Every engine on every paper topology exports a schema-valid
    Chrome trace with at least one span per wave and matched flows."""
    spec = _spec(label, engine)
    tr = tt.trace_spec(spec, label=f"{label}/{engine}")
    assert tt.validate_trace(tr) == []
    evs = tr["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    flows_s = [e for e in evs if e["ph"] == "s"]
    flows_f = [e for e in evs if e["ph"] == "f"]
    assert spans, "no spans"
    assert len(flows_s) == len(flows_f)
    waves = {e["args"]["wave"] for e in spans}
    assert waves == set(range(len(waves))), "missing wave indices"
    # spans carry the byte accounting the CostModel predicted from
    assert all(e["args"]["bytes"] >= 0 and e["args"]["wire_bytes"] >= 0
               for e in spans)


def test_trace_flows_follow_happens_before():
    """Flow arrows bind producer->consumer pairs: every flow-finish lands
    at or after its flow-start (Perfetto renders backwards arrows as
    broken), and ids pair exactly once."""
    spec = _spec("torus4x4", "pipelined")
    _, msgs = tt.spec_messages(spec)
    edges = tt.happens_before(msgs)
    assert edges, "torus4x4 pipelined must have cross-wave dependencies"
    for prod, cons in edges:
        assert msgs[prod][0] < msgs[cons][0], "flow within a single wave"
        # the consumer's source must have heard from the producer's tree
        assert msgs[prod][1] == msgs[cons][1]
        assert msgs[prod][4] == msgs[cons][3]


def test_trace_lane_modes_agree_on_spans():
    spec = _spec("torus4x4", "striped")
    by_dev = tt.trace_spec(spec, lane="device")
    by_tree = tt.trace_spec(spec, lane="tree")
    n_dev = sum(e["ph"] == "X" for e in by_dev["traceEvents"])
    n_tree = sum(e["ph"] == "X" for e in by_tree["traceEvents"])
    assert n_dev == n_tree
    assert tt.validate_trace(by_tree) == []


def test_trace_golden_torus4x4_pipelined():
    """Byte-exact regression vs the committed golden trace: timings come
    from the default CostModel constants and 3-decimal rounding, so any
    diff is a real change to the exporter or the schedule compiler."""
    spec = _spec("torus4x4", "pipelined")
    tr = tt.trace_spec(spec, label="torus4x4/pipelined")
    with open(os.path.join(GOLDEN, "trace_torus4x4_pipelined.json")) as f:
        golden = json.load(f)
    assert tr == golden


def test_trace_runtime_renders_entry_table():
    from repro.dist.steps import fault_runtime_for_mesh
    rt = fault_runtime_for_mesh((16, 1), ("data", "model"),
                                dp_torus_shape=(4, 4))
    tr = tt.trace_runtime(rt, nbytes=1 << 12)
    assert tt.validate_trace(tr) == []
    pids = {e["pid"] for e in tr["traceEvents"] if e["ph"] == "X"}
    assert len(pids) >= 2, "one lane group per precompiled failure class"


def test_trace_validator_catches_breakage():
    spec = _spec("torus4x4", "fused")
    tr = tt.trace_spec(spec)
    ok = json.loads(json.dumps(tr))
    ok["traceEvents"][-1]["ts"] = -1.0
    assert tt.validate_trace(ok)
    bad = json.loads(json.dumps(tr))
    for e in bad["traceEvents"]:
        if e["ph"] == "f":
            e["id"] += 10_000   # orphan every flow finish
    assert tt.validate_trace(bad)


def test_trace_cli_writes_and_validates(tmp_path):
    out = tmp_path / "tr.json"
    rc = tt.main(["--topology", "torus4x4", "--engine", "striped",
                  "--out", str(out), "--validate"])
    assert rc == 0
    tr = json.loads(out.read_text())
    assert tt.validate_trace(tr) == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_counter_gauge_histogram():
    reg = tm.MetricsRegistry()
    c = reg.counter("c_total", "help")
    c.inc()
    c.inc(2.0, engine="striped")
    assert c.value() == 1.0
    assert c.value(engine="striped") == 2.0
    g = reg.gauge("g", "help")
    g.set(3.5, dev="0")
    g.inc(0.5, dev="0")
    assert g.value(dev="0") == 4.0
    h = reg.histogram("h_us", "help", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    snap = reg.snapshot()
    assert snap["h_us"]["values"][0]["value"]["count"] == 3
    assert reg.counter("c_total") is c, "registry must be idempotent"
    with pytest.raises(TypeError):
        reg.gauge("c_total")


def test_metrics_prometheus_text_shape():
    reg = tm.MetricsRegistry()
    reg.counter("edst_x_total", "things").inc(3, kind="a b")
    text = reg.prometheus_text()
    assert "# TYPE edst_x_total counter" in text
    assert 'edst_x_total{kind="a b"} 3' in text


def test_note_program_counts_traces_and_retraces():
    tm.reset()
    tm.note_program("pipelined", ("k1",), waves=4, wire_bytes=100)
    tm.note_program("pipelined", ("k1",), waves=4, wire_bytes=100)
    tm.note_program("pipelined", ("k2",), waves=4, wire_bytes=100)
    vals = tm.counter_values("edst_program_traces_total")
    assert vals[(("engine", "pipelined"),)] == 3.0
    re = tm.counter_values("edst_retrace_detections_total")
    assert re.get((("engine", "pipelined"),), 0.0) == 1.0
    tm.reset()


def test_executor_note_trace_fires(monkeypatch):
    """A jitted pipelined allreduce records exactly one program trace and
    flags a retrace when the same (engine, key, bytes) traces twice."""
    from repro.core import topologies as topo
    from repro.core.collectives import (allreduce_schedule,
                                        pipelined_spec_from_schedule)
    from repro.core.edst_star import star_edsts
    from repro.dist.tree_allreduce import _note_trace
    tm.reset()
    sp = topo.device_topology((2, 2))
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    spec = pipelined_spec_from_schedule(sched, ("data",))
    x = jnp.ones((8,), jnp.float32)
    _note_trace("pipelined", spec, x)
    assert tm.counter_values("edst_program_traces_total")[
        (("engine", "pipelined"),)] == 1.0
    assert tm.counter_values("edst_retrace_detections_total") == {}
    _note_trace("pipelined", spec, x)
    assert tm.counter_values("edst_retrace_detections_total")[
        (("engine", "pipelined"),)] == 1.0
    tm.reset()


# ---------------------------------------------------------------------------
# recovery journal JSONL sink
# ---------------------------------------------------------------------------

def _controller(tmp_path, journal=True):
    from repro.dist.recovery import RecoveryController
    from repro.dist.steps import fault_runtime_for_mesh
    rt = fault_runtime_for_mesh((4, 1), ("data", "model"),
                                dp_torus_shape=(2, 2))
    path = str(tmp_path / "journal.jsonl") if journal else None
    return RecoveryController(rt, journal_path=path), path


def test_journal_jsonl_sink_monotonic_and_replayable(tmp_path):
    from repro.dist.recovery import load_journal, replay_journal
    ctrl, path = _controller(tmp_path)
    ctrl._journal(0, "probe_failure", "flip", 0, 1, 2, 0.5,
                  detail={"x": 1})
    ctrl._journal(5, "probe_failure", "flip", 1, 0, 1, None)
    rows = [json.loads(line) for line in open(path)]
    assert [r["seq"] for r in rows] == [0, 1]
    entries = load_journal(path)
    assert len(entries) == 2 and entries[1].to_schedule == 0
    # file form and in-memory form replay identically
    assert replay_journal(path) == replay_journal(ctrl.journal)


def test_journal_load_rejects_non_monotonic(tmp_path):
    from repro.dist.recovery import load_journal
    ctrl, path = _controller(tmp_path)
    ctrl._journal(0, "probe_failure", "flip", 0, 1, 0, None)
    row = json.loads(open(path).read())   # replay seq 0: not monotonic
    with open(path, "a") as f:
        f.write(json.dumps(row) + "\n")
    with pytest.raises(ValueError):
        load_journal(path)


def test_journal_metric_reconciles_with_file(tmp_path):
    tm.reset()
    ctrl, path = _controller(tmp_path)
    ctrl._journal(0, "probe_failure", "flip", 0, 1, 0, None)
    ctrl._journal(1, "straggler", "backoff", 1, 1, 0, None)
    ctrl._journal(2, "probe_failure", "flip", 1, 2, 0, None)
    vals = tm.counter_values("edst_recovery_transitions_total")
    by_pair: dict = {}
    for line in open(path):
        r = json.loads(line)
        key = (("action", r["action"]), ("cause", r["cause"]))
        by_pair[key] = by_pair.get(key, 0.0) + 1.0
    assert vals == by_pair
    tm.reset()


# ---------------------------------------------------------------------------
# train-step telemetry dict
# ---------------------------------------------------------------------------

def test_telemetry_dict_single_device_no_retrace():
    """telemetry=True returns the structured sync metrics dict on the
    non-manual path too, and two distinct batches reuse one trace."""
    from repro.dist.steps import make_train_step
    from repro.models.api import build
    from repro.optim import AdamW, cosine_schedule
    cfg = configs.get("smollm-135m").reduced()
    api = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = AdamW(cosine_schedule(1e-3, 5, 50))
    params, _ = api.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    with jax.set_mesh(mesh):
        jstep = jax.jit(make_train_step(api, opt, mesh, telemetry=True))
        for i in range(2):
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(i), (8, 65), 0, cfg.vocab)}
            params, opt_state, m = jstep(params, opt_state, batch)
    assert jstep._cache_size() == 1, "telemetry dict must not retrace"
    for key in ("sync_dev", "sync_grad_norm", "sync_schedule_id",
                "sync_wire_bytes"):
        assert key in m, key
    assert float(m["sync_grad_norm"]) > 0.0
    assert int(m["sync_schedule_id"]) == 0
    assert float(m["sync_wire_bytes"]) == 0.0   # no manual sync program


EDST_TELEMETRY_CODE = r"""
import jax, jax.numpy as jnp
from repro import configs
from repro.core.collectives import wave_wire_bytes
from repro.models.api import build
from repro.dist.steps import fault_runtime_for_mesh, make_train_step
from repro.optim import AdamW, cosine_schedule

cfg = configs.get('smollm-135m').reduced()
api = build(cfg)
mesh = jax.make_mesh((16, 1), ('data', 'model'))
rt = fault_runtime_for_mesh((16, 1), ('data', 'model'), dp_torus_shape=(4, 4))
opt = AdamW(cosine_schedule(1e-3, 10, 100))
params, _ = api.init(jax.random.PRNGKey(0))
opt_state = opt.init(params)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (16, 65), 0,
                                      cfg.vocab)}
step = make_train_step(api, opt, mesh, mode='edst', fault_runtime=rt,
                       telemetry=True)
jstep = jax.jit(step)
with jax.set_mesh(mesh):
    p, o, m = jstep(params, opt_state, batch, jnp.int32(rt.active))
    # second call reaches the steady-state sharding of the train loop
    # (step 1's outputs feed step 2); only then is the cache size the
    # no-retrace baseline a schedule flip must preserve
    p, o, m = jstep(p, o, batch, jnp.int32(rt.active))
    traces = jstep._cache_size()
    # flip to a degraded schedule: gauge moves, executable does not
    sid_flip = None
    for i, e in enumerate(rt.entries):
        if i != rt.active and e.k > 0:
            sid_flip = i
            break
    p, o, m2 = jstep(p, o, batch, jnp.int32(sid_flip))
    assert jstep._cache_size() == traces, 'schedule flip retraced'
wire0, wire1 = float(m['sync_wire_bytes']), float(m2['sync_wire_bytes'])
flat = sum(int(x.size) for x in jax.tree.leaves(p))
e0, e1 = rt.entries[rt.active], rt.entries[sid_flip]
want0 = float(sum(wave_wire_bytes(e0.spec, flat * 4, 4,
                                  e0.fractions or None)))
want1 = float(sum(wave_wire_bytes(e1.spec, flat * 4, 4,
                                  e1.fractions or None)))
assert abs(wire0 - want0) < 1e-3 * max(1.0, want0), (wire0, want0)
assert abs(wire1 - want1) < 1e-3 * max(1.0, want1), (wire1, want1)
assert wire0 != wire1, 'gauge must move with the schedule id'
assert float(m['sync_grad_norm']) > 0.0
print('EDST_TELEMETRY_OK')
"""


def test_telemetry_dict_edst_wire_gauge_tracks_schedule(subproc):
    out = subproc(EDST_TELEMETRY_CODE, 16)
    assert "EDST_TELEMETRY_OK" in out
