"""Pipelined segmented executor under shard_map on 16 fake host devices:
psum/simulator equivalence (quantize on/off, uneven m, m < S, weighted
fractions with a retired tree), scan-program jit-cache stability, the HLO
contract (one collective per wave, independent of the segment count), and
fault-runtime link-kill equality on the pipelined engine."""

CODE = r"""
import os
assert "XLA_FLAGS" in os.environ
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist  # installs compat shard_map
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    pipelined_spec_from_schedule,
                                    simulate_wave_program)
from repro.dist.tree_allreduce import pipelined_tree_allreduce

mesh = jax.make_mesh((4, 4), ('a', 'b'))


def smapped(body):
    return jax.shard_map(lambda xs: body(xs.reshape(xs.shape[1:]))[None],
                         mesh=mesh, in_specs=P(('a', 'b')),
                         out_specs=P(('a', 'b')))


for dims in [(4, 4), (2, 8)]:
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    spec = pipelined_spec_from_schedule(sched, ('a', 'b'))

    # the packet-level replay validates the compiled wave program itself
    vals = np.random.RandomState(0).randn(sp.n, 8 * sched.k + 5)
    for S in (1, 2, 4, 8):
        for q in (False, True):
            sim = simulate_wave_program(spec, vals, segments=S, quantized=q)
            assert sim.ok, (dims, S, q)
            waves = spec.q8_waves if q else spec.waves
            assert sim.rounds == len(waves) + S - 1

    # uneven m (53 % k != 0) and m < S (d=3, S=8): psum equivalence
    for d in (53, 3):
        x = jnp.asarray(np.random.RandomState(d).randn(16, d)
                        .astype(np.float32))
        yp = jax.jit(smapped(lambda v: jax.lax.psum(v, ('a', 'b'))))(x)
        for S in (1, 2, 8, "auto"):
            y = jax.jit(smapped(lambda v, S=S: pipelined_tree_allreduce(
                v, spec, segments=S)))(x)
            assert jnp.allclose(y, yp, atol=1e-4), (dims, d, S)

        # quantized wires (forced codecs -- "auto" may disable
        # compression on host backends): bounded relative error
        expect = x.sum(0)
        for codec in ("full", "hybrid", "bcast"):
            for S in (1, 4):
                yq = jax.jit(smapped(
                    lambda v, c=codec, S=S: pipelined_tree_allreduce(
                        v, spec, quantize=True, segments=S, codec=c)))(x)
                rel = float(jnp.max(jnp.abs(yq[0] - expect)
                                    / (jnp.abs(expect) + 1)))
                assert rel < 0.35, (dims, d, codec, S, rel)
        # the model-picked codec stays psum-close on every backend
        ya = jax.jit(smapped(lambda v: pipelined_tree_allreduce(
            v, spec, quantize=True)))(x)
        rel = float(jnp.max(jnp.abs(ya[0] - expect)
                            / (jnp.abs(expect) + 1)))
        assert rel < 0.35, (dims, d, rel)

    # weighted fractions, including a retired (fraction-0) tree
    if sched.k >= 2:
        x = jnp.asarray(np.random.RandomState(7).randn(16, 53)
                        .astype(np.float32))
        yp = jax.jit(smapped(lambda v: jax.lax.psum(v, ('a', 'b'))))(x)
        for fr in [(0.7, 0.3), (1.0, 0.0)]:
            for S in (1, 4):
                y = jax.jit(smapped(
                    lambda v, fr=fr, S=S: pipelined_tree_allreduce(
                        v, spec, segments=S, fractions=fr)))(x)
                assert jnp.allclose(y, yp, atol=1e-4), (dims, fr, S)

print("PIPELINED_ALLREDUCE_OK")
"""

HLO_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist
from repro.analysis.hlo import lint_hlo
from repro.analysis.verify import hlo_contract_for
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    pipelined_spec_from_schedule)
from repro.dist.tree_allreduce import pipelined_tree_allreduce

mesh = jax.make_mesh((4, 4), ('a', 'b'))
x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01


def smapped(body):
    return jax.shard_map(lambda xs: body(xs.reshape(xs.shape[1:]))[None],
                         mesh=mesh, in_specs=P(('a', 'b')),
                         out_specs=P(('a', 'b')))


def hlo_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


for dims in [(4, 4), (2, 8)]:
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    spec = pipelined_spec_from_schedule(sched, ('a', 'b'))

    # the pipeline runs waves + S - 1 steps
    for S in (1, 2, 8):
        assert spec.steps(S) == len(spec.waves) + S - 1

    # S=1 unrolls, S>1 scans: either way the HLO holds each wave's
    # collective exactly ONCE -- program size flat in the segment count
    # (the whole point of the scan compile).  The contract is derived
    # from the spec itself (hlo_contract_for) and enforced by lint_hlo.
    contract = hlo_contract_for(spec)
    assert contract.ppermutes == len(spec.waves)
    for S in (1, 2, 8):
        text = hlo_text(smapped(
            lambda v, S=S: pipelined_tree_allreduce(v, spec, segments=S)), x)
        bad = lint_hlo(text, contract)
        assert not bad, (dims, S, bad)

    # quantized S=1: one collective per q8 wave, int8 reduce wires -- f32
    # sites only on the packed broadcast waves, and every f32 wire is the
    # packed lane width, never a full mrow-element row (a full row means
    # the codec was silently dropped)
    qcontract = hlo_contract_for(spec, quantize=True, m=53)
    assert qcontract.ppermutes == len(spec.q8_waves)
    text = hlo_text(smapped(
        lambda v: pipelined_tree_allreduce(v, spec, quantize=True,
                                           segments=1, codec="full")), x)
    bad = lint_hlo(text, qcontract)
    assert not bad, (dims, bad)

print("PIPELINED_HLO_OK")
"""

CACHE_CODE = r"""
import functools
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    pipelined_spec_from_schedule)
from repro.dist.tree_allreduce import pipelined_tree_allreduce

mesh = jax.make_mesh((4, 4), ('a', 'b'))
x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01


@functools.partial(jax.jit, static_argnums=(1, 2))
def run(xs, spec, segments):
    return jax.shard_map(
        lambda v: pipelined_tree_allreduce(v.reshape(v.shape[1:]), spec,
                                           segments=segments)[None],
        mesh=mesh, in_specs=P(('a', 'b')), out_specs=P(('a', 'b')))(xs)


def fresh_spec():
    sp = topo.device_topology((4, 4))
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    return pipelined_spec_from_schedule(sched, ('a', 'b'))


s1, s2 = fresh_spec(), fresh_spec()
assert s1 is s2, "spec cache must return the identical object"
for segments in (1, 4):   # both the unrolled and the scan program
    y1 = run(x, s1, segments)
    before = run._cache_size()
    y2 = run(x, s2, segments)
    assert run._cache_size() == before, \
        f"pipelined spec swap retraced (segments={segments})"
    assert jnp.allclose(y1, y2)
    assert jnp.allclose(y1, jnp.tile(x.sum(0), (16, 1)))
print("PIPELINED_CACHE_OK")
"""

FAULT_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist
from repro.core.collectives import PipelinedAllreduceSpec
from repro.core.fault import FailureEvent
from repro.dist.steps import fault_runtime_for_mesh

rt = fault_runtime_for_mesh((16, 1), ('data', 'model'), dp_torus_shape=(4, 4))
# the elastic runtime's precompiled programs are pipelined specs now
assert all(isinstance(e.spec, PipelinedAllreduceSpec) for e in rt.entries)
mesh = jax.make_mesh((16, 1), ('data', 'model'))
sync = rt.make_allreduce(quantize=True, segments=2)  # scan path in-switch

x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01

f = jax.jit(jax.shard_map(
    lambda xs, sid: sync(xs.reshape(xs.shape[1:]), sid)[None],
    mesh=mesh, in_specs=(P('data'), P()), out_specs=P('data'),
    axis_names={'data'}, check_vma=False))
g = jax.jit(jax.shard_map(
    lambda xs: jax.lax.psum(xs.reshape(xs.shape[1:]), 'data')[None],
    mesh=mesh, in_specs=P('data'), out_specs=P('data'),
    axis_names={'data'}, check_vma=False))

yp = g(x)
y0 = f(x, jnp.int32(0))

# kill a tree-0 link mid-run: scalar flip, no retrace, psum equality holds
dead = next(iter(rt.entries[0].sched.trees[0].tree))
rt2 = rt.on_failure(FailureEvent(links=frozenset({dead})))
traces = f._cache_size()
y1 = f(x, jnp.int32(rt2.active))
assert f._cache_size() == traces, "link-kill schedule flip retraced"
rt3 = rt.on_failure(FailureEvent(links=frozenset({dead})),
                    prefer="degraded")
y2 = f(x, jnp.int32(rt3.active))
for y in (y0, y1, y2):
    assert jnp.allclose(y, yp, atol=1e-2), float(jnp.max(jnp.abs(y - yp)))
print("PIPELINED_FAULT_OK")
"""


def test_pipelined_matches_psum_and_simulator(subproc):
    out = subproc(CODE, 16)
    assert "PIPELINED_ALLREDUCE_OK" in out


def test_pipelined_hlo_contract_flat_in_segments(subproc):
    out = subproc(HLO_CODE, 16)
    assert "PIPELINED_HLO_OK" in out


def test_pipelined_scan_program_jit_cache_stable(subproc):
    out = subproc(CACHE_CODE, 16)
    assert "PIPELINED_CACHE_OK" in out


def test_pipelined_fault_runtime_link_kill(subproc):
    out = subproc(FAULT_CODE, 16)
    assert "PIPELINED_FAULT_OK" in out
