"""Static wave-program verifier: mutation tests, build-time rejection,
HLO contract linter, and the AST repo lint.

The interesting property of a verifier is not that correct specs pass
(the CLI gate covers that on all five paper topologies) but that each
*class* of corruption is caught with its own named diagnostic.  Every
mutation below deep-copies a cached spec (the compilers return identical
objects on purpose -- never mutate a cache hit) or rebuilds it with
``dataclasses.replace``, breaks exactly one invariant, and asserts the
verifier reports the matching violation code.
"""
import copy
import dataclasses
import os
from functools import lru_cache

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.hlo import HloContract, collective_sites, lint_hlo
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.verify import (SpecVerificationError, assert_valid,
                                   engine_of, hlo_contract_for, verify_spec)
from repro.analysis.verify import _schedule_for
from repro.core.collectives import (BCAST, REDUCE, AllreduceSchedule,
                                    fused_spec_from_schedule,
                                    pipelined_spec_from_schedule,
                                    striped_spec_from_schedule)

TOPOS = ("torus4x4", "hyperx4x4", "slimfly_q5")
SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


@lru_cache(maxsize=None)
def sched_for(label):
    return _schedule_for(label)


def codes_of(spec):
    return {v.code for v in verify_spec(spec, level="full").violations}


# ---------------------------------------------------------------------------
# clean specs verify on every engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label", TOPOS)
def test_clean_specs_verify(label):
    sched = sched_for(label)
    for compile_ in (fused_spec_from_schedule, pipelined_spec_from_schedule,
                     striped_spec_from_schedule):
        spec = compile_(sched, ("data",))
        report = verify_spec(spec, level="full")
        assert report.ok, report.summary()
        assert report.messages > 0 and report.waves > 0
        assert_valid(spec)           # and the raising form doesn't raise


def test_engine_of():
    sched = sched_for("torus4x4")
    assert engine_of(fused_spec_from_schedule(sched, ("data",))) == "fused"
    assert engine_of(
        pipelined_spec_from_schedule(sched, ("data",))) == "pipelined"
    assert engine_of(
        striped_spec_from_schedule(sched, ("data",))) == "striped"


# ---------------------------------------------------------------------------
# mutations: one corruption class -> one named diagnostic
# ---------------------------------------------------------------------------

def mutate_drop_recv(label):
    """A receive flag silently cleared: the arrival has nowhere to land."""
    spec = copy.deepcopy(pipelined_spec_from_schedule(sched_for(label),
                                                      ("data",)))
    _, d = spec.waves[0].perm[0]
    spec.waves[0].reduce_flag[:, d] = False
    spec.waves[0].bcast_flag[:, d] = False
    return spec, "recv-dropped"


def mutate_swap_sends(label):
    """Two senders' chunk rows swapped: arrivals land in the wrong tree."""
    spec = copy.deepcopy(pipelined_spec_from_schedule(sched_for(label),
                                                      ("data",)))
    for wv in spec.waves:
        if len(wv.rows) >= 2:
            senders = [s for s, _ in wv.perm]
            by_row = {int(wv.send_row[s]): s for s in senders}
            rows = sorted(by_row)[:2]
            s1, s2 = by_row[rows[0]], by_row[rows[1]]
            wv.send_row[s1], wv.send_row[s2] = rows[1], rows[0]
            return spec, "row-misroute"
    raise AssertionError(f"{label}: no wave ships two distinct rows")


def mutate_double_book_link(label):
    """A whole wave replayed later: every one of its directed links is
    double-booked, which would corrupt segment streaming at any S > 1."""
    spec = pipelined_spec_from_schedule(sched_for(label), ("data",))
    return (dataclasses.replace(spec, waves=spec.waves + (spec.waves[0],)),
            "link-race")


def mutate_cross_wire_trees(label):
    """Two trees routed over the same physical links: the EDST property
    itself violated.  Built via raw AllreduceSchedule -- the public
    allreduce_schedule() already refuses this, so go around it."""
    sched = sched_for(label)
    bad = AllreduceSchedule(sched.n, [sched.trees[0], sched.trees[0]])
    spec = pipelined_spec_from_schedule(bad, ("cross", "wire"), verify=False)
    return spec, "edge-disjointness"


def mutate_reorder_waves(label):
    """The wave order reversed: every dependency of the message DAG now
    runs backwards."""
    spec = pipelined_spec_from_schedule(sched_for(label), ("data",))
    return (dataclasses.replace(spec, waves=tuple(reversed(spec.waves))),
            "happens-before")


def mutate_fused_drop_recv(label):
    spec = copy.deepcopy(fused_spec_from_schedule(sched_for(label),
                                                  ("data",)))
    _, d = spec.reduce_rounds[0].perm[0]
    spec.reduce_rounds[0].recv_flag[d] = False
    return spec, "recv-dropped"


def mutate_stripe_window(label):
    """A stripe window widened by one slot on both endpoints: the tables
    still agree with each other, but some owner slot now crosses the
    edge twice (conservation broken)."""
    spec = copy.deepcopy(striped_spec_from_schedule(sched_for(label),
                                                    ("data",)))
    wv = spec.waves[0]
    s, d = wv.perm[0]
    wv.send_nslot[s] += 1
    wv.recv_nslot[d] += 1
    return spec, "stripe-conservation"


def mutate_striped_op(label):
    """A reduce-scatter wave's op flipped to overwrite: partial sums
    would be clobbered instead of accumulated."""
    spec = striped_spec_from_schedule(sched_for(label), ("data",))
    flipped = dataclasses.replace(
        spec.waves[0], op=BCAST if spec.waves[0].op == REDUCE else REDUCE)
    return (dataclasses.replace(spec,
                                waves=(flipped,) + spec.waves[1:]),
            "op-mixed")


def mutate_drop_ag_wave(label):
    """The last AG-only wave dropped: the zero1 params allgather would
    silently never deliver some stripes.  Caught twice over -- the split
    program stops moving the composed message multiset, and every edge
    the wave carried loses its allgather leg."""
    spec = striped_spec_from_schedule(sched_for(label), ("data",))
    return (dataclasses.replace(spec, ag_waves=spec.ag_waves[:-1]),
            "message-conservation")


def mutate_stale_ownership(label):
    """The DFS-preorder ownership table rolled one slot: the routing is
    untouched (windows still conserve), but executors cut owner stripes
    with ``trees[j].pre``/``size``, so every owner cut mis-slices -- the
    failure mode of a stripe table kept across a re-striping failover.
    Distinct from the dropped-wave code by design: table-vs-routing
    staleness is not a transport bug."""
    spec = striped_spec_from_schedule(sched_for(label), ("data",))
    st0 = spec.trees[0]
    rolled = dataclasses.replace(st0, pre=np.roll(st0.pre, 1))
    return (dataclasses.replace(spec, trees=(rolled,) + spec.trees[1:]),
            "stale-ownership")


MUTATIONS = {
    "drop-recv-flag": mutate_drop_recv,
    "swap-two-sends": mutate_swap_sends,
    "double-book-link": mutate_double_book_link,
    "cross-wire-trees": mutate_cross_wire_trees,
    "reorder-waves": mutate_reorder_waves,
    "fused-drop-recv": mutate_fused_drop_recv,
    "stripe-window": mutate_stripe_window,
    "striped-op-flip": mutate_striped_op,
    "drop-ag-wave": mutate_drop_ag_wave,
    "stale-ownership": mutate_stale_ownership,
}


def test_zero1_mutations_distinct_codes():
    """The two zero1-path corruptions (a transport wave lost vs a stale
    ownership table) must map to DIFFERENT named codes -- conflating
    them would point the operator at the wrong layer."""
    _, wave_code = mutate_drop_ag_wave("torus4x4")
    _, table_code = mutate_stale_ownership("torus4x4")
    assert wave_code != table_code


@pytest.mark.parametrize("name", sorted(MUTATIONS))
def test_mutation_detected(name):
    spec, expected = MUTATIONS[name]("torus4x4")
    codes = codes_of(spec)
    assert expected in codes, (
        f"mutation {name} expected [{expected}], verifier said {codes}")


def test_mutations_have_distinct_diagnostics():
    """Each corruption class maps to its own code -- a verifier that says
    'something is wrong' for everything is not actionable."""
    expected = {MUTATIONS[n]("torus4x4")[1] for n in MUTATIONS}
    assert len(expected) >= 6


def test_violation_detail_names_the_site():
    spec, _ = mutate_drop_recv("torus4x4")
    report = verify_spec(spec, level="full")
    _, d = spec.waves[0].perm[0]
    assert any(f"vertex {d}" in v.detail for v in report.violations
               if v.code == "recv-dropped")
    assert "[recv-dropped]" in report.summary()


@settings(max_examples=12, deadline=None)
@given(label=st.sampled_from(TOPOS), name=st.sampled_from(sorted(MUTATIONS)))
def test_mutation_detected_across_topologies(label, name):
    spec, expected = MUTATIONS[name](label)
    assert expected in codes_of(spec)


# ---------------------------------------------------------------------------
# build-time rejection (the verify= flag on the spec compilers)
# ---------------------------------------------------------------------------

def test_compile_rejects_illegal_schedule():
    sched = sched_for("torus4x4")
    bad = AllreduceSchedule(sched.n, [sched.trees[0], sched.trees[0]])
    with pytest.raises(SpecVerificationError) as ei:
        pipelined_spec_from_schedule(bad, ("rej", "pipe"), verify=True)
    assert "edge-disjointness" in {v.code for v in
                                   ei.value.report.violations}
    assert "pipelined_spec_from_schedule" in str(ei.value)
    with pytest.raises(SpecVerificationError):
        fused_spec_from_schedule(bad, ("rej", "fused"), verify=True)
    with pytest.raises(SpecVerificationError):
        striped_spec_from_schedule(bad, ("rej", "striped"), verify=True)


def test_verify_true_rechecks_cache_hits():
    """verify=True forces a full check even when the compiler returns a
    cached spec object."""
    sched = sched_for("torus4x4")
    a = pipelined_spec_from_schedule(sched, ("data",))
    b = pipelined_spec_from_schedule(sched, ("data",), verify=True)
    assert a is b                      # same cached object, re-verified


# ---------------------------------------------------------------------------
# HLO contract linter
# ---------------------------------------------------------------------------

FAKE_HLO = """\
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %cp0 = f32[16]{0} collective-permute(f32[16]{0} %p0), channel_id=1
  %cp1 = s8[18]{0} collective-permute-start(s8[18]{0} %w), channel_id=2
  %ar = f32[16]{0} all-reduce(f32[16]{0} %cp0), to_apply=%add
  %done = s8[18]{0} collective-permute-done(s8[18]{0} %cp1)
  ROOT %out = f32[16]{0} add(f32[16]{0} %cp0, f32[16]{0} %done)
}
"""


def test_collective_sites_flat():
    sites = collective_sites(FAKE_HLO)
    perms = [s for s in sites if s.kind == "collective-permute"]
    assert len(perms) == 2             # -start counted, -done not
    assert {(s.dtype, s.elems) for s in perms} == {("f32", 16), ("s8", 18)}
    assert any(s.kind == "all-reduce" for s in sites)


def test_lint_hlo_contract():
    ok = HloContract(ppermutes=2, max_f32_sites=1, max_f32_wire_elems=16)
    assert lint_hlo(FAKE_HLO, ok) == []
    bad_count = lint_hlo(FAKE_HLO, HloContract(ppermutes=5))
    assert bad_count and "site count 2 != contracted 5" in bad_count[0]
    bad_f32 = lint_hlo(FAKE_HLO, HloContract(max_f32_sites=0))
    assert bad_f32 and "f32-wire" in bad_f32[0]
    bad_wire = lint_hlo(FAKE_HLO, HloContract(max_f32_wire_elems=8))
    assert bad_wire and "packed-lane cap" in bad_wire[0]


def test_hlo_contract_for_pipelined():
    spec = pipelined_spec_from_schedule(sched_for("torus4x4"), ("data",))
    c = hlo_contract_for(spec)
    assert c.ppermutes == len(spec.waves)
    assert c.max_f32_sites is None     # f32 wires unconstrained un-quantized
    q = hlo_contract_for(spec, quantize=True, m=53)
    assert q.ppermutes == len(spec.q8_waves)
    assert q.max_f32_sites == len(spec.q8_waves) - spec.q8_boundary
    mrow = -(-53 // spec.k)
    assert q.max_f32_wire_elems == -(-mrow // 4) + 2
    assert q.max_f32_wire_elems < mrow  # a full row must trip the linter


def test_hlo_contract_for_fused_and_striped():
    sched = sched_for("torus4x4")
    f = fused_spec_from_schedule(sched, ("data",))
    assert hlo_contract_for(f).ppermutes == f.num_collectives
    s = striped_spec_from_schedule(sched, ("data",))
    assert hlo_contract_for(s).ppermutes == len(s.waves)
    # striped wires are never quantized: contract ignores quantize=True
    assert hlo_contract_for(s, quantize=True).max_f32_sites is None


def test_hlo_contract_for_striped_phases():
    """phase= selects the RS-only / AG-only / zero1 wave budgets, bound
    to the payload: on torus4x4 k=2 the zero1 step (rs + ag, no gradient
    allgather) must contract strictly fewer ppermutes than the composed
    allreduce step -- the headline wave saving of the zero1 PR."""
    sched = sched_for("torus4x4")
    s = striped_spec_from_schedule(sched, ("data",))
    m = 53
    rs = hlo_contract_for(s, m=m, phase="rs")
    ag = hlo_contract_for(s, m=m, phase="ag")
    z = hlo_contract_for(s, m=m, phase="zero1")
    comp = hlo_contract_for(s, m=m, phase="composed")
    assert rs.ppermutes > 0 and ag.ppermutes > 0
    assert z.ppermutes == rs.ppermutes + ag.ppermutes
    assert z.ppermutes < comp.ppermutes
    # unbound: whole-program wave counts
    assert hlo_contract_for(s, phase="rs").ppermutes == len(s.rs_waves)
    assert hlo_contract_for(s, phase="ag").ppermutes == len(s.ag_waves)
    # phases are a striped-engine concept
    p = pipelined_spec_from_schedule(sched, ("data",))
    with pytest.raises(ValueError):
        hlo_contract_for(p, phase="rs")
    with pytest.raises(ValueError):
        hlo_contract_for(s, phase="bogus")


# ---------------------------------------------------------------------------
# AST repo lint
# ---------------------------------------------------------------------------

def test_repo_lint_clean():
    assert lint_paths([SRC]) == []


def test_lint_spec_construct():
    src = "spec = FusedAllreduceSpec(n=4, k=1)\n"
    bad = lint_source(src, "src/repro/launch/foo.py")
    assert [f.rule for f in bad] == ["spec-construct"]
    # the defining compiler module is allowed to construct its own specs
    assert lint_source(src, "src/repro/core/collectives.py") == []


def test_lint_axis_literal():
    src = ("def f(x):\n"
           "    return jax.lax.ppermute(x, 'data', perm=[(0, 1)])\n")
    bad = lint_source(src, "src/repro/dist/foo.py")
    assert [f.rule for f in bad] == ["axis-literal"]
    # outside dist/ the rule does not apply (analysis helpers may pin axes)
    assert lint_source(src, "src/repro/analysis/foo.py") == []
    ok = ("def f(spec, x):\n"
          "    return jax.lax.ppermute(x, _axis_arg(spec.axes), perm=p)\n")
    assert lint_source(ok, "src/repro/dist/foo.py") == []


def test_lint_traced_table_build():
    src = ("def outer(spec):\n"
           "    def step(x):\n"
           "        t = jnp.asarray([1, 2, 3])\n"
           "        return x + t\n"
           "    return step\n")
    bad = lint_source(src, "src/repro/dist/foo.py")
    assert "traced-table-build" in {f.rule for f in bad}
    # module-level table prep is the idiom, not a violation
    ok = "TABLE = np.asarray([1, 2, 3])\n"
    assert lint_source(ok, "src/repro/dist/foo.py") == []


def test_lint_nested_numpy():
    src = ("def outer():\n"
           "    def inner(x):\n"
           "        return np.roll(x, 1)\n"
           "    return inner\n")
    bad = lint_source(src, "src/repro/dist/foo.py")
    assert [f.rule for f in bad] == ["nested-numpy"]
    # jnp in a traced body is exactly right
    ok = src.replace("np.roll", "jnp.roll")
    assert lint_source(ok, "src/repro/dist/foo.py") == []
