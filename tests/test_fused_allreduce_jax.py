"""Fused global-round executor under shard_map on 16 fake host devices:
psum/simulator equivalence (quantize on/off), jit-cache stability across
spec recompiles, and the HLO collective-count contract (depth-of-deepest-
tree waves, one collective per quantized hop)."""

CODE = r"""
import os
assert "XLA_FLAGS" in os.environ
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist  # installs compat shard_map
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    fused_spec_from_schedule,
                                    simulate_allreduce)
from repro.dist.tree_allreduce import (fused_tree_allreduce,
                                       per_tree_allreduce,
                                       spec_from_schedule)

mesh = jax.make_mesh((4, 4), ('a', 'b'))
x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01
expect = x.sum(0)


import re

def hlo_collectives(f, *args):
    # op position only ("%x = f32[...] collective-permute(...)"), not
    # fusion metadata that mentions the op name; async start/done pairs
    # count once via -start
    text = jax.jit(f).lower(*args).compile().as_text()
    return sum(1 for l in text.splitlines()
               if re.search(r"=\s+\S+\s+collective-permute(-start)?\(", l))


def smapped(body):
    return jax.shard_map(lambda xs: body(xs.reshape(xs.shape[1:]))[None],
                         mesh=mesh, in_specs=P(('a', 'b')),
                         out_specs=P(('a', 'b')))

for dims in [(4, 4), (2, 8)]:
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    fspec = fused_spec_from_schedule(sched, ('a', 'b'))
    lspec = spec_from_schedule(sched, ('a', 'b'))

    # the packet-level simulator accepts the same schedule
    vals = np.random.RandomState(0).randn(sp.n, 8 * sched.k)
    assert simulate_allreduce(sched, vals).ok

    # psum equivalence, quantize off/on
    yp = jax.jit(smapped(lambda v: jax.lax.psum(v, ('a', 'b'))))(x)
    y = jax.jit(smapped(lambda v: fused_tree_allreduce(v, fspec)))(x)
    assert jnp.allclose(y, yp, atol=1e-5), dims
    assert jnp.allclose(y, jnp.tile(expect, (16, 1))), dims
    yq = jax.jit(smapped(
        lambda v: fused_tree_allreduce(v, fspec, quantize=True)))(x)
    rel = float(jnp.max(jnp.abs(yq[0] - expect) / (jnp.abs(expect) + 1)))
    assert rel < 0.05, (dims, rel)

    # HLO contract: one collective per wave -- depth-of-deepest-tree
    # global rounds, NOT sum-of-all-trees rounds; quantization must not
    # add a second collective per hop (the scale rides the payload tail)
    legacy_rounds = sum(len(t.reduce_rounds) + len(t.bcast_rounds)
                        for t in lspec.trees)
    n_fused = hlo_collectives(smapped(
        lambda v: fused_tree_allreduce(v, fspec)), x)
    n_fused_q = hlo_collectives(smapped(
        lambda v: fused_tree_allreduce(v, fspec, quantize=True)), x)
    n_legacy = hlo_collectives(smapped(
        lambda v: per_tree_allreduce(v, lspec)), x)
    n_legacy_q = hlo_collectives(smapped(
        lambda v: per_tree_allreduce(v, lspec, quantize=True)), x)
    assert n_fused == fspec.num_collectives, (dims, n_fused)
    assert n_fused_q == fspec.num_collectives, (dims, n_fused_q)
    assert n_legacy == n_legacy_q == legacy_rounds, (dims, n_legacy)
    if sched.k >= 2:
        assert n_fused < n_legacy, (dims, n_fused, n_legacy)

print("FUSED_ALLREDUCE_OK")
"""

CACHE_CODE = r"""
import functools
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    fused_spec_from_schedule)
from repro.dist.tree_allreduce import fused_tree_allreduce

mesh = jax.make_mesh((4, 4), ('a', 'b'))
x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01

@functools.partial(jax.jit, static_argnums=(1,))
def run(xs, spec):
    return jax.shard_map(
        lambda v: fused_tree_allreduce(v.reshape(v.shape[1:]), spec)[None],
        mesh=mesh, in_specs=P(('a', 'b')), out_specs=P(('a', 'b')))(xs)

def fresh_spec():
    sp = topo.device_topology((4, 4))
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    return fused_spec_from_schedule(sched, ('a', 'b'))

s1, s2 = fresh_spec(), fresh_spec()
assert s1 is s2, "spec cache must return the identical object"
y1 = run(x, s1)
before = run._cache_size()
y2 = run(x, s2)
assert run._cache_size() == before, "fused spec swap retraced"
assert jnp.allclose(y1, y2)
assert jnp.allclose(y1, jnp.tile(x.sum(0), (16, 1)))
print("FUSED_CACHE_OK")
"""


def test_fused_allreduce_matches_psum_and_hlo_contract(subproc):
    out = subproc(CODE, 16)
    assert "FUSED_ALLREDUCE_OK" in out


def test_fused_spec_swap_does_not_retrace(subproc):
    out = subproc(CACHE_CODE, 16)
    assert "FUSED_CACHE_OK" in out
