"""Composition correctness of the star-product schedule compiler
(:mod:`repro.core.product_schedule`): composed trees are the flat
``star_edsts`` trees exactly (same edges, same tree-center roots), the
ASAP-assembled wave programs pass the FULL static verifier, replay
bit-identically through the packet simulators (same per-link byte
multiset as the flat pipelined program -- message conservation), never
cost more than a bounded factor over the flat greedy wave counts, and
recompile as the identical cached object (the no-retrace contract
elastic rescales rely on)."""
import numpy as np
import pytest

from repro.analysis.verify import _topology_case, verify_spec
from repro.core.collectives import (allreduce_schedule,
                                    pipelined_spec_from_schedule,
                                    simulate_striped_program,
                                    simulate_wave_program,
                                    striped_spec_from_schedule)
from repro.core.edst_star import star_edsts
from repro.core.factor_graphs import complete, cycle
from repro.core.product_schedule import (asap_fused_spec,
                                         asap_pipelined_spec,
                                         asap_striped_spec,
                                         composed_allreduce_schedule,
                                         composed_spec_for_star,
                                         composed_star_trees)
from repro.core.star import cartesian

AXES = ("data",)
# C4 x K3 (the doc example) + the asymmetric paper fabrics; torus4x4 /
# hyperx4x4 are cartesian squares already covered by C4xK3's shape.
CASE_LABELS = ("C4xK3", "slimfly_q5", "polarstar_er3_qr5",
               "bundlefly_q4_a5")

_CASES: dict = {}


def _case(label):
    """(sp, Es, flat_sched, comp_sched), memoized per module run."""
    if label not in _CASES:
        if label == "C4xK3":
            sp, es = cartesian(cycle(4), complete(3)), None
        else:
            sp, es = _topology_case(label)
        n = sp.product().n
        res = star_edsts(sp, Es=es) if es is not None else star_edsts(sp)
        flat = allreduce_schedule(n, res.trees)
        comp = composed_allreduce_schedule(sp, Es=es)
        _CASES[label] = (sp, es, flat, comp)
    return _CASES[label]


@pytest.mark.parametrize("label", CASE_LABELS)
def test_composed_trees_and_roots_match_flat(label):
    """composed_star_trees assembles the SAME edge sets star_edsts
    proves, and the composed schedule picks the same tree-center
    roots -- so composed and flat compile the same paper construction."""
    sp, es, flat, comp = _case(label)
    composed = composed_star_trees(sp, Es=es)
    flat_trees = star_edsts(sp, Es=es) if es is not None else star_edsts(sp)
    assert [frozenset(t) for t in composed.trees] \
        == [frozenset(t) for t in flat_trees.trees]
    assert [ts.root for ts in comp.trees] == [ts.root for ts in flat.trees]
    assert [ts.tree for ts in comp.trees] == [ts.tree for ts in flat.trees]
    assert comp.depth == flat.depth


@pytest.mark.parametrize("label", CASE_LABELS)
@pytest.mark.parametrize("engine", ("pipelined", "striped", "fused"))
def test_composed_spec_full_verify_clean(label, engine):
    _, _, _, comp = _case(label)
    spec = {"pipelined": asap_pipelined_spec, "striped": asap_striped_spec,
            "fused": asap_fused_spec}[engine](comp, AXES, verify=False)
    rep = verify_spec(spec, level="full")
    assert rep.ok, rep.summary()


@pytest.mark.parametrize("label", CASE_LABELS)
def test_composed_replay_bit_identical_conservation(label):
    """The composed programs move the SAME per-link byte multiset as the
    flat pipelined program (the trees are identical, so conservation is
    exact, not approximate) and both simulators reproduce the allreduce
    sums."""
    sp, _, flat, comp = _case(label)
    n = sp.product().n
    rng = np.random.RandomState(7)
    vals = rng.randn(n, 8 * comp.k + 3)
    cp = asap_pipelined_spec(comp, AXES, verify=False)
    cs = asap_striped_spec(comp, AXES, verify=False)
    fp = pipelined_spec_from_schedule(flat, AXES, verify=False)
    simc = simulate_wave_program(cp, vals, 1)
    simf = simulate_wave_program(fp, vals, 1)
    assert simc.ok and simf.ok
    assert simc.per_link_bytes == simf.per_link_bytes
    sims = simulate_striped_program(cs, vals)
    assert sims.ok and sims.stripes_ok


@pytest.mark.parametrize("label", CASE_LABELS)
def test_composed_wave_counts_bounded(label):
    """ASAP assembly must not regress schedule quality: composed
    pipelined waves equal the flat greedy count exactly, composed
    striped waves stay within ~15% (the measured envelope is ~5%)."""
    _, _, flat, comp = _case(label)
    cp = asap_pipelined_spec(comp, AXES, verify=False)
    fp = pipelined_spec_from_schedule(flat, AXES, verify=False)
    assert len(cp.waves) == len(fp.waves)
    cs = asap_striped_spec(comp, AXES, verify=False)
    fs = striped_spec_from_schedule(flat, AXES, verify=False)
    assert len(cs.waves) <= int(len(fs.waves) * 1.15) + 1


def test_composed_compile_is_cached_identity():
    """Recompiling the same fabric returns the IDENTICAL objects at every
    layer (schedule and spec) -- the no-retrace contract: jitted
    executors keyed on the spec never recompile across elastic events
    that land on an already-seen fabric."""
    sp = cartesian(cycle(4), complete(3))
    a = composed_allreduce_schedule(sp)
    b = composed_allreduce_schedule(sp)
    assert a is b
    assert asap_pipelined_spec(a, AXES) is asap_pipelined_spec(b, AXES)
    assert asap_striped_spec(a, AXES) is asap_striped_spec(b, AXES)
    assert composed_spec_for_star(sp, AXES, engine="striped") \
        is asap_striped_spec(a, AXES)


def test_schedule_kwarg_routes_to_composed():
    """``striped_spec_from_schedule(..., schedule="composed")`` on a
    composed schedule returns the composed-cache object, and an unknown
    strategy raises."""
    sp = cartesian(cycle(4), complete(3))
    sched = composed_allreduce_schedule(sp)
    via_kwarg = striped_spec_from_schedule(sched, AXES, schedule="composed")
    assert via_kwarg is asap_striped_spec(sched, AXES)
    assert via_kwarg.key[-1] == "composed"
    with pytest.raises(ValueError, match="schedule="):
        striped_spec_from_schedule(sched, AXES, schedule="annealed")
