"""Minimal offline stand-in for the ``hypothesis`` package.

This environment cannot install hypothesis; ``conftest.py`` registers this
module as ``sys.modules["hypothesis"]`` ONLY when the real package is
absent, so the property-test bodies run unchanged either way.

Semantics: ``@settings(max_examples=N)`` + ``@given(**strategies)`` replays
the test body over a deterministic, seeded example corpus (seeded per test
name, so runs are reproducible and order-independent).  No shrinking, no
adaptive search -- just broad seeded coverage, which is what the property
tests here need (their invariants are verified internally via .verify()).

Supported strategies: ``integers(min, max)`` and ``sampled_from(seq)`` --
the only two the test suite uses.  Extend ``_Strategy`` draws as needed.
"""
from __future__ import annotations

import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


class settings:
    """Decorator recording run options; applied above ``@given``."""

    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def given(*args, **strategies):
    if args:
        raise NotImplementedError("shim supports keyword strategies only")

    def deco(fn):
        def runner():
            cfg = getattr(runner, "_shim_settings", None)
            n = cfg.max_examples if cfg is not None else 20
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(**{k: s.draw(rng) for k, s in strategies.items()})

        # plain __name__/__doc__ copy only: functools.wraps would expose the
        # strategy parameter names and pytest would look for fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco


def install():
    """Register this shim as the ``hypothesis`` package (call only when the
    real one is missing)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
