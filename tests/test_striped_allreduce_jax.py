"""Striped reduce-scatter/allgather engine under shard_map on 16 fake
host devices: striped_allreduce == psum == packet simulator (uneven m,
m < n, quantized wires, weighted fractions with a retired tree), the
first-class tree_reduce_scatter / tree_allgather ops against the numpy
stripe layout, spec-cache jit stability, and fault-runtime link kills on
an engine="striped" runtime."""

CODE = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist  # installs compat shard_map
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    simulate_striped_program,
                                    striped_spec_from_schedule)
from repro.dist.striped import striped_allreduce

mesh = jax.make_mesh((16,), ('data',))


def smapped(body):
    return jax.shard_map(lambda xs: body(xs.reshape(xs.shape[1:]))[None],
                         mesh=mesh, in_specs=P('data'),
                         out_specs=P('data'))


for dims in [(4, 4), (2, 8)]:
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    spec = striped_spec_from_schedule(sched, ('data',))

    # the packet replay validates the compiled program itself, with the
    # per-stripe conservation check on
    vals = np.random.RandomState(0).randn(sp.n, 8 * sched.k + 5)
    sim = simulate_striped_program(spec, vals)
    assert sim.ok and sim.stripes_ok, dims

    # uneven m (53 % k != 0), m < n (d=3): psum equivalence
    for d in (53, 3, 64):
        x = jnp.asarray(np.random.RandomState(d).randn(16, d)
                        .astype(np.float32))
        yp = jax.jit(smapped(lambda v: jax.lax.psum(v, 'data')))(x)
        y = jax.jit(smapped(lambda v: striped_allreduce(v, spec)))(x)
        assert jnp.allclose(y, yp, atol=1e-4), (dims, d)

        # quantized stripe wires (forced codecs -- "auto" may disable
        # compression on host backends): bounded relative error
        expect = x.sum(0)
        for codec in ("full", "hybrid", "bcast"):
            yq = jax.jit(smapped(
                lambda v, c=codec: striped_allreduce(
                    v, spec, quantize=True, codec=c)))(x)
            rel = float(jnp.max(jnp.abs(yq[0] - expect)
                                / (jnp.abs(expect) + 1)))
            assert rel < 0.35, (dims, d, codec, rel)
        # the model-picked codec stays psum-close on every backend
        ya = jax.jit(smapped(lambda v: striped_allreduce(
            v, spec, quantize=True)))(x)
        rel = float(jnp.max(jnp.abs(ya[0] - expect)
                            / (jnp.abs(expect) + 1)))
        assert rel < 0.35, (dims, d, rel)

    # weighted fractions, including a retired (fraction-0) tree
    if sched.k >= 2:
        x = jnp.asarray(np.random.RandomState(7).randn(16, 53)
                        .astype(np.float32))
        yp = jax.jit(smapped(lambda v: jax.lax.psum(v, 'data')))(x)
        for fr in [(0.7, 0.3), (1.0, 0.0)]:
            y = jax.jit(smapped(
                lambda v, fr=fr: striped_allreduce(
                    v, spec, fractions=fr)))(x)
            assert jnp.allclose(y, yp, atol=1e-4), (dims, fr)
            assert simulate_striped_program(
                spec, np.random.RandomState(1).randn(16, 53), fr).ok

print("STRIPED_ALLREDUCE_OK")
"""

RS_AG_CODE = r"""
import functools
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import (allreduce_schedule,
                                    striped_spec_from_schedule)
from repro.dist.striped import (stripe_layout, striped_allreduce,
                                tree_allgather, tree_reduce_scatter)

mesh = jax.make_mesh((16,), ('data',))
sp = topo.device_topology((4, 4))
sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
spec = striped_spec_from_schedule(sched, ('data',))

d = 37
x = jnp.asarray(np.random.RandomState(11).randn(16, d).astype(np.float32))
lay = stripe_layout(spec, d)

owned = jax.jit(jax.shard_map(
    lambda xs: tree_reduce_scatter(xs.reshape(xs.shape[1:]), spec)[None],
    mesh=mesh, in_specs=P('data'), out_specs=P('data')))(x)

# every vertex holds the globally-summed stripe its preorder slot owns
tot = np.asarray(x).sum(0)
off = 0
for j, s in enumerate(lay.sizes):
    chunk = np.zeros(lay.mrow, np.float32)
    chunk[:s] = tot[off:off + s]
    off += s
    for v in range(16):
        o = int(lay.own_off[j, v])
        l = int(lay.own_len[j, v])
        assert np.allclose(np.asarray(owned[v, j, :l]), chunk[o:o + l],
                           atol=1e-4), (j, v)
        assert np.allclose(np.asarray(owned[v, j, l:]), 0.0), (j, v)

# allgather is the exact inverse: every vertex reassembles the full sum
y = jax.jit(jax.shard_map(
    lambda ow: tree_allgather(ow.reshape(ow.shape[1:]), spec, (d,))[None],
    mesh=mesh, in_specs=P('data'), out_specs=P('data')))(owned)
assert jnp.allclose(y, jnp.tile(x.sum(0), (16, 1)), atol=1e-4)

# spec cache: recompiles return the identical object and never retrace
@functools.partial(jax.jit, static_argnums=1)
def run(xs, sp_):
    return jax.shard_map(
        lambda v: striped_allreduce(v.reshape(v.shape[1:]), sp_)[None],
        mesh=mesh, in_specs=P('data'), out_specs=P('data'))(xs)

s2 = striped_spec_from_schedule(
    allreduce_schedule(sp.n, star_edsts(sp).trees), ('data',))
assert s2 is spec, "spec cache must return the identical object"
y1 = run(x, spec)
before = run._cache_size()
y2 = run(x, s2)
assert run._cache_size() == before, "striped spec swap retraced"
assert jnp.allclose(y1, y2)
print("STRIPED_RS_AG_OK")
"""

FAULT_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist
from repro.core.collectives import StripedCollectiveSpec
from repro.core.fault import FailureEvent
from repro.dist.steps import edst_spec_for_mesh, fault_runtime_for_mesh
from repro.dist.tree_allreduce import tree_allreduce

# engine selection end to end: spec compile + generic dispatch
spec = edst_spec_for_mesh((16, 1), ('data', 'model'),
                          dp_torus_shape=(4, 4), engine="striped")
assert isinstance(spec, StripedCollectiveSpec)
assert edst_spec_for_mesh((16, 1), ('data', 'model'),
                          dp_torus_shape=(4, 4), engine="striped") is spec

rt = fault_runtime_for_mesh((16, 1), ('data', 'model'),
                            dp_torus_shape=(4, 4), engine="striped")
assert rt.engine == "striped"
assert all(isinstance(e.spec, StripedCollectiveSpec) for e in rt.entries)
mesh = jax.make_mesh((16, 1), ('data', 'model'))
sync = rt.make_allreduce()

x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01

f = jax.jit(jax.shard_map(
    lambda xs, sid: sync(xs.reshape(xs.shape[1:]), sid)[None],
    mesh=mesh, in_specs=(P('data'), P()), out_specs=P('data'),
    axis_names={'data'}, check_vma=False))
g = jax.jit(jax.shard_map(
    lambda xs: jax.lax.psum(xs.reshape(xs.shape[1:]), 'data')[None],
    mesh=mesh, in_specs=P('data'), out_specs=P('data'),
    axis_names={'data'}, check_vma=False))
h = jax.jit(jax.shard_map(
    lambda xs: tree_allreduce(xs.reshape(xs.shape[1:]), spec)[None],
    mesh=mesh, in_specs=P('data'), out_specs=P('data'),
    axis_names={'data'}, check_vma=False))

yp = g(x)
assert jnp.allclose(h(x), yp, atol=1e-4)     # dispatcher path
y0 = f(x, jnp.int32(0))

# kill a tree-0 link mid-run: scalar flip, no retrace, ownership
# re-stripes over the k-1 survivors, psum equality holds
dead = next(iter(rt.entries[0].sched.trees[0].tree))
rt2 = rt.on_failure(FailureEvent(links=frozenset({dead})))
traces = f._cache_size()
y1 = f(x, jnp.int32(rt2.active))
assert f._cache_size() == traces, "link-kill schedule flip retraced"
rt3 = rt.on_failure(FailureEvent(links=frozenset({dead})),
                    prefer="degraded")
assert rt3.entries[rt3.active].spec.k == rt.k - 1
y2 = f(x, jnp.int32(rt3.active))
for y in (y0, y1, y2):
    assert jnp.allclose(y, yp, atol=1e-2), float(jnp.max(jnp.abs(y - yp)))
print("STRIPED_FAULT_OK")
"""


def test_striped_matches_psum_and_simulator(subproc):
    out = subproc(CODE, 16)
    assert "STRIPED_ALLREDUCE_OK" in out


def test_reduce_scatter_allgather_first_class(subproc):
    out = subproc(RS_AG_CODE, 16)
    assert "STRIPED_RS_AG_OK" in out


def test_striped_fault_runtime_link_kill(subproc):
    out = subproc(FAULT_CODE, 16)
    assert "STRIPED_FAULT_OK" in out
