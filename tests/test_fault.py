"""Fault-tolerance machinery: core.fault invariants and the dist.fault
elastic runtime's pure-Python layer (fast unit tier; the shard_map
execution path is covered by tests/test_fault_runtime_jax.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import topologies as topo
from repro.core.collectives import allreduce_schedule, simulate_allreduce
from repro.core.edst_star import star_edsts
from repro.core.fault import (FailureEvent, FaultTolerantAllreduce,
                              rebalance_chunks, rebuild_edsts,
                              surviving_trees)
from repro.core.graph import (edges_are_spanning_tree,
                              pairwise_edge_disjoint)
from repro.dist.fault import (FaultAwareAllreduce, NoScheduleError,
                              chunk_sizes)

pytestmark = pytest.mark.unit


def _fabric(dims=(4, 4)):
    sp = topo.device_topology(dims)
    return sp.product(), star_edsts(sp).trees


# ---------------------------------------------------------------------------
# core.fault
# ---------------------------------------------------------------------------

def test_rebuild_edsts_preserves_edge_disjointness():
    g, trees = _fabric()
    rng = np.random.RandomState(0)
    edges = sorted(g.edges)
    for trial in range(5):
        kill = {edges[i] for i in rng.choice(len(edges), size=3,
                                             replace=False)}
        rebuilt, residual = rebuild_edsts(g, kill)
        assert pairwise_edge_disjoint(rebuilt)
        for t in rebuilt:
            assert edges_are_spanning_tree(g.n, t)
            assert not set(t) & kill, "rebuilt tree uses a dead link"
            assert set(t) <= residual.edges


def test_rebuild_edsts_on_disconnected_residual_returns_empty():
    g, _ = _fabric()
    # kill every link of node 0: residual cannot span
    kill = {tuple(sorted((0, w))) for w in g.adj()[0]}
    rebuilt, residual = rebuild_edsts(g, kill)
    assert rebuilt == []
    assert not residual.is_connected()


def test_rebalance_chunks_conserves_mass():
    g, trees = _fabric()
    sched = allreduce_schedule(g.n, trees)
    for delays in ({}, {3: 4.0}, {0: 2.0, 7: 8.0}):
        fracs = rebalance_chunks(sched, delays)
        assert len(fracs) == sched.k
        assert all(f >= 0 for f in fracs)
        assert abs(sum(fracs) - 1.0) < 1e-9
    # weighted striping conserves total chunk bytes exactly
    for delays in ({}, {5: 16.0}):
        fracs = rebalance_chunks(sched, delays)
        for total in (64, 1 << 20, (1 << 20) + 13):
            assert sum(chunk_sizes(total, fracs)) == total


def test_on_failure_matches_simulator_on_4x4_torus():
    g, trees = _fabric((4, 4))
    sched = allreduce_schedule(g.n, trees)
    fta = FaultTolerantAllreduce(g, sched)
    vals = np.random.RandomState(0).randn(g.n, 4)
    assert simulate_allreduce(fta.schedule, vals).ok

    dead = next(iter(trees[0]))
    fta2 = fta.on_failure(FailureEvent(links=frozenset({dead})))
    assert fta2.k == len(trees) - 1
    keep = surviving_trees(trees, {dead})
    assert [ts.tree for ts in fta2.schedule.trees] == \
        [frozenset(t) for t in keep]
    vals2 = np.random.RandomState(1).randn(g.n, fta2.k * 3)
    assert simulate_allreduce(fta2.schedule, vals2).ok


# ---------------------------------------------------------------------------
# dist.fault: precompiled failure classes
# ---------------------------------------------------------------------------

def test_chunk_sizes_partition_exactly():
    for total in (1, 7, 103, 1024):
        for fracs in ((1.0,), (0.5, 0.5), (0.7, 0.3), (0.4, 0.35, 0.25),
                      (0.0, 1.0)):
            sizes = chunk_sizes(total, fracs)
            assert sum(sizes) == total
            assert all(s >= 0 for s in sizes)
            if total >= len(fracs):
                for s, f in zip(sizes, fracs):
                    assert f > 0 or s == 0, "retired tree got traffic"


def test_entry_layout_and_validity():
    g, trees = _fabric()
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    k = len(trees)
    assert rt.k == k
    assert len(rt.entries) == 2 * k + 1
    assert rt.entries[0].name == "full" and rt.entries[0].k == k
    for j in range(k):
        deg = rt.entries[1 + j]
        assert deg.k == k - 1
        # degraded/rebuilt class j is valid for EVERY link of tree j
        for link in trees[j]:
            ev = FailureEvent(links=frozenset({link}))
            valid = rt.valid_ids(ev)
            assert 1 + j in valid
            assert 1 + k + j in valid
            assert 0 not in valid
    for e in rt.entries:
        assert abs(sum(e.fractions) - 1.0) < 1e-9
        assert rt.verify_entry(rt.entries.index(e))


def test_on_failure_switches_id_without_rebuilding():
    g, trees = _fabric()
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    link = next(iter(trees[1]))
    rt2 = rt.on_failure(FailureEvent(links=frozenset({link})))
    assert rt2.entries is rt.entries  # same precompiled programs
    assert rt2.entry.name.endswith("tree1")
    assert not rt2.entry.uses_link({link})
    rt3 = rt.on_failure(FailureEvent(links=frozenset({link})),
                        prefer="degraded")
    assert rt3.entry.name == "degraded/tree1"
    assert rt3.entry.k == len(trees) - 1


def test_spare_link_failure_keeps_full_schedule():
    g, trees = _fabric((2, 16))  # ring-ish fabric with spare links
    used = set().union(*trees)
    spare = sorted(g.edges - used)
    if not spare:
        pytest.skip("no spare links on this fabric")
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    rt2 = rt.on_failure(FailureEvent(links=frozenset({spare[0]})))
    assert rt2.entry.k == rt.k  # nothing lost


def test_multi_tree_failure_escalates_to_dynamic_rebuild():
    g, trees = _fabric()
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    # hit every precompiled program: one dead link from each entry's trees
    links = frozenset(next(iter(e.sched.trees[0].tree)) for e in rt.entries)
    ev = FailureEvent(links=links)
    with pytest.raises(NoScheduleError):
        rt.on_failure(ev)
    rt2 = rt.with_rebuild(ev)
    assert rt2.k >= 1
    dead = ev.dead_links(g)
    for ts in rt2.entries[0].sched.trees:
        assert not set(ts.tree) & dead
    assert rt2.verify_entry(0)


def test_node_failure_raises_toward_elastic_rescale():
    g, trees = _fabric()
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    with pytest.raises(NoScheduleError):
        rt.on_failure(FailureEvent(nodes=frozenset({3})))


def test_failure_drill_reports_recovery():
    from repro.launch.elastic import failure_drill
    g, trees = _fabric()
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    rep = failure_drill(rt, n_events=2, nbytes=1 << 20, seed=0)
    assert rep["k"] == len(trees) and rep["healthy_gbps"] > 0
    assert len(rep["events"]) == 2
    for ev in rep["events"]:
        assert ev["sim_ok"]
        assert ev["k"] >= 1
        assert 0 < ev["bw_retained"] <= 1.0
        assert ev["gbps"] >= ev.get("degraded_gbps", 0)


def test_fault_sweep_report_coverage():
    from benchmarks.fault_sweep import run_sweep
    tops = (("torus-4x4", lambda: topo.torus([4, 4])),
            ("slimfly-q5", lambda: topo.slimfly(5)),
            ("polarstar-q3-qr5", lambda: topo.polarstar(3, "qr", 5)))
    rep = run_sweep(nbytes=1 << 20, trials=1, topologies=tops,
                    failure_counts=(0, 1, 2))
    assert len(rep["topologies"]) >= 3
    for t in rep["topologies"]:
        assert {r["failures"] for r in t["sweep"]} >= {0, 1, 2}
        assert t["healthy"]["gbps"] > 0
        for row in t["sweep"]:
            stages = {s["stage"]: s for s in row["stages"]}
            assert stages["degraded"]["k"] <= t["k"]
            # bandwidth degrades with lost trees (gbps can exceed healthy
            # only in the latency-dominated regime when the deepest tree is
            # the one lost, so compare tree counts, not gbps)
            if row["residual_connected"]:
                assert stages["rebuilt"]["k"] >= stages["degraded"]["k"]
                assert stages["rebuilt"]["gbps"] > 0


def test_effective_bandwidth_degrades_gracefully():
    g, trees = _fabric()
    rt = FaultAwareAllreduce.build(g, trees, ("data",))
    nbytes = 64 << 20
    full = rt.effective_bandwidth(nbytes, 0)
    deg = rt.effective_bandwidth(nbytes, 1)
    assert full > deg > 0, "degraded mode should lose, not zero, bandwidth"
    rep = rt.report(nbytes)
    assert len(rep["entries"]) == len(rt.entries)
    assert rep["entries"][0]["gbps"] == pytest.approx(full / 1e9)


def test_striped_engine_runtime_entries():
    """engine="striped" runtimes carry striped specs in every failure
    class, so a link kill re-stripes ownership over the k-1 survivors."""
    from repro.core.collectives import StripedCollectiveSpec
    sp = topo.device_topology((4, 4))
    g = sp.product()
    trees = star_edsts(sp).trees
    rt = FaultAwareAllreduce.build(g, trees, ("data",), engine="striped")
    assert rt.engine == "striped"
    assert all(isinstance(e.spec, StripedCollectiveSpec)
               for e in rt.entries)
    assert [e.spec.k for e in rt.entries[1:len(trees) + 1]] \
        == [len(trees) - 1] * len(trees)
    # failure flip + verify_entry run on the same core schedules
    dead = next(iter(rt.entries[0].sched.trees[0].tree))
    rt2 = rt.on_failure(FailureEvent(links=frozenset({dead})))
    assert rt2.active != 0 and rt2.engine == "striped"
    assert rt.verify_entry(rt2.active)
    with pytest.raises(ValueError):
        FaultAwareAllreduce.build(g, trees, ("data",), engine="bogus")
