"""JAX shard_map EDST tree allreduce: numerical equivalence with psum, on
16 fake devices (subprocess so the main test process keeps 1 device)."""

CODE = r"""
import os
assert "XLA_FLAGS" in os.environ
import sys
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import topologies as topo
from repro.core.edst_star import star_edsts
from repro.core.collectives import allreduce_schedule
from repro.dist.tree_allreduce import spec_from_schedule, tree_allreduce

mesh = jax.make_mesh((4, 4), ('a', 'b'))
x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01
expect = x.sum(0)

for dims in [(4, 4), (2, 8)]:
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    spec = spec_from_schedule(sched, ('a', 'b'))
    def f(xs):
        return tree_allreduce(xs.reshape(xs.shape[1:]), spec)[None]
    y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(('a','b')),
                              out_specs=P(('a','b'))))(x)
    assert jnp.allclose(y, jnp.tile(expect, (16, 1))), dims
    def fq(xs):
        return tree_allreduce(xs.reshape(xs.shape[1:]), spec, quantize=True)[None]
    yq = jax.jit(jax.shard_map(fq, mesh=mesh, in_specs=P(('a','b')),
                               out_specs=P(('a','b'))))(x)
    rel = float(jnp.max(jnp.abs(yq[0] - expect) / (jnp.abs(expect) + 1)))
    assert rel < 0.05, (dims, rel)
print("TREE_ALLREDUCE_OK")
"""

TRAIN_CODE = r"""
import os, jax, jax.numpy as jnp
from repro import configs
from repro.models.api import build
from repro.dist.steps import make_train_step
from repro.optim import AdamW, cosine_schedule

cfg = configs.get('smollm-135m').reduced()
api = build(cfg)
mesh = jax.make_mesh((4, 4), ('data', 'model'))
opt = AdamW(cosine_schedule(1e-3, 10, 100))
params, _ = api.init(jax.random.PRNGKey(0))
opt_state = opt.init(params)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 65), 0, cfg.vocab)}
outs = {}
for mode in ['gspmd', 'psum_dp', 'edst']:
    step = make_train_step(api, opt, mesh, mode=mode)
    with jax.set_mesh(mesh):
        p2, o2, m = jax.jit(step)(params, opt_state, batch)
    outs[mode] = (float(m['loss']), p2)
ref_loss, ref_p = outs['gspmd']
for mode in ['psum_dp', 'edst']:
    loss, p = outs[mode]
    assert abs(loss - ref_loss) < 1e-4, (mode, loss, ref_loss)
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(p)))
    assert diff < 1e-4, (mode, diff)
print("TRAIN_MODES_OK")
"""


def test_tree_allreduce_matches_sum(subproc):
    out = subproc(CODE, 16)
    assert "TREE_ALLREDUCE_OK" in out


def test_train_step_sync_modes_agree(subproc):
    out = subproc(TRAIN_CODE, 16)
    assert "TRAIN_MODES_OK" in out


DP_TORUS_CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist.steps import edst_spec_for_mesh
from repro.dist.tree_allreduce import tree_allreduce

# pure-DP pod: 16 devices on the 'data' axis, physically a 4x4 torus
mesh = jax.make_mesh((16, 1), ('data', 'model'))
spec = edst_spec_for_mesh((16, 1), ('data', 'model'), dp_torus_shape=(4, 4))
assert spec.k == 2, spec.k   # the 2D torus gives the maximal 2 EDSTs
x = jnp.arange(16 * 19, dtype=jnp.float32).reshape(16, 19)
def f(xs):
    return tree_allreduce(xs.reshape(xs.shape[1:]), spec)[None]
y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('data'),
                          out_specs=P('data'), axis_names={'data'},
                          check_vma=False))(x)
assert jnp.allclose(y, jnp.tile(x.sum(0), (16, 1)))
print("DP_TORUS_OK")
"""


def test_dp_torus_shape_override(subproc):
    out = subproc(DP_TORUS_CODE, 16)
    assert "DP_TORUS_OK" in out
