"""End-to-end behaviour: training loop convergence, checkpoint/restart
determinism, data pipeline determinism + host sharding, optimizer,
sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import latest_step, restore, save_checkpoint
from repro.data import SyntheticLMStream
from repro.dist.sharding import spec_for
from repro.launch.train import main as train_main
from repro.optim import AdamW, cosine_schedule, global_norm_clip


def test_training_loss_decreases(tmp_path):
    losses = train_main(["--arch", "smollm-135m", "--reduced", "--steps", "40",
                         "--batch", "8", "--seq", "96", "--mesh", "1,1",
                         "--log-every", "100"])
    assert losses[-1] < losses[0]


def test_checkpoint_restart_bit_identical(tmp_path):
    d = str(tmp_path / "ck")
    args = ["--arch", "smollm-135m", "--reduced", "--batch", "4",
            "--seq", "64", "--mesh", "1,1", "--ckpt-dir", d,
            "--log-every", "100"]
    # run 20 steps straight through
    full = train_main(args + ["--steps", "20", "--ckpt-every", "10000"])
    # run 10, checkpoint, resume to 20
    import shutil
    shutil.rmtree(d, ignore_errors=True)
    train_main(args + ["--steps", "10", "--ckpt-every", "10000"])
    assert latest_step(d) == 10
    resumed = train_main(args + ["--steps", "20", "--ckpt-every", "10000"])
    np.testing.assert_allclose(resumed[-1], full[-1], atol=1e-4)


def test_data_determinism_and_host_sharding():
    s1 = SyntheticLMStream(100, 32, 8, seed=3)
    s2 = SyntheticLMStream(100, 32, 8, seed=3)
    np.testing.assert_array_equal(s1.batch(7), s2.batch(7))
    assert not np.array_equal(s1.batch(7), s1.batch(8))
    # 2-host sharding tiles the global batch disjointly & deterministically
    h0 = SyntheticLMStream(100, 32, 8, seed=3, n_hosts=2, host_id=0)
    h1 = SyntheticLMStream(100, 32, 8, seed=3, n_hosts=2, host_id=1)
    b0, b1 = h0.batch(5), h1.batch(5)
    assert b0.shape == (4, 33) and b1.shape == (4, 33)
    assert not np.array_equal(b0, b1)


def test_adamw_and_clip():
    opt = AdamW(cosine_schedule(1e-2, 2, 50))
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.ones((4,))}
    clipped, gn = global_norm_clip(grads, 1.0)
    assert float(gn) > 1.0
    norm_after = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(clipped)))
    assert float(norm_after) == pytest.approx(1.0, rel=1e-5)
    p2, s2, m = opt.apply(params, grads, state)
    assert not jnp.allclose(p2["w"], params["w"])
    assert int(s2.step) == 1


def test_sharding_rules_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    m = FakeMesh()
    # heads=28 not divisible by 16 -> falls through to head_dim
    spec = spec_for(("embed", "heads", "head_dim"), (3584, 28, 128), m)
    assert spec == jax.sharding.PartitionSpec("data", None, "model")
    # vocab padded divisible
    spec = spec_for(("vocab", "embed"), (152064, 3584), m)
    assert spec == jax.sharding.PartitionSpec("model", "data")
    # experts win priority over mlp
    spec = spec_for(("experts", "embed", "mlp"), (64, 2048, 1024), m)
    assert spec[0] == "model"
    # batch=1 (long_500k) stays replicated
    spec = spec_for(("batch", None), (1, 7), m, fsdp=False)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_checkpoint_atomic_layout(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    save_checkpoint(d, 3, tree)
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 9, tree)   # keeps two most recent
    assert latest_step(d) == 9
    steps = sorted(int(x[5:]) for x in os.listdir(d) if x.startswith("step_"))
    assert steps == [7, 9]
    restored, step, _ = restore(d, tree)
    assert step == 9
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_arch_registry_complete():
    assert len(configs.ARCHS) == 10
    for name, cfg in configs.ARCHS.items():
        assert cfg.name == name
        assert cfg.param_count() > 0
        r = cfg.reduced()
        assert r.n_layers <= 4 and r.d_model <= 256
        # skip bookkeeping: long_500k only runs for sub-quadratic archs
        if cfg.family in ("rglru", "rwkv6"):
            assert "long_500k" not in cfg.skip_shapes
        else:
            assert "long_500k" in cfg.skip_shapes and cfg.skip_reason


def test_serve_driver_smoke():
    from repro.launch.serve import main as serve_main
    gen = serve_main(["--arch", "smollm-135m", "--reduced", "--batch", "2",
                      "--prompt-len", "8", "--gen", "4"])
    assert gen.shape == (2, 4)


def test_grad_accumulation_matches_full_batch():
    """grad_accum=N == single-step on the same global batch."""
    from repro.models.api import build
    from repro.dist.steps import make_train_step
    cfg = configs.get("smollm-135m").reduced()
    api = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = AdamW(cosine_schedule(1e-3, 5, 50))
    params, _ = api.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 65), 0,
                                          cfg.vocab)}
    with jax.set_mesh(mesh):
        p1, _, m1 = jax.jit(make_train_step(api, opt, mesh))(
            params, opt_state, batch)
        p4, _, m4 = jax.jit(make_train_step(api, opt, mesh, grad_accum=4))(
            params, opt_state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert diff < 5e-3, diff


def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint on one mesh, resume on a different mesh: params identical,
    EDST schedule rebuilt for the new fabric."""
    from repro.launch.elastic import rebuild_schedule, reshard_checkpoint
    from repro.models.api import build
    d = str(tmp_path / "ck")
    cfg = configs.get("smollm-135m").reduced()
    api = build(cfg)
    opt = AdamW(cosine_schedule(3e-4, 10, 100))
    train_main(["--arch", "smollm-135m", "--reduced", "--steps", "4",
                "--batch", "4", "--seq", "48", "--mesh", "1,1",
                "--ckpt-dir", d, "--ckpt-every", "4", "--log-every", "100"])
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    params, opt_state, step = reshard_checkpoint(api, opt, d, mesh2)
    assert step == 4
    assert int(opt_state.step) == 4
    # single-data-shard mesh: no DP fabric, nothing to sync
    assert rebuild_schedule(jax.make_mesh((1, 1), ("data", "model"))) is None


# ---------------------------------------------------------------------------
# ZeRO-1 sharded checkpoints (owner-stripe save / re-shard restore)
# ---------------------------------------------------------------------------

def test_flatten_prefix_keys_never_collide():
    """Regression: "/"-joined flat keys used to collide for trees like
    {"a": {"b/c": x}} vs {"a/b": {"c": x}} -- one silently clobbered the
    other in the npz.  Keys are now percent-escaped per level."""
    from repro.ckpt.checkpoint import _flatten, _unflatten_into
    tree = {"a": {"b/c": np.ones(2)}, "a/b": {"c": np.zeros(2)},
            "pct%": {"x": np.full(2, 3.0)}}
    flat = _flatten(tree)
    assert len(flat) == 3
    back = _unflatten_into(tree, flat)
    assert back["a"]["b/c"][0] == 1.0
    assert back["a/b"]["c"][0] == 0.0
    assert back["pct%"]["x"][0] == 3.0


def _zero1_fixture(m=53):
    from repro.core.collectives import owner_element_map
    from repro.dist.steps import edst_spec_for_mesh
    from repro.optim import ShardedOptState
    spec = edst_spec_for_mesh((16, 1), ("data", "model"), (4, 4),
                              engine="striped")
    emap = owner_element_map(spec, m)
    rng = np.random.RandomState(5)
    params = {"w": jnp.asarray(rng.randn(6, 8), jnp.float32),
              "b": jnp.asarray(rng.randn(5), jnp.float32)}
    mu = jnp.asarray(np.where(emap >= 0, rng.randn(*emap.shape), 0.0),
                     jnp.float32)
    nu = jnp.asarray(np.where(emap >= 0, rng.rand(*emap.shape), 0.0),
                     jnp.float32)
    state = ShardedOptState(jnp.asarray(9, jnp.int32), mu, nu)
    return spec, emap, params, state


def _reassemble(stacks, emap, m):
    flat = np.zeros(m, np.float32)
    live = np.asarray(emap) >= 0
    flat[np.asarray(emap)[live]] = np.asarray(stacks)[live]
    return flat


def test_sharded_checkpoint_roundtrip_bitwise(tmp_path):
    """Same fabric: per-host stripe shards re-assemble bit-identical,
    params/step/extra survive, and the step dir holds one shard file per
    owner host next to the replicated arrays."""
    from repro.ckpt import restore_sharded, save_sharded_checkpoint
    m = 53
    spec, emap, params, state = _zero1_fixture(m)
    d = str(tmp_path / "zck")
    final = save_sharded_checkpoint(d, 7, params, state, emap, m,
                                    extra={"tokens": 123})
    names = sorted(os.listdir(final))
    assert "arrays.npz" in names and "manifest.json" in names
    assert sum(nm.startswith("shard_") for nm in names) == spec.n
    p2, st2, step, extra = restore_sharded(d, params, emap)
    assert step == 7 and extra == {"tokens": 123}
    assert int(st2.step) == 9
    assert np.array_equal(np.asarray(st2.mu), np.asarray(state.mu))
    assert np.array_equal(np.asarray(st2.nu), np.asarray(state.nu))
    for k in params:
        assert np.array_equal(np.asarray(p2[k]), np.asarray(params[k]))


def test_sharded_checkpoint_reshards_to_degraded_fabric(tmp_path):
    """A checkpoint taken on the healthy k-tree fabric restores onto the
    re-striped k-1 (retired-tree) ownership map: different (kmax, smax)
    geometry, same flat moments."""
    from repro.ckpt import restore_sharded, save_sharded_checkpoint
    from repro.core.collectives import owner_element_map
    m = 53
    spec, emap, params, state = _zero1_fixture(m)
    d = str(tmp_path / "zck")
    save_sharded_checkpoint(d, 4, params, state, emap, m)
    fr = tuple(1.0 if j == 0 else 0.0 for j in range(spec.k))
    emap2 = owner_element_map(spec, m, fr)
    assert np.asarray(emap2).shape != np.asarray(emap).shape
    p3, st3, step, _ = restore_sharded(d, params, emap2)
    assert step == 4
    np.testing.assert_allclose(_reassemble(st3.mu, emap2, m),
                               _reassemble(state.mu, emap, m), rtol=0)
    np.testing.assert_allclose(_reassemble(st3.nu, emap2, m),
                               _reassemble(state.nu, emap, m), rtol=0)


def test_sharded_checkpoint_detects_torn_shard(tmp_path):
    """S3: every stripe shard's CRC32 is recorded in the manifest and
    verified on restore -- a single flipped byte in one host's shard file
    fails the restore loudly, naming the torn file, instead of silently
    loading corrupt optimizer moments; restoring the original bytes
    succeeds again."""
    from repro.ckpt import restore_sharded, save_sharded_checkpoint
    m = 53
    spec, emap, params, state = _zero1_fixture(m)
    d = str(tmp_path / "zck")
    final = save_sharded_checkpoint(d, 7, params, state, emap, m)
    shard = os.path.join(final, "shard_00007.npz")
    with open(shard, "rb") as f:
        blob = bytearray(f.read())
    blob[len(blob) // 2] ^= 0xFF
    with open(shard, "wb") as f:
        f.write(blob)
    with pytest.raises(ValueError, match="shard_00007"):
        restore_sharded(d, params, emap)
    # untearing the file restores a loadable checkpoint
    blob[len(blob) // 2] ^= 0xFF
    with open(shard, "wb") as f:
        f.write(blob)
    _, st2, step, _ = restore_sharded(d, params, emap)
    assert step == 7
    assert np.array_equal(np.asarray(st2.mu), np.asarray(state.mu))
