"""Elastic EDST runtime under shard_map on 16 fake host devices: killing a
tree's link mid-run flips a scalar schedule id (no retrace) and keeps the
edst gradient sync numerically equal to ``jax.lax.psum``."""

ALLREDUCE_CODE = r"""
import os
assert "XLA_FLAGS" in os.environ
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro.dist  # installs compat shard_map
from repro.core.fault import FailureEvent
from repro.dist.steps import fault_runtime_for_mesh

rt = fault_runtime_for_mesh((16, 1), ('data', 'model'), dp_torus_shape=(4, 4))
assert rt.k == 2 and len(rt.entries) == 5
mesh = jax.make_mesh((16, 1), ('data', 'model'))
sync = rt.make_allreduce()

x = jnp.arange(16 * 53, dtype=jnp.float32).reshape(16, 53) * 0.01
expect = x.sum(0)

def body(xs, sid):
    return sync(xs.reshape(xs.shape[1:]), sid)[None]

f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P('data'), P()),
                          out_specs=P('data'), axis_names={'data'},
                          check_vma=False))

# healthy run, then kill a tree-0 link mid-run: same compiled fn, new id
y0 = f(x, jnp.int32(0))
assert jnp.allclose(y0, jnp.tile(expect, (16, 1)))

dead = next(iter(rt.entries[0].sched.trees[0].tree))
rt2 = rt.on_failure(FailureEvent(links=frozenset({dead})))
assert rt2.active != 0 and rt2.entries is rt.entries
traces_before = f._cache_size()
y1 = f(x, jnp.int32(rt2.active))             # schedule flip: no retrace
assert f._cache_size() == traces_before, "schedule switch retraced"
assert jnp.allclose(y1, jnp.tile(expect, (16, 1)))

# the degraded (k-1 striping) program agrees too
rt3 = rt.on_failure(FailureEvent(links=frozenset({dead})), prefer="degraded")
assert rt3.entry.name == "degraded/tree0" and rt3.entry.k == 1
y2 = f(x, jnp.int32(rt3.active))
assert jnp.allclose(y2, jnp.tile(expect, (16, 1)))

# equality with psum on the same mesh
g = jax.jit(jax.shard_map(
    lambda xs: jax.lax.psum(xs.reshape(xs.shape[1:]), 'data')[None],
    mesh=mesh, in_specs=P('data'), out_specs=P('data'),
    axis_names={'data'}, check_vma=False))
yp = g(x)
for y in (y0, y1, y2):
    assert jnp.allclose(y, yp, atol=1e-5)
print("FAULT_ALLREDUCE_OK")
"""

TRAIN_CODE = r"""
import jax, jax.numpy as jnp
from repro import configs
from repro.core.fault import FailureEvent
from repro.models.api import build
from repro.dist.steps import fault_runtime_for_mesh, make_train_step
from repro.optim import AdamW, cosine_schedule

cfg = configs.get('smollm-135m').reduced()
api = build(cfg)
mesh = jax.make_mesh((16, 1), ('data', 'model'))
rt = fault_runtime_for_mesh((16, 1), ('data', 'model'), dp_torus_shape=(4, 4))
opt = AdamW(cosine_schedule(1e-3, 10, 100))
params, _ = api.init(jax.random.PRNGKey(0))
opt_state = opt.init(params)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (16, 65), 0,
                                      cfg.vocab)}

ref_step = make_train_step(api, opt, mesh, mode='psum_dp')
step = make_train_step(api, opt, mesh, mode='edst', fault_runtime=rt)

with jax.set_mesh(mesh):
    jstep = jax.jit(step)
    jref = jax.jit(ref_step)
    # step 1: healthy schedule
    p1, o1, m1 = jstep(params, opt_state, batch, jnp.int32(0))
    r1, ro1, rm1 = jref(params, opt_state, batch)
    # mid-run link failure: flip the schedule id, keep the compiled step
    dead = next(iter(rt.entries[0].sched.trees[0].tree))
    rt = rt.on_failure(FailureEvent(links=frozenset({dead})),
                       prefer="degraded")
    p2, o2, m2 = jstep(p1, o1, batch, jnp.int32(rt.active))
    r2, ro2, rm2 = jref(r1, ro1, batch)

for (ma, mb) in ((m1, rm1), (m2, rm2)):
    assert abs(float(ma['loss']) - float(mb['loss'])) < 1e-4
diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                 b.astype(jnp.float32))))
           for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(r2)))
assert diff < 1e-4, diff
print("FAULT_TRAIN_OK")
"""


def test_fault_allreduce_survives_link_kill(subproc):
    out = subproc(ALLREDUCE_CODE, 16)
    assert "FAULT_ALLREDUCE_OK" in out


def test_fault_train_step_matches_psum_after_failure(subproc):
    out = subproc(TRAIN_CODE, 16)
    assert "FAULT_TRAIN_OK" in out
