"""GPipe stage runner == sequential stage application (4 fake devices)."""

CODE = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist.pipeline import pipeline_apply

n_stages, n_micro, mb, d = 4, 8, 2, 16
mesh = jax.make_mesh((n_stages,), ('stage',))
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (n_stages, d, d)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

def stage_fn(w, h):
    return jnp.tanh(h @ w[0])

def pipelined(ws, x):
    return pipeline_apply(stage_fn, ws, x, 'stage')

y = jax.jit(jax.shard_map(pipelined, mesh=mesh,
                          in_specs=(P('stage'), P()),
                          out_specs=P(), check_vma=False))(ws, x)
# sequential reference
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ ws[s])
assert jnp.allclose(y, ref, atol=1e-5), float(jnp.max(jnp.abs(y - ref)))
from repro.dist.pipeline import bubble_fraction
assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential(subproc):
    out = subproc(CODE, 4)
    assert "PIPELINE_OK" in out
