"""Allreduce schedules, simulator, cost model, fault tolerance."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CostModel, FailureEvent, FaultTolerantAllreduce,
                        allreduce_schedule, rebalance_chunks,
                        simulate_allreduce, star_edsts)
from repro.core import topologies as topo


@pytest.fixture(scope="module")
def pod_sched():
    sp = topo.device_topology((16, 16))
    res = star_edsts(sp)
    return sp, allreduce_schedule(sp.n, res.trees)


def test_schedule_contention_free(pod_sched):
    _, sched = pod_sched
    assert sched.check_contention_free()


def test_simulated_allreduce_correct(pod_sched):
    sp, sched = pod_sched
    vals = np.random.RandomState(0).randn(sp.n, 8 * sched.k)
    sim = simulate_allreduce(sched, vals)
    assert sim.ok
    assert sim.max_link_load == 1  # EDST property: no link carries 2 msgs


@settings(max_examples=8, deadline=None)
@given(dims=st.sampled_from([(4, 4), (2, 8), (8, 8), (2, 4, 4)]),
       seed=st.integers(0, 100))
def test_allreduce_on_any_torus(dims, seed):
    sp = topo.device_topology(dims)
    res = star_edsts(sp)
    sched = allreduce_schedule(sp.n, res.trees)
    vals = np.random.RandomState(seed).randn(sp.n, 4 * sched.k)
    assert simulate_allreduce(sched, vals).ok


def test_cost_model_k_trees_beat_ring(pod_sched):
    sp, sched = pod_sched
    cm = CostModel()
    b = 64 * 2 ** 20
    assert cm.edst_tree_allreduce(b, sched) < cm.ring_allreduce(b, sp.n)
    # in-network mode halves the endpoint traversal
    assert cm.edst_tree_allreduce(b, sched, in_network=True) < \
        cm.edst_tree_allreduce(b, sched)


def test_link_failure_degrade_and_rebuild(pod_sched):
    sp, sched = pod_sched
    g = sp.product()
    fta = FaultTolerantAllreduce(g, sched)
    dead = next(iter(sched.trees[0].tree))
    fta2 = fta.on_failure(FailureEvent(links=frozenset({dead})))
    assert fta2.k == sched.k - 1
    vals = np.random.RandomState(1).randn(g.n, 8)
    assert simulate_allreduce(fta2.schedule, vals).ok
    fta3 = fta2.rebuild()
    assert fta3.k == sched.k
    assert simulate_allreduce(fta3.schedule, vals).ok


def test_node_failure_rebuild(pod_sched):
    sp, sched = pod_sched
    g = sp.product()
    fta = FaultTolerantAllreduce(g, sched).on_failure(
        FailureEvent(nodes=frozenset({7})))
    assert fta.graph.n == g.n - 1
    vals = np.random.RandomState(2).randn(fta.graph.n, 8 * fta.k)
    assert simulate_allreduce(fta.schedule, vals).ok


def test_straggler_rebalance(pod_sched):
    _, sched = pod_sched
    fracs = rebalance_chunks(sched, {5: 3.0})
    assert abs(sum(fracs) - 1.0) < 1e-9
    assert all(f >= 0 for f in fracs)


@settings(max_examples=6, deadline=None)
@given(n_fail=st.integers(1, 3), seed=st.integers(0, 1000))
def test_random_link_failures_property(n_fail, seed):
    """Property: after ANY set of random link failures that keeps >= 1 tree
    intact, the degraded schedule still computes exact sums; after rebuild,
    tree count equals the residual fabric's maximum packing."""
    import random
    from repro.core import topologies as topo
    sp = topo.device_topology((4, 4))
    g = sp.product()
    res = star_edsts(sp)
    sched = allreduce_schedule(g.n, res.trees)
    rng = random.Random(seed)
    # fail links from one tree only (keeps the other intact)
    tree0 = sorted(sched.trees[0].tree)
    dead = frozenset(rng.sample(tree0, min(n_fail, len(tree0))))
    fta = FaultTolerantAllreduce(g, sched).on_failure(FailureEvent(links=dead))
    vals = np.random.RandomState(seed).randn(g.n, 4 * fta.k)
    assert simulate_allreduce(fta.schedule, vals).ok
    rebuilt = fta.rebuild()
    vals2 = np.random.RandomState(seed + 1).randn(g.n, 4 * rebuilt.k)
    assert simulate_allreduce(rebuilt.schedule, vals2).ok
    from repro.core.edst_rt import max_edsts
    trees, _ = max_edsts(fta.graph)
    assert rebuilt.k == max(len(trees), fta.k)
