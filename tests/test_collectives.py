"""Allreduce schedules, simulator, cost model, fault tolerance."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CostModel, FailureEvent, FaultTolerantAllreduce,
                        allreduce_schedule, rebalance_chunks,
                        simulate_allreduce, star_edsts)
from repro.core import topologies as topo


@pytest.fixture(scope="module")
def pod_sched():
    sp = topo.device_topology((16, 16))
    res = star_edsts(sp)
    return sp, allreduce_schedule(sp.n, res.trees)


def test_schedule_contention_free(pod_sched):
    _, sched = pod_sched
    assert sched.check_contention_free()


def test_simulated_allreduce_correct(pod_sched):
    sp, sched = pod_sched
    vals = np.random.RandomState(0).randn(sp.n, 8 * sched.k)
    sim = simulate_allreduce(sched, vals)
    assert sim.ok
    assert sim.max_link_load == 1  # EDST property: no link carries 2 msgs


@settings(max_examples=8, deadline=None)
@given(dims=st.sampled_from([(4, 4), (2, 8), (8, 8), (2, 4, 4)]),
       seed=st.integers(0, 100))
def test_allreduce_on_any_torus(dims, seed):
    sp = topo.device_topology(dims)
    res = star_edsts(sp)
    sched = allreduce_schedule(sp.n, res.trees)
    vals = np.random.RandomState(seed).randn(sp.n, 4 * sched.k)
    assert simulate_allreduce(sched, vals).ok


def test_cost_model_k_trees_beat_ring(pod_sched):
    sp, sched = pod_sched
    cm = CostModel()
    b = 64 * 2 ** 20
    assert cm.edst_tree_allreduce(b, sched) < cm.ring_allreduce(b, sp.n)
    # in-network mode halves the endpoint traversal
    assert cm.edst_tree_allreduce(b, sched, in_network=True) < \
        cm.edst_tree_allreduce(b, sched)


def test_link_failure_degrade_and_rebuild(pod_sched):
    sp, sched = pod_sched
    g = sp.product()
    fta = FaultTolerantAllreduce(g, sched)
    dead = next(iter(sched.trees[0].tree))
    fta2 = fta.on_failure(FailureEvent(links=frozenset({dead})))
    assert fta2.k == sched.k - 1
    vals = np.random.RandomState(1).randn(g.n, 8)
    assert simulate_allreduce(fta2.schedule, vals).ok
    fta3 = fta2.rebuild()
    assert fta3.k == sched.k
    assert simulate_allreduce(fta3.schedule, vals).ok


def test_node_failure_rebuild(pod_sched):
    sp, sched = pod_sched
    g = sp.product()
    fta = FaultTolerantAllreduce(g, sched).on_failure(
        FailureEvent(nodes=frozenset({7})))
    assert fta.graph.n == g.n - 1
    vals = np.random.RandomState(2).randn(fta.graph.n, 8 * fta.k)
    assert simulate_allreduce(fta.schedule, vals).ok


def test_straggler_rebalance(pod_sched):
    _, sched = pod_sched
    fracs = rebalance_chunks(sched, {5: 3.0})
    assert abs(sum(fracs) - 1.0) < 1e-9
    assert all(f >= 0 for f in fracs)


@settings(max_examples=6, deadline=None)
@given(n_fail=st.integers(1, 3), seed=st.integers(0, 1000))
def test_random_link_failures_property(n_fail, seed):
    """Property: after ANY set of random link failures that keeps >= 1 tree
    intact, the degraded schedule still computes exact sums; after rebuild,
    tree count equals the residual fabric's maximum packing."""
    import random
    from repro.core import topologies as topo
    sp = topo.device_topology((4, 4))
    g = sp.product()
    res = star_edsts(sp)
    sched = allreduce_schedule(g.n, res.trees)
    rng = random.Random(seed)
    # fail links from one tree only (keeps the other intact)
    tree0 = sorted(sched.trees[0].tree)
    dead = frozenset(rng.sample(tree0, min(n_fail, len(tree0))))
    fta = FaultTolerantAllreduce(g, sched).on_failure(FailureEvent(links=dead))
    vals = np.random.RandomState(seed).randn(g.n, 4 * fta.k)
    assert simulate_allreduce(fta.schedule, vals).ok
    rebuilt = fta.rebuild()
    vals2 = np.random.RandomState(seed + 1).randn(g.n, 4 * rebuilt.k)
    assert simulate_allreduce(rebuilt.schedule, vals2).ok
    from repro.core.edst_rt import max_edsts
    trees, _ = max_edsts(fta.graph)
    assert rebuilt.k == max(len(trees), fta.k)


# ---------------------------------------------------------------------------
# pipelined wave program (list-scheduled, segment-streaming compiled form)
# ---------------------------------------------------------------------------

from repro.core import (pipelined_spec_from_schedule,  # noqa: E402
                        simulate_wave_program)
from repro.core.collectives import BCAST, REDUCE, empty_pipelined_spec


def _spec_for(dims):
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    return sched, pipelined_spec_from_schedule(sched, ("data",))


@pytest.mark.parametrize("dims", [(4, 4), (2, 8), (3, 3), (2, 4, 4)])
def test_wave_program_legality_and_conservation(dims):
    sched, spec = _spec_for(dims)
    for waves in (spec.waves, spec.q8_waves):
        seen = []
        for wv in waves:
            srcs = [s for s, _ in wv.perm]
            dsts = [d for _, d in wv.perm]
            assert len(set(srcs)) == len(srcs), "wave reuses a source"
            assert len(set(dsts)) == len(dsts), "wave reuses a destination"
            seen.extend(wv.perm)
        # conservation: every tree edge carries exactly one reduce and one
        # broadcast message over the whole program
        assert len(seen) == 2 * sum(len(ts.tree) for ts in sched.trees)


@pytest.mark.parametrize("dims", [(4, 4), (2, 8), (3, 3)])
def test_wave_program_beats_fused_wave_count(dims):
    sched, spec = _spec_for(dims)
    from repro.core import fused_spec_from_schedule
    fused = fused_spec_from_schedule(sched, ("data",))
    # the DAG list schedule packs across trees, rounds AND phases: never
    # more waves than the round-aligned fused program, and its floor is
    # the dependency critical path (2 * depth)
    assert len(spec.waves) <= fused.num_collectives
    assert len(spec.waves) >= 2 * spec.depth


@pytest.mark.parametrize("dims", [(4, 4), (2, 8), (2, 4, 4)])
@pytest.mark.parametrize("segments", [1, 2, 4, 8, 16])
def test_wave_program_simulates_correct_for_any_segments(dims, segments):
    sched, spec = _spec_for(dims)
    vals = np.random.RandomState(segments).randn(sched.n, 6 * sched.k + 1)
    for q in (False, True):
        sim = simulate_wave_program(spec, vals, segments, quantized=q)
        assert sim.ok, (dims, segments, q)
        waves = spec.q8_waves if q else spec.waves
        assert sim.rounds == spec.steps(segments) if not q \
            else sim.rounds == len(waves) + segments - 1
        # EDST property survives pipelining: one message per directed link
        # per step (full-duplex: a phase-mixed wave may use both directions)
        assert sim.max_link_load == 1


def test_q8_program_is_phase_separated():
    _, spec = _spec_for((4, 4))
    for i, wv in enumerate(spec.q8_waves):
        kinds = set()
        if wv.reduce_flag.any():
            kinds.add(REDUCE)
        if wv.bcast_flag.any():
            kinds.add(BCAST)
        assert len(kinds) == 1
        assert (kinds == {REDUCE}) == (i < spec.q8_boundary)


def test_pipelined_spec_cache_and_tables():
    sched, spec = _spec_for((4, 4))
    assert pipelined_spec_from_schedule(sched, ("data",)) is spec
    send, dst, recv, kind = spec.tables
    r, n = len(spec.waves), spec.n
    assert send.shape == dst.shape == recv.shape == kind.shape == (r, n)
    for w, wv in enumerate(spec.waves):
        for s, d in wv.perm:
            assert dst[w, s] == d
            j = send[w, s]
            assert recv[w, d] == j
            assert kind[w, d] == (REDUCE if wv.reduce_flag[j, d] else BCAST)
    empty = empty_pipelined_spec(16, ("data",))
    assert empty.k == 0 and empty.steps(4) == 3


def test_cost_model_backend_calibration_picks_segments():
    _, spec = _spec_for((4, 4))
    host = CostModel.for_backend("cpu")
    fabric = CostModel.for_backend("tpu")
    # serialized collectives: pipelining never pays, S=1
    assert host.best_segments(64 << 10, spec) == 1
    # overlapping fabric links: large payloads stream many segments
    assert fabric.best_segments(64 << 20, spec) > 8
    # fill/drain model: more segments always cost more steps
    assert spec.steps(8) == len(spec.waves) + 7
    t1 = fabric.pipelined_allreduce(64 << 20, spec, 1)
    t8 = fabric.pipelined_allreduce(64 << 20, spec, 8)
    assert t8 < t1   # bandwidth-dominated: streaming wins


def test_bench_diff_gates_regressions():
    import importlib
    bd = importlib.import_module("benchmarks.bench_diff")
    base = {"exec/t/fused": {"us_per_call": 200.0},
            "exec/t/pipelined": {"us_per_call": 100.0},
            "exec/t/psum": {"us_per_call": 10.0},
            "compile/t/x": {"us_per_call": 5.0}}
    ok = {"exec/t/fused": {"us_per_call": 420.0},     # 2.1x, psum 2x -> 1.05
          "exec/t/pipelined": {"us_per_call": 240.0},
          "exec/t/psum": {"us_per_call": 20.0}}
    rows, regs = bd.diff(base, ok, threshold=1.25)
    assert [r[0] for r in rows] == ["exec/t/fused", "exec/t/pipelined"]
    assert not regs
    bad = {"exec/t/fused": {"us_per_call": 300.0},    # 1.5x vs psum 1x
           "exec/t/pipelined": {"us_per_call": 100.0},
           "exec/t/psum": {"us_per_call": 10.0}}
    _, regs = bd.diff(base, bad, threshold=1.25)
    assert regs == ["exec/t/fused"]


# ---------------------------------------------------------------------------
# striped reduce-scatter / allgather program (owner stripes per vertex)
# ---------------------------------------------------------------------------

from repro.core import (chunk_sizes,  # noqa: E402
                        simulate_striped_program,
                        striped_spec_from_schedule, striped_tables)
from repro.core.collectives import (AG_DOWN, AG_UP,  # noqa: E402
                                    RS_DOWN, RS_UP, empty_striped_spec)


def _striped_for(dims):
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    return sched, striped_spec_from_schedule(sched, ("data",))


@settings(max_examples=12, deadline=None)
@given(total=st.integers(1, 4096), seed=st.integers(0, 10_000))
def test_chunk_sizes_partitions_exactly(total, seed):
    """Property: the canonical largest-remainder helper partitions any
    total exactly, for uneven fractions and retired (fraction-0) trees."""
    import random
    rng = random.Random(seed)
    k = rng.randint(1, 6)
    weights = [rng.random() for _ in range(k)]
    if k > 1 and rng.random() < 0.5:
        weights[rng.randrange(k)] = 0.0   # retired tree
    s = sum(weights) or 1.0
    fracs = [w / s for w in weights]
    sizes = chunk_sizes(total, fracs)
    assert sum(sizes) == total
    assert all(sz >= 0 for sz in sizes)
    assert all(sz == 0 for sz, f in zip(sizes, fracs) if f == 0.0)


def test_chunk_sizes_is_canonical_everywhere():
    """The dedup satellite: dist.tree_allreduce and dist.fault re-export
    the ONE core helper instead of reimplementing the rounding."""
    from repro.core.collectives import chunk_sizes as core_cs
    from repro.dist.fault import chunk_sizes as fault_cs
    from repro.dist.tree_allreduce import chunk_sizes as dist_cs
    assert dist_cs is core_cs
    assert fault_cs is core_cs


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([1, 3, 7, 15, 16, 17, 53, 256, 257]),
       seed=st.integers(0, 1000))
def test_owner_stripes_partition_padded_rows(m, seed):
    """Property: per tree, the n owner stripes partition the padded row
    exactly -- uneven m, m < n, and weighted fractions with a retired
    tree all included."""
    import random
    sched, spec = _striped_for((4, 4))
    rng = random.Random(seed)
    fr = None
    if sched.k >= 2 and rng.random() < 0.5:
        fr = [rng.random() for _ in range(sched.k)]
        if rng.random() < 0.5:
            fr[rng.randrange(sched.k)] = 0.0
        s = sum(fr) or 1.0
        fr = tuple(f / s for f in fr)
    bound = striped_tables(spec, m, fr)
    assert sum(bound.sizes) == m              # true chunks partition m
    assert bound.mrow == max(bound.sizes)
    widths = np.diff(bound.offsets)
    assert bound.offsets[0] == 0 and bound.offsets[-1] == bound.mrow
    assert (widths >= 0).all() and widths.max() == bound.smax
    for j, st_tree in enumerate(spec.trees):
        # each vertex's own stripe is exactly its preorder slot
        assert (bound.own_off[j] == bound.offsets[:-1][st_tree.pre]).all()
        assert (bound.own_len[j] == widths[st_tree.pre]).all()
        assert int(bound.own_len[j].sum()) == bound.mrow


def test_striped_wave_legality_and_op_homogeneity():
    sched, spec = _striped_for((4, 4))
    n, k = sched.n, sched.k
    for waves, ops in ((spec.waves, {REDUCE, BCAST}),
                       (spec.rs_waves, {REDUCE}),
                       (spec.ag_waves, {BCAST})):
        for wv in waves:
            srcs = [s for s, _ in wv.perm]
            dsts = [d for _, d in wv.perm]
            assert len(set(srcs)) == len(srcs), "wave reuses a source"
            assert len(set(dsts)) == len(dsts), "wave reuses a destination"
            assert wv.op in ops
            for (j, kind, s, d) in wv.msgs:
                assert (wv.op == REDUCE) == (kind in (RS_UP, RS_DOWN))
    # conservation: 2 messages per phase per tree edge (one each way)
    n_msgs = sum(len(wv.msgs) for wv in spec.waves)
    assert n_msgs == 4 * sum(len(ts.tree) for ts in sched.trees)


@pytest.mark.parametrize("dims", [(4, 4), (2, 8), (3, 3), (2, 4, 4)])
@pytest.mark.parametrize("d_mult", [1, 8])
def test_striped_simulator_exact_and_conserving(dims, d_mult):
    sched, spec = _striped_for(dims)
    d = d_mult * sched.n * sched.k + 3    # uneven; d_mult=1 keeps m >= n
    vals = np.random.RandomState(d).randn(sched.n, d)
    sim = simulate_striped_program(spec, vals)
    assert sim.ok
    assert sim.stripes_ok, "per-stripe conservation violated"
    assert sim.max_link_load == 1


@pytest.mark.parametrize("mk", [
    lambda: topo.device_topology((4, 4)),
    lambda: topo.hyperx([4, 4]),
    lambda: topo.slimfly(5),
    lambda: topo.polarstar(3, "qr", 5),
], ids=["torus4x4", "hyperx4x4", "slimfly_q5", "polarstar_er3_qr5"])
def test_striped_conservation_on_paper_fabrics(mk):
    sp = mk()
    g = sp.product()
    sched = allreduce_schedule(g.n, star_edsts(sp).trees)
    spec = striped_spec_from_schedule(sched, ("data",))
    vals = np.random.RandomState(7).randn(g.n, 4 * sched.k + 1)
    sim = simulate_striped_program(spec, vals)
    assert sim.ok and sim.stripes_ok


def test_striped_wire_bytes_bounded_below_m():
    """Acceptance: per-wave wire bytes drop from m to
    <= ceil(m/n) * slots-in-window, strictly below m once m >= n."""
    sched, spec = _striped_for((4, 4))
    n = sched.n
    m = 8 * n                              # m >= n: no empty stripes
    vals = np.random.RandomState(3).randn(n, m * sched.k)
    sim = simulate_striped_program(spec, vals)
    bound = striped_tables(spec, m * sched.k)
    assert sim.ok and sim.stripes_ok
    assert bound.mrow == m
    # no empty stripe -> no dropped message, so bound and spec waves align
    for bw, wv, wire in zip(bound.waves, spec.waves, sim.wire_elems):
        assert wire == int(bw.recv_len.max())
        for _, dst in bw.perm:
            nslot = int(wv.recv_nslot[dst])
            assert 1 <= nslot <= n - 1
            assert int(bw.recv_len[dst]) <= bound.smax * nslot
    assert sim.max_wire <= bound.smax * (n - 1)
    assert sim.max_wire < m


@settings(max_examples=6, deadline=None)
@given(drop=st.integers(0, 1), seed=st.integers(0, 1000))
def test_striped_degraded_k_minus_1_restripes(drop, seed):
    """Property: the (k-1)-tree spec a link kill degrades to re-stripes
    ownership over the survivors and still sums exactly."""
    sp = topo.device_topology((4, 4))
    trees = star_edsts(sp).trees
    keep = [t for j, t in enumerate(trees) if j != drop]
    sched = allreduce_schedule(sp.n, keep)
    spec = striped_spec_from_schedule(sched, ("data",))
    assert spec.k == len(trees) - 1
    vals = np.random.RandomState(seed).randn(sp.n, 29)
    sim = simulate_striped_program(spec, vals)
    assert sim.ok and sim.stripes_ok
    for st_tree in spec.trees:             # ownership covers every vertex
        assert sorted(st_tree.pre.tolist()) == list(range(sp.n))


def test_striped_spec_cache_and_empty():
    sched, spec = _striped_for((4, 4))
    assert striped_spec_from_schedule(sched, ("data",)) is spec
    assert spec.num_collectives == len(spec.waves)
    empty = empty_striped_spec(16, ("data",))
    assert empty.k == 0 and empty.waves == ()
    # simulate_wave_program dispatches striped specs to the striped replay
    vals = np.random.RandomState(0).randn(sched.n, 10)
    assert simulate_wave_program(spec, vals).stripes_ok


def test_cost_model_striped_entry():
    sched, spec = _striped_for((4, 4))
    cm = CostModel()
    b = 64 << 20
    t = cm.striped_allreduce(b, spec)
    assert 0 < t < float("inf")
    # stripe-sized wires: the modelled striped wire total undercuts the
    # full-chunk wire total of the same wave count
    full_chunk = spec.num_collectives * (cm.alpha + (b / sched.k)
                                         / cm.link_bw)
    assert t < full_chunk


def test_cost_model_backend_calibration_registry(caplog):
    import logging
    CostModel._WARNED_BACKENDS.discard("test_backend_xyz")
    CostModel._MEASURED.pop("test_backend_xyz", None)
    assert CostModel.calibration_for("cpu") is not None
    assert CostModel.calibration_for("tpu") is not None
    assert CostModel.calibration_for("test_backend_xyz") is None
    with caplog.at_level(logging.WARNING, "repro.core.collectives"):
        cm = CostModel.for_backend("test_backend_xyz")
        assert cm == CostModel()           # explicit default fallback
        assert any("no calibration" in r.message for r in caplog.records)
        n_warnings = len(caplog.records)
        CostModel.for_backend("test_backend_xyz")   # warns once per backend
        assert len(caplog.records) == n_warnings
    CostModel.register_calibration("test_backend_xyz", alpha=1e-5,
                                   link_bw=1e9, overlap=False)
    cm = CostModel.for_backend("test_backend_xyz")
    assert cm.alpha == 1e-5 and cm.link_bw == 1e9 and not cm.overlap
    with pytest.raises(ValueError):
        CostModel.register_calibration("test_backend_xyz", bogus=1.0)
    CostModel._MEASURED.pop("test_backend_xyz", None)


# ---------------------------------------------------------------------------
# ZeRO-1 owner-stripe optimizer (scattered AdamW == dense AdamW)
# ---------------------------------------------------------------------------

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.flatten_util import ravel_pytree  # noqa: E402

from repro.core.collectives import owner_element_map  # noqa: E402
from repro.optim import (AdamW, ShardedAdamW,  # noqa: E402
                         cosine_schedule, decay_mask)


def _scatter_owned(flat, emap):
    """Owner scatter: flat (m,) -> (n, k, smax) stripe stacks (numpy
    stand-in for tree_reduce_scatter's placement; padding stays 0)."""
    out = np.zeros(emap.shape, np.float32)
    live = emap >= 0
    out[live] = flat[emap[live]]
    return out


def _gather_owned(stacks, emap, m):
    """Owner gather: the tree_allgather stand-in (exact inverse on the
    live cells because owner stripes partition [0, m))."""
    flat = np.zeros(m, np.float32)
    live = emap >= 0
    flat[emap[live]] = np.asarray(stacks)[live]
    return flat


@settings(max_examples=8, deadline=None)
@given(dims=st.sampled_from([(4, 4), (2, 8), (3, 3)]),
       m=st.sampled_from([7, 29, 53, 128]),
       drop=st.integers(-1, 1),
       seed=st.integers(0, 1000))
def test_sharded_adamw_equals_dense(dims, m, drop, seed):
    """Property (the zero1 equivalence claim, collective-free): scatter
    the mean grads to owner stripes, run ShardedAdamW with the summed
    stripe-local partial norms, gather the updated params -- equals
    dense ``AdamW.apply`` on the same grads, across random torus
    fabrics, uneven ``m``, ``m < n``, and retired-tree (k-1) re-striped
    fractions, over multiple steps with evolving moments."""
    sched, spec = _striped_for(dims)
    fr = None
    if drop >= 0 and sched.k >= 2:      # retire one tree, re-stripe rest
        fr = [0.0 if j == drop % sched.k else 1.0 for j in range(sched.k)]
        s = sum(fr)
        fr = tuple(f / s for f in fr)
    emap = owner_element_map(spec, m, fr)
    live_ids = emap[emap >= 0]
    assert sorted(live_ids.tolist()) == list(range(m))  # exact partition

    rng = np.random.RandomState(seed)
    mvec = m // 3
    params = {"w": jnp.asarray(rng.randn(m - mvec, 1), jnp.float32)}
    if mvec:
        params["b"] = jnp.asarray(rng.randn(mvec), jnp.float32)
    opt = AdamW(cosine_schedule(1e-2, 3, 10))
    sopt = ShardedAdamW(opt)
    state = opt.init(params)
    dense_p = params
    flat_np = np.asarray(ravel_pytree(params)[0])
    dvec = np.asarray(decay_mask(params, opt.weight_decay))
    mu = np.zeros(emap.shape, np.float32)
    nu = np.zeros(emap.shape, np.float32)

    for t in range(3):
        grads = jax.tree.map(
            lambda p: jnp.asarray(
                rng.randn(*p.shape) * (t + 1), jnp.float32), dense_p)
        dense_p, state, metrics = opt.apply(dense_p, grads, state)

        flat_g = np.asarray(ravel_pytree(grads)[0])
        owned_g = _scatter_owned(flat_g, emap)
        # stripe-local partial sumsq + "psum" == dense squared norm
        partials = [float(sopt.partial_sumsq(jnp.asarray(owned_g[v])))
                    for v in range(sched.n)]
        gnorm = np.sqrt(np.float32(sum(partials)))
        assert np.isclose(gnorm, float(metrics["grad_norm"]), rtol=1e-5)

        new_P, MU, NU, lr = sopt.update_stripes(
            jnp.asarray(_scatter_owned(flat_np, emap)),
            jnp.asarray(owned_g),
            jnp.asarray(_scatter_owned(dvec, emap)),
            jnp.asarray(mu), jnp.asarray(nu),
            jnp.asarray(t + 1, jnp.int32), jnp.asarray(gnorm))
        flat_np = _gather_owned(new_P, emap, m)
        mu, nu = np.asarray(MU), np.asarray(NU)

        dense_flat = np.asarray(ravel_pytree(dense_p)[0])
        np.testing.assert_allclose(flat_np, dense_flat,
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(
            _gather_owned(mu, emap, m),
            np.asarray(ravel_pytree(state.mu)[0]), rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(
            _gather_owned(nu, emap, m),
            np.asarray(ravel_pytree(state.nu)[0]), rtol=1e-5, atol=1e-7)
        assert np.isclose(float(lr), float(metrics["lr"]))
