"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus decode-path consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import layers as L
from repro.models.api import build

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, gb=2, s=48):
    tokens = jax.random.randint(KEY, (gb, s + 1), 0, cfg.vocab)
    if cfg.family == "encdec":
        return {"frames": jnp.ones((gb, s, cfg.d_model), cfg.act_dtype),
                "tokens": tokens}
    if cfg.family == "vlm":
        return {"patches": jnp.ones((gb, cfg.n_img_tokens, cfg.d_model),
                                    cfg.act_dtype), "tokens": tokens}
    return {"tokens": tokens}


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_arch_smoke_train_step(name):
    cfg = configs.get(name).reduced()
    api = build(cfg)
    params, axes = api.init(KEY)
    # axes tree mirrors params
    assert {type(x) for x in jax.tree.leaves(
        axes, is_leaf=lambda t: isinstance(t, tuple))} <= {tuple}
    batch = make_batch(cfg)
    loss, metrics = api.loss_fn(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    grads = jax.grad(lambda p: api.loss_fn(p, batch)[0])(params)
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gsum > 0 and not jnp.isnan(jnp.asarray(gsum))


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_arch_smoke_decode_step(name):
    cfg = configs.get(name).reduced()
    api = build(cfg)
    params, _ = api.init(KEY)
    gb = 2
    caches, _ = api.init_cache(gb, 64)
    batch = {"tokens": jnp.zeros((gb, 1), jnp.int32),
             "cache_len": jnp.int32(0)}
    if cfg.family == "encdec":
        batch["cross_k"] = jnp.zeros((cfg.n_dec_layers, gb, 16, cfg.n_kv,
                                      cfg.head_dim_), jnp.bfloat16)
        batch["cross_v"] = batch["cross_k"]
    logits, new_caches = api.decode_fn(params, caches, batch)
    assert logits.shape == (gb, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ["qwen3-8b", "rwkv6-7b", "recurrentgemma-2b"])
def test_prefill_then_decode_matches_full_forward(name):
    """Decoding token-by-token after prefill == full forward logits."""
    cfg = configs.get(name).reduced()
    api = build(cfg)
    params, _ = api.init(KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 17), 0, cfg.vocab)

    if cfg.family in ("lm", "moe"):
        from repro.models import transformer as T
        full, _, _ = T.forward(cfg, params, tokens)
        last, cache = T.prefill(cfg, params, tokens[:, :16], 32)
        nxt, _ = T.decode_step(cfg, params, cache, tokens[:, 16:17],
                               jnp.int32(16))
        ref16 = full[:, 15]
        assert jnp.allclose(last, ref16, atol=2e-2), "prefill last logits"
        assert jnp.allclose(nxt, full[:, 16], atol=2e-2), "decode logits"
    elif cfg.family == "rwkv6":
        from repro.models import rwkv6 as R
        full, _ = R.forward(cfg, params, tokens)
        last, caches = R.prefill(cfg, params, tokens[:, :16])
        nxt, _ = R.decode_step(cfg, params, caches, tokens[:, 16:17])
        assert jnp.allclose(last, full[:, 15], atol=2e-2)
        assert jnp.allclose(nxt, full[:, 16], atol=2e-2)
    else:
        from repro.models import rglru as G
        full, _ = G.forward(cfg, params, tokens)
        last, caches = G.prefill(cfg, params, tokens[:, :16])
        nxt, _ = G.decode_step(cfg, params, caches, tokens[:, 16:17],
                               jnp.int32(16))
        assert jnp.allclose(last, full[:, 15], atol=2e-2)
        assert jnp.allclose(nxt, full[:, 16], atol=2e-2)


def test_chunked_xent_matches_dense():
    key = jax.random.PRNGKey(1)
    vocab, d = 50, 16
    emb, _ = L.init_embedding(key, 64, d)
    x = jax.random.normal(key, (2, 13, d))
    labels = jax.random.randint(key, (2, 13), 0, vocab)
    dense = L.softmax_xent(L.unembed(emb, x, vocab), labels)
    chunked = L.chunked_unembed_xent(emb, x, labels, vocab, chunk=4)
    assert jnp.allclose(dense, chunked, atol=1e-5)
    # grads agree too
    g1 = jax.grad(lambda e: L.softmax_xent(L.unembed(e, x, vocab), labels))(emb)
    g2 = jax.grad(lambda e: L.chunked_unembed_xent(e, x, labels, vocab,
                                                   chunk=4))(emb)
    assert jnp.allclose(g1["table"], g2["table"], atol=1e-5)


def test_vocab_padding_masked():
    cfg = configs.get("internvl2-2b")   # full config: 92553 -> padded
    assert cfg.vocab_padded > cfg.vocab
    assert cfg.vocab_padded % (16 * cfg.tp_divisor) == 0
    emb, _ = L.init_embedding(KEY, cfg.vocab_padded, 8)
    x = jax.random.normal(KEY, (1, 2, 8))
    logits = L.unembed(emb, x, cfg.vocab)
    assert float(logits[..., cfg.vocab:].max()) < -1e29
    assert float(logits[..., : cfg.vocab].max()) > -1e29
