"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose -- smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Every spec compiled under the test suite runs the FULL static verifier
# (tree recovery, happens-before, edge-disjointness, stripe windows) --
# not just the cheap wave scans of the production default.  setdefault so
# a developer can still override, and subprocess tests inherit it through
# run_with_devices' environment copy.
os.environ.setdefault("REPRO_VERIFY_SPECS", "full")

# Offline fallback: this container cannot install hypothesis, so register a
# seeded deterministic shim in its place (property-test bodies unchanged).
# The real package wins whenever it is importable.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_shim

    _hypothesis_shim.install()


def pytest_collection_modifyitems(items):
    """Every test driving the multi-device subprocess runner is 'slow';
    deselect the tier with ``-m "not slow"`` for the fast unit tier."""
    for item in items:
        if "subproc" in getattr(item, "fixturenames", ()):
            item.add_marker(pytest.mark.slow)


def run_with_devices(code: str, n_devices: int, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
