"""Fast single-device unit tests for repro.dist.sharding: tensor-parallel
priority, FSDP dim selection, replicated scalars, absent mesh axes."""
import jax
import pytest

from repro.dist.sharding import spec_for, tree_shardings

P = jax.sharding.PartitionSpec

pytestmark = pytest.mark.unit


def fake_mesh(names, shape):
    class _Devices:
        pass

    class _Mesh:
        axis_names = tuple(names)
        devices = _Devices()

    _Mesh.devices.shape = tuple(shape)
    return _Mesh()


@pytest.fixture
def mesh16():
    return fake_mesh(("data", "model"), (16, 16))


# -- FSDP dim selection -------------------------------------------------------

def test_fsdp_picks_largest_divisible_dim(mesh16):
    # mlp wins the model axis by priority; FSDP then takes embed (largest
    # remaining divisible), not the smaller mlp leftovers
    assert spec_for(("embed", "mlp"), (4096, 11008), mesh16) == \
        P("data", "model")
    # wo: ("mlp", "embed") -- same pair, transposed order
    assert spec_for(("mlp", "embed"), (11008, 4096), mesh16) == \
        P("model", "data")


def test_fsdp_skips_indivisible_and_layers(mesh16):
    # embed 100 not divisible by 16: nothing to FSDP, model takes head_dim
    spec = spec_for(("embed", "head_dim"), (100, 128), mesh16)
    assert spec == P(None, "model")
    # the scan-stacked "layers" dim is never sharded even when divisible
    spec = spec_for(("layers", "embed"), (32, 4096), mesh16)
    assert spec == P(None, "data")


def test_fsdp_off_replicates_data_dims(mesh16):
    assert spec_for(("embed", "mlp"), (4096, 11008), mesh16, fsdp=False) == \
        P(None, "model")


def test_fsdp_never_doubles_the_model_dim(mesh16):
    # one dim, divisible by both axes: model wins, FSDP must not re-shard it
    assert spec_for(("mlp",), (4096,), mesh16) == P("model")


# -- replicated scalars and unnamed dims --------------------------------------

def test_replicated_scalars_and_unnamed(mesh16):
    assert spec_for((), (), mesh16) == P()
    assert spec_for((None,), (7,), mesh16) == P(None)
    # unnamed dims stay replicated even when divisible
    assert spec_for((None, None), (64, 64), mesh16) == P(None, None)


# -- axis names absent from the mesh ------------------------------------------

def test_mesh_without_model_axis():
    m = fake_mesh(("data",), (8,))
    # no model axis: tensor dims fall back to replication, FSDP still works
    assert spec_for(("vocab", "embed"), (50304, 4096), m) == P("data", None)
    assert spec_for(("vocab", "embed"), (50304, 4096), m, fsdp=False) == \
        P(None, None)


def test_mesh_without_data_axes():
    m = fake_mesh(("model",), (4,))
    # no DP fabric: batch and FSDP have nowhere to go
    assert spec_for(("batch", None), (8, 128), m) == P(None, None)
    assert spec_for(("embed", "mlp"), (4096, 11008), m) == P(None, "model")


def test_unknown_logical_axis_is_fsdp_eligible(mesh16):
    # names outside the TP priority list replicate on model but may FSDP
    spec = spec_for(("state", "embed"), (8192, 4096), mesh16)
    assert spec == P("data", None)


# -- batch + pod/data composition ---------------------------------------------

def test_batch_maps_to_all_dp_axes():
    m = fake_mesh(("pod", "data", "model"), (2, 16, 16))
    assert spec_for(("batch", None), (64, 128), m, fsdp=False) == \
        P(("pod", "data"), None)
    # batch not divisible by pod*data: replicated
    assert spec_for(("batch", None), (16, 128), m, fsdp=False) == \
        P(None, None)


# -- tree_shardings -----------------------------------------------------------

def test_tree_shardings_structure_and_cache_pairs():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((64, 128), jax.numpy.float32),
              "scale": jax.ShapeDtypeStruct((64,), jax.numpy.float32),
              "cache": (jax.ShapeDtypeStruct((2, 8, 4, 16), jax.numpy.float32),
                        jax.ShapeDtypeStruct((2, 8, 4, 16), jax.numpy.float32))}
    axes = {"w": ("embed", "mlp"), "scale": ("embed",),
            "cache": (("batch", None, "kv_heads", "head_dim"),
                      ("batch", None, "kv_heads", "head_dim"))}
    sh = tree_shardings(axes, params, mesh)
    assert sh["w"].spec == P("data", "model")
    assert sh["scale"].spec == P("data")
    # a (k, v) tuple of axis-tuples is an interior node, not one leaf
    assert isinstance(sh["cache"], tuple) and len(sh["cache"]) == 2
    assert sh["cache"][0].spec == P("data", None, "model", None)
    for s in jax.tree.leaves(sh):
        assert isinstance(s, jax.sharding.NamedSharding)
