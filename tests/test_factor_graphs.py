"""Factor graphs vs paper Table 4: vertex/edge counts and (t, r) from the
EDST constructions (explicit or Roskind-Tarjan)."""
import pytest

from repro.core import factor_graphs as fg
from repro.core.factor_edsts import complete_graph_edsts, edsts_for
from repro.core.gf import gf


@pytest.mark.parametrize("q", [2, 3, 4, 5, 7, 8, 9, 11, 13, 16, 17, 25])
def test_gf_field_axioms(q):
    F = gf(q)
    for a in range(1, q):
        assert F.mul(a, F.inv(a)) == 1
        assert F.add(a, F.neg(a)) == 0
    # primitive element generates the multiplicative group
    seen, x = set(), 1
    for _ in range(q - 1):
        x = F.mul(x, F.primitive)
        seen.add(x)
    assert len(seen) == q - 1


@pytest.mark.parametrize("m", [4, 5, 6, 7, 8, 9, 10, 11, 12])
def test_complete_graph_walecki(m):
    E = complete_graph_edsts(fg.complete(m))
    assert E.t == m // 2
    assert E.r == (0 if m % 2 == 0 else (m - 1) // 2)


@pytest.mark.parametrize("q,k", [(5, 1), (13, 3), (17, 4)])
def test_paley_t_r(q, k):
    E = edsts_for(fg.paley(q))
    assert (E.t, E.r) == (k, k)


@pytest.mark.parametrize("q,t,r", [
    (3, 1, 4), (4, 2, 2), (5, 2, 7), (7, 3, 10), (8, 4, 4)])
def test_bipartite_t_r(q, t, r):
    E = edsts_for(fg.complete_bipartite(q))
    assert (E.t, E.r) == (t, r)


@pytest.mark.parametrize("q,k", [(5, 1), (4, 1), (7, 2), (8, 2), (13, 3)])
def test_mms_supernode_t_r(q, k):
    g = fg.mms_supernode(q)
    exp_e = {1: q * (q - 1) // 4, 0: q * q // 4, 3: q * (q + 1) // 4}[q % 4]
    assert g.m == exp_e
    E = edsts_for(g)
    assert (E.t, E.r) == (k, k)


@pytest.mark.parametrize("q", [2, 3, 4, 5])
def test_erdos_renyi_t_r(q):
    g = fg.erdos_renyi_polarity(q)
    assert (g.n, g.m) == (q * q + q + 1, q * (q + 1) ** 2 // 2)
    E = edsts_for(g)
    if q % 2:
        assert (E.t, E.r) == ((q + 1) // 2, 0)
    else:
        assert (E.t, E.r) == (q // 2, q * (q + 1) // 2)


@pytest.mark.parametrize("d", [4, 8, 3, 7])
def test_inductive_quad_t_r(d):
    E = edsts_for(fg.inductive_quad(d))
    if d % 4 == 0:
        assert (E.t, E.r) == (d // 2, d // 2)
    else:
        assert (E.t, E.r) == ((d - 1) // 2, (3 * d + 1) // 2)


@pytest.mark.parametrize("d", [3, 4, 5, 6])
def test_bdf_t_r(d):
    g = fg.bdf(d)
    assert (g.n, g.m) == (2 * d, d * d)
    E = edsts_for(g)
    assert E.t == d // 2


def test_mms_graph_diameter_2():
    """The searched connection sets must produce true MMS graphs."""
    from repro.core.topologies import slimfly
    for q in (4, 5, 7):
        g = slimfly(q).product()
        assert g.n == 2 * q * q
        assert g.diameter() == 2
