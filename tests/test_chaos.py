"""Chaos-injection units (:mod:`repro.dist.chaos`) plus the S4 property
sweep: on every paper topology x {pipelined, striped} engine, EVERY
precompiled failure-class entry passes the static verifier, and a
scripted recovery session's journal replays to the controller's live
(generation, schedule-id) state."""
import numpy as np
import pytest

from repro.analysis.verify import (PAPER_TOPOLOGIES, _topology_case)
from repro.core.edst_star import star_edsts
from repro.core.fault import FailureEvent
from repro.core.graph import canon
from repro.dist.chaos import (ChaosEvent, ChaosInjector, make_trace,
                              out_of_class_burst, trace_summary)
from repro.dist.fault import FaultAwareAllreduce
from repro.dist.health import HealthReport, compile_link_probe
from repro.dist.recovery import (RecoveryController, RecoveryPolicy,
                                 replay_journal)
from repro.dist.steps import fault_runtime_for_mesh


@pytest.fixture(scope="module")
def rt():
    return fault_runtime_for_mesh((16, 1), ("data", "model"),
                                  dp_torus_shape=(4, 4))


def test_make_trace_is_deterministic_and_ordered(rt):
    kinds = ("flap", "kill", "burst", "straggler", "corruption", "node")
    a = make_trace(rt, 48, seed=3, kinds=kinds)
    b = make_trace(rt, 48, seed=3, kinds=kinds)
    assert a == b
    assert make_trace(rt, 48, seed=4, kinds=kinds) != a
    assert tuple(e.kind for e in a) == kinds
    ticks = [e.tick for e in a]
    assert ticks == sorted(ticks) and ticks[0] >= 2
    assert trace_summary(a)      # human-readable, never empty


def test_make_trace_rejects_overfull_window(rt):
    with pytest.raises(ValueError):
        make_trace(rt, 6, kinds=("flap", "kill", "burst", "node"))


def test_out_of_class_burst_kills_every_class_but_stays_connected(rt):
    for seed in range(3):
        burst = out_of_class_burst(rt, np.random.default_rng(seed))
        assert rt.valid_ids(FailureEvent(links=frozenset(burst))) == []
        assert rt.graph.without_edges(burst).is_connected()
        # minimal-ish: it is a burst, not the whole fabric
        assert len(burst) < len(rt.graph.edges) // 2


def test_injector_masks_expires_and_clears(rt):
    plan = compile_link_probe(rt)
    edge = canon(*plan.links[0])
    v = plan.links[-1][1]
    trace = (ChaosEvent(tick=1, kind="flap", links=(edge,), duration=1),
             ChaosEvent(tick=3, kind="kill", links=(edge,)),
             ChaosEvent(tick=5, kind="corruption", duration=1,
                        magnitude=1.0),
             ChaosEvent(tick=7, kind="straggler", duration=2,
                        magnitude=4.0),
             ChaosEvent(tick=10, kind="node", node=v))
    inj = ChaosInjector(trace)
    slots = [i for i, l in enumerate(plan.links) if canon(*l) == edge]
    nslots = [i for i, l in enumerate(plan.links) if v in l]

    def mask():
        return inj.fault_mask(plan)

    inj.advance()                                  # tick 0: healthy
    assert mask().all() and inj.time_dilation() == 1.0
    assert inj.checksum_injection() == 0.0
    inj.advance()                                  # tick 1: flap fires
    assert not mask()[slots].any() and mask().sum() == len(mask()) - 2
    inj.advance()                                  # tick 2: flap expired
    assert mask().all()
    inj.advance()                                  # tick 3: permanent kill
    assert not mask()[slots].any()
    inj.advance()                                  # tick 4: still dead
    assert not mask()[slots].any()
    inj.advance()                                  # tick 5: corruption
    assert inj.checksum_injection() == 1.0
    inj.advance()                                  # tick 6: expired
    assert inj.checksum_injection() == 0.0
    inj.advance()                                  # tick 7: straggler on
    assert inj.time_dilation() == 4.0
    inj.advance()                                  # tick 8: still on
    assert inj.time_dilation() == 4.0
    inj.advance()                                  # tick 9: expired
    assert inj.time_dilation() == 1.0
    inj.advance()                                  # tick 10: node loss
    assert not mask()[nslots].any()
    inj.clear_fabric_state()                       # post-rescale reset
    assert mask().all()
    assert inj.done


def _scripted_kill_session(runtime):
    """Confirm a tree-link kill through the controller; return it."""
    plan = compile_link_probe(runtime)
    ctrl = RecoveryController(
        runtime, RecoveryPolicy(background_rebuild=False))
    edge = next(iter(sorted(runtime.entries[0].sched.trees[0].tree)))
    dead = frozenset({edge})
    ok = np.array([canon(s, d) not in dead for s, d in plan.links])
    for step in (0, 1):
        ctrl.observe(HealthReport(step=step, links=plan.links, link_ok=ok))
    return ctrl


@pytest.mark.parametrize("label", PAPER_TOPOLOGIES)
def test_every_failure_class_verifies_statically(label):
    """S4: on each paper topology, both engines' full precompiled entry
    tables (full + degraded + rebuilt per tree) pass the O(messages)
    static verifier, and a scripted kill session's journal replays to
    the same final schedule id the controller holds."""
    sp, es = _topology_case(label)
    res = star_edsts(sp, Es=es) if es is not None else star_edsts(sp)
    g = sp.product()
    for engine in ("pipelined", "striped"):
        rt = FaultAwareAllreduce.build(g, res.trees, ("data",),
                                       engine=engine)
        assert len(rt.entries) == 2 * rt.k + 1
        for i, e in enumerate(rt.entries):
            if e.sched is None:        # k=0 stub on a k=1 fabric
                continue
            assert rt.verify_entry(i, static=True), (label, engine, e.name)
        ctrl = _scripted_kill_session(rt)
        assert ctrl.journal, (label, engine)
        assert replay_journal(ctrl.journal) == (ctrl.generation,
                                                ctrl.schedule_id)
