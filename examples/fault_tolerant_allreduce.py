"""Elastic EDST allreduce: kill links mid-run, keep the compiled step.

Drives :mod:`repro.dist.fault` end to end on 16 fake host devices (a 4x4
torus DP fabric):
  1. build the elastic runtime: ONE compile covers the healthy k-tree
     schedule plus every degraded/rebuilt failure-class program;
  2. run the jitted allreduce healthy, then fail a tree-0 link: recovery is
     a scalar schedule-id flip into the SAME compiled executable (no
     retrace), verified numerically against the plain sum;
  3. compare the immediate degraded program (k-1 striping, ~1/k bandwidth
     lost) with the precompiled Roskind-Tarjan rebuilt program;
  4. a multi-tree failure escapes the precompiled classes ->
     ``with_rebuild`` repacks the actual residual fabric (one new compile);
  5. straggler mitigation stays schedule-level: ``rebalance_chunks``
     re-stripes chunk fractions around a slow chip.

Expected output (exact ids/links can shift with the EDST construction):

    elastic runtime: n=16 fabric, k=2 trees, 5 precompiled programs
      id 0: full            k=2 depth=10   48.1 GB/s
      id 1: degraded/tree0  k=1 depth=10   24.5 GB/s
      ...
    healthy allreduce correct: True (schedule id 0)
    *** link failure (4, 8) -> schedule id flips, no retrace ***
    recovery program rebuilt/tree0: k=1, correct: True
    bandwidth: healthy 48.1 GB/s -> degraded 24.5 GB/s -> rebuilt 24.5 GB/s
    *** multi-tree failure -> dynamic rebuild ***
    with_rebuild: k=1 on the residual fabric, sim correct: True
    *** straggler: chip 5 running 8x slow ***
    re-striped chunk fractions: [...]

    PYTHONPATH=src python examples/fault_tolerant_allreduce.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
from jax.sharding import PartitionSpec as P                       # noqa: E402

import repro.dist                                                 # noqa: E402
from repro.core.fault import FailureEvent, rebalance_chunks       # noqa: E402
from repro.dist.fault import NoScheduleError                      # noqa: E402
from repro.dist.steps import fault_runtime_for_mesh               # noqa: E402

# 1. the elastic runtime: all failure-class programs precompiled ------------
rt = fault_runtime_for_mesh((16, 1), ("data", "model"), dp_torus_shape=(4, 4))
report = rt.report(nbytes=64 << 20)
print(f"elastic runtime: n={report['n']} fabric, k={report['k']} trees, "
      f"{len(report['entries'])} precompiled programs")
for row in report["entries"]:
    print(f"  id {row['id']}: {row['name']:15s} k={row['k']} "
          f"depth={row['depth']:<3d} {row['gbps']:5.1f} GB/s")

# 2. jitted switch: healthy run, then a link failure mid-run ----------------
mesh = jax.make_mesh((16, 1), ("data", "model"))
sync = rt.make_allreduce()
x = jnp.arange(16 * 37, dtype=jnp.float32).reshape(16, 37) * 0.01
expect = jnp.tile(x.sum(0), (16, 1))

f = jax.jit(jax.shard_map(
    lambda xs, sid: sync(xs.reshape(xs.shape[1:]), sid)[None],
    mesh=mesh, in_specs=(P("data"), P()), out_specs=P("data"),
    axis_names={"data"}, check_vma=False))

y = f(x, jnp.int32(0))
print(f"\nhealthy allreduce correct: {bool(jnp.allclose(y, expect))} "
      f"(schedule id 0)")

dead = next(iter(rt.entries[0].sched.trees[0].tree))
print(f"\n*** link failure {dead} -> schedule id flips, no retrace ***")
rt_fail = rt.on_failure(FailureEvent(links=frozenset({dead})))
y2 = f(x, jnp.int32(rt_fail.active))      # same executable, new scalar
print(f"recovery program {rt_fail.entry.name}: k={rt_fail.entry.k}, "
      f"correct: {bool(jnp.allclose(y2, expect))}")

# 3. degraded vs rebuilt bandwidth ------------------------------------------
nb = 64 << 20
deg = rt.on_failure(FailureEvent(links=frozenset({dead})), prefer="degraded")
print(f"bandwidth: healthy {rt.effective_bandwidth(nb, 0) / 1e9:.1f} GB/s -> "
      f"degraded {deg.effective_bandwidth(nb) / 1e9:.1f} GB/s -> "
      f"rebuilt {rt_fail.effective_bandwidth(nb) / 1e9:.1f} GB/s")

# 4. beyond the precompiled classes: dynamic rebuild ------------------------
print("\n*** multi-tree failure -> dynamic rebuild ***")
multi = FailureEvent(links=frozenset(
    next(iter(e.sched.trees[0].tree)) for e in rt.entries))
try:
    rt.on_failure(multi)
    print("unexpected: a precompiled program survived")
except NoScheduleError:
    rt_dyn = rt.with_rebuild(multi)
    print(f"with_rebuild: k={rt_dyn.k} on the residual fabric, "
          f"sim correct: {rt_dyn.verify_entry(0)}")

# 5. straggler mitigation (schedule-level, from core.fault) -----------------
print("\n*** straggler: chip 5 running 8x slow ***")
fracs = rebalance_chunks(rt.entries[0].sched, {5: 8.0})
print("re-striped chunk fractions:", [round(fr, 3) for fr in fracs])
