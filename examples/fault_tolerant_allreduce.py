"""Fault tolerance on EDST collectives: kill links, keep training.

Demonstrates the paper's fault-tolerance payoff on the 2-pod fabric:
  1. build maximal EDSTs on the 512-chip (2,16,16) torus;
  2. fail a link: the surviving tree keeps the allreduce correct (degraded);
  3. Roskind-Tarjan rebuild on the residual fabric restores 2 trees;
  4. straggler mitigation: rebalance chunk fractions around a slow chip.

    PYTHONPATH=src python examples/fault_tolerant_allreduce.py
"""
import numpy as np

from repro.core import (FailureEvent, FaultTolerantAllreduce,
                        allreduce_schedule, rebalance_chunks,
                        simulate_allreduce, star_edsts)
from repro.core import topologies as topo

fabric = topo.device_topology((2, 16, 16))
g = fabric.product()
res = star_edsts(fabric)
print(f"fabric: 2-pod v5e, |V|={g.n}, |E|={g.m}; EDSTs={res.count} "
      f"(maximal={res.maximal}, theorem {res.theorem})")

sched = allreduce_schedule(g.n, res.trees)
fta = FaultTolerantAllreduce(g, sched)
vals = np.random.RandomState(0).randn(g.n, 32)
print("healthy allreduce correct:",
      simulate_allreduce(fta.schedule, vals).ok, f"(k={fta.k})")

# fail one link used by tree 0
dead_link = next(iter(res.trees[0]))
print(f"\n*** link failure: {dead_link} ***")
fta = fta.on_failure(FailureEvent(links=frozenset({dead_link})))
print(f"degraded mode: k={fta.k} surviving tree(s); allreduce correct:",
      simulate_allreduce(fta.schedule, vals).ok)

fta = fta.rebuild()
print(f"after Roskind-Tarjan rebuild on residual fabric: k={fta.k}; correct:",
      simulate_allreduce(fta.schedule, vals).ok)
print("history:", fta.history)

# straggler mitigation
print("\n*** straggler: chip 37 running 4x slow ***")
fracs = rebalance_chunks(fta.schedule, {37: 4.0})
print("per-tree chunk fractions:", [round(f, 3) for f in fracs])

# a failed NODE kills every spanning tree -> eager rebuild on the 511
# surviving chips (the dead chip is excluded from the collective)
print("\n*** node failure: chip 100 ***")
fta2 = FaultTolerantAllreduce(g, sched).on_failure(
    FailureEvent(nodes=frozenset({100})))
vals511 = np.random.RandomState(1).randn(fta2.graph.n, 32)
print(f"rebuilt on residual fabric: k={fta2.k}, chips={fta2.graph.n}; "
      f"correct: {simulate_allreduce(fta2.schedule, vals511).ok}")
print("history:", fta2.history)
