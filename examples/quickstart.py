"""Quickstart: build star-product fabrics, construct maximal EDST sets
(paper Sections 2-4), and turn them into contention-free Allreduce schedules.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CostModel, allreduce_schedule, simulate_allreduce,
                        star_edsts)
from repro.core import topologies as topo


def show(name, sp):
    g = sp.product()
    res = star_edsts(sp)
    ub = g.m // (g.n - 1)
    print(f"{name:28s} |V|={g.n:5d} |E|={g.m:6d} trees={res.count} "
          f"upper-bound={ub} theorem={res.theorem} maximal={res.maximal}")
    return res


print("=== Star-product fabrics and their EDST packings (Table 3) ===")
show("SlimFly H_5 (K_qq*C(q))", topo.slimfly(5))
show("SlimFly H_7", topo.slimfly(7))
show("BundleFly H_4*QR(5)", topo.bundlefly(4, 5))
show("PolarStar ER_3*QR(5)", topo.polarstar(3, "qr", 5))
show("PolarStar ER_2*IQ(4)", topo.polarstar(2, "iq", 4))
show("HyperX (2,4,0,0)", topo.hyperx([4, 4]))
show("Torus 8x8", topo.torus([8, 8]))

print("\n=== TPU pod ICI as a star product: 16x16 torus ===")
pod = topo.device_topology((16, 16))
res = show("v5e pod (Torus 16x16)", pod)

sched = allreduce_schedule(pod.n, res.trees)
print(f"\nAllreduce schedule: k={sched.k} trees, depth={sched.depth}, "
      f"contention-free={sched.check_contention_free()}")

vals = np.random.RandomState(0).randn(pod.n, 64)
sim = simulate_allreduce(sched, vals)
print(f"packet-level simulation: correct={sim.ok}, rounds={sim.rounds}, "
      f"max link load/round={sim.max_link_load}")

cm = CostModel()
for mb in (1, 16, 100):
    b = mb * 2 ** 20
    ring = cm.ring_allreduce(b, pod.n)
    tree = cm.edst_tree_allreduce(b, sched)
    innet = cm.edst_tree_allreduce(b, sched, in_network=True)
    print(f"{mb:4d} MiB gradient: ring={ring * 1e3:7.3f} ms  "
          f"edst-2tree={tree * 1e3:7.3f} ms  (in-network={innet * 1e3:7.3f} ms)"
          f"  speedup vs ring={ring / tree:.2f}x")
