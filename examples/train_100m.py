"""End-to-end driver: train the ~135M smollm config with EDST gradient sync.

CPU-sized invocation (what CI runs; a few minutes):
    PYTHONPATH=src python examples/train_100m.py --quick

Full 100M-scale run (hours on CPU; production: --mesh 16,16 on a pod):
    PYTHONPATH=src python examples/train_100m.py --steps 300 --seq 512 --batch 16
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true",
                help="reduced config, 120 steps (CI-sized)")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq", type=int, default=512)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--sync", default="gspmd", choices=["gspmd", "edst", "psum_dp"])
ap.add_argument("--mesh", default="1,1")
ap.add_argument("--ckpt-dir", default="/tmp/startree_100m_ckpt")
args = ap.parse_args()

argv = ["--arch", "smollm-135m", "--sync", args.sync, "--mesh", args.mesh,
        "--ckpt-dir", args.ckpt_dir]
if args.quick:
    argv += ["--reduced", "--steps", "120", "--batch", "8", "--seq", "128"]
else:
    argv += ["--steps", str(args.steps), "--batch", str(args.batch),
             "--seq", str(args.seq)]
losses = train_main(argv)
assert losses[-1] < losses[0], "loss did not improve"
print("OK: loss improved", losses[0], "->", losses[-1])
