#!/usr/bin/env bash
# Static verification gate (the CI `verify` job, runnable locally):
#
#   scripts/verify.sh                # verifier CLI + AST lint (+ ruff
#                                    # when installed) -- seconds, no JAX
#   scripts/verify.sh --simulate     # extra args go to the verifier CLI
#                                    # (here: add the packet-simulator
#                                    # replays, the old wave_check gate)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m repro.analysis.verify --all-engines --topologies paper5 "$@"
python -m repro.analysis.lint src
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "ruff not installed; skipping ruff baseline (CI runs it)"
fi
