#!/usr/bin/env bash
# Tier-1 test runner: sets PYTHONPATH=src and runs the full suite.
#
#   scripts/test.sh                 # full tier-1 suite
#   scripts/test.sh -m "not slow"   # fast unit tier (no subprocess
#                                   # multi-device tests)
#   scripts/test.sh tests/test_system.py -k ckpt   # any pytest args
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q "$@"
