"""CI gate for the allreduce perf trajectory: diff a fresh bench JSON
against a committed baseline and fail on regressions of any gated
``exec/*`` row.

Absolute microseconds are not comparable across machines, so every
``exec/<fabric>/<engine>`` row is normalized by its fabric's
``exec/<fabric>/psum`` row from the SAME file before comparing: psum is
the XLA-native collective both runs execute on identical hardware, which
cancels host speed and iteration-count differences and leaves the
engine-vs-XLA ratio the trajectory actually tracks.  Payload size does
NOT cancel (smaller payloads shift every tree engine toward the
alpha-dominated regime), so rows are only compared when baseline and new
agree on ``bytes`` -- CI therefore diffs its ``--quick`` run against the
committed ``BENCH_allreduce_quick.json``, not the full-run trajectory
file.  The ``pipelined_s{2,4,8}`` sweep rows are informational (the S>1
scan serializes its per-step waves on host backends by design, ~10x the
headline rows and noisy at smoke iteration counts) and are excluded from
the gate.  Every other ``exec/*`` engine row IS gated -- including the
``striped`` / ``striped_q8`` reduce-scatter/allgather rows (slower than
pipelined on alpha-dominated hosts by design, but their *ratio to psum*
must not drift) -- and ``calibration/*`` / ``compile/*`` rows are not
exec rows, so they never gate.  A gated row regresses when its
normalized cost grows by more than ``--threshold`` (default 1.25x).

    python -m benchmarks.bench_diff --baseline BENCH_allreduce_quick.json \
        --new /tmp/new.json --threshold 1.25

A second, same-file mode gates telemetry overhead: ``--overhead FILE``
pairs every ``telemetry/<fabric>/<engine>/scoped`` row with its
``.../plain`` sibling from the SAME file (same process, interleaved
timing, so nothing needs normalizing) and fails when the scoped build
runs more than ``--threshold`` slower than the plain one -- the wave
named-scopes are trace-time metadata and must stay free at run time.

    python -m benchmarks.bench_diff --overhead BENCH_telemetry.json \
        --threshold 1.05
"""
from __future__ import annotations

import argparse
import json
import sys


def normalized_exec(results: dict) -> dict:
    """exec/<fabric>/<engine> -> (us_per_call / same-fabric psum us, bytes)."""
    out = {}
    for name, row in results.items():
        if not name.startswith("exec/"):
            continue
        fabric = name.split("/")[1]
        psum = results.get(f"exec/{fabric}/psum")
        if psum is None or psum["us_per_call"] <= 0:
            continue
        out[name] = (row["us_per_call"] / psum["us_per_call"],
                     row.get("bytes"))
    return out


def diff(baseline: dict, new: dict, threshold: float):
    """(rows, regressions): rows are (name, base_norm, new_norm, ratio)."""
    base_n, new_n = normalized_exec(baseline), normalized_exec(new)
    rows, regressions = [], []
    for name in sorted(base_n):
        if name.endswith("/psum") or name not in new_n:
            continue
        if "/pipelined_s" in name:   # informational sweep, not gated
            continue
        (b, b_bytes), (n, n_bytes) = base_n[name], new_n[name]
        if b_bytes != n_bytes:       # cross-payload ratios don't compare
            continue
        ratio = n / b
        rows.append((name, b, n, ratio))
        if ratio > threshold:
            regressions.append(name)
    return rows, regressions


def overhead_diff(results: dict, threshold: float):
    """Same-file scoped-vs-plain pairs: (rows, regressions) where rows
    are (scoped_name, plain_us, scoped_us, ratio)."""
    rows, regressions = [], []
    for name in sorted(results):
        if not (name.startswith("telemetry/") and name.endswith("/scoped")):
            continue
        plain = results.get(name[:-len("scoped")] + "plain")
        if plain is None or plain["us_per_call"] <= 0:
            continue
        ratio = results[name]["us_per_call"] / plain["us_per_call"]
        rows.append((name, plain["us_per_call"],
                     results[name]["us_per_call"], ratio))
        if ratio > threshold:
            regressions.append(name)
    return rows, regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline")
    ap.add_argument("--new")
    ap.add_argument("--threshold", type=float, default=1.25)
    ap.add_argument("--overhead", metavar="FILE", default=None,
                    help="same-file mode: gate telemetry/*/scoped rows "
                         "against their /plain siblings in FILE")
    args = ap.parse_args()

    if args.overhead:
        with open(args.overhead) as f:
            results = json.load(f)
        rows, regressions = overhead_diff(results, args.threshold)
        if not rows:
            print("bench_diff: no telemetry/*/{plain,scoped} pairs in "
                  f"{args.overhead}; an empty comparison disables the "
                  "gate, so this is an error")
            return 1
        width = max(len(name) for name, *_ in rows)
        print(f"{'row':<{width}}  {'plain(us)':>10} {'scoped(us)':>10} "
              f"{'ratio':>7}")
        for name, p, s, r in rows:
            mark = "  <-- OVERHEAD" if name in regressions else ""
            print(f"{name:<{width}}  {p:>10.1f} {s:>10.1f} {r:>7.3f}{mark}")
        if regressions:
            print(f"\n{len(regressions)} scoped row(s) above "
                  f"{args.threshold:.2f}x their plain sibling")
            return 1
        print(f"\nscope overhead within {args.threshold:.2f}x on all rows")
        return 0

    if not args.baseline or not args.new:
        ap.error("--baseline and --new are required (or use --overhead)")
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    rows, regressions = diff(baseline, new, args.threshold)
    if not rows:
        print("bench_diff: no comparable exec/* rows (payload size or "
              "fabric set changed without regenerating the baseline, or "
              "psum rows missing) -- an empty comparison disables the "
              "gate, so this is an error; regenerate the baseline file")
        return 1
    width = max(len(name) for name, *_ in rows)
    print(f"{'row':<{width}}  {'base(xpsum)':>12} {'new(xpsum)':>12} "
          f"{'ratio':>7}")
    for name, b, n, r in rows:
        mark = "  <-- REGRESSION" if name in regressions else ""
        print(f"{name:<{width}}  {b:>12.2f} {n:>12.2f} {r:>7.2f}{mark}")
    if regressions:
        print(f"\n{len(regressions)} row(s) regressed beyond "
              f"{args.threshold:.2f}x vs baseline")
        return 1
    print(f"\nall rows within {args.threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
