"""Telemetry cost + fidelity bench: scope overhead pairs, per-wave
measured-vs-predicted residuals, and the fitted calibration row.

Three row families land in ``BENCH_telemetry.json``:

  * ``telemetry/<fabric>/<engine>/plain`` and ``.../scoped`` -- the SAME
    jitted allreduce timed with the executors' ``edst/t*/w*/op`` named
    scopes disabled vs enabled, interleaved in one round-robin so host
    drift hits both alike.  ``jax.named_scope`` is trace-time HLO
    metadata (the compiled executable is identical), so the pair must
    agree to measurement noise; CI gates ``scoped/plain <= 1.05`` via
    ``python -m benchmarks.bench_diff --overhead``.
  * ``waves/<fabric>/<engine>`` -- the wave-by-wave instrumented
    executor (:func:`repro.telemetry.timing.wave_report`): per-wave
    measured times (block-until-ready per wave, best of iters) against
    the CostModel's per-wave predictions, with residuals.
  * ``calibration/<backend>`` -- ``t = alpha + bytes/link_bw`` fitted
    from every measured wave and fed back into the registry
    ``CostModel.for_backend`` consults (the measured-calibration loop).

Runs on 16 fake host devices; absolute numbers are host-collective
latencies, only the plain/scoped ratio and the residual STRUCTURE are
meaningful off real fabrics.

    python -m benchmarks.telemetry_bench --out BENCH_telemetry.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

_FORCE = "--xla_force_host_platform_device_count=16"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FORCE).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import topologies as topo  # noqa: E402
from repro.core.collectives import (allreduce_schedule,  # noqa: E402
                                    pipelined_spec_from_schedule,
                                    striped_spec_from_schedule)
from repro.core.edst_star import star_edsts  # noqa: E402
from repro.dist.striped import striped_allreduce  # noqa: E402
from repro.dist.tree_allreduce import (pipelined_tree_allreduce,  # noqa: E402
                                       set_wave_scopes)
from repro.telemetry import timing  # noqa: E402

FABRICS = (("torus4x4", (4, 4)), ("torus2x8", (2, 8)))
ENGINES = ("pipelined", "striped")
DEFAULT_ELEMS = 1 << 20          # 4 MiB of f32 -- the trace default


def _specs(dims):
    sp = topo.device_topology(dims)
    sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
    return {"pipelined": pipelined_spec_from_schedule(sched, ("data",)),
            "striped": striped_spec_from_schedule(sched, ("data",))}


def _jitted(body, mesh, x):
    f = jax.jit(jax.shard_map(
        lambda xs: body(xs.reshape(xs.shape[1:]))[None],
        mesh=mesh, in_specs=P("data"), out_specs=P("data")))
    return lambda: jax.block_until_ready(f(x))


def _paired(fns: dict, rounds: int) -> dict:
    """Best single-call wall clock per case, round-robin interleaved (the
    allreduce_bench discipline: drift lands on every case alike)."""
    for fn in fns.values():
        fn()   # compile
        fn()
    best = {name: float("inf") for name in fns}
    for _ in range(rounds):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def bench_overhead(results: dict, elems: int, iters: int) -> None:
    """plain/scoped pairs per fabric x engine.  The scope toggle flips a
    module flag read at TRACE time, so each arm jits its own callable
    under the matching flag state and both executables are compiled
    before any timed call."""
    mesh = jax.make_mesh((16,), ("data",))
    x = (jnp.arange(16 * elems, dtype=jnp.float32).reshape(16, elems)
         * 1e-4)
    nbytes = elems * 4
    for label, dims in FABRICS:
        specs = _specs(dims)
        bodies = {
            "pipelined": lambda v: pipelined_tree_allreduce(
                v, specs["pipelined"]),
            "striped": lambda v: striped_allreduce(v, specs["striped"]),
        }
        fns = {}
        for eng, body in bodies.items():
            prev = set_wave_scopes(False)
            try:
                fns[f"{eng}/plain"] = _jitted(body, mesh, x)
                fns[f"{eng}/plain"]()          # compile under scopes-off
                set_wave_scopes(True)
                fns[f"{eng}/scoped"] = _jitted(body, mesh, x)
                fns[f"{eng}/scoped"]()         # compile under scopes-on
            finally:
                set_wave_scopes(prev)
        timed = _paired(fns, iters)
        for name, sec in timed.items():
            eng = name.split("/")[0]
            results[f"telemetry/{label}/{name}"] = {
                "us_per_call": round(sec * 1e6, 1),
                "bytes": nbytes,
                "waves": len(specs[eng].waves),
            }


def bench_waves(results: dict, elems: int, iters: int) -> None:
    """Wave-by-wave measured-vs-predicted rows + the fitted calibration
    fed back into the CostModel registry."""
    mesh = jax.make_mesh((16,), ("data",))
    nbytes = elems * 4
    all_wires, all_meas = [], []
    for label, dims in FABRICS:
        specs = _specs(dims)
        for eng in ENGINES:
            rep = timing.wave_report(specs[eng], nbytes, iters=iters,
                                     mesh=mesh)
            results[f"waves/{label}/{eng}"] = rep
            all_wires.extend(rep["wire_bytes"])
            all_meas.extend(t * 1e-6 for t in rep["measured_us"])
    cal = timing.register_measured(all_wires, all_meas)
    results[f"calibration/{cal['backend']}"] = {
        "alpha": cal["alpha"], "link_bw": cal["link_bw"],
        "samples": len(all_wires),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_telemetry.json")
    ap.add_argument("--elems", type=int, default=DEFAULT_ELEMS)
    ap.add_argument("--iters", type=int, default=30,
                    help="round-robin rounds for the overhead pairs")
    ap.add_argument("--wave-iters", type=int, default=5,
                    help="best-of iterations per instrumented wave")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller payload, fewer rounds")
    args = ap.parse_args(argv)
    if args.quick:
        args.elems = min(args.elems, 1 << 16)
        args.iters = min(args.iters, 8)
        args.wave_iters = min(args.wave_iters, 3)

    results: dict = {}
    bench_overhead(results, args.elems, args.iters)
    bench_waves(results, args.elems, args.wave_iters)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")

    width = max(len(n) for n in results)
    for name in sorted(results):
        row = results[name]
        if "us_per_call" in row:
            print(f"{name:<{width}}  {row['us_per_call']:>10.1f} us")
        elif name.startswith("waves/"):
            s = row["summary"]
            print(f"{name:<{width}}  measured {s['measured_total_us']:>10.1f}"
                  f" us  predicted {s['predicted_total_us']:>10.1f} us  "
                  f"mean|resid| {s['mean_abs_residual_us']:.1f} us")
        else:
            print(f"{name:<{width}}  alpha {row['alpha']:.2e} s  "
                  f"link_bw {row['link_bw']:.3g} B/s")
    print(f"\nwrote {len(results)} rows to {args.out}")

    bad = []
    for label, _ in FABRICS:
        for eng in ENGINES:
            p = results[f"telemetry/{label}/{eng}/plain"]["us_per_call"]
            s = results[f"telemetry/{label}/{eng}/scoped"]["us_per_call"]
            if p > 0 and s / p > 1.05:
                bad.append(f"telemetry/{label}/{eng}: {s / p:.3f}x")
    if bad:
        print("scope overhead above 1.05x (named_scope must be free):")
        for b in bad:
            print(f"  {b}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
