"""Roofline table (§Roofline) from the dry-run JSON artifacts.

Reads dryrun_single_pod.json (produced by ``python -m repro.launch.dryrun
--all --out ...``) and emits the three roofline terms per (arch x shape),
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.
"""
from __future__ import annotations

import json
import os

from repro import configs
from repro.analysis.roofline import roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SINGLE = os.path.join(REPO, "dryrun_single_pod.json")


def load_rows(path: str = SINGLE):
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    with open(path) as f:
        return json.load(f)


def terms_for(rec: dict):
    cfg = configs.get(rec["arch"])
    shape = cfg.shape(rec["shape"])
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    la = rec.get("loop_aware", {})
    flops = la.get("dot_flops") or rec.get("flops", 0.0)
    byts = la.get("bytes_touched") or rec.get("bytes_accessed", 0.0)
    coll = la.get("total_collective_bytes") or \
        rec.get("collectives", {}).get("total_bytes", 0.0)
    return roofline(cfg, shape, rec["mesh"], n_dev, flops, byts, coll)


def summary_rows(path: str = SINGLE):
    out = []
    for rec in load_rows(path):
        if rec.get("skipped") or "error" in rec:
            continue
        t = terms_for(rec)
        out.append(
            f"roofline/{t.arch}/{t.shape},{t.bound_s * 1e6:.1f},"
            f"compute={t.compute_s:.2e};memory={t.memory_s:.2e};"
            f"collective={t.collective_s:.2e};dominant={t.dominant};"
            f"useful={t.useful_flop_ratio:.2f};"
            f"frac={t.roofline_fraction:.3f}")
    return out


def markdown_table(path: str = SINGLE):
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
             "dominant | MODEL/HLO flops | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_rows(path):
        if rec.get("skipped"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | -- | -- | -- | "
                         f"skipped | -- | -- |")
            continue
        if "error" in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | ERROR "
                         f"| | | | | |")
            continue
        t = terms_for(rec)
        lines.append(
            f"| {t.arch} | {t.shape} | {t.compute_s:.2e} | {t.memory_s:.2e} "
            f"| {t.collective_s:.2e} | {t.dominant} "
            f"| {t.useful_flop_ratio:.2f} | {t.roofline_fraction:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
