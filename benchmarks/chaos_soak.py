"""Chaos soak: sustained seeded faults through real train loops, with the
full detect -> classify -> escalate -> recover loop closed.

For each engine configuration this bench trains the shared quadratic toy
problem (the ZeRO-1 differential suite's problem: dense, well-scaled
gradients, uneven 53-element payload) on 16 fake host devices arranged
as a 4x4 torus DP fabric, while a seeded
:class:`repro.dist.chaos.ChaosInjector` trace drives every rung of the
recovery ladder:

  * ``dense``   -- pipelined-engine fault runtime; flap, kill,
    out-of-class burst (background ``with_rebuild`` + hot-swap),
    straggler, payload corruption, and a node loss that checkpoints
    atomically and elastically rescales onto the 8 surviving devices
    (a (2,4) torus sub-mesh);
  * ``striped`` -- reduce-scatter/allgather engine; flap, kill, burst;
  * ``zero1``   -- the sharded-optimizer step; flap, kill (with the
    ``reshard_owned`` mu/nu stripe migration on the schedule flip), and
    corruption.

Every detection tick probes the fabric BEFORE stepping (the heartbeat of
:mod:`repro.dist.health` with the injector's ``fault_mask``), so no
train step ever executes over a schedule the prober knows is dead: while
a link is suspect or a rebuild is in flight the harness stalls (the
batch index does not advance) and the committed loss sequence stays
bit-comparable to a fault-free ``psum_dp`` reference run over the SAME
batches -- the acceptance check.  Payload corruption is injected at the
telemetry boundary (a healthy host fabric cannot corrupt wires
physically); the recovery is a rollback of the just-committed step to
its pre-step snapshot and a redo, which must reconverge exactly.  The
in-graph checksum machinery itself (``telemetry=True`` ->
``replication_divergence`` / ``rs_conservation_gap``) runs live in every
step and feeds the detector alongside the injection.

A background ``with_rebuild`` holds the detection clock (the harness
polls the controller without advancing the injector) so MTTR-in-ticks
and steps-lost stay deterministic across hosts -- wall-clock MTTR
(including the repack + re-jit) is recorded separately per event.

Rows land in ``BENCH_recovery.json``:

  * ``soak/<config>/<kind>``   -- per-fault recovery: ``mttr_ticks``
    (detection ticks from first failed probe to recovery; deterministic),
    ``mttr_s`` (wall clock, informational), ``action``, ``events``;
  * ``soak/<config>/totals``   -- ``committed`` steps, ``steps_lost``,
    ``max_loss_diff`` / ``final_loss_diff`` vs the fault-free reference,
    ``unhandled_exceptions`` (must be 0), ``bw_retained``,
    ``generations``, and the full recovery ``journal``.

``benchmarks/recovery_diff.py`` gates CI on these rows against the
committed baseline (``BENCH_recovery_quick.json`` for the smoke tier).

    PYTHONPATH=src python -m benchmarks.chaos_soak
    PYTHONPATH=src python -m benchmarks.chaos_soak --quick \
        --out BENCH_recovery_quick.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# 16 fake host devices; must be set before jax initializes the backend
_FORCE = "--xla_force_host_platform_device_count=16"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FORCE).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.ckpt import restore, save_checkpoint  # noqa: E402
from repro.core.collectives import CostModel  # noqa: E402
from repro.dist.chaos import ChaosInjector, make_trace  # noqa: E402
from repro.dist.health import HealthMonitor  # noqa: E402
from repro.dist.recovery import (RecoveryController,  # noqa: E402
                                 RecoveryPolicy)
from repro.dist.steps import (dp_size, fault_runtime_for_mesh,  # noqa: E402
                              make_train_step)
from repro.optim import AdamW, ShardedAdamW, cosine_schedule  # noqa: E402
from repro.telemetry import metrics as tmetrics  # noqa: E402

MESH_ARGS = ((16, 1), ("data", "model"))
TORUS = (4, 4)
BASE_DT = 0.1            # synthetic healthy step time fed to the detector
NBYTES = 64 << 20        # bandwidth bookkeeping payload
CAUSE_TO_KIND = {"link-flap": "flap", "link-kill": "kill",
                 "link-burst": "burst", "payload-corruption": "corruption",
                 "straggler": "straggler", "node-loss": "node"}
CONFIG_KINDS = {
    "dense": ("flap", "kill", "burst", "straggler", "corruption", "node"),
    "striped": ("flap", "kill", "burst"),
    "zero1": ("flap", "kill", "corruption"),
}


class QuadAPI:
    def loss_fn(self, params, batch):
        pred = jnp.einsum("bij,ij->b", batch["x"], params["w"]) \
            + batch["x2"] @ params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}


def make_params():
    rng = np.random.RandomState(0)
    return {"w": jnp.asarray(rng.randn(6, 8), jnp.float32) * 0.3,
            "b": jnp.asarray(rng.randn(5), jnp.float32) * 0.3}


def batch_for(i: int, rows: int = 16) -> dict:
    """Deterministic global batch for commit index ``i`` -- the soak and
    the fault-free reference consume the identical sequence."""
    rng = np.random.RandomState(1000 + i)
    return {"x": jnp.asarray(rng.randn(rows, 6, 8), jnp.float32),
            "x2": jnp.asarray(rng.randn(rows, 5), jnp.float32),
            "y": jnp.asarray(rng.randn(rows), jnp.float32)}


PSIZE = 53  # flat param count of make_params() -- the zero1 stripe payload


def _sub_torus(n: int) -> tuple:
    return {8: (2, 4), 4: (2, 2), 2: (2, 1)}[n]


def run_soak(config: str, kinds, n_ticks: int, seed: int = 0,
             ckpt_dir: str | None = None, verbose: bool = True) -> dict:
    """One soaked training run; returns the bench rows for ``config``."""
    zero1 = config == "zero1"
    engine = "pipelined" if config == "dense" else "striped"
    # baseline for the journal <-> metrics reconciliation: everything the
    # process-wide transition counter gains during THIS soak must match
    # the controller's journal exactly
    m0 = tmetrics.counter_values("edst_recovery_transitions_total")
    opt = AdamW(cosine_schedule(1e-2, 5, max(n_ticks, 20)))
    api = QuadAPI()
    cm = CostModel()

    st = {  # mutable harness state the rescale callback swaps out
        "mesh": jax.make_mesh(*MESH_ARGS),
        "runtime": fault_runtime_for_mesh(*MESH_ARGS, TORUS, engine=engine),
        "params": make_params(),
    }
    healthy_bw = st["runtime"].effective_bandwidth(NBYTES, 0, cm)
    if zero1:
        st["opt_state"] = ShardedAdamW(opt).init_for(
            st["params"], st["runtime"], dp_size(st["mesh"]))
    else:
        st["opt_state"] = opt.init(st["params"])

    def rebuild_exec(runtime, straggler=None):
        st["runtime"] = runtime
        step = make_train_step(api, opt, st["mesh"], mode="edst",
                               fault_runtime=runtime, zero1=zero1,
                               telemetry=True)
        st["jstep"] = jax.jit(step)
        st["monitor"] = HealthMonitor(st["mesh"], runtime,
                                      straggler=straggler)

    rebuild_exec(st["runtime"])
    trace = make_trace(st["runtime"], n_ticks, seed=seed, kinds=kinds)
    inj = ChaosInjector(trace)

    commits: list = []          # committed per-step losses, in batch order
    gdiffs: list = []
    prev_snapshot = None        # state before the last committed step
    steps_lost = 0
    unhandled = 0

    def on_checkpoint():
        if ckpt_dir is not None:
            save_checkpoint(ckpt_dir, len(commits),
                            {"p": st["params"], "o": st["opt_state"]})

    def on_rescale(event):
        """Node loss: power-of-two sub-mesh over the survivors, fresh
        fault runtime on its torus, state restored from the checkpoint
        the controller just committed."""
        survivors = [v for v in range(st["runtime"].graph.n)
                     if v not in event.nodes]
        keep = 1 << int(np.log2(len(survivors)))
        if keep < 2:
            return None
        sel = survivors[:keep]
        devs = np.array(jax.devices())[sel].reshape(keep, 1)
        st["mesh"] = jax.sharding.Mesh(devs, ("data", "model"))
        new_rt = fault_runtime_for_mesh((keep, 1), ("data", "model"),
                                        dp_torus_shape=_sub_torus(keep),
                                        engine=engine)
        if ckpt_dir is not None:    # exercise the atomic restore path
            state, _, _ = restore(ckpt_dir,
                                  {"p": st["params"], "o": st["opt_state"]})
            st["params"], st["opt_state"] = state["p"], state["o"]
        inj.clear_fabric_state()
        return new_rt

    ctrl = RecoveryController(
        st["runtime"], RecoveryPolicy(backoff_base_s=0.01),
        on_checkpoint=on_checkpoint,
        on_rescale=on_rescale if config == "dense" else None)

    last_sync_dev = 0.0
    for tick in range(n_ticks):
        try:
            inj.advance()
            mask = inj.fault_mask(st["monitor"].plan)
            report = st["monitor"].check(
                tick, fault_mask=mask,
                step_time=BASE_DT * inj.time_dilation(),
                checksum_dev=max(inj.checksum_injection(), last_sync_dev))
            dec = ctrl.observe(report)
            # hold the detection clock while a background rebuild is in
            # flight: MTTR-in-ticks stays host-speed independent, the
            # wall clock (journal mttr_s) still records the repack cost
            waited = 0
            while dec.stall and ctrl.state == "rebuilding":
                time.sleep(0.02)
                dec = ctrl.observe(report)
                waited += 1
                if waited > 30000:
                    raise RuntimeError("background rebuild never landed")
            if dec.runtime_changed:
                rebuild_exec(ctrl.runtime,
                             straggler=st["monitor"].straggler)
            if dec.redo_step:
                # the step committed last tick went over a corrupt wire:
                # roll it back and recompute the same batch
                if prev_snapshot is not None and commits:
                    st["params"], st["opt_state"] = prev_snapshot
                    commits.pop()
                    gdiffs.pop()
                    steps_lost += 1
            elif dec.stall:
                steps_lost += 1
                if dec.backoff_s:
                    time.sleep(min(dec.backoff_s, 0.05))
                continue
            if zero1 and dec.action == "flip":
                rt, frm = ctrl.runtime, dec.detail["from_schedule"]
                s = st["opt_state"]
                st["opt_state"] = type(s)(
                    s.step,
                    rt.reshard_owned(s.mu, frm, rt.active, PSIZE),
                    rt.reshard_owned(s.nu, frm, rt.active, PSIZE))
            prev_snapshot = (st["params"], st["opt_state"])
            batch = batch_for(len(commits))
            st["params"], st["opt_state"], m = st["jstep"](
                st["params"], st["opt_state"], batch,
                jnp.int32(ctrl.schedule_id))
            commits.append(float(m["loss"]))
            gdiffs.append(float(m["grad_norm"]))
            last_sync_dev = float(m.get("sync_dev", 0.0))
        except Exception as exc:  # the soak contract: count, never crash
            unhandled += 1
            if verbose:
                print(f"[soak:{config}] UNHANDLED at tick {tick}: "
                      f"{type(exc).__name__}: {exc}")
            break

    # fault-free psum_dp reference over the identical batch sequence, on
    # the original healthy mesh
    ref_mesh = jax.make_mesh(*MESH_ARGS)
    ref = jax.jit(make_train_step(api, opt, ref_mesh, mode="psum_dp"))
    rp, rstate = make_params(), opt.init(make_params())
    ref_losses, ref_gnorms = [], []
    for i in range(len(commits)):
        rp, rstate, rm = ref(rp, rstate, batch_for(i))
        ref_losses.append(float(rm["loss"]))
        ref_gnorms.append(float(rm["grad_norm"]))

    loss_diffs = [abs(a - b) for a, b in zip(commits, ref_losses)]
    gnorm_diffs = [abs(a - b) for a, b in zip(gdiffs, ref_gnorms)]
    final_bw = ctrl.runtime.effective_bandwidth(
        NBYTES, ctrl.runtime.active, cm)

    m1 = tmetrics.counter_values("edst_recovery_transitions_total")
    observed = {k: m1[k] - m0.get(k, 0.0) for k in m1
                if m1[k] != m0.get(k, 0.0)}
    expected: dict = {}
    for e in ctrl.journal:
        key = (("action", str(e.action)), ("cause", str(e.cause)))
        expected[key] = expected.get(key, 0.0) + 1.0
    metrics_reconciled = observed == expected

    rows = {}
    by_kind: dict = {}
    for e in ctrl.journal:
        by_kind.setdefault(CAUSE_TO_KIND[e.cause], []).append(e)
    for kind, entries in by_kind.items():
        e = entries[0]
        rows[f"soak/{config}/{kind}"] = {
            "mttr_ticks": int(e.steps_degraded),
            "mttr_s": None if e.mttr_s is None else round(e.mttr_s, 4),
            "action": e.action, "events": len(entries)}
    rows[f"soak/{config}/totals"] = {
        "ticks": n_ticks, "committed": len(commits),
        "steps_lost": steps_lost,
        "max_loss_diff": max(loss_diffs, default=0.0),
        "final_loss_diff": loss_diffs[-1] if loss_diffs else 0.0,
        "max_gnorm_diff": max(gnorm_diffs, default=0.0),
        "unhandled_exceptions": unhandled,
        "metrics_reconciled": metrics_reconciled,
        "bw_retained": round(final_bw / healthy_bw, 3),
        "generations": ctrl.generation,
        "n_final": ctrl.runtime.graph.n,
        "journal": ctrl.journal_rows()}
    if verbose:
        t = rows[f"soak/{config}/totals"]
        print(f"[soak:{config}] committed {t['committed']}/{n_ticks} ticks, "
              f"lost {t['steps_lost']}, max loss diff "
              f"{t['max_loss_diff']:.2e}, gens {t['generations']}, "
              f"unhandled {t['unhandled_exceptions']}")
        for e in ctrl.journal:
            print(f"[soak:{config}]   t={e.step} {e.cause} -> {e.action} "
                  f"(sid {e.from_schedule}->{e.to_schedule}, "
                  f"{e.steps_degraded} ticks degraded)")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: dense config only, flap+kill trace")
    ap.add_argument("--configs", default=None,
                    help="comma list from dense,striped,zero1")
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_recovery.json")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir for the node-loss rung "
                         "(default: a temp dir)")
    args = ap.parse_args(argv)

    if args.quick:
        plan = {"dense": ("flap", "kill")}
        default_ticks = 16
    else:
        plan = {c: CONFIG_KINDS[c] for c in
                (args.configs.split(",") if args.configs
                 else ("dense", "striped", "zero1"))}
        default_ticks = None
    n_ticks = args.ticks or default_ticks

    import tempfile
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="chaos_soak_ckpt_")
    results = {}
    failed = 0
    for config, kinds in plan.items():
        ticks = n_ticks or (48 if len(kinds) > 3 else 24)
        rows = run_soak(config, kinds, ticks, seed=args.seed,
                        ckpt_dir=os.path.join(ckpt_dir, config))
        results.update(rows)
        totals = rows[f"soak/{config}/totals"]
        if (totals["unhandled_exceptions"] or totals["max_loss_diff"] > 1e-3
                or not totals["metrics_reconciled"]):
            failed += 1

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"[soak] wrote {len(results)} rows to {args.out}")
    if failed:
        print(f"[soak] FAILED: {failed} config(s) diverged or crashed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
