"""Allreduce executor + schedule-compiler benchmark (the repo's perf
trajectory for the hot collective).

Two families of entries:

  * ``exec/<fabric>/<engine>`` -- wall-clock of one allreduce on 16 fake
    host devices, comparing the fused global-round executor against the
    per-tree baseline chains and ``jax.lax.psum``, with and without int8
    quantization, on the (4,4) and (2,8) torus DP fabrics;
  * ``compile/<fabric>/<center>`` -- schedule-compile time of the
    depth-minimizing root search: the CSR double-BFS center
    (``repro.core.csr``) against the historical O(n^2) every-vertex
    probe, on the paper's diameter-2/3 fabrics (Slim Fly, PolarStar) and
    a 1024-node torus.

Every entry lands in ``BENCH_allreduce.json`` with the schema
``name -> {us_per_call, bytes, k, depth}`` so successive PRs can append
to the perf trajectory.

    PYTHONPATH=src python -m benchmarks.allreduce_bench
    PYTHONPATH=src python -m benchmarks.allreduce_bench --quick --out BENCH_allreduce.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# 16 fake host devices; must be set before jax initializes the backend
_FORCE = "--xla_force_host_platform_device_count=16"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FORCE).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.dist  # noqa: E402  (installs compat shard_map)
from repro.core import topologies as topo  # noqa: E402
from repro.core.collectives import (allreduce_schedule,  # noqa: E402
                                    _best_root_probe,
                                    fused_spec_from_schedule, tree_schedule)
from repro.core.csr import tree_center  # noqa: E402
from repro.core.edst_star import star_edsts  # noqa: E402
from repro.dist.tree_allreduce import (fused_tree_allreduce,  # noqa: E402
                                       per_tree_allreduce,
                                       spec_from_schedule)

EXEC_FABRICS = (("torus4x4", (4, 4)), ("torus2x8", (2, 8)))
COMPILE_FABRICS = (
    ("torus32x32", lambda: topo.device_topology((32, 32))),   # n = 1024
    ("slimfly_q7", lambda: topo.slimfly(7)),                  # n = 98
    ("polarstar_er3_qr5", lambda: topo.polarstar(3, "qr", 5)),  # n = 65
)


def _time_call(fn, iters: int) -> float:
    fn()  # warmup (compile)
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def bench_executors(results: dict, elems: int, iters: int) -> None:
    mesh = jax.make_mesh((16,), ("data",))
    x = (jnp.arange(16 * elems, dtype=jnp.float32).reshape(16, elems)
         * 1e-4)
    nbytes = elems * 4

    for label, dims in EXEC_FABRICS:
        sp = topo.device_topology(dims)
        sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
        fspec = fused_spec_from_schedule(sched, ("data",))
        lspec = spec_from_schedule(sched, ("data",))

        def run(body):
            f = jax.jit(jax.shard_map(
                lambda xs: body(xs.reshape(xs.shape[1:]))[None],
                mesh=mesh, in_specs=P("data"), out_specs=P("data")))
            return _time_call(lambda: jax.block_until_ready(f(x)), iters)

        cases = {
            "fused": lambda v: fused_tree_allreduce(v, fspec),
            "per_tree": lambda v: per_tree_allreduce(v, lspec),
            "fused_q8": lambda v: fused_tree_allreduce(v, fspec,
                                                       quantize=True),
            "per_tree_q8": lambda v: per_tree_allreduce(v, lspec,
                                                        quantize=True),
            "psum": lambda v: jax.lax.psum(v, "data"),
        }
        for engine, body in cases.items():
            sec = run(body)
            results[f"exec/{label}/{engine}"] = {
                "us_per_call": round(sec * 1e6, 1),
                "bytes": nbytes,
                "k": sched.k,
                "depth": 0 if engine == "psum" else sched.depth,
            }


def bench_compile(results: dict, iters: int) -> None:
    for label, mk in COMPILE_FABRICS:
        sp = mk()
        g = sp.product()
        tree = sorted(g.bfs_tree(0))
        n = g.n

        csr_sec = _time_call(lambda: tree_center(n, tree), iters)
        probe_sec = _time_call(lambda: _best_root_probe(n, tree),
                               max(1, iters // 4))
        root_csr, depth_csr = tree_center(n, tree)
        assert root_csr == _best_root_probe(n, tree), label
        # full-schedule compile with the CSR center (what callers pay)
        sched_sec = _time_call(lambda: tree_schedule(n, tree), iters)

        for center, sec in (("csr_center", csr_sec),
                            ("probe_center", probe_sec),
                            ("schedule_csr", sched_sec)):
            results[f"compile/{label}/{center}"] = {
                "us_per_call": round(sec * 1e6, 1),
                "bytes": 0,
                "k": 1,
                "depth": depth_csr,
            }


def run_bench(quick: bool = False) -> dict:
    elems = 4096 if quick else 16384
    iters = 5 if quick else 20
    results: dict = {}
    bench_executors(results, elems, iters)
    bench_compile(results, 2 if quick else 5)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_allreduce.json")
    ap.add_argument("--quick", action="store_true",
                    help="smaller payloads / fewer iters (CI smoke)")
    args = ap.parse_args()

    results = run_bench(args.quick)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")

    width = max(len(k) for k in results)
    for name, row in results.items():
        print(f"{name:<{width}}  {row['us_per_call']:>10.1f} us  "
              f"k={row['k']} depth={row['depth']} bytes={row['bytes']}")
    for label, _ in EXEC_FABRICS:
        fused = results[f"exec/{label}/fused"]
        per_tree = results[f"exec/{label}/per_tree"]
        if fused["k"] >= 2:
            print(f"{label}: fused/per_tree = "
                  f"{fused['us_per_call'] / per_tree['us_per_call']:.2f}x")
    big = "torus32x32"
    speedup = (results[f"compile/{big}/probe_center"]["us_per_call"]
               / results[f"compile/{big}/csr_center"]["us_per_call"])
    print(f"{big}: probe/csr center speedup = {speedup:.0f}x")


if __name__ == "__main__":
    main()
