"""Allreduce executor + schedule-compiler benchmark (the repo's perf
trajectory for the hot collective).

Three families of entries:

  * ``exec/<fabric>/<engine>`` -- wall-clock of one allreduce on 16 fake
    host devices: the pipelined segmented engine (the default; plus its
    S in {1,2,4,8} segment sweep and the ``segments="auto"`` pick, which
    the row records), the striped reduce-scatter/allgather engine
    (stripe-sized wires, ~2x the wave count: slower on this
    alpha-dominated host -- that IS the datapoint the engine-selection
    matrix documents), the ``zero1`` train-step stand-in (reduce-scatter
    -> owner-stripe update -> params allgather: the same stripe program
    minus the gradient allgather, so its row records ``waves`` vs
    ``composed_waves``), the fused global-round and per-tree baselines,
    and ``jax.lax.psum``, each with and without the int8 wire, on the
    (4,4) and (2,8) torus DP fabrics.  Cases are timed *interleaved*
    (every engine once per block, best block wins) so slow drift on
    shared CI hosts cannot skew one engine's row;
  * ``compile/<fabric>/<center>`` -- schedule-compile time of the
    depth-minimizing root search: the CSR double-BFS center
    (``repro.core.csr``) against the historical O(n^2) every-vertex
    probe, on the paper's diameter-2/3 fabrics (Slim Fly, PolarStar) and
    a 1024-node torus;
  * ``calibration/<backend>`` -- measured CostModel constants (per-
    collective alpha from the pipelined wave timings, achievable
    collective bandwidth from the psum row).  The bench *loads* any
    calibration already persisted in ``BENCH_allreduce.json`` before
    autotuning (``CostModel.register_calibration``), so backends without
    built-in constants stop falling back silently -- see
    ``CostModel.for_backend``'s logged fallback.

Every entry lands in ``BENCH_allreduce.json`` with the schema
``name -> {us_per_call, bytes, k, depth, [segments], [codec]}`` so
successive PRs can append to the perf trajectory.
``BENCH_allreduce_quick.json`` is the committed ``--quick`` twin:
``benchmarks/bench_diff.py`` gates CI against it (psum-normalized,
same-payload rows only; striped rows are gated like every other
headline engine row).

    PYTHONPATH=src python -m benchmarks.allreduce_bench
    PYTHONPATH=src python -m benchmarks.allreduce_bench --quick --out BENCH_allreduce_quick.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# 16 fake host devices; must be set before jax initializes the backend
_FORCE = "--xla_force_host_platform_device_count=16"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FORCE).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

import repro.dist  # noqa: E402  (installs compat shard_map)
from repro.core import topologies as topo  # noqa: E402
from repro.core.collectives import (CostModel,  # noqa: E402
                                    allreduce_schedule, _best_root_probe,
                                    fused_spec_from_schedule,
                                    pipelined_spec_from_schedule,
                                    striped_spec_from_schedule,
                                    striped_tables, tree_schedule)
from repro.core.csr import tree_center  # noqa: E402
from repro.core.edst_star import star_edsts  # noqa: E402
from repro.dist.striped import (striped_allreduce,  # noqa: E402
                                tree_allgather, tree_reduce_scatter)
from repro.dist.tree_allreduce import (auto_segments,  # noqa: E402
                                       fused_tree_allreduce,
                                       per_tree_allreduce,
                                       pipelined_tree_allreduce,
                                       resolve_codec, spec_from_schedule)

TRAJECTORY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_allreduce.json")

EXEC_FABRICS = (("torus4x4", (4, 4)), ("torus2x8", (2, 8)))
SEGMENT_SWEEP = (1, 2, 4, 8)
COMPILE_FABRICS = (
    ("torus32x32", lambda: topo.device_topology((32, 32))),   # n = 1024
    ("slimfly_q7", lambda: topo.slimfly(7)),                  # n = 98
    ("polarstar_er3_qr5", lambda: topo.polarstar(3, "qr", 5)),  # n = 65
)


def _time_call(fn, iters: int) -> float:
    fn()  # warmup (compile)
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _time_interleaved(fns: dict, rounds: int) -> dict:
    """Best single-call wall clock per case over ``rounds`` round-robin
    sweeps.  Interleaving one call at a time spreads host-machine drift
    over every engine alike (consecutive same-engine blocks let a slow
    patch skew one row), and the min discards contention outliers."""
    for fn in fns.values():
        fn()  # compile
        fn()
    best = {name: float("inf") for name in fns}
    for _ in range(rounds):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def load_calibration(path: str = TRAJECTORY) -> None:
    """Re-register the CostModel constants a previous bench run persisted
    (``calibration/<backend>`` rows), so ``segments="auto"`` autotunes
    from measurements instead of the built-in table."""
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, ValueError):
        return
    for name, row in rows.items():
        if not name.startswith("calibration/"):
            continue
        consts = {k: row[k] for k in ("link_bw", "alpha", "overlap")
                  if k in row}
        if consts:
            CostModel.register_calibration(name.split("/", 1)[1], **consts)


def bench_executors(results: dict, elems: int, iters: int) -> None:
    mesh = jax.make_mesh((16,), ("data",))
    x = (jnp.arange(16 * elems, dtype=jnp.float32).reshape(16, elems)
         * 1e-4)
    nbytes = elems * 4
    cal_alpha, cal_bw = [], []

    for label, dims in EXEC_FABRICS:
        sp = topo.device_topology(dims)
        sched = allreduce_schedule(sp.n, star_edsts(sp).trees)
        pspec = pipelined_spec_from_schedule(sched, ("data",))
        fspec = fused_spec_from_schedule(sched, ("data",))
        lspec = spec_from_schedule(sched, ("data",))
        sspec = striped_spec_from_schedule(sched, ("data",))
        mrow = -(-elems // max(1, sched.k))
        auto_s = auto_segments(pspec, mrow)
        codec = resolve_codec()

        def jitted(body):
            f = jax.jit(jax.shard_map(
                lambda xs: body(xs.reshape(xs.shape[1:]))[None],
                mesh=mesh, in_specs=P("data"), out_specs=P("data")))
            return lambda: jax.block_until_ready(f(x))

        # zero1 step stand-in: RS grads -> elementwise owner-stripe
        # update -> AG params (full precision, like the real step); the
        # gradient allgather of the composed allreduce never runs, so
        # the row's wave count is rs+ag of the *same* stripe program
        bt = striped_tables(sspec, elems)
        z_waves = len(bt.rs_waves) + len(bt.ag_waves)

        def zero1_body(v, quantize=False):
            owned = tree_reduce_scatter(v, sspec, quantize=quantize)
            owned = owned * (0.999 / sp.n)
            return tree_allgather(owned, sspec, v.shape)

        cases = {
            "pipelined": lambda v: pipelined_tree_allreduce(v, pspec),
            "striped": lambda v: striped_allreduce(v, sspec),
            "zero1": zero1_body,
            "fused": lambda v: fused_tree_allreduce(v, fspec),
            "per_tree": lambda v: per_tree_allreduce(v, lspec),
            "psum": lambda v: jax.lax.psum(v, "data"),
        }
        if codec != "off":
            cases.update({
                "pipelined_q8": lambda v: pipelined_tree_allreduce(
                    v, pspec, quantize=True),
                "striped_q8": lambda v: striped_allreduce(v, sspec,
                                                          quantize=True),
                "zero1_q8": lambda v: zero1_body(v, quantize=True),
                "fused_q8": lambda v: fused_tree_allreduce(v, fspec,
                                                           quantize=True),
                "per_tree_q8": lambda v: per_tree_allreduce(v, lspec,
                                                            quantize=True),
            })
        # the S>1 scan issues every wave each step -- two orders of
        # magnitude slower on serialized-collective hosts (that IS the
        # datapoint) -- so the sweep times in its own group to keep the
        # headline engine rows' round-robin tight
        sweep = {f"pipelined_s{s}":
                 (lambda v, s=s: pipelined_tree_allreduce(v, pspec,
                                                          segments=s))
                 for s in SEGMENT_SWEEP}

        timed = _time_interleaved({n: jitted(b) for n, b in cases.items()},
                                  iters)
        if codec == "off":
            # the model-disabled codec compiles the IDENTICAL program as
            # f32 (resolve_codec docstring), so the q8 rows share their
            # counterpart's measurement rather than re-timing the same
            # executable into measurement noise (the striped engine's
            # allgather wire is disabled by codec="off" too)
            for eng in ("pipelined", "striped", "zero1", "fused",
                        "per_tree"):
                timed[f"{eng}_q8"] = timed[eng]
        timed.update(_time_interleaved(
            {n: jitted(b) for n, b in sweep.items()}, max(2, iters // 6)))
        cal_alpha.append(timed["pipelined"] / max(1, len(pspec.waves)))
        cal_bw.append(nbytes / max(timed["psum"], 1e-9))
        for engine, sec in timed.items():
            row = {
                "us_per_call": round(sec * 1e6, 1),
                "bytes": nbytes,
                "k": sched.k,
                "depth": 0 if engine == "psum" else sched.depth,
            }
            if engine.startswith("pipelined"):
                row["segments"] = (int(engine.rsplit("_s", 1)[1])
                                   if "_s" in engine else auto_s)
            if engine.startswith("striped"):
                row["stripes"] = sp.n
            if engine.startswith("zero1"):
                row["stripes"] = sp.n
                row["waves"] = z_waves
                row["composed_waves"] = len(bt.waves)
            if engine.endswith("_q8"):
                row["codec"] = codec
            results[f"exec/{label}/{engine}"] = row

    backend = jax.default_backend()
    row = {
        "us_per_call": round(min(cal_alpha) * 1e6, 1),
        "bytes": nbytes,
        "k": 0,
        "depth": 0,
        "alpha": min(cal_alpha),
        "link_bw": max(cal_bw),
    }
    # only the XLA host runtime's collective serialization is a KNOWN
    # property worth persisting; for other backends overlap is left to
    # CostModel's defaults rather than recorded as if it were measured
    if backend == "cpu":
        row["overlap"] = False
    results[f"calibration/{backend}"] = row


def bench_compile(results: dict, iters: int) -> None:
    for label, mk in COMPILE_FABRICS:
        sp = mk()
        g = sp.product()
        tree = sorted(g.bfs_tree(0))
        n = g.n

        csr_sec = _time_call(lambda: tree_center(n, tree), iters)
        probe_sec = _time_call(lambda: _best_root_probe(n, tree),
                               max(1, iters // 4))
        root_csr, depth_csr = tree_center(n, tree)
        assert root_csr == _best_root_probe(n, tree), label
        # full-schedule compile with the CSR center (what callers pay)
        sched_sec = _time_call(lambda: tree_schedule(n, tree), iters)

        for center, sec in (("csr_center", csr_sec),
                            ("probe_center", probe_sec),
                            ("schedule_csr", sched_sec)):
            results[f"compile/{label}/{center}"] = {
                "us_per_call": round(sec * 1e6, 1),
                "bytes": 0,
                "k": 1,
                "depth": depth_csr,
            }


def run_bench(quick: bool = False) -> dict:
    load_calibration()   # autotune from persisted measurements if present
    elems = 4096 if quick else 16384
    iters = 12 if quick else 42
    results: dict = {}
    bench_executors(results, elems, iters)
    bench_compile(results, 2 if quick else 5)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_allreduce.json")
    ap.add_argument("--quick", action="store_true",
                    help="smaller payloads / fewer iters (CI smoke)")
    args = ap.parse_args()

    results = run_bench(args.quick)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")

    width = max(len(k) for k in results)
    for name, row in results.items():
        extra = "".join(f" {key}={row[key]}"
                        for key in ("segments", "stripes", "codec")
                        if key in row)
        print(f"{name:<{width}}  {row['us_per_call']:>10.1f} us  "
              f"k={row['k']} depth={row['depth']} bytes={row['bytes']}"
              f"{extra}")
    for label, _ in EXEC_FABRICS:
        rows = {e: results[f"exec/{label}/{e}"]["us_per_call"]
                for e in ("pipelined", "pipelined_q8", "striped",
                          "striped_q8", "zero1", "zero1_q8",
                          "fused", "fused_q8",
                          "per_tree", "per_tree_q8", "psum")}
        zrow = results[f"exec/{label}/zero1"]
        print(f"{label}: fused/pipelined = "
              f"{rows['fused'] / rows['pipelined']:.2f}x   "
              f"striped/pipelined = "
              f"{rows['striped'] / rows['pipelined']:.2f}x   "
              f"psum/pipelined = {rows['psum'] / rows['pipelined']:.2f}x")
        print(f"  zero1/striped = {rows['zero1'] / rows['striped']:.2f}x  "
              f"waves {zrow['waves']} vs composed "
              f"{zrow['composed_waves']}")
        for eng in ("pipelined", "striped", "zero1", "fused", "per_tree"):
            flag = "OK" if rows[f"{eng}_q8"] <= rows[eng] else "REGRESSION"
            print(f"  {eng}_q8 vs {eng}: "
                  f"{rows[f'{eng}_q8'] / rows[eng]:.2f}x [{flag}]")
    big = "torus32x32"
    speedup = (results[f"compile/{big}/probe_center"]["us_per_call"]
               / results[f"compile/{big}/csr_center"]["us_per_call"])
    print(f"{big}: probe/csr center speedup = {speedup:.0f}x")


if __name__ == "__main__":
    main()
