"""DEPRECATED thin alias: the CI legality gate moved into the static
wave-program verifier CLI (:mod:`repro.analysis.verify`).

``python -m benchmarks.wave_check`` now runs

    python -m repro.analysis.verify --all-engines --topologies paper5 \
        --simulate

i.e. the *static* verifier (partial-bijection waves, link races,
happens-before, edge-disjointness recovered from the routing tables,
stripe-window conservation) on every engine and paper topology, plus the
NumPy packet-simulator replays this script used to run (``--simulate``).
Prefer invoking the verifier module directly; this shim exists so older
CI configs and docs keep working.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.analysis.verify import main as _verify_main  # noqa: E402


def main() -> int:
    print("benchmarks.wave_check is deprecated; running "
          "`python -m repro.analysis.verify --all-engines "
          "--topologies paper5 --simulate`\n", file=sys.stderr)
    return _verify_main(["--all-engines", "--topologies", "paper5",
                         "--simulate"])


if __name__ == "__main__":
    sys.exit(main())
