"""CI legality gate for every compiled allreduce engine: replay the wave
programs of all engines through the NumPy packet simulators on the five
paper topology families and fail on any violated invariant.

Per topology (torus, HyperX, Slim Fly, PolarStar, BundleFly -- the
networks of the paper's Tables 1-3) and its maximal EDST schedule:

  * per-tree engine  -- ``simulate_allreduce``: exact sums, link load 1
    (edge-disjointness: no physical link ever carries two messages);
  * fused engine     -- every wave ppermute-legal (unique sources and
    destinations) and message conservation (each tree edge carries
    exactly one reduce and one broadcast message);
  * pipelined engine -- ``simulate_wave_program`` at S in {1, 4}, f32
    and quantized programs: exact sums, steps == waves + S - 1,
    per-directed-link exclusivity;
  * striped engine   -- ``simulate_striped_program``: exact sums,
    per-stripe conservation (each owner slot crosses each tree edge
    exactly once per phase), and the wire-bytes bound (every wave's
    wire <= ceil(m/n) * slots-per-window, strictly < m when m >= n).

Run from CI as ``python -m benchmarks.wave_check`` (pure NumPy -- no
fake-device subprocesses, a few seconds per topology).
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.core import topologies as topo  # noqa: E402
from repro.core.collectives import (allreduce_schedule,  # noqa: E402
                                    fused_spec_from_schedule,
                                    pipelined_spec_from_schedule,
                                    simulate_allreduce,
                                    simulate_striped_program,
                                    simulate_wave_program,
                                    striped_spec_from_schedule,
                                    striped_tables)
from repro.core.edst_star import star_edsts  # noqa: E402
from repro.core.topologies import edst_set_for  # noqa: E402

TOPOLOGIES = (
    ("torus4x4", lambda: topo.device_topology((4, 4)), None),
    ("hyperx4x4", lambda: topo.hyperx([4, 4]), None),
    ("slimfly_q5", lambda: topo.slimfly(5), None),
    ("polarstar_er3_qr5", lambda: topo.polarstar(3, "qr", 5), None),
    ("bundlefly_q4_a5", lambda: topo.bundlefly(4, 5),
     lambda: edst_set_for(topo.slimfly(4))),
)


def check_topology(label: str, sp, es=None) -> list:
    failures = []
    res = star_edsts(sp, Es=es) if es is not None else star_edsts(sp)
    sched = allreduce_schedule(sp.product().n, res.trees)
    n, k = sched.n, sched.k
    rng = np.random.RandomState(sum(map(ord, label)))
    d = 8 * k + 3                         # uneven on purpose
    vals = rng.randn(n, d)

    # per-tree engine: the schedule executed literally (needs d % k == 0)
    sim = simulate_allreduce(sched, rng.randn(n, 8 * k))
    if not sim.ok:
        failures.append("per_tree: wrong sums")
    if sim.max_link_load != 1:
        failures.append(f"per_tree: link load {sim.max_link_load} != 1")

    # fused engine: wave legality + message conservation
    fspec = fused_spec_from_schedule(sched, ("data",))
    seen = []
    for rnd in fspec.reduce_rounds + fspec.bcast_rounds:
        srcs = [s for s, _ in rnd.perm]
        dsts = [t for _, t in rnd.perm]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            failures.append("fused: wave reuses a source/destination")
        seen.extend(rnd.perm)
    if len(seen) != 2 * sum(len(ts.tree) for ts in sched.trees):
        failures.append("fused: message conservation violated")

    # pipelined engine: segment-streamed replay, f32 and quantized
    pspec = pipelined_spec_from_schedule(sched, ("data",))
    for segments in (1, 4):
        for q in (False, True):
            sim = simulate_wave_program(pspec, vals, segments, quantized=q)
            if not sim.ok:
                failures.append(f"pipelined: wrong sums (S={segments} q={q})")
            if sim.max_link_load != 1:
                failures.append(
                    f"pipelined: directed-link load {sim.max_link_load}"
                    f" != 1 (S={segments} q={q})")

    # striped engine: per-stripe conservation + wire-bytes bound
    sspec = striped_spec_from_schedule(sched, ("data",))
    ssim = simulate_striped_program(sspec, vals)
    bound = striped_tables(sspec, d)
    if not ssim.ok:
        failures.append("striped: wrong sums")
    if not ssim.stripes_ok:
        failures.append("striped: per-stripe conservation violated")
    for bw, wire in zip(bound.waves, ssim.wire_elems):
        if wire != int(bw.recv_len.max()):
            failures.append("striped: wave wire != max window length")
        if wire > bound.smax * (n - 1):
            failures.append(
                f"striped: wire {wire} exceeds ceil(m/n) * (n-1) slots")
    if bound.mrow >= n and ssim.max_wire >= bound.mrow:
        failures.append(
            f"striped: max wire {ssim.max_wire} not < m {bound.mrow}")
    return failures


def main() -> int:
    bad = 0
    for label, mk, mk_es in TOPOLOGIES:
        sp = mk()
        es = mk_es() if mk_es is not None else None
        failures = check_topology(label, sp, es)
        status = "ok" if not failures else "FAIL"
        print(f"wave_check/{label}: {status}"
              + "".join(f"\n  - {f}" for f in failures))
        bad += len(failures)
    if bad:
        print(f"\n{bad} invariant violation(s)")
        return 1
    print("\nall engines legal on all paper topologies")
    return 0


if __name__ == "__main__":
    sys.exit(main())
