"""One benchmark per paper table.

Table 1 (Cartesian EDST counts, from [16], validated by our constructions on
Cartesian instances), Table 2 (star-product EDST counts per condition row),
Table 3 (network EDSTs: constructed vs combinatorial bound), Table 4 (factor
graph t/r), plus the Allreduce bandwidth model (Sec 1.1 motivation).

Each function returns (name, seconds_per_call, derived) rows.
"""
from __future__ import annotations

import time

from repro.core import factor_graphs as fg
from repro.core import topologies as topo
from repro.core.collectives import (CostModel, allreduce_schedule,
                                    pipelined_spec_from_schedule,
                                    striped_spec_from_schedule)
from repro.core.edst_star import (maximal_edsts, one_sided_edsts,
                                  property_461_edsts, star_edsts,
                                  universal_edsts)
from repro.core.factor_edsts import edsts_for
from repro.core.star import cartesian, random_star
from repro.core.topologies import edst_set_for


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def table1_cartesian():
    """Cartesian-product rows of Table 1 ([16]'s counts, via our general
    star machinery with identity bijections)."""
    rows = []
    cases = [
        ("K5xK5 r=t both", lambda: cartesian(fg.complete(5), fg.complete(5)),
         "t1+t2", lambda t1, t2: t1 + t2),
        ("K4xK4 r=0 both", lambda: cartesian(fg.complete(4), fg.complete(4)),
         "t1+t2-1", lambda t1, t2: t1 + t2 - 1),
        ("C8xC8 torus", lambda: cartesian(fg.cycle(8), fg.cycle(8)),
         "t1+t2", lambda t1, t2: t1 + t2),
        ("K6xC6", lambda: cartesian(fg.complete(6), fg.cycle(6)),
         "t1+t2-1", lambda t1, t2: t1 + t2 - 1),
    ]
    for name, mk, rule, expect in cases:
        sp = mk()
        es, en = edsts_for(sp.gs), edsts_for(sp.gn)
        res, dt = _timed(lambda: star_edsts(sp, es, en))
        rows.append((f"table1/{name}", dt,
                     f"trees={res.count} rule={rule} "
                     f"expected={expect(es.t, en.t)} max={res.maximal}"))
        assert res.count >= expect(es.t, en.t), name
    return rows


def table2_star_conditions():
    """Each row of Table 2 on a star product meeting its conditions."""
    rows = []
    # r1=t1 AND r2=t2 -> t1+t2 (maximal)
    sp = random_star(fg.complete(5), fg.cycle(5), seed=11)
    es, en = edsts_for(sp.gs), edsts_for(sp.gn)
    res, dt = _timed(lambda: maximal_edsts(sp, es, en))
    rows.append(("table2/r=t_both_4.5.2", dt,
                 f"trees={res.count} expect={es.t+en.t} max={res.maximal}"))
    # r1>=t1 OR r2>=t2 -> t1+t2-1
    sp = topo.polarstar(3, "qr", 5)
    es, en = edsts_for(sp.gs), edsts_for(sp.gn)
    res, dt = _timed(lambda: one_sided_edsts(sp, es, en))
    rows.append(("table2/one_sided_4.5.9", dt,
                 f"trees={res.count} expect={es.t+en.t-1} max={res.maximal}"))
    # Property 4.6.1 (Cartesian) -> t1+t2-1 when r<t both
    sp = cartesian(fg.complete(4), fg.complete(4))
    es, en = edsts_for(sp.gs), edsts_for(sp.gn)
    res, dt = _timed(lambda: property_461_edsts(sp, es, en))
    rows.append(("table2/property461_4.6.2", dt,
                 f"trees={res.count} expect={es.t+en.t-1} max={res.maximal}"))
    # universal, any star product -> t1+t2-2
    sp = random_star(fg.complete(6), fg.complete(6), seed=3)
    es, en = edsts_for(sp.gs), edsts_for(sp.gn)
    res, dt = _timed(lambda: universal_edsts(sp, es, en))
    rows.append(("table2/universal_4.3.1", dt,
                 f"trees={res.count} expect={es.t+en.t-2}"))
    return rows


def table3_networks():
    """Constructed EDSTs vs the upper bound for each Table-3 network family
    instantiable at test scale."""
    rows = []
    cases = [
        ("slimfly_q5_4k+1", lambda: topo.slimfly(5), 3),
        ("slimfly_q4_4k", lambda: topo.slimfly(4), 3),
        ("slimfly_q7_4k-1", lambda: topo.slimfly(7), 5),
        ("slimfly_q8_4k", lambda: topo.slimfly(8), 6),
        ("slimfly_q9_4k+1", lambda: topo.slimfly(9), 6),
        ("bundlefly_q4_a5", lambda: topo.bundlefly(4, 5), 4),
        ("bundlefly_q5_a5", lambda: topo.bundlefly(5, 5), 4),
        ("polarstar_er2_qr5", lambda: topo.polarstar(2, "qr", 5), 2),
        ("polarstar_er3_qr5", lambda: topo.polarstar(3, "qr", 5), 2),
        ("polarstar_er4_qr5", lambda: topo.polarstar(4, "qr", 5), 3),
        ("polarstar_er2_iq4", lambda: topo.polarstar(2, "iq", 4), 3),
        ("polarstar_er3_iq4", lambda: topo.polarstar(3, "iq", 4), 3),
        # q odd, d=4m+3: paper Table 3 row "Maybe": floor((q+d)/2) - 1
        ("polarstar_er3_iq7", lambda: topo.polarstar(3, "iq", 7), 4),
        ("hyperx_4x4", lambda: topo.hyperx([4, 4]), 3),
        ("torus_16x16", lambda: topo.device_topology((16, 16)), 2),
    ]
    for name, mk, expected in cases:
        sp = mk()
        if name.startswith("bundlefly"):
            es = edst_set_for(topo.slimfly(int(name.split("_q")[1][0])))
            res, dt = _timed(lambda: star_edsts(sp, Es=es))
        else:
            res, dt = _timed(lambda: star_edsts(sp))
        g = sp.product()
        ub = g.m // (g.n - 1)
        rows.append((f"table3/{name}", dt,
                     f"V={g.n} trees={res.count} expected={expected} "
                     f"bound={ub} thm={res.theorem} max={res.maximal}"))
        assert res.count == expected, (name, res.count, expected)
    return rows


def table4_factor_graphs():
    """Factor-graph (t, r) for every family in Table 4."""
    rows = []
    cases = [
        ("C(5)=QR(5)", lambda: fg.paley(5), (1, 1)),
        ("C(13)=QR(13)", lambda: fg.paley(13), (3, 3)),
        ("C(4)", lambda: fg.mms_supernode(4), (1, 1)),
        ("C(7)", lambda: fg.mms_supernode(7), (2, 2)),
        ("K_{5,5}", lambda: fg.complete_bipartite(5), (2, 7)),
        ("K_{4,4}", lambda: fg.complete_bipartite(4), (2, 2)),
        ("K6", lambda: fg.complete(6), (3, 0)),
        ("K7", lambda: fg.complete(7), (3, 3)),
        ("BDF(4)", lambda: fg.bdf(4), (2, 2)),
        ("BDF(5)", lambda: fg.bdf(5), (2, 7)),
        ("IQ(4)", lambda: fg.inductive_quad(4), (2, 2)),
        ("IQ(7)", lambda: fg.inductive_quad(7), (3, 11)),
        ("ER_3", lambda: fg.erdos_renyi_polarity(3), (2, 0)),
        ("ER_4", lambda: fg.erdos_renyi_polarity(4), (2, 10)),
    ]
    for name, mk, (t, r) in cases:
        g = mk()
        E, dt = _timed(lambda: edsts_for(g))
        rows.append((f"table4/{name}", dt, f"t={E.t} r={E.r} "
                     f"expected=({t},{r}) ok={(E.t, E.r) == (t, r)}"))
        assert (E.t, E.r) == (t, r), name
    return rows


def allreduce_bandwidth():
    """Sec 1.1 motivation: k-tree EDST allreduce vs ring vs single tree,
    plus the modelled per-engine sweep (pipelined segment counts, the
    striped reduce-scatter/allgather program).  Sweep rows share the
    fabric's base name and carry a params dict -- ``benchmarks/run.py``
    keys its JSON by name+params so the engines stop overwriting each
    other."""
    rows = []
    cm = CostModel()
    for dims, label in [((16, 16), "pod_16x16"), ((2, 16, 16), "2pod"),
                        ((8, 8), "torus8x8")]:
        sp = topo.device_topology(dims)
        res = star_edsts(sp)
        sched, dt = _timed(lambda: allreduce_schedule(sp.n, res.trees))
        b = 100 * 2 ** 20
        ring = cm.ring_allreduce(b, sp.n)
        ktree = cm.edst_tree_allreduce(b, sched)
        innet = cm.edst_tree_allreduce(b, sched, in_network=True)
        one = cm.single_tree_allreduce(b, sched.trees[0])
        rows.append((f"allreduce/{label}", dt,
                     f"k={sched.k} ring_ms={ring*1e3:.2f} "
                     f"ktree_ms={ktree*1e3:.2f} innet_ms={innet*1e3:.2f} "
                     f"1tree_ms={one*1e3:.2f} "
                     f"speedup_vs_ring={ring/ktree:.2f}x "
                     f"speedup_vs_1tree={one/ktree:.2f}x"))
        pspec, pdt = _timed(lambda: pipelined_spec_from_schedule(
            sched, ("data",)))
        for s in (1, 8, 64):
            ms = cm.pipelined_allreduce(b, pspec, s) * 1e3
            rows.append((f"allreduce/{label}", pdt,
                         f"model_ms={ms:.2f} waves={len(pspec.waves)}",
                         {"engine": "pipelined", "segments": s}))
        sspec, sdt = _timed(lambda: striped_spec_from_schedule(
            sched, ("data",)))
        ms = cm.striped_allreduce(b, sspec) * 1e3
        rows.append((f"allreduce/{label}", sdt,
                     f"model_ms={ms:.2f} waves={len(sspec.waves)}",
                     {"engine": "striped", "stripes": sp.n}))
    return rows


ALL = [table1_cartesian, table2_star_conditions, table3_networks,
       table4_factor_graphs, allreduce_bandwidth]
