"""Benchmark harness: one function per paper table + allreduce bandwidth +
roofline summary (from dry-run artifacts when present).

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --json BENCH_tables.json

Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes the same rows as ``name -> {us_per_call, derived}`` so they can
join the ``BENCH_*.json`` perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from benchmarks.table_benchmarks import ALL  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON to PATH")
    args = ap.parse_args()

    rows = {}
    print("name,us_per_call,derived")
    failures = 0
    for fn in ALL:
        try:
            for name, sec, derived in fn():
                print(f"{name},{sec * 1e6:.1f},{derived}")
                rows[name] = {"us_per_call": round(sec * 1e6, 1),
                              "derived": derived}
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},ERROR,{e!r}")
    # roofline summary if the dry-run artifacts exist
    try:
        from benchmarks.roofline_report import summary_rows
        for row in summary_rows():
            print(row)
    except FileNotFoundError:
        print("roofline,skipped,run `python -m repro.launch.dryrun --all "
              "--out dryrun_single_pod.json` first")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
