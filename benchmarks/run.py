"""Benchmark harness: one function per paper table + allreduce bandwidth +
roofline summary (from dry-run artifacts when present).

    PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --json BENCH_tables.json

Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes the same rows as ``key -> {us_per_call, derived, ...params}`` so
they can join the ``BENCH_*.json`` perf trajectory.  Sweep rows (the
allreduce model swept per engine / segment count / stripe count) carry a
params dict; the JSON key embeds it -- ``allreduce/pod_16x16[engine=
striped,stripes=256]`` -- so rows that share a base name no longer
overwrite each other across engines, and a residual collision is
suffixed ``#2``/``#3`` instead of silently dropped.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from benchmarks.table_benchmarks import ALL  # noqa: E402


def row_key(name: str, params: dict | None) -> str:
    """The JSON key of one bench row: the row name plus its identifying
    sweep parameters (engine, segments, stripes, ...), sorted for
    stability."""
    if not params:
        return name
    inner = ",".join(f"{k}={params[k]}" for k in sorted(params))
    return f"{name}[{inner}]"


def add_row(rows: dict, name: str, sec: float, derived: str,
            params: dict | None) -> None:
    key = row_key(name, params)
    if key in rows:         # never overwrite: disambiguate leftovers
        i = 2
        while f"{key}#{i}" in rows:
            i += 1
        key = f"{key}#{i}"
    rows[key] = {"us_per_call": round(sec * 1e6, 1), "derived": derived,
                 **(params or {})}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON to PATH")
    args = ap.parse_args()

    rows = {}
    print("name,us_per_call,derived")
    failures = 0
    for fn in ALL:
        try:
            for row in fn():
                name, sec, derived = row[:3]
                params = row[3] if len(row) > 3 else None
                print(f"{row_key(name, params)},{sec * 1e6:.1f},{derived}")
                add_row(rows, name, sec, derived, params)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},ERROR,{e!r}")
    # roofline summary if the dry-run artifacts exist
    try:
        from benchmarks.roofline_report import summary_rows
        for row in summary_rows():
            print(row)
    except FileNotFoundError:
        print("roofline,skipped,run `python -m repro.launch.dryrun --all "
              "--out dryrun_single_pod.json` first")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
