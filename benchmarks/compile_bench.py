"""Compile-time benchmark for the compositional star-product schedule
compiler and the anytime wave-schedule search, writing the committed
``BENCH_compile.json`` / ``BENCH_compile_quick.json`` artifacts that
``benchmarks/compile_diff.py`` gates in CI.

Two row families:

  * ``compile`` -- composed-vs-flat wall-clock on large PolarStar
    fabrics, one row per (fabric, engine).  Both paths receive the SAME
    precomputed factor EDST sets (``factors_s`` is recorded but excluded
    from both timings: the compositional compiler's premise is that
    factor structure is packed once and cached across fabrics), then
    each is timed in two stages -- schedule build (``*_sched_s``:
    ``star_edsts``+``allreduce_schedule`` flat, composed-tree assembly
    composed) and spec compile (``*_spec_s``: the greedy list schedule
    over the flat message DAG vs ASAP earliest-wave placement).
    ``speedup_spec`` is the spec-stage ratio the >=10x acceptance gate
    reads (wave-program compilation, the stage the tentpole replaces);
    ``speedup_total`` includes both stages.  ``composed_ok`` is the
    static verifier's verdict on the composed program -- the speedup
    only counts because the result is verifier-clean.
  * ``search`` -- schedule-quality rows on the five paper topologies:
    greedy vs searched wave counts and 64 MiB CostModel makespans per
    engine.  Deterministic (seeded search), so the diff gate can require
    search <= greedy exactly and a strict win somewhere.

    python -m benchmarks.compile_bench --quick --out /tmp/compile.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

SCORE_NBYTES = 64 * 1024 * 1024


def _compile_rows(fabrics, verify_level):
    from repro.analysis.verify import verify_spec
    from repro.core.collectives import (allreduce_schedule,
                                        pipelined_spec_from_schedule,
                                        striped_spec_from_schedule)
    from repro.core.edst_star import star_edsts
    from repro.core.product_schedule import (asap_pipelined_spec,
                                             asap_striped_spec,
                                             composed_allreduce_schedule,
                                             factor_edsts_cached)
    rows = []
    for name, sp in fabrics:
        n = sp.product().n
        t0 = time.perf_counter()
        Es = factor_edsts_cached(sp.gs)
        En = factor_edsts_cached(sp.gn)
        factors_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        comp_sched = composed_allreduce_schedule(sp, Es=Es, En=En)
        comp_sched_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        res = star_edsts(sp, Es, En)
        flat_sched = allreduce_schedule(n, res.trees)
        flat_sched_s = time.perf_counter() - t0

        for engine, comp_fn, flat_fn in (
                ("pipelined", asap_pipelined_spec,
                 pipelined_spec_from_schedule),
                ("striped", asap_striped_spec,
                 striped_spec_from_schedule)):
            t0 = time.perf_counter()
            cspec = comp_fn(comp_sched, ("data",), verify=False)
            comp_spec_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            fspec = flat_fn(flat_sched, ("data",), verify=False)
            flat_spec_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            ok = verify_spec(cspec, level=verify_level).ok
            verify_s = time.perf_counter() - t0
            rows.append({
                "fabric": name, "n": n, "k": comp_sched.k,
                "engine": engine,
                "factors_s": round(factors_s, 3),
                "flat_sched_s": round(flat_sched_s, 3),
                "composed_sched_s": round(comp_sched_s, 3),
                "flat_spec_s": round(flat_spec_s, 3),
                "composed_spec_s": round(comp_spec_s, 3),
                "speedup_spec": round(flat_spec_s / comp_spec_s, 2),
                "speedup_total": round(
                    (flat_sched_s + flat_spec_s)
                    / (comp_sched_s + comp_spec_s), 2),
                "flat_waves": len(fspec.waves),
                "composed_waves": len(cspec.waves),
                "composed_ok": bool(ok),
                "verify_level": verify_level,
                "verify_s": round(verify_s, 3),
            })
            print(f"compile/{name}/{engine}: n={n} "
                  f"spec {flat_spec_s:.2f}s -> {comp_spec_s:.2f}s "
                  f"({rows[-1]['speedup_spec']}x) "
                  f"ok={ok}", flush=True)
    return rows


def _search_rows(labels):
    from repro.analysis.verify import _schedule_for
    from repro.core import schedule_search as ss
    from repro.core.collectives import (CostModel,
                                        pipelined_spec_from_schedule,
                                        striped_spec_from_schedule)
    cm = CostModel()
    rows = []
    for label in labels:
        sched = _schedule_for(label)
        gp = pipelined_spec_from_schedule(sched, ("data",), verify=False)
        sp_ = ss.search_pipelined_spec(sched, ("data",), verify=False)
        gs = striped_spec_from_schedule(sched, ("data",), verify=False)
        st = ss.search_striped_spec(sched, ("data",), verify=False)

        def _pipe_us(spec):
            return cm.pipelined_allreduce(
                SCORE_NBYTES, spec,
                cm.best_segments(SCORE_NBYTES, spec)) * 1e6

        for engine, greedy, searched, us in (
                ("pipelined", gp, sp_, _pipe_us),
                ("striped", gs, st,
                 lambda s: cm.striped_allreduce(SCORE_NBYTES, s) * 1e6)):
            rows.append({
                "topology": label, "n": sched.n, "k": sched.k,
                "engine": engine,
                "greedy_waves": len(greedy.waves),
                "search_waves": len(searched.waves),
                "greedy_makespan_us": round(us(greedy), 2),
                "search_makespan_us": round(us(searched), 2),
            })
            r = rows[-1]
            print(f"search/{label}/{engine}: waves "
                  f"{r['greedy_waves']} -> {r['search_waves']}, makespan "
                  f"{r['greedy_makespan_us']} -> "
                  f"{r['search_makespan_us']}us", flush=True)
    return rows


def run(quick: bool) -> dict:
    from repro.analysis.verify import PAPER_TOPOLOGIES
    from repro.core import topologies as topo
    # >=1k-node PolarStar for the CI budget row; the full run adds the
    # >=10k-node fabric the acceptance gate reads.
    fabrics = [("polarstar_q11_qr29", topo.polarstar(11, "qr", 29))]
    if not quick:
        fabrics.append(("polarstar_q17_qr37", topo.polarstar(17, "qr", 37)))
    t0 = time.perf_counter()
    out = {
        "meta": {"quick": quick, "score_nbytes": SCORE_NBYTES},
        "compile": _compile_rows(fabrics, "full"),
        "search": _search_rows(PAPER_TOPOLOGIES),
    }
    out["meta"]["wall_s"] = round(time.perf_counter() - t0, 1)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="the CI variant: the ~4k-node PolarStar compile "
                         "row only (the full run adds the >=10k fabric)")
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_compile_quick.json "
                         "with --quick, else BENCH_compile.json)")
    args = ap.parse_args()
    out = args.out or ("BENCH_compile_quick.json" if args.quick
                       else "BENCH_compile.json")
    results = run(args.quick)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
        f.write("\n")
    print(f"wrote {out} ({results['meta']['wall_s']}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
