"""CI gate for the compile-time trajectory of the compositional
star-product schedule compiler and the anytime wave-schedule search
(``benchmarks/compile_bench.py`` JSON).

Two kinds of checks:

  * **Invariants on the new run alone** (machine-independent, always
    enforced):

      - every ``search`` row has ``search_waves <= greedy_waves`` (the
        search only ever accepts strict improvements over the greedy
        incumbent);
      - at least one search row strictly wins -- fewer waves, or equal
        waves at a strictly lower modelled makespan (the anytime-search
        acceptance bar);
      - every ``compile`` row is ``composed_ok`` (the composed program
        passed the static verifier -- speed without legality is a
        non-result);
      - every striped ``compile`` row with ``n >= 10000`` has
        ``speedup_spec >= 10`` (the compositional-compile acceptance
        bar: wave-program compilation of a 10k+-node fabric at least
        10x faster than the flat message-DAG list schedule);
      - with ``--budget-s``, every compile row's composed path
        (schedule + spec) fits the wall-clock budget (the CI >=1k-node
        PolarStar row).

  * **Diff vs a committed baseline** (``--baseline``): wave counts are
    deterministic, so ``composed_waves`` and ``search_waves`` must not
    exceed the baseline's AT ALL (schedule-quality regressions fail
    exactly), while ``speedup_spec`` -- a same-process ratio, so host
    speed cancels -- must not fall below ``baseline / --threshold``.

    python -m benchmarks.compile_diff --baseline BENCH_compile_quick.json \
        --new /tmp/compile_quick.json --threshold 1.5 --budget-s 120
"""
from __future__ import annotations

import argparse
import json
import sys

_EPS = 1e-9


def check_invariants(new: dict, budget_s: float | None) -> list:
    """Machine-independent acceptance checks on one bench run; returns
    failure strings."""
    fails = []
    strict_win = False
    for r in new.get("search", ()):
        name = f"search/{r['topology']}/{r['engine']}"
        if r["search_waves"] > r["greedy_waves"]:
            fails.append(f"{name}: search produced MORE waves than greedy "
                         f"({r['search_waves']} > {r['greedy_waves']})")
        if (r["search_waves"] < r["greedy_waves"]
                or r["search_makespan_us"] < r["greedy_makespan_us"] - _EPS):
            strict_win = True
    if new.get("search") and not strict_win:
        fails.append("search: no strict win over greedy on any paper "
                     "fabric (fewer waves or lower makespan required "
                     "somewhere)")
    for r in new.get("compile", ()):
        name = f"compile/{r['fabric']}/{r['engine']}"
        if not r.get("composed_ok"):
            fails.append(f"{name}: composed spec FAILED static "
                         "verification")
        if r["engine"] == "striped" and r["n"] >= 10000 \
                and r["speedup_spec"] < 10:
            fails.append(f"{name}: spec-stage speedup "
                         f"{r['speedup_spec']}x < the 10x acceptance bar "
                         f"at n={r['n']}")
        if budget_s is not None:
            spent = r["composed_sched_s"] + r["composed_spec_s"]
            if spent > budget_s:
                fails.append(f"{name}: composed compile took {spent:.1f}s "
                             f"> the {budget_s:.0f}s budget")
    return fails


def _index(run: dict, family: str, keys: tuple) -> dict:
    return {tuple(r[k] for k in keys): r for r in run.get(family, ())}


def diff(baseline: dict, new: dict, threshold: float):
    """(rows, regressions) vs the committed baseline; rows are
    (name, metric, base, new) and regressions their names."""
    rows, regressions = [], []
    b_c = _index(baseline, "compile", ("fabric", "engine"))
    n_c = _index(new, "compile", ("fabric", "engine"))
    for key in sorted(b_c):
        if key not in n_c:
            continue
        b, r = b_c[key], n_c[key]
        name = f"compile/{key[0]}/{key[1]}"
        rows.append((name, "composed_waves", b["composed_waves"],
                     r["composed_waves"]))
        if r["composed_waves"] > b["composed_waves"]:
            regressions.append(name + " (waves)")
        rows.append((name, "speedup_spec", b["speedup_spec"],
                     r["speedup_spec"]))
        if r["speedup_spec"] < b["speedup_spec"] / threshold:
            regressions.append(name + " (speedup)")
    b_s = _index(baseline, "search", ("topology", "engine"))
    n_s = _index(new, "search", ("topology", "engine"))
    for key in sorted(b_s):
        if key not in n_s:
            continue
        b, r = b_s[key], n_s[key]
        name = f"search/{key[0]}/{key[1]}"
        rows.append((name, "search_waves", b["search_waves"],
                     r["search_waves"]))
        if r["search_waves"] > b["search_waves"]:
            regressions.append(name + " (waves)")
    return rows, regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--new", required=True)
    ap.add_argument("--baseline", default=None,
                    help="committed bench JSON to diff against (omit to "
                         "check the new run's invariants only)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="tolerated speedup_spec shrink vs baseline "
                         "(wave counts tolerate none)")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="wall-clock budget for every composed compile "
                         "row (schedule + spec stages)")
    args = ap.parse_args()

    with open(args.new) as f:
        new = json.load(f)
    fails = check_invariants(new, args.budget_s)

    rows = []
    if args.baseline is not None:
        with open(args.baseline) as f:
            baseline = json.load(f)
        rows, regressions = diff(baseline, new, args.threshold)
        if not rows:
            print("compile_diff: no comparable rows between baseline and "
                  "new run (fabric/topology set changed without "
                  "regenerating the baseline) -- an empty comparison "
                  "disables the gate, so this is an error")
            return 1
        fails.extend(f"{name}: regressed vs baseline"
                     for name in regressions)
        width = max(len(f"{n} {m}") for n, m, *_ in rows)
        for name, metric, b, r in rows:
            mark = ("  <-- REGRESSION"
                    if any(x.startswith(name) for x in regressions)
                    and (metric != "speedup_spec"
                         or r < b / args.threshold) else "")
            print(f"{f'{name} {metric}':<{width}}  {b:>9} -> {r:<9}{mark}")

    if fails:
        print("\n" + "\n".join(f"FAIL: {f}" for f in fails))
        return 1
    print(f"\ncompile gate ok ({len(new.get('compile', ()))} compile rows, "
          f"{len(new.get('search', ()))} search rows"
          + (f", {len(rows)} diffed" if rows else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
