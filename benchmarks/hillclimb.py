"""§Perf hillclimb driver: re-lower single cells with candidate changes and
report the roofline-term deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb --which 1
"""
import argparse
import json
import os
import sys

# must precede jax import
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import jax  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro import configs  # noqa: E402
from repro.analysis.hlo import analyze_hlo  # noqa: E402
from repro.analysis.roofline import roofline  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def measure(arch, shape_name, overrides=None, fsdp=True, sync="gspmd",
            multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    step_fn, shapes, shards = build_cell(arch, shape_name, mesh,
                                         sync_mode=sync, fsdp=fsdp,
                                         cfg_overrides=overrides)
    with jax.set_mesh(mesh):
        c = jax.jit(step_fn, in_shardings=shards).lower(*shapes).compile()
    st = analyze_hlo(c.as_text())
    mem = c.memory_analysis()
    cfg = configs.get(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    t = roofline(cfg, cfg.shape(shape_name),
                 "2x16x16" if multi_pod else "16x16",
                 512 if multi_pod else 256,
                 st.dot_flops, st.bytes_touched, st.total_collective_bytes)
    return {
        "compute_s": t.compute_s, "memory_s": t.memory_s,
        "collective_s": t.collective_s, "dominant": t.dominant,
        "roofline_fraction": t.roofline_fraction,
        "useful_ratio": t.useful_flop_ratio,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "collective_counts": st.collective_counts,
    }


def hc1():
    """smollm-135m prefill_32k (worst non-decode roofline fraction):
    memory-bound; hypothesis: kv re-streaming scales 1/q_block."""
    out = {"baseline_qb1024": measure("smollm-135m", "prefill_32k")}
    for qb in (2048, 4096):
        out[f"qb{qb}"] = measure("smollm-135m", "prefill_32k",
                                 {"q_block": qb, "kv_block": qb})
    return out


def hc2():
    """recurrentgemma-2b decode_32k (most collective-bound cell):
    hypothesis: the collectives are FSDP param all-gathers per decode step;
    serving should keep weights TP-resident (fsdp=False)."""
    return {
        "baseline_fsdp": measure("recurrentgemma-2b", "decode_32k", fsdp=True),
        "no_fsdp": measure("recurrentgemma-2b", "decode_32k", fsdp=False),
    }


def hc2b():
    """olmoe train_4k EP combine: seq-shard the MoE output so the model-axis
    partial-sum all-reduce becomes a reduce-scatter."""
    return {
        "baseline": measure("olmoe-1b-7b", "train_4k"),
        "seq_shard_out": measure("olmoe-1b-7b", "train_4k",
                                 {"moe_seq_shard_out": True}),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", required=True, choices=["1", "2", "2b"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    fn = {"1": hc1, "2": hc2, "2b": hc2b}[args.which]
    res = fn()
    print(json.dumps(res, indent=1))
    if args.out:
        json.dump(res, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
