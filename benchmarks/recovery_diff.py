"""CI gate for the recovery trajectory: diff a fresh chaos-soak JSON
(``benchmarks/chaos_soak.py``) against a committed baseline and fail
when fault-recovery quality regresses.

Unlike the perf gate (``bench_diff.py``) these rows are mostly
*deterministic*: the soak holds the detection clock while background
work is in flight, so ``mttr_ticks`` (probe ticks from first failed
heartbeat to recovery) and ``steps_lost`` are functions of the seeded
fault trace and the controller's ladder, not of host speed.  Wall-clock
``mttr_s`` IS host-dependent (it absorbs the Roskind-Tarjan repack) and
is never gated -- it is carried for trend reading only.

Gate rules, per row kind:

  * ``soak/<config>/totals`` -- hard invariants first:
    ``unhandled_exceptions`` must be 0 and ``max_loss_diff`` (vs the
    fault-free ``psum_dp`` reference over identical batches) must stay
    under ``--loss-tol``; then ``steps_lost`` must not exceed
    ``baseline * threshold`` (rounded up);
  * ``soak/<config>/<kind>`` -- ``mttr_ticks`` must not exceed
    ``baseline * threshold`` (rounded up, and at least baseline + 1 so
    a 1-tick baseline is not frozen at exactly 1);
  * a soak row present in the baseline but MISSING from the new run is
    a failure -- a fault kind silently dropping out of the trace is a
    coverage regression, not a pass.

An empty comparison (no ``soak/*`` rows shared) disables the gate and is
therefore itself an error, mirroring ``bench_diff.py``.

    python -m benchmarks.recovery_diff \
        --baseline BENCH_recovery_quick.json --new /tmp/new.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def diff(baseline: dict, new: dict, threshold: float, loss_tol: float):
    """(rows, failures): rows are (name, metric, base, new, note)."""
    rows, failures = [], []

    def check(name, metric, b, n, limit):
        bad = n > limit
        rows.append((name, metric, b, n,
                     f"> {limit:g}  <-- FAIL" if bad else f"<= {limit:g}"))
        if bad:
            failures.append(f"{name}:{metric}")

    for name in sorted(k for k in baseline if k.startswith("soak/")):
        base = baseline[name]
        if name not in new:
            rows.append((name, "-", "-", "-", "missing  <-- FAIL"))
            failures.append(f"{name}:missing")
            continue
        cur = new[name]
        if name.endswith("/totals"):
            check(name, "unhandled", base["unhandled_exceptions"],
                  cur["unhandled_exceptions"], 0)
            check(name, "loss_diff", base["max_loss_diff"],
                  cur["max_loss_diff"], loss_tol)
            check(name, "steps_lost", base["steps_lost"],
                  cur["steps_lost"],
                  math.ceil(base["steps_lost"] * threshold))
        else:
            check(name, "mttr_ticks", base["mttr_ticks"],
                  cur["mttr_ticks"],
                  max(math.ceil(base["mttr_ticks"] * threshold),
                      base["mttr_ticks"] + 1))
    return rows, failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--new", required=True)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="allowed growth of mttr_ticks / steps_lost")
    ap.add_argument("--loss-tol", type=float, default=1e-3,
                    help="max per-step loss deviation vs the fault-free "
                         "reference")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    rows, failures = diff(baseline, new, args.threshold, args.loss_tol)
    if not rows:
        print("recovery_diff: no soak/* rows in the baseline -- an empty "
              "comparison disables the gate, so this is an error; "
              "regenerate the baseline with benchmarks/chaos_soak.py")
        return 1
    width = max(len(name) for name, *_ in rows)
    print(f"{'row':<{width}}  {'metric':<11} {'base':>10} {'new':>10}  "
          "verdict")
    for name, metric, b, n, note in rows:
        bs = f"{b:.3g}" if isinstance(b, float) else str(b)
        ns = f"{n:.3g}" if isinstance(n, float) else str(n)
        print(f"{name:<{width}}  {metric:<11} {bs:>10} {ns:>10}  {note}")
    if failures:
        print(f"\n{len(failures)} recovery metric(s) regressed vs baseline:"
              f" {', '.join(failures)}")
        return 1
    print(f"\nall recovery metrics within {args.threshold:.2f}x of baseline"
          f" (loss tol {args.loss_tol:g})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
