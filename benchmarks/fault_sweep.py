"""Link-failure sweep over star-product fabrics: how gracefully does the
EDST allreduce degrade, and how much does a Roskind-Tarjan rebuild recover?

For each topology and each failure count f, kill f random links (seeded
trials), then record for the three recovery stages -- healthy, degraded
(surviving trees only), rebuilt (max repacking of the residual fabric) --
the tree count, schedule depth, and modelled allreduce cost / effective
bandwidth from :class:`repro.core.collectives.CostModel`.

    PYTHONPATH=src python -m benchmarks.fault_sweep --out fault_sweep.json
    PYTHONPATH=src python -m benchmarks.fault_sweep --nbytes 16777216 --trials 2

Emits the JSON report to ``--out`` (default stdout).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import numpy as np  # noqa: E402

from repro.core import topologies as topo  # noqa: E402
from repro.core.collectives import CostModel, allreduce_schedule  # noqa: E402
from repro.core.edst_star import star_edsts  # noqa: E402
from repro.core.fault import rebuild_edsts, surviving_trees  # noqa: E402

TOPOLOGIES = (
    ("slimfly-q5", lambda: topo.slimfly(5)),
    ("bundlefly-q4-a5", lambda: topo.bundlefly(4, 5)),
    ("polarstar-q3-qr5", lambda: topo.polarstar(3, "qr", 5)),
    ("torus-4x4", lambda: topo.torus([4, 4])),
    ("torus-4x4x4", lambda: topo.torus([4, 4, 4])),
)
FAILURE_COUNTS = (0, 1, 2, 4)


def _stage(name, n, trees, cm: CostModel, nbytes: float) -> dict:
    if not trees:
        return {"stage": name, "k": 0, "depth": None, "cost_ms": None,
                "gbps": 0.0}
    sched = allreduce_schedule(n, trees)
    cost = cm.edst_tree_allreduce(nbytes, sched)
    return {"stage": name, "k": sched.k, "depth": sched.depth,
            "cost_ms": round(cost * 1e3, 4),
            "gbps": round(nbytes / cost / 1e9, 3)}


def sweep_topology(name, sp, cm: CostModel, nbytes: float, trials: int,
                   failure_counts=FAILURE_COUNTS, seed: int = 0) -> dict:
    g = sp.product()
    res = star_edsts(sp)
    trees = res.trees
    edges = sorted(g.edges)
    healthy = _stage("healthy", g.n, trees, cm, nbytes)
    rows = []
    for nfail in failure_counts:
        for trial in range(trials if nfail else 1):
            rng = np.random.RandomState(seed + 7919 * trial + nfail)
            kill = ({edges[i] for i in
                     rng.choice(len(edges), size=nfail, replace=False)}
                    if nfail else set())
            keep = surviving_trees(trees, kill)
            t0 = time.perf_counter()
            rebuilt, residual = rebuild_edsts(g, kill)
            rebuild_s = time.perf_counter() - t0
            rows.append({
                "failures": nfail,
                "trial": trial,
                "killed_tree_links": sum(1 for t in trees if set(t) & kill),
                "residual_connected": residual.is_connected(),
                "rebuild_s": round(rebuild_s, 4),
                "stages": [
                    _stage("degraded", g.n, keep, cm, nbytes),
                    _stage("rebuilt", g.n, rebuilt, cm, nbytes),
                ],
            })
    return {"topology": name, "n": g.n, "m": g.m, "k": res.count,
            "theorem": res.theorem, "healthy": healthy, "sweep": rows}


def run_sweep(nbytes: float = 64 << 20, trials: int = 3,
              topologies=TOPOLOGIES, failure_counts=FAILURE_COUNTS,
              seed: int = 0) -> dict:
    cm = CostModel()
    return {
        "nbytes": nbytes,
        "cost_model": {"link_bw": cm.link_bw, "alpha": cm.alpha,
                       "segment": cm.segment},
        "failure_counts": list(failure_counts),
        "topologies": [sweep_topology(name, mk(), cm, nbytes, trials,
                                      failure_counts, seed)
                       for name, mk in topologies],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None, help="write JSON here (else stdout)")
    ap.add_argument("--nbytes", type=int, default=64 << 20)
    ap.add_argument("--trials", type=int, default=3,
                    help="seeded trials per nonzero failure count")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    report = run_sweep(nbytes=args.nbytes, trials=args.trials, seed=args.seed)
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        ntop = len(report["topologies"])
        print(f"[fault_sweep] {ntop} topologies x {len(FAILURE_COUNTS)} "
              f"failure counts -> {args.out}")
    else:
        print(text)


if __name__ == "__main__":
    main()
